//! End-to-end validation driver (the repo's required full-system proof).
//!
//! Exercises every layer on a real workload in one process:
//!
//! 1. **L1/L2 via PJRT** — runs the three apps on the live runtime with the
//!    jax/Pallas AOT artifacts (falls back to native BLAS if artifacts are
//!    missing) and checks statistical correctness (KNN accuracy, K-means
//!    convergence, regression recovery/R²);
//! 2. **L3 runtime semantics** — cross-checks PJRT results against the
//!    native backend, exercises fault tolerance with injected failures,
//!    and compares scheduler policies;
//! 3. **Serialization substrate** — round-trips app-scale payloads through
//!    every Table-1 codec;
//! 4. **Simulator fidelity** — verifies the simulated DAG has exactly the
//!    task counts of the live run, then produces the paper-shaped scaling
//!    signal (efficiency at 1 vs many workers).
//!
//! The output of this binary is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example end_to_end`

use std::sync::Arc;

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::linreg::{self, LinregConfig};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::coordinator::fault::FailureInjector;
use rcompss::sim::{CostModel, SimEngine, SimSink};
use rcompss::value::{Gen, RValue};

fn check(name: &str, ok: bool, detail: String) -> bool {
    println!("  [{}] {name}: {detail}", if ok { "PASS" } else { "FAIL" });
    ok
}

fn main() -> anyhow::Result<()> {
    let mut all_ok = true;
    let backend = Backend::auto();
    println!("=== RCOMPSs end-to-end validation (backend: {backend:?}) ===\n");

    // ---- 1. Three apps on the live runtime -------------------------------
    println!("[1/4] benchmark apps on the live runtime");
    let t0 = std::time::Instant::now();
    {
        let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
        let mut cfg = KnnConfig::small(42);
        cfg.train_fragments = 4;
        cfg.test_blocks = 2;
        let res = knn::run_knn(&rt, &cfg, backend)?;
        let stats = rt.stop()?;
        all_ok &= check(
            "knn",
            res.accuracy > 0.85 && stats.tasks_failed == 0,
            format!(
                "accuracy {:.1}% over {} points, {} tasks",
                res.accuracy * 100.0,
                res.total_test_points,
                stats.tasks_done
            ),
        );
    }
    {
        let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
        let mut cfg = KmeansConfig::small(42);
        cfg.fragments = 4;
        cfg.iterations = 6;
        cfg.tol = Some(1e-3);
        let res = kmeans::run_kmeans(&rt, &cfg, backend)?;
        rt.stop()?;
        all_ok &= check(
            "kmeans",
            res.last_shift < 0.1,
            format!(
                "{} iterations, final shift {:.5}",
                res.iterations_run, res.last_shift
            ),
        );
    }
    {
        let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
        let mut cfg = LinregConfig::small(42);
        cfg.fragments = 4;
        cfg.pred_blocks = 2;
        let res = linreg::run_linreg(&rt, &cfg, backend)?;
        rt.stop()?;
        all_ok &= check(
            "linreg",
            res.beta_max_err < 0.01 && res.r2 > 0.95,
            format!("beta err {:.5}, R^2 {:.4}", res.beta_max_err, res.r2),
        );
    }
    println!("  ({:.1}s)\n", t0.elapsed().as_secs_f64());

    // ---- 2. Runtime semantics --------------------------------------------
    println!("[2/4] runtime semantics");
    // Backend cross-check: KNN classifications identical across backends.
    if backend == Backend::Pjrt {
        let small = |bk| -> anyhow::Result<Vec<i32>> {
            let rt = CompssRuntime::start(RuntimeConfig::local(2))?;
            let mut cfg = KnnConfig::small(7);
            cfg.train_fragments = 2;
            cfg.test_blocks = 1;
            let mut sink = rcompss::apps::LiveSink::new(
                &rt,
                rcompss::apps::backend::knn_task_defs(cfg.shapes, bk),
            );
            let plan = knn::plan_knn(&mut sink, &cfg)?;
            let v = sink.fetch(plan.classes[0])?;
            let out = v.as_int().unwrap().to_vec();
            rt.stop()?;
            Ok(out)
        };
        let a = small(Backend::Pjrt)?;
        let b = small(Backend::Native)?;
        let agree = a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
        all_ok &= check(
            "backend cross-check",
            agree as f64 / a.len() as f64 > 0.98,
            format!("{}/{} classifications agree (pjrt vs native)", agree, a.len()),
        );
    } else {
        println!("  [SKIP] backend cross-check: artifacts not built");
    }
    // Fault tolerance: injected failures must not change the result.
    {
        let mut config = RuntimeConfig::local(4);
        config.injector = Arc::new(FailureInjector::new(0.4, "KNN_frag", 6, 99));
        let rt = CompssRuntime::start(config)?;
        let mut cfg = KnnConfig::small(42);
        cfg.train_fragments = 4;
        cfg.test_blocks = 2;
        let res = knn::run_knn(&rt, &cfg, Backend::Native)?;
        let stats = rt.stop()?;
        all_ok &= check(
            "fault tolerance",
            stats.resubmissions > 0 && stats.tasks_failed == 0 && res.accuracy > 0.85,
            format!(
                "{} injected resubmissions, 0 permanent failures, accuracy {:.1}%",
                stats.resubmissions,
                res.accuracy * 100.0
            ),
        );
    }
    // Scheduler policies all complete with identical results.
    {
        let mut accs = Vec::new();
        for policy in ["fifo", "lifo", "locality"] {
            let rt = CompssRuntime::start(RuntimeConfig::local(4).with_scheduler(policy))?;
            let mut cfg = KnnConfig::small(42);
            cfg.train_fragments = 3;
            cfg.test_blocks = 1;
            let res = knn::run_knn(&rt, &cfg, Backend::Native)?;
            rt.stop()?;
            accs.push(res.accuracy);
        }
        all_ok &= check(
            "scheduler policies",
            accs.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12),
            format!("fifo/lifo/locality all produced accuracy {:.3}", accs[0]),
        );
    }
    println!();

    // ---- 3. Serialization substrate ---------------------------------------
    println!("[3/4] Table-1 codecs on app-scale payloads");
    {
        let mut rng = rcompss::util::prng::Pcg64::seeded(1);
        let payload = Gen::new(&mut rng).normal_matrix(512, 256);
        let mut ok = true;
        let mut names = Vec::new();
        for codec in rcompss::serialization::all_codecs() {
            let bytes = codec.encode(&payload)?;
            let back = codec.decode(&bytes)?;
            ok &= payload.identical(&back);
            names.push(format!("{}({})", codec.name(), bytes.len() / 1024));
        }
        all_ok &= check(
            "codec roundtrips",
            ok,
            format!("512x256 matrix through {}", names.join(", ")),
        );
    }
    println!();

    // ---- 4. Simulator fidelity ---------------------------------------------
    println!("[4/4] simulator fidelity + scaling signal");
    {
        // DAG parity: live run's per-type counts == simulated plan's.
        let mut cfg = KnnConfig::small(42);
        cfg.train_fragments = 5;
        cfg.test_blocks = 2;
        let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
        knn::run_knn(&rt, &cfg, Backend::Native)?;
        let live_stats = rt.stop()?;
        let mut sink = SimSink::new();
        knn::plan_knn(&mut sink, &cfg)?;
        let plan = sink.finish();
        let sim_counts = plan.type_counts();
        let parity = live_stats.per_type.iter().all(|(ty, (count, _))| {
            sim_counts.get(ty).map(|c| *c as u64) == Some(*count)
        });
        all_ok &= check(
            "DAG parity (live vs sim)",
            parity,
            format!(
                "{} task types, {} tasks",
                sim_counts.len(),
                plan.graph.len()
            ),
        );

        // Scaling signal: weak-efficiency at 64 workers stays above 50% for
        // KNN on the Shaheen profile (paper: >70% at 128).
        let plan_of = |frags: usize| {
            let mut c = KnnConfig::small(42);
            c.train_fragments = 8;
            c.test_blocks = frags;
            let mut s = SimSink::new();
            knn::plan_knn(&mut s, &c).unwrap();
            s.finish()
        };
        let spec1 = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(1);
        let spec64 = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(64);
        let t1 = SimEngine::new(spec1, CostModel::default())
            .run(plan_of(1), "w1")?
            .makespan_s;
        let t64 = SimEngine::new(spec64, CostModel::default())
            .run(plan_of(64), "w64")?
            .makespan_s;
        let eff = rcompss::util::stats::weak_efficiency(t1, t64);
        all_ok &= check(
            "weak scaling shape",
            eff > 0.5,
            format!("KNN weak efficiency at 64 workers: {:.0}%", eff * 100.0),
        );
    }

    println!(
        "\n=== end-to-end: {} ===",
        if all_ok { "ALL CHECKS PASSED" } else { "FAILURES PRESENT" }
    );
    // Keep RValue in scope for doc parity.
    let _ = RValue::Null;
    if all_ok {
        Ok(())
    } else {
        anyhow::bail!("end-to-end validation failed")
    }
}
