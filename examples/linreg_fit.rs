//! Linear regression with prediction over RCOMPSs (§4.3, Figure 5).
//!
//! The deepest DAG of the three apps: fill → partial X^T X / X^T y →
//! merge trees → solve → predict. Reports coefficient recovery error and
//! out-of-sample R².
//!
//! Run: `cargo run --release --example linreg_fit -- [fragments] [pred_blocks]`

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::linreg::{run_linreg, LinregConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fragments: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let pred_blocks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let backend = Backend::auto();
    let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
    let mut cfg = LinregConfig::small(99);
    cfg.fragments = fragments;
    cfg.pred_blocks = pred_blocks;
    let s = cfg.shapes;
    println!(
        "Linear regression: {} fit fragments of {}x{}, {} prediction blocks of {}x{}, backend {backend:?}",
        fragments, s.lr_frag_n, s.lr_p, pred_blocks, s.lr_pred_block, s.lr_p
    );

    let t0 = std::time::Instant::now();
    let res = run_linreg(&rt, &cfg, backend)?;
    println!(
        "fit {} rows in {:.2}s — max |beta - beta_true| = {:.6}, prediction R^2 = {:.4}",
        fragments * s.lr_frag_n,
        t0.elapsed().as_secs_f64(),
        res.beta_max_err,
        res.r2
    );
    assert!(res.r2 > 0.9, "R^2 should be high on synthetic linear data");

    let beta = res.beta.as_real().unwrap();
    println!(
        "first coefficients: [{}]",
        beta.iter()
            .take(6)
            .map(|b| format!("{b:7.4}"))
            .collect::<Vec<_>>()
            .join(", ")
    );

    let stats = rt.stop()?;
    println!("tasks: {} done", stats.tasks_done);
    println!("DAG critical path vs. breadth is what limits this app's scaling (§5.2).");
    Ok(())
}
