//! Quickstart: the paper's Figure-2 example — summing four numbers with a
//! two-argument `add()` task — transcribed to the Rust API.
//!
//! ```text
//! add <- function(x, y) x + y            |  TaskDef::new("add", 2, ...)
//! compss_start()                         |  CompssRuntime::start(...)
//! add.dec <- task(add, "add.R", ...)     |  rt.register_task(def)
//! res1 <- add.dec(a, b)                  |  rt.submit(&add, &[a, b])
//! res3 <- compss_wait_on(res3)           |  rt.wait_on(&res3)
//! compss_stop()                          |  rt.stop()
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use rcompss::prelude::*;

fn main() -> anyhow::Result<()> {
    // compss_start()
    let rt = CompssRuntime::start(RuntimeConfig::local(2))?;

    // task(add, ...): two IN arguments, one return value.
    let add = rt.register_task(TaskDef::new("add", 2, |args| {
        let x = args[0].as_f64().ok_or_else(|| anyhow::anyhow!("x not scalar"))?;
        let y = args[1].as_f64().ok_or_else(|| anyhow::anyhow!("y not scalar"))?;
        Ok(vec![RValue::scalar(x + y)])
    }));

    let (a, b, c, d) = (4.0, 5.0, 6.0, 7.0);

    // Task (1), Task (2): independent — run in parallel.
    let res1 = rt.submit(&add, &[a.into(), b.into()])?;
    let res2 = rt.submit(&add, &[c.into(), d.into()])?;
    // Task (3): depends on both results (the DAG diamond of Figure 2).
    let res3 = rt.submit(&add, &[res1.into(), res2.into()])?;

    // compss_wait_on(res3)
    let result = rt.wait_on(&res3)?;
    println!("The result is: {}", result.as_f64().unwrap());
    assert_eq!(result.as_f64(), Some(22.0));

    // The generated DAG, as `runcompss -g` would produce it.
    println!("\n--- task dependency graph (Graphviz DOT) ---");
    println!("{}", rt.dag_dot("add four numbers (Figure 2)"));

    // compss_stop()
    let stats = rt.stop()?;
    println!("tasks executed: {}", stats.tasks_done);
    Ok(())
}
