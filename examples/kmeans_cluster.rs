//! K-means clustering over RCOMPSs (§4.2, Figure 4).
//!
//! Fragments are generated in parallel tasks; each iteration runs
//! `partial_sum` per fragment, a hierarchical merge tree, and a centroid
//! update, with the master checking convergence between iterations exactly
//! like the paper's `converged` function.
//!
//! Run: `cargo run --release --example kmeans_cluster -- [fragments] [max_iters]`

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{run_kmeans, KmeansConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fragments: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let max_iters: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);

    let backend = Backend::auto();
    let rt = CompssRuntime::start(RuntimeConfig::local(4))?;
    let mut cfg = KmeansConfig::small(7);
    cfg.fragments = fragments;
    cfg.iterations = max_iters;
    cfg.tol = Some(1e-4);
    let s = cfg.shapes;
    println!(
        "K-means: {} fragments of {}x{}, k={}, max {} iterations, backend {backend:?}",
        fragments, s.km_frag_n, s.km_d, s.km_k, max_iters
    );

    let t0 = std::time::Instant::now();
    let res = run_kmeans(&rt, &cfg, backend)?;
    println!(
        "converged after {} iterations in {:.2}s (final centroid shift {:.6})",
        res.iterations_run,
        t0.elapsed().as_secs_f64(),
        res.last_shift
    );

    // Show the centroids' first few coordinates.
    let (c, k, d) = res.centroids.as_matrix().unwrap();
    println!("centroids ({k} x {d}), first 4 dims:");
    for r in 0..k.min(8) {
        let row: Vec<String> = (0..d.min(4)).map(|j| format!("{:7.3}", c[j * k + r])).collect();
        println!("  c{r:02}: [{} ...]", row.join(", "));
    }

    let stats = rt.stop()?;
    println!(
        "tasks: {} done across {} types",
        stats.tasks_done,
        stats.per_type.len()
    );
    Ok(())
}
