//! KNN classification over RCOMPSs (§4.1, Figure 3).
//!
//! Generates a fragmented training set and test blocks inside tasks,
//! computes per-fragment nearest neighbours in parallel, merges them
//! through the binary tree, classifies by majority vote, and reports
//! accuracy against the generating labels.
//!
//! Run: `cargo run --release --example knn_classify -- [fragments] [blocks]`

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::knn::{run_knn, KnnConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fragments: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let blocks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let backend = Backend::auto();
    println!(
        "KNN classification: {fragments} training fragments, {blocks} test blocks, backend {backend:?}"
    );

    let rt = CompssRuntime::start(RuntimeConfig::local(4).with_trace(true))?;
    let mut cfg = KnnConfig::small(2024);
    cfg.train_fragments = fragments;
    cfg.test_blocks = blocks;
    let shapes = cfg.shapes;
    println!(
        "  train: {} x {}x{} fragments | test: {} x {}x{} blocks | k={}",
        fragments,
        shapes.knn_train_n,
        shapes.knn_d,
        blocks,
        shapes.knn_test_block,
        shapes.knn_d,
        shapes.knn_k
    );

    let t0 = std::time::Instant::now();
    let res = run_knn(&rt, &cfg, backend)?;
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "classified {} points in {:.2}s — accuracy {:.1}%",
        res.total_test_points,
        elapsed,
        res.accuracy * 100.0
    );
    assert!(
        res.accuracy > 0.8,
        "classification should beat 80% on well-separated blobs"
    );

    println!("\nexecution trace (Figure 10a style):");
    println!("{}", rt.trace("knn live").ascii_timeline(100));

    let stats = rt.stop()?;
    println!(
        "tasks: {} done | serialization {:.3}s | deserialization {:.3}s",
        stats.tasks_done, stats.serialize_s, stats.deserialize_s
    );
    Ok(())
}
