//! Regenerates **Table 1**: serialization (S) and deserialization (D)
//! times for the codec set across square double-precision blocks.
//!
//! The paper measured 10K/20K/30K square blocks on a 56-core Ice Lake; by
//! default this bench uses scaled sizes that fit this box's RAM and time
//! budget (override with `T1_SIZES=10000,20000,30000` for the full run).
//! Expected *shape* (paper): RMVL ≈ qs < fst < serialize_Rcpp << RDS on
//! serialization; RMVL/qs fastest on deserialization.
//!
//! Run: `cargo bench --bench table1_serialization`

use rcompss::bench_harness::{banner, record_result, time_reps};
use rcompss::serialization::all_codecs;
use rcompss::util::json::Json;
use rcompss::util::prng::Pcg64;
use rcompss::util::table::{fmt_secs, Table};
use rcompss::value::Gen;

fn sizes() -> Vec<usize> {
    if let Ok(env) = std::env::var("T1_SIZES") {
        return env
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect();
    }
    if rcompss::bench_harness::quick() {
        vec![512, 1024]
    } else {
        vec![1000, 2000, 3000]
    }
}

fn main() {
    let sizes = sizes();
    banner(
        "Table 1 — serialization/deserialization times (seconds)",
        &format!(
            "square f64 blocks, sides {sizes:?} (paper: 10000/20000/30000; set T1_SIZES for full size)"
        ),
    );

    let dir = std::env::temp_dir().join(format!("rcompss_table1_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let reps = rcompss::bench_harness::reps(3);

    let mut header: Vec<String> = vec!["Method".into()];
    for n in &sizes {
        header.push(format!("{n} S"));
        header.push(format!("{n} D"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);

    // Paper row order first (serialize_Rcpp, RDS, fst, qs, RMVL), then our
    // extra baselines (rawbin, csv).
    for codec in all_codecs() {
        let mut row = vec![codec.name().to_string()];
        for &n in &sizes {
            let mut rng = Pcg64::seeded(n as u64);
            let block = Gen::new(&mut rng).square_block(n);
            let path = dir.join(format!("{}_{n}.bin", codec.name()));

            let s = time_reps(reps, || codec.write_file(&block, &path).unwrap());
            let d = time_reps(reps, || {
                std::hint::black_box(codec.read_file(&path).unwrap());
            });
            // Sanity: the roundtrip must be exact.
            assert!(codec.read_file(&path).unwrap().identical(&block));
            row.push(fmt_secs(s.median));
            row.push(fmt_secs(d.median));
            record_result(
                "table1",
                vec![
                    ("method", Json::Str(codec.name().into())),
                    ("side", Json::Num(n as f64)),
                    ("serialize_s", Json::Num(s.median)),
                    ("deserialize_s", Json::Num(d.median)),
                    ("bytes", Json::Num((n * n * 8) as f64)),
                ],
            );
            std::fs::remove_file(&path).ok();
        }
        table.row(row);
        eprintln!("  measured {}", codec.name());
    }
    println!();
    table.print();

    println!(
        "\npaper shape check: RMVL & qs should lead both columns; RDS serialization\n\
         should be the outlier (gzip). Raw numbers in target/bench_results.jsonl."
    );
    std::fs::remove_dir_all(&dir).ok();
}
