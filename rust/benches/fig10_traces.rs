//! Regenerates **Figure 10**: execution traces of the three apps on 4
//! nodes of each machine profile (top: Shaheen-III, bottom: MareNostrum 5
//! in the paper).
//!
//! Each (app, machine) pair is simulated with tracing on; the bench prints
//! the ASCII timeline (one row per worker, letters per task type) and
//! writes Paraver-style `.prv` files under `target/traces/`.
//!
//! Expected features (paper §5.4): on the MN5 profile the worker-init
//! stagger visibly serializes the fill phase; K-means shows the black
//! synchronization gap between iterations; linreg shows the staged
//! pipeline with decreasing parallelism toward merge/solve/predict.
//!
//! Run: `cargo bench --bench fig10_traces`

use rcompss::bench_harness::{banner, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;

fn plan_for(app: &str, wpn: usize) -> rcompss::sim::sink::SimPlan {
    // 4 nodes, paper-sized fragments (Figure 10 captions), fragment counts
    // scaled to the rendered lane count so the timeline stays readable.
    let nodes = 4;
    let s = rcompss::apps::Shapes::paper_multi_node();
    match app {
        "knn" => plans::knn_plan_with(4, nodes * wpn, 10, s).unwrap(),
        // Paper's K-means trace shows two computation rounds.
        "kmeans" => plans::kmeans_plan_with(nodes * wpn, 2, 10, s).unwrap(),
        "linreg" => plans::linreg_plan_with(nodes * wpn, wpn, 10, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Figure 10 — execution traces (4 nodes)",
        "ASCII timelines below; Paraver .prv files in target/traces/",
    );
    std::fs::create_dir_all("target/traces").ok();
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        // Render a manageable worker count per node (the paper's panes are
        // also downsampled to visible lanes).
        let wpn = 8u32;
        for app in ["knn", "kmeans", "linreg"] {
            let spec = ClusterSpec::new(profile.clone(), 4).with_workers_per_node(wpn);
            let label = format!("{app}@{}", profile.name);
            let report = SimEngine::new(spec, CostModel::default())
                .with_trace(true)
                .run(plan_for(app, wpn as usize), &label)
                .unwrap();
            println!("{}", report.trace.ascii_timeline(100));
            let prv_path = format!("target/traces/fig10_{app}_{}.prv", profile.name);
            std::fs::write(&prv_path, report.trace.to_prv()).unwrap();
            println!("  -> {prv_path}\n");
            record_result(
                "fig10",
                vec![
                    ("machine", Json::Str(profile.name.clone())),
                    ("app", Json::Str(app.into())),
                    ("makespan_s", Json::Num(report.makespan_s)),
                    ("utilization", Json::Num(report.utilization)),
                    ("events", Json::Num(report.trace.events.len() as f64)),
                ],
            );
        }
    }
    println!(
        "paper features to look for: MN5 worker-init stagger ('#' ramp), the\n\
         K-means inter-iteration gap, linreg's narrowing pipeline tail."
    );
}
