//! Regenerates **Figure 6**: weak scalability on single nodes of
//! Shaheen-III (up to 128 worker threads) and MareNostrum 5 (up to 80),
//! for KNN, K-means, and linear regression.
//!
//! The problem size grows proportionally with the core count (paper: KNN
//! test set 2000x50 per core with fixed training; K-means 864,000x50 per
//! core; linreg 80,000x1000 per core). Here the unit of growth is the
//! canonical fragment; per-task costs are the calibrated model described
//! in DESIGN.md §3. For each (machine, app, cores) the bench prints time
//! and weak-scaling efficiency T(1)/T(p) — the paper's metric.
//!
//! Expected shape (paper §5.2): on the Shaheen profile KNN stays ≥70%
//! efficient at 128 cores, K-means ≥60%, linreg decays to ≈41%; the MN5
//! profile degrades noticeably beyond 32 cores.
//!
//! Run: `cargo bench --bench fig6_weak_single_node`

use rcompss::bench_harness::{banner, quick, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;
use rcompss::util::stats::weak_efficiency;
use rcompss::util::table::{fmt_pct, fmt_secs, Table};

fn sweep(max: u32) -> Vec<u32> {
    let full: Vec<u32> = vec![1, 2, 4, 8, 16, 32, 48, 64, 80, 96, 112, 128];
    let pts: Vec<u32> = full.into_iter().filter(|c| *c <= max).collect();
    if quick() {
        pts.into_iter().filter(|c| [1, 4, 16, 64].contains(c)).collect()
    } else {
        pts
    }
}

fn plan_for(app: &str, cores: usize) -> rcompss::sim::sink::SimPlan {
    // The paper's single-node workload sizes (§5.2), one growth unit per
    // core: KNN training fixed at 2000x50 (one fragment) with a 2000x50
    // test block per core; K-means one 864,000x50 fragment per core;
    // linreg one 80,000x1000 fitting fragment + one 20,000x1000 prediction
    // block per core.
    let s = rcompss::apps::Shapes::paper_single_node();
    match app {
        "knn" => plans::knn_plan_with(1, cores, 6, s).unwrap(),
        "kmeans" => plans::kmeans_plan_with(cores, 3, 6, s).unwrap(),
        "linreg" => plans::linreg_plan_with(cores, cores, 6, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Figure 6 — weak scalability, single node",
        "time (s) and weak efficiency T(1)/T(p); problem grows with cores",
    );
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        let max = profile.workers_per_node;
        println!("--- {} (up to {} worker threads) ---", profile.name, max);
        for app in ["knn", "kmeans", "linreg"] {
            let mut table = Table::new(&["cores", "time", "efficiency"])
                .with_title(&format!("{app} @ {}", profile.name));
            let mut t1 = None;
            for cores in sweep(max) {
                let spec =
                    ClusterSpec::new(profile.clone(), 1).with_workers_per_node(cores);
                let plan = plan_for(app, cores as usize);
                let report = SimEngine::new(spec, CostModel::default())
                    .run(plan, &format!("{app}@{cores}"))
                    .unwrap();
                let t = report.makespan_s;
                let base = *t1.get_or_insert(t);
                let eff = weak_efficiency(base, t);
                table.row(vec![cores.to_string(), fmt_secs(t), fmt_pct(eff)]);
                record_result(
                    "fig6",
                    vec![
                        ("machine", Json::Str(profile.name.clone())),
                        ("app", Json::Str(app.into())),
                        ("cores", Json::Num(cores as f64)),
                        ("time_s", Json::Num(t)),
                        ("efficiency", Json::Num(eff)),
                    ],
                );
            }
            table.print();
            println!();
        }
    }
    println!(
        "paper shape: Shaheen KNN ≥70% @128, K-means ≥60% @128, linreg ≈41% @128;\n\
         MN5 degrades beyond 32 cores (KNN <30% @80, K-means 43%, linreg 45%)."
    );
}
