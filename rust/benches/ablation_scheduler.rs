//! Ablation: scheduler policy (FIFO / LIFO / data-locality) across the
//! three apps on the multi-node Shaheen profile.
//!
//! COMPSs ships these as pluggable policies (§3.1); the paper runs FIFO.
//! This ablation quantifies what the choice is worth on each app's DAG
//! shape: locality should pay on merge-tree-heavy workloads (fewer
//! inter-node transfers), LIFO should help depth-first pipelines, and the
//! differences should stay small for embarrassingly-parallel phases.
//!
//! Run: `cargo bench --bench ablation_scheduler`

use rcompss::bench_harness::{banner, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;
use rcompss::util::table::{fmt_secs, Table};

fn plan_for(app: &str) -> rcompss::sim::sink::SimPlan {
    let s = rcompss::apps::Shapes::paper_multi_node();
    match app {
        "knn" => plans::knn_plan_with(4, 512, 21, s).unwrap(),
        "kmeans" => plans::kmeans_plan_with(512, 3, 21, s).unwrap(),
        "linreg" => plans::linreg_plan_with(512, 128, 21, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Ablation — scheduler policy x app (4 nodes, Shaheen profile)",
        "makespan, transfer volume, utilization per policy",
    );
    let mut table = Table::new(&["app", "policy", "makespan", "transfer s", "util"]);
    for app in ["knn", "kmeans", "linreg"] {
        let mut base: Option<f64> = None;
        for policy in ["fifo", "lifo", "locality"] {
            let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4);
            let report = SimEngine::new(spec, CostModel::default())
                .with_scheduler(policy)
                .run(plan_for(app), &format!("{app}/{policy}"))
                .unwrap();
            let t = report.makespan_s;
            let b = *base.get_or_insert(t);
            table.row(vec![
                app.into(),
                format!("{policy}{}", if (t - b).abs() < 1e-9 { "" } else { "" }),
                format!("{} ({:+.1}%)", fmt_secs(t), (t / b - 1.0) * 100.0),
                fmt_secs(report.total_transfer_s),
                format!("{:.0}%", report.utilization * 100.0),
            ]);
            record_result(
                "ablation_scheduler",
                vec![
                    ("app", Json::Str(app.into())),
                    ("policy", Json::Str(policy.into())),
                    ("makespan_s", Json::Num(t)),
                    ("transfer_s", Json::Num(report.total_transfer_s)),
                ],
            );
        }
    }
    table.print();
    println!(
        "\nreading: locality's win shows in the transfer column (merge trees stay\n\
         node-local); FIFO is the paper's default and the baseline row per app."
    );
}
