//! Regenerates **Figure 9**: strong scalability on up to 32 nodes.
//!
//! Fixed totals sized for ~8 nodes (paper: KNN 32.76M x50 test; K-means
//! 1.22B x100; linreg 81.92M x1000 + 20.48M x1000 predictions); node count
//! sweeps 1→32. Metric: strong efficiency T1/(n·Tn).
//!
//! Expected shape (paper §5.3): KNN 44% (Shaheen) / 56% (MN5) at 32 nodes;
//! K-means 38% / 47%; linreg 28% on the fast-BLAS profile but >70% on the
//! slow-BLAS profile.
//!
//! Run: `cargo bench --bench fig9_strong_multi_node`

use rcompss::bench_harness::{banner, quick, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;
use rcompss::util::stats::strong_efficiency;
use rcompss::util::table::{fmt_pct, fmt_secs, Table};

fn nodes_sweep() -> Vec<u32> {
    if quick() {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    }
}

fn plan_for(app: &str) -> rcompss::sim::sink::SimPlan {
    // The paper's fixed totals (§5.3): KNN train 8000x50 / test 32.76Mx50
    // (4096 blocks of 8000); K-means 1.22Bx100 (~4096 fragments of 300k);
    // linreg 81.92Mx1000 (4096 fragments of 20k) + 20.48Mx1000 predictions
    // (1024 blocks).
    let s = rcompss::apps::Shapes::paper_multi_node();
    match app {
        "knn" => plans::knn_plan_with(4, 4096, 9, s).unwrap(),
        "kmeans" => plans::kmeans_plan_with(4096, 3, 9, s).unwrap(),
        "linreg" => plans::linreg_plan_with(4096, 1024, 9, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Figure 9 — strong scalability, up to 32 nodes",
        "fixed totals (~8-node-sized); locality scheduler",
    );
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        let wpn = profile.workers_per_node as usize;
        println!("--- {} ({} workers/node) ---", profile.name, wpn);
        for app in ["knn", "kmeans", "linreg"] {
            let mut table = Table::new(&["nodes", "time", "speedup", "efficiency"])
                .with_title(&format!("{app} @ {}", profile.name));
            let mut t1 = None;
            for nodes in nodes_sweep() {
                let spec = ClusterSpec::new(profile.clone(), nodes);
                let report = SimEngine::new(spec, CostModel::default())
                    .with_scheduler("locality")
                    .run(plan_for(app), &format!("{app}@{nodes}n"))
                    .unwrap();
                let t = report.makespan_s;
                let base = *t1.get_or_insert(t);
                let eff = strong_efficiency(base, t, nodes as f64);
                table.row(vec![
                    nodes.to_string(),
                    fmt_secs(t),
                    format!("{:.1}x", base / t),
                    fmt_pct(eff),
                ]);
                record_result(
                    "fig9",
                    vec![
                        ("machine", Json::Str(profile.name.clone())),
                        ("app", Json::Str(app.into())),
                        ("nodes", Json::Num(nodes as f64)),
                        ("time_s", Json::Num(t)),
                        ("efficiency", Json::Num(eff)),
                    ],
                );
            }
            table.print();
            println!();
        }
    }
    println!(
        "paper shape: @32 nodes — KNN 44%/56%, K-means 38%/47%,\n\
         linreg 28% (fast BLAS) vs >70% (slow BLAS)."
    );
}
