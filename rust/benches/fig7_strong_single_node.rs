//! Regenerates **Figure 7**: strong scalability on single nodes of
//! Shaheen-III and MareNostrum 5 for the three apps.
//!
//! The total problem is fixed (paper: KNN 1,228,800x50 train / 64,000x50
//! test; K-means 51.2Mx100; linreg 10.24Mx1000 + 2.56Mx1000 predictions)
//! and the worker count sweeps up. Metric: strong efficiency T1/(p*Tp).
//!
//! Expected shape (paper §5.2): KNN & K-means ≥80% at 64 cores on the
//! Shaheen profile; linreg declines to ≈47% at 128 (dependency depth);
//! on the MN5 profile linreg is ~100x slower in absolute time (RBLAS) but
//! *scales* well because compute hides I/O.
//!
//! Run: `cargo bench --bench fig7_strong_single_node`

use rcompss::bench_harness::{banner, quick, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;
use rcompss::util::stats::strong_efficiency;
use rcompss::util::table::{fmt_pct, fmt_secs, Table};

fn sweep(max: u32) -> Vec<u32> {
    let full: Vec<u32> = vec![1, 2, 4, 8, 16, 32, 48, 64, 80, 96, 112, 128];
    let pts: Vec<u32> = full.into_iter().filter(|c| *c <= max).collect();
    if quick() {
        pts.into_iter().filter(|c| [1, 4, 16, 64].contains(c)).collect()
    } else {
        pts
    }
}

fn plan_for(app: &str) -> rcompss::sim::sink::SimPlan {
    // The paper's fixed totals (§5.2), decomposed into canonical fragments:
    // KNN train 1,228,800x50 (512 fragments of ~2000) / test 64,000x50
    // (32 blocks); K-means 51.2Mx100 (~64 fragments of 864k, d=50 in our
    // shape set); linreg 10.24Mx1000 (128 fragments of 80k) + 2.56Mx1000
    // predictions (128 blocks of 20k).
    let s = rcompss::apps::Shapes::paper_single_node();
    match app {
        "knn" => plans::knn_plan_with(512, 32, 7, s).unwrap(),
        "kmeans" => plans::kmeans_plan_with(64, 3, 7, s).unwrap(),
        "linreg" => plans::linreg_plan_with(128, 128, 7, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Figure 7 — strong scalability, single node",
        "fixed problem; time (s) and strong efficiency T1/(p·Tp)",
    );
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        let max = profile.workers_per_node;
        println!("--- {} (up to {} worker threads) ---", profile.name, max);
        for app in ["knn", "kmeans", "linreg"] {
            let mut table = Table::new(&["cores", "time", "speedup", "efficiency"])
                .with_title(&format!("{app} @ {}", profile.name));
            let mut t1 = None;
            for cores in sweep(max) {
                let spec =
                    ClusterSpec::new(profile.clone(), 1).with_workers_per_node(cores);
                let report = SimEngine::new(spec, CostModel::default())
                    .run(plan_for(app), &format!("{app}@{cores}"))
                    .unwrap();
                let t = report.makespan_s;
                let base = *t1.get_or_insert(t);
                let eff = strong_efficiency(base, t, cores as f64);
                table.row(vec![
                    cores.to_string(),
                    fmt_secs(t),
                    format!("{:.1}x", base / t),
                    fmt_pct(eff),
                ]);
                record_result(
                    "fig7",
                    vec![
                        ("machine", Json::Str(profile.name.clone())),
                        ("app", Json::Str(app.into())),
                        ("cores", Json::Num(cores as f64)),
                        ("time_s", Json::Num(t)),
                        ("efficiency", Json::Num(eff)),
                    ],
                );
            }
            table.print();
            println!();
        }
    }
    println!(
        "paper shape: Shaheen KNN/K-means ≥80% @64; linreg →47% @128.\n\
         MN5 linreg ~100x slower in absolute time but ≥83% efficient @80."
    );
}
