//! Hot-path microbenchmarks + cost-model calibration (§Perf substrate).
//!
//! Measures, on this box:
//!
//! 1. **MKL/RBLAS ratio** — PJRT/XLA GEMM (the artifact path) vs the naive
//!    native GEMM, the measured constant behind
//!    `MachineProfile::gemm_slowdown` (paper: ≈100x on linreg's GEMM
//!    tasks);
//! 2. **Per-task-type unit costs** — live execution of each app task body,
//!    normalized to seconds/unit, compared against the defaults in
//!    `sim::cost::DEFAULT_UNIT_COSTS`;
//! 3. **Codec throughput** — RMVL and friends in GB/s (feeds the disk
//!    model and the §Perf targets);
//! 4. **Runtime dispatch overhead** — per-task wall overhead of the live
//!    coordinator with trivial task bodies;
//! 5. **Scheduler + DES throughput** — ops/sec of the pure coordination
//!    structures;
//! 6. **Batched vs sequential submission** — control-lock amortization;
//! 7. **`bytes` vs `cost` routing** — transfer-heavy 2-node workload
//!    through the placement engine (prefetch overlap split);
//! 8. **`cost` vs `adaptive` routing** — the same workload under a
//!    bandwidth-skewed observation profile (the feedback-driven model
//!    routes on observed throughput, the byte heuristic cannot);
//! 9. **File-backed vs warm-tier fan-out staging** — an N-node fan-out of
//!    memory-resident versions, `--warm-budget 0` (one encode + N file
//!    round-trips per version) against the warm tier (one encode, zero
//!    file I/O, blob shipped directly);
//! 10. **Fleet-scale DES throughput** — a 1,000-node, 10^6-task synthetic
//!     plan (`sim::fleet_plan`) through the fuzzed event heap, in
//!     events/sec — the schedule-fuzz sweep's per-seed capacity bar;
//! 11. **Greedy vs window-compiled dispatch** — the same workload routed
//!     one verdict per task vs one verdict per 64-task window, with an
//!     InOut supersede chain surfacing the compiler's fusion/AOT-free
//!     counters.
//! 12. **Relay vs direct-shipped TCP fan-out** — the same N-node warm
//!     fan-out over loopback TCP with `--p2p off` (every blob relayed
//!     through the coordinator) against the default direct
//!     worker-to-worker BlobChunk path, reporting the ship mix and the
//!     coordinator's own egress bytes — the fabric's scaling bottleneck.
//!
//! Run: `cargo bench --bench runtime_hotpath`

use rcompss::api::{CompssRuntime, RuntimeConfig, TaskDef};
use rcompss::apps::backend::{self, Backend};
use rcompss::apps::Shapes;
use rcompss::bench_harness::{banner, record_result, time_once, time_reps};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::coordinator::access::Direction;
use rcompss::coordinator::registry::NodeId;
use rcompss::coordinator::scheduler::{scheduler_by_name, ReadyTask};
use rcompss::coordinator::dag::TaskId;
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::{obj, Json};
use rcompss::util::prng::Pcg64;
use rcompss::util::table::{fmt_bytes, Table};
use rcompss::value::{Gen, RValue};

fn gemm_ratio() {
    println!("[1] MKL-class (PJRT/XLA) vs RBLAS-class (native) GEMM");
    let n = 512usize;
    let mut rng = Pcg64::seeded(1);
    let a = Gen::new(&mut rng).normal_matrix(n, n);
    let b = Gen::new(&mut rng).normal_matrix(n, n);

    // Native single-thread GEMM.
    let (am, bm) = (to_native(&a), to_native(&b));
    let native = time_reps(3, || {
        std::hint::black_box(rcompss::blas::gemm(&am, &bm).unwrap());
    });

    #[cfg(feature = "pjrt")]
    if rcompss::runtime::artifacts_available() {
        // Pure execution time: literals built once outside the timed loop
        // (the conversion cost is measured separately by [4]).
        let pjrt = rcompss::runtime::with_engine(|eng| {
            let la = rcompss::runtime::tensor::matrix_to_f32_literal(&a)?;
            let lb = rcompss::runtime::tensor::matrix_to_f32_literal(&b)?;
            eng.execute("gemm_cal", &[la.clone(), lb.clone()])?; // warm compile
            Ok(time_reps(10, || {
                std::hint::black_box(eng.execute("gemm_cal", &[la.clone(), lb.clone()]).unwrap());
            }))
        })
        .unwrap();
        let flops = 2.0 * (n as f64).powi(3);
        let ratio = native.median / pjrt.median;
        println!(
            "  {n}x{n} GEMM: pjrt {:.1} ms ({:.1} GFLOP/s) vs native {:.1} ms ({:.2} GFLOP/s) -> ratio {ratio:.0}x",
            pjrt.median * 1e3,
            flops / pjrt.median / 1e9,
            native.median * 1e3,
            flops / native.median / 1e9,
        );
        println!(
            "  (paper's MKL-vs-RBLAS observation: ~100x; profile constant gemm_slowdown=100)"
        );
        record_result(
            "hotpath_gemm",
            vec![
                ("pjrt_s", Json::Num(pjrt.median)),
                ("native_s", Json::Num(native.median)),
                ("ratio", Json::Num(ratio)),
            ],
        );
        println!();
        return;
    }
    println!(
        "  artifacts missing (or pjrt feature off); native GEMM only: {:.1} ms",
        native.median * 1e3
    );
    println!();
}

fn to_native(v: &RValue) -> rcompss::blas::Mat {
    let (data, nrow, ncol) = v.as_matrix().unwrap();
    let mut m = rcompss::blas::Mat::new(nrow, ncol);
    for c in 0..ncol {
        for r in 0..nrow {
            m.data[r * ncol + c] = data[c * nrow + r] as f32;
        }
    }
    m
}

fn unit_costs() {
    println!("[2] per-task-type unit costs (live bodies, seconds/unit)");
    let backend = Backend::auto();
    let shapes = Shapes::from_manifest();
    let model = CostModel::default();
    let mut table = Table::new(&["task type", "measured s/unit", "model s/unit"]);

    // (defs, type, args, units)
    let seed_args: Vec<rcompss::value::RValue> =
        vec![RValue::int_scalar(1), RValue::int_scalar(0)];
    let mut run_body = |defs: Vec<(&'static str, TaskDef)>,
                        ty: &str,
                        args: &[RValue],
                        units: f64| {
        let def = defs.into_iter().find(|(n, _)| *n == ty).unwrap().1;
        // Execute the body directly (no runtime) for a pure compute number.
        let body = {
            // TaskDef fields are crate-private; go through a runtime once.
            let rt = CompssRuntime::start(RuntimeConfig::local(1)).unwrap();
            let reg = rt.register_task(def);
            let task_args: Vec<rcompss::api::TaskArg> =
                args.iter().map(|v| v.clone().into()).collect();
            let (elapsed, _) = time_once(|| {
                let r = rt.submit(&reg, &task_args).unwrap();
                rt.wait_on(&r).unwrap()
            });
            rt.stop().unwrap();
            elapsed
        };
        let measured = body / units;
        table.row(vec![
            ty.to_string(),
            format!("{measured:.2e}"),
            format!("{:.2e}", model.unit_cost(ty)),
        ]);
        record_result(
            "hotpath_unit_cost",
            vec![
                ("task", Json::Str(ty.into())),
                ("measured", Json::Num(measured)),
                ("model", Json::Num(model.unit_cost(ty))),
            ],
        );
    };

    // Fill + frag for KNN.
    let s = shapes;
    run_body(
        backend::knn_task_defs(s, backend),
        "KNN_fill_fragment",
        &seed_args,
        (s.knn_train_n * s.knn_d) as f64,
    );
    let (tx, ty_) = backend::gen_knn_points(1, 0, s.knn_train_n, s.knn_d, s.knn_classes);
    let (qx, _) = backend::gen_knn_points(1, 99, s.knn_test_block, s.knn_d, s.knn_classes);
    run_body(
        backend::knn_task_defs(s, backend),
        "KNN_frag",
        &[qx, tx, ty_],
        (s.knn_test_block * s.knn_train_n * s.knn_d) as f64,
    );
    // K-means partial.
    let pts = backend::gen_kmeans_points(1, 0, s.km_frag_n, s.km_d, s.km_k);
    let cents = backend::gen_kmeans_init(1, s.km_k, s.km_d);
    run_body(
        backend::kmeans_task_defs(s, backend),
        "partial_sum",
        &[pts, cents],
        (s.km_frag_n * s.km_k * s.km_d) as f64,
    );
    // Linreg ztz.
    let (x, _y) = backend::gen_lr_fragment(1, 0, s.lr_frag_n, s.lr_p);
    run_body(
        backend::linreg_task_defs(s, backend),
        "partial_ztz",
        &[x],
        (s.lr_frag_n * s.lr_p * s.lr_p) as f64,
    );
    table.print();
    println!("  (measured includes one-time artifact compile + file I/O; the model\n   constants approximate steady-state compute.)\n");
}

fn codec_throughput() {
    println!("[3] codec throughput (64 MiB matrix)");
    let mut rng = Pcg64::seeded(2);
    let block = Gen::new(&mut rng).square_block(2896); // ~64 MiB
    let bytes = block.byte_size();
    let dir = std::env::temp_dir().join(format!("rcompss_hotpath_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut table = Table::new(&["codec", "write GB/s", "read GB/s", "file size"]);
    for codec in rcompss::serialization::all_codecs() {
        if codec.name() == "csv" {
            continue; // text path is orders slower; covered by table1
        }
        let path = dir.join(format!("tp.{}", codec.name()));
        let w = time_reps(3, || codec.write_file(&block, &path).unwrap());
        let r = time_reps(3, || {
            std::hint::black_box(codec.read_file(&path).unwrap());
        });
        let fsize = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        table.row(vec![
            codec.name().to_string(),
            format!("{:.2}", bytes as f64 / w.median / 1e9),
            format!("{:.2}", bytes as f64 / r.median / 1e9),
            fmt_bytes(fsize as usize),
        ]);
        record_result(
            "hotpath_codec",
            vec![
                ("codec", Json::Str(codec.name().into())),
                ("write_gbps", Json::Num(bytes as f64 / w.median / 1e9)),
                ("read_gbps", Json::Num(bytes as f64 / r.median / 1e9)),
            ],
        );
    }
    table.print();
    println!();
    std::fs::remove_dir_all(&dir).ok();
}

/// Case [4]: per-task dispatch overhead of the live runtime with trivial
/// bodies, comparing the file data plane (every parameter through the
/// codec + workdir, as the seed runtime did) against the in-memory
/// zero-copy plane, at 1 and 8 workers. Appends to the shared summary
/// that `main` writes to `BENCH_hotpath.json` after every case ran, so
/// the perf trajectory is tracked in-repo (acceptance target: >= 2x lower
/// overhead with the memory plane at 8 workers).
fn dispatch_overhead(summary: &mut Vec<Json>) {
    println!("[4] live runtime dispatch overhead (trivial bodies, file vs memory plane)");
    let n_tasks = 2000usize;
    let mut us_file_8 = f64::NAN;
    let mut us_mem_8 = f64::NAN;
    for (plane, budget) in [("file", 0u64), ("memory", 256 << 20)] {
        for workers in [1u32, 8] {
            // GC pinned off so the file-plane arm stays seed-identical
            // (the comparison this case has always measured).
            let config = RuntimeConfig::local(workers)
                .with_memory_budget(budget)
                .with_gc(false);
            let rt = CompssRuntime::start(config).unwrap();
            let noop = rt.register_task(TaskDef::new("noop", 1, |args| {
                Ok(vec![args[0].as_ref().clone()])
            }));
            let (elapsed, _) = time_once(|| {
                for i in 0..n_tasks {
                    rt.submit(&noop, &[(i as f64).into()]).unwrap();
                }
                rt.barrier().unwrap();
            });
            let stats = rt.stop().unwrap();
            let per_task = elapsed / n_tasks as f64 * 1e6;
            println!(
                "  {plane:6} plane, {workers} worker(s): {n_tasks} tasks in {elapsed:.2}s \
                 -> {per_task:.0} µs/task (store hits {}, spills {})",
                stats.store_hits, stats.spills
            );
            record_result(
                "hotpath_dispatch",
                vec![
                    ("plane", Json::Str(plane.into())),
                    ("workers", Json::Num(workers as f64)),
                    ("us_per_task", Json::Num(per_task)),
                    ("store_hits", Json::Num(stats.store_hits as f64)),
                    ("spills", Json::Num(stats.spills as f64)),
                ],
            );
            summary.push(obj(vec![
                ("metric", Json::Str("dispatch_us_per_task".into())),
                ("plane", Json::Str(plane.into())),
                ("workers", Json::Num(workers as f64)),
                ("n_tasks", Json::Num(n_tasks as f64)),
                ("us_per_task", Json::Num(per_task)),
            ]));
            if workers == 8 {
                if plane == "file" {
                    us_file_8 = per_task;
                } else {
                    us_mem_8 = per_task;
                }
            }
        }
    }
    let speedup = us_file_8 / us_mem_8;
    println!("  memory-plane speedup at 8 workers: {speedup:.1}x (target >= 2x)");
    summary.push(obj(vec![
        ("metric", Json::Str("memory_plane_speedup_8w".into())),
        ("speedup", Json::Num(speedup)),
        ("target", Json::Num(2.0)),
    ]));
    println!();
}

/// Case [6]: batched vs sequential submission. `Runtime::submit_batch`
/// amortizes the control lock across a partition loop; this measures the
/// per-task submission cost both ways on the memory plane.
fn batched_submission(summary: &mut Vec<Json>) {
    println!("[6] batched vs sequential submission (memory plane, 4 workers)");
    let n_tasks = 2000usize;
    for mode in ["sequential", "batched"] {
        let rt = CompssRuntime::start(RuntimeConfig::local_in_memory(4)).unwrap();
        let noop = rt.register_task(TaskDef::new("noop", 1, |args| {
            Ok(vec![args[0].as_ref().clone()])
        }));
        let (elapsed, _) = time_once(|| {
            if mode == "batched" {
                let calls: Vec<_> = (0..n_tasks)
                    .map(|i| (&noop, vec![rcompss::api::TaskArg::from(i as f64)]))
                    .collect();
                rt.submit_batch(&calls).unwrap();
            } else {
                for i in 0..n_tasks {
                    rt.submit(&noop, &[(i as f64).into()]).unwrap();
                }
            }
            rt.barrier().unwrap();
        });
        rt.stop().unwrap();
        let per_task = elapsed / n_tasks as f64 * 1e6;
        println!("  {mode:10}: {n_tasks} tasks -> {per_task:.1} µs/task");
        record_result(
            "hotpath_submit_batch",
            vec![
                ("mode", Json::Str(mode.into())),
                ("n_tasks", Json::Num(n_tasks as f64)),
                ("us_per_task", Json::Num(per_task)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("submit_us_per_task".into())),
            ("mode", Json::Str(mode.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
        ]));
    }
    println!();
}

/// Case [7]: `bytes` vs `cost` routing under a transfer-heavy 2-node
/// workload. Producers spread across both nodes; each combiner reads two
/// producers' outputs that live on *different* nodes, so every placement
/// forces a transfer — the question is whether the router rides the
/// prefetcher (`cost` counts in-flight bytes as local) or fights it
/// (`bytes` chases resident replicas only). Reports wall time per task and
/// the prefetch-overlap split.
fn routing_models(summary: &mut Vec<Json>) {
    println!("[7] bytes vs cost routing (transfer-heavy workload, 2 nodes x 2 workers)");
    let producers = 64usize;
    let payload = 32 * 1024usize; // 256 KiB per produced vector
    for router in ["bytes", "cost"] {
        let config = RuntimeConfig::local(2)
            .with_nodes(2, 2)
            .with_router(router)
            .with_transfer_threads(1);
        let rt = CompssRuntime::start(config).unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 1, move |args| {
            let seed = args[0].as_f64().unwrap_or(0.0);
            Ok(vec![RValue::Real(vec![seed; payload])])
        }));
        let combine = rt.register_task(TaskDef::new("combine", 2, |args| {
            let a = args[0].as_real().unwrap();
            let b = args[1].as_real().unwrap();
            Ok(vec![RValue::scalar(a[0] + b[0])])
        }));
        let (elapsed, _) = time_once(|| {
            let outs: Vec<_> = (0..producers)
                .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
                .collect();
            // Cross pairing: out[i] with out[i + half] — under any routing
            // the two halves of most pairs sit on different nodes.
            let half = producers / 2;
            for i in 0..half {
                rt.submit(&combine, &[outs[i].into(), outs[i + half].into()])
                    .unwrap();
            }
            rt.barrier().unwrap();
        });
        let stats = rt.stop().unwrap();
        let n_tasks = producers + producers / 2;
        let per_task = elapsed / n_tasks as f64 * 1e6;
        let overlap = stats.transfers_prefetched as f64
            / (stats.transfers_prefetched + stats.transfers_waited).max(1) as f64;
        println!(
            "  router {router:5}: {n_tasks} tasks -> {per_task:.1} µs/task | transfers: \
             {} requested, {} prefetched, {} waited, {} dropped ({:.0}% overlap), sync decodes {}",
            stats.transfers_requested,
            stats.transfers_prefetched,
            stats.transfers_waited,
            stats.transfers_dropped,
            overlap * 100.0,
            stats.sync_transfer_decodes,
        );
        record_result(
            "hotpath_routing",
            vec![
                ("router", Json::Str(router.into())),
                ("us_per_task", Json::Num(per_task)),
                ("transfers_requested", Json::Num(stats.transfers_requested as f64)),
                ("prefetch_overlap", Json::Num(overlap)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("routing_us_per_task".into())),
            ("router", Json::Str(router.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
            ("prefetch_overlap", Json::Num(overlap)),
        ]));
    }
    println!();
}

/// Case [8]: `cost` vs `adaptive` routing under a bandwidth-skewed 2-node
/// workload. A single box cannot physically skew a link, so the skew is
/// injected as *observations*: the adaptive router's feedback sink is
/// pre-seeded so node 0 looks ~1 MB/s away while node 1 looks ~1 GB/s
/// away (live mover observations keep folding in on top). `cost` ignores
/// bandwidth by construction; the case reports wall time per task and the
/// prefetch-overlap split for both models on the case-[7] workload.
fn adaptive_routing(summary: &mut Vec<Json>) {
    println!("[8] cost vs adaptive routing (bandwidth-skewed observations, 2 nodes x 2 workers)");
    let producers = 64usize;
    let payload = 32 * 1024usize; // 256 KiB per produced vector
    for router in ["cost", "adaptive"] {
        let config = RuntimeConfig::local(2)
            .with_nodes(2, 2)
            .with_router(router)
            .with_transfer_threads(1);
        let rt = CompssRuntime::start(config).unwrap();
        if let Some(fb) = rt.feedback_stats() {
            // Observed skew, past the warm gate: reaching node 0 crawls,
            // reaching node 1 flies; combiners take ~1 ms.
            for _ in 0..4 {
                fb.record_transfer(NodeId(0), 1 << 20, 1.0);
                fb.record_transfer(NodeId(1), 1 << 30, 1.0);
            }
            fb.record_task("combine", 0.001);
        }
        let mk = rt.register_task(TaskDef::new("mk", 1, move |args| {
            let seed = args[0].as_f64().unwrap_or(0.0);
            Ok(vec![RValue::Real(vec![seed; payload])])
        }));
        let combine = rt.register_task(TaskDef::new("combine", 2, |args| {
            let a = args[0].as_real().unwrap();
            let b = args[1].as_real().unwrap();
            Ok(vec![RValue::scalar(a[0] + b[0])])
        }));
        let (elapsed, _) = time_once(|| {
            let outs: Vec<_> = (0..producers)
                .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
                .collect();
            let half = producers / 2;
            for i in 0..half {
                rt.submit(&combine, &[outs[i].into(), outs[i + half].into()])
                    .unwrap();
            }
            rt.barrier().unwrap();
        });
        let stats = rt.stop().unwrap();
        let n_tasks = producers + producers / 2;
        let per_task = elapsed / n_tasks as f64 * 1e6;
        let overlap = stats.transfers_prefetched as f64
            / (stats.transfers_prefetched + stats.transfers_waited).max(1) as f64;
        println!(
            "  router {router:8}: {n_tasks} tasks -> {per_task:.1} µs/task | transfers: \
             {} requested, {} prefetched, {} waited ({:.0}% overlap), sync decodes {}",
            stats.transfers_requested,
            stats.transfers_prefetched,
            stats.transfers_waited,
            overlap * 100.0,
            stats.sync_transfer_decodes,
        );
        record_result(
            "hotpath_adaptive_routing",
            vec![
                ("router", Json::Str(router.into())),
                ("us_per_task", Json::Num(per_task)),
                ("transfers_requested", Json::Num(stats.transfers_requested as f64)),
                ("prefetch_overlap", Json::Num(overlap)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("adaptive_routing_us_per_task".into())),
            ("router", Json::Str(router.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
            ("prefetch_overlap", Json::Num(overlap)),
        ]));
    }
    println!();
}

/// Case [9]: N-node fan-out transfer staging — file-backed vs warm tier.
/// Each of 16 producers' outputs is consumed on every node of a 4-node
/// fabric (round-robin spreads the consumers), so every version fans out
/// to up to 3 remote destinations. With `--warm-budget 0` each staging
/// publishes/rereads the spill file; with the warm tier on the mover
/// ships the cached blob — the stats columns (encodes, file writes/reads)
/// show the mechanism, the wall time the win.
fn fanout_staging(summary: &mut Vec<Json>) {
    println!("[9] fan-out transfer staging: file-backed vs warm tier (4 nodes x 1 worker)");
    let producers = 16usize;
    let consumers_per = 8usize;
    let payload = 32 * 1024usize; // 256 KiB per produced vector
    for (mode, warm) in [
        ("file", 0u64),
        ("warm", rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET),
    ] {
        let config = RuntimeConfig::local(1)
            .with_nodes(4, 1)
            .with_router("roundrobin")
            .with_transfer_threads(1)
            .with_warm_budget(warm);
        let rt = CompssRuntime::start(config).unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 1, move |args| {
            let seed = args[0].as_f64().unwrap_or(0.0);
            Ok(vec![RValue::Real(vec![seed; payload])])
        }));
        let consume = rt.register_task(TaskDef::new("consume", 1, |args| {
            let a = args[0].as_real().unwrap();
            Ok(vec![RValue::scalar(a[0] + a[a.len() - 1])])
        }));
        let (elapsed, _) = time_once(|| {
            let outs: Vec<_> = (0..producers)
                .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
                .collect();
            for out in &outs {
                for _ in 0..consumers_per {
                    rt.submit(&consume, &[(*out).into()]).unwrap();
                }
            }
            rt.barrier().unwrap();
        });
        let stats = rt.stop().unwrap();
        let n_tasks = producers * (1 + consumers_per);
        let per_task = elapsed / n_tasks as f64 * 1e6;
        println!(
            "  {mode:4} staging: {n_tasks} tasks -> {per_task:.1} µs/task | {} encodes, \
             {} file writes, {} file reads, {} warm hits, {} moved",
            stats.store_encodes,
            stats.store_file_writes,
            stats.store_file_reads,
            stats.warm_hits,
            fmt_bytes(stats.transfer_bytes as usize),
        );
        record_result(
            "hotpath_fanout_staging",
            vec![
                ("mode", Json::Str(mode.into())),
                ("us_per_task", Json::Num(per_task)),
                ("store_encodes", Json::Num(stats.store_encodes as f64)),
                ("file_writes", Json::Num(stats.store_file_writes as f64)),
                ("file_reads", Json::Num(stats.store_file_reads as f64)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("fanout_staging_us_per_task".into())),
            ("mode", Json::Str(mode.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
            ("store_encodes", Json::Num(stats.store_encodes as f64)),
            ("file_writes", Json::Num(stats.store_file_writes as f64)),
            ("file_reads", Json::Num(stats.store_file_reads as f64)),
        ]));
    }
    println!();
}

/// Case [12]: relay vs direct-shipped TCP fan-out. The warm fan-out of
/// case [9], re-run over loopback TCP both ways: with `--p2p off` every
/// remote destination costs the coordinator one full blob `Put` (egress
/// scales with fan-out width), with direct shipping on the coordinator
/// seeds each version to one worker and the blob then travels
/// worker-to-worker as BlobChunk streams over pooled peer links — the
/// egress column collapses to roughly one blob per version plus control
/// frames, which is the number that decides how wide a single
/// coordinator can fan out.
fn fanout_relay_vs_direct(summary: &mut Vec<Json>) {
    println!("[12] TCP fan-out: coordinator relay vs direct worker-to-worker (5 nodes x 1 worker)");
    let producers = 16usize;
    let consumers_per = 8usize;
    let payload = 32 * 1024usize; // 256 KiB per produced vector
    for (mode, p2p) in [("relay", false), ("direct", true)] {
        let config = RuntimeConfig::local(1)
            .with_nodes(5, 1)
            .with_router("roundrobin")
            .with_transfer_threads(1)
            .with_warm_budget(rcompss::coordinator::runtime::DEFAULT_WARM_BUDGET)
            .with_transport("tcp")
            .with_p2p(p2p);
        let rt = CompssRuntime::start(config).unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 1, move |args| {
            let seed = args[0].as_f64().unwrap_or(0.0);
            Ok(vec![RValue::Real(vec![seed; payload])])
        }));
        let consume = rt.register_task(TaskDef::new("consume", 1, |args| {
            let a = args[0].as_real().unwrap();
            Ok(vec![RValue::scalar(a[0] + a[a.len() - 1])])
        }));
        let (elapsed, _) = time_once(|| {
            let outs: Vec<_> = (0..producers)
                .map(|i| rt.submit(&mk, &[(i as f64).into()]).unwrap())
                .collect();
            for out in &outs {
                for _ in 0..consumers_per {
                    rt.submit(&consume, &[(*out).into()]).unwrap();
                }
            }
            rt.barrier().unwrap();
        });
        let stats = rt.stop().unwrap();
        let n_tasks = producers * (1 + consumers_per);
        let per_task = elapsed / n_tasks as f64 * 1e6;
        println!(
            "  {mode:6} fan-out: {n_tasks} tasks -> {per_task:.1} µs/task | {} direct, \
             {} relay, {} seed ships, {} pool hits | coordinator egress {} of {} moved",
            stats.direct_ships,
            stats.relay_ships,
            stats.seed_ships,
            stats.pool_hits,
            fmt_bytes(stats.coord_egress_bytes as usize),
            fmt_bytes(stats.transfer_bytes as usize),
        );
        record_result(
            "hotpath_fanout_relay_vs_direct",
            vec![
                ("mode", Json::Str(mode.into())),
                ("us_per_task", Json::Num(per_task)),
                ("direct_ships", Json::Num(stats.direct_ships as f64)),
                ("relay_ships", Json::Num(stats.relay_ships as f64)),
                ("seed_ships", Json::Num(stats.seed_ships as f64)),
                ("pool_hits", Json::Num(stats.pool_hits as f64)),
                ("coord_egress_bytes", Json::Num(stats.coord_egress_bytes as f64)),
                ("transfer_bytes", Json::Num(stats.transfer_bytes as f64)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("fanout_relay_vs_direct_us_per_task".into())),
            ("mode", Json::Str(mode.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
            ("direct_ships", Json::Num(stats.direct_ships as f64)),
            ("relay_ships", Json::Num(stats.relay_ships as f64)),
            ("seed_ships", Json::Num(stats.seed_ships as f64)),
            ("pool_hits", Json::Num(stats.pool_hits as f64)),
            ("coord_egress_bytes", Json::Num(stats.coord_egress_bytes as f64)),
            ("transfer_bytes", Json::Num(stats.transfer_bytes as f64)),
        ]));
    }
    println!();
}

/// Case [11]: greedy vs window-compiled dispatch. The same workload —
/// 2,000 independent producers plus a 64-deep InOut supersede chain —
/// dispatched greedily (one placement verdict per task, every chain
/// version published and GC'd individually) and through the window
/// compiler (one verdict per 64-task window; the sub-threshold chain
/// fuses into dispatch units whose intermediates are handed worker-
/// locally, never published). Reports wall time per task and the
/// compiler counters that explain it.
fn window_compile(summary: &mut Vec<Json>) {
    println!("[11] greedy vs window-compiled dispatch (2 nodes x 2 workers)");
    let producers = 2000usize;
    let chain = 64usize;
    let payload = 1024usize; // 8 KiB per produced vector
    for mode in ["off", "window"] {
        let config = RuntimeConfig::local(2).with_nodes(2, 2).with_compile(mode);
        let rt = CompssRuntime::start(config).unwrap();
        let mk = rt.register_task(TaskDef::new("mk", 1, move |args| {
            let seed = args[0].as_f64().unwrap_or(0.0);
            Ok(vec![RValue::Real(vec![seed; payload])])
        }));
        let bump = rt.register_task(
            TaskDef::new("bump", 1, |args| {
                let v = args[0].as_real().unwrap();
                Ok(vec![RValue::Real(v.iter().map(|x| x + 1.0).collect())])
            })
            .with_outputs(0)
            .with_directions(vec![Direction::InOut]),
        );
        let (elapsed, _) = time_once(|| {
            for i in 0..producers {
                rt.submit(&mk, &[(i as f64).into()]).unwrap();
            }
            let mut latest = rt.submit(&mk, &[0.0.into()]).unwrap();
            for _ in 0..chain {
                latest = rt.submit_multi(&bump, &[latest.into()]).unwrap()[0];
            }
            rt.barrier().unwrap();
        });
        let stats = rt.stop().unwrap();
        let n_tasks = producers + 1 + chain;
        let per_task = elapsed / n_tasks as f64 * 1e6;
        println!(
            "  compile {mode:6}: {n_tasks} tasks -> {per_task:.1} µs/task | \
             {} placement verdicts, {} windows, {} fused, {} aot frees, {} alias reuses",
            stats.placement_verdicts,
            stats.windows_flushed,
            stats.window_fused,
            stats.aot_frees,
            stats.alias_reuses,
        );
        record_result(
            "hotpath_window_compile",
            vec![
                ("compile", Json::Str(mode.into())),
                ("us_per_task", Json::Num(per_task)),
                ("placement_verdicts", Json::Num(stats.placement_verdicts as f64)),
                ("window_fused", Json::Num(stats.window_fused as f64)),
            ],
        );
        summary.push(obj(vec![
            ("metric", Json::Str("window_compile_us_per_task".into())),
            ("compile", Json::Str(mode.into())),
            ("n_tasks", Json::Num(n_tasks as f64)),
            ("us_per_task", Json::Num(per_task)),
            ("placement_verdicts", Json::Num(stats.placement_verdicts as f64)),
            ("window_fused", Json::Num(stats.window_fused as f64)),
            ("aot_frees", Json::Num(stats.aot_frees as f64)),
            ("alias_reuses", Json::Num(stats.alias_reuses as f64)),
        ]));
    }
    println!();
}

fn pure_structures() {
    println!("[5] pure coordination structures");
    // Scheduler ops.
    for name in ["fifo", "lifo", "locality"] {
        let mut s = scheduler_by_name(name).unwrap();
        let n = 100_000u64;
        let (t, _) = time_once(|| {
            for i in 0..n {
                s.push(ReadyTask {
                    id: TaskId(i),
                    inputs: vec![(1024, vec![NodeId((i % 4) as u32)])],
                    type_name: "t".into(),
                });
            }
            let mut popped = 0u64;
            while s.pop_for(NodeId(0)).is_some() {
                popped += 1;
            }
            assert_eq!(popped, n);
        });
        println!("  scheduler {name:9}: {:.1} M push+pop/s", n as f64 / t / 1e6);
        record_result(
            "hotpath_scheduler",
            vec![
                ("policy", Json::Str(name.into())),
                ("mops", Json::Num(n as f64 / t / 1e6)),
            ],
        );
    }
    // DES throughput.
    let plan = plans::knn_plan(8, 512, 3).unwrap();
    let n_tasks = plan.graph.len();
    let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4);
    let (t, report) = time_once(|| {
        SimEngine::new(spec, CostModel::default())
            .run(plan, "des-bench")
            .unwrap()
    });
    println!(
        "  DES: {} tasks (~{} events) in {:.3}s -> {:.0}k tasks/s wall",
        n_tasks,
        n_tasks * 3,
        t,
        n_tasks as f64 / t / 1e3
    );
    record_result(
        "hotpath_des",
        vec![
            ("tasks", Json::Num(n_tasks as f64)),
            ("wall_s", Json::Num(t)),
            ("sim_makespan_s", Json::Num(report.makespan_s)),
        ],
    );
    println!();
}

fn fleet_sim(summary: &mut Vec<Json>) {
    println!("[10] fleet-scale DES throughput (1,000 nodes, 10^6 tasks, fuzzed heap)");
    // The schedule-fuzz harness's capacity bar: a 1,000-node synthetic
    // plan of 20,000 x 50 chained tasks (one million tasks, ~3 heap
    // events each) must drain in single-digit seconds per seed. Runs
    // *with* a fuzz seed so the measured number includes the perturbation
    // layer's batching — the sweep's real cost, not a best case.
    let nodes = 1_000u32;
    let plan = plans::fleet_plan(20_000, 50);
    let n_tasks = plan.graph.len();
    let events = n_tasks * 3;
    let spec = ClusterSpec::new(MachineProfile::shaheen3(), nodes).with_workers_per_node(4);
    let (t, report) = time_once(|| {
        SimEngine::new(spec, CostModel::default())
            .with_router("roundrobin")
            .with_fuzz_seed(1)
            .run(plan, "fleet-bench")
            .unwrap()
    });
    assert_eq!(report.tasks_done, n_tasks);
    let eps = events as f64 / t;
    println!(
        "  fleet: {} tasks on {} nodes (~{} events) in {:.2}s -> {:.2} M events/s",
        n_tasks,
        nodes,
        events,
        t,
        eps / 1e6
    );
    record_result(
        "hotpath_fleet_sim",
        vec![
            ("nodes", Json::Num(nodes as f64)),
            ("tasks", Json::Num(n_tasks as f64)),
            ("wall_s", Json::Num(t)),
            ("events_per_sec", Json::Num(eps)),
        ],
    );
    summary.push(obj(vec![
        ("metric", Json::Str("fleet_sim_events_per_sec".into())),
        ("nodes", Json::Num(nodes as f64)),
        ("tasks", Json::Num(n_tasks as f64)),
        ("wall_s", Json::Num(t)),
        ("events_per_sec", Json::Num(eps)),
    ]));
    println!();
}

fn main() {
    banner(
        "runtime_hotpath — calibration + hot-path microbenchmarks",
        "feeds sim::cost::CostModel and EXPERIMENTS.md §Perf",
    );
    gemm_ratio();
    unit_costs();
    codec_throughput();
    // Cases [4], [6], [7], [8], [9], [10], [11], and [12] share one
    // committed summary file; it is written only after all eight ran, so a
    // measured BENCH_hotpath.json always carries the dispatch,
    // batched-submit, routing, fan-out-staging, fleet-sim, window-compile,
    // and relay-vs-direct metrics the projected copy has.
    let mut summary: Vec<Json> = Vec::new();
    dispatch_overhead(&mut summary);
    batched_submission(&mut summary);
    routing_models(&mut summary);
    adaptive_routing(&mut summary);
    fanout_staging(&mut summary);
    fleet_sim(&mut summary);
    window_compile(&mut summary);
    fanout_relay_vs_direct(&mut summary);
    rcompss::bench_harness::write_json_summary("hotpath", summary);
    pure_structures();
}
