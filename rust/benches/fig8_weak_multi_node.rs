//! Regenerates **Figure 8**: weak scalability on up to 32 nodes of
//! Shaheen-III (128 workers/node) and MareNostrum 5 (80 workers/node).
//!
//! The workload grows proportionally with the node count (paper: KNN test
//! ~1M x50 per node, K-means ~38M x100 per node, linreg 2.56M x1000 per
//! node). Efficiency metric: T(1 node)/T(n nodes).
//!
//! Expected shape (paper §5.3): KNN ≥78% (Shaheen) / ≥95% (MN5) at 32
//! nodes; K-means 61% / 64%; linreg poor on the fast-BLAS profile but
//! good on the slow-BLAS profile (expensive GEMM hides I/O).
//!
//! Run: `cargo bench --bench fig8_weak_multi_node`

use rcompss::api::{CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::bench_harness::{banner, quick, record_result};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{plans, CostModel, SimEngine};
use rcompss::util::json::Json;
use rcompss::util::stats::weak_efficiency;
use rcompss::util::table::{fmt_pct, fmt_secs, Table};

fn nodes_sweep() -> Vec<u32> {
    if quick() {
        vec![1, 4, 16]
    } else {
        vec![1, 2, 4, 8, 16, 32]
    }
}

fn plan_for(app: &str, nodes: usize) -> rcompss::sim::sink::SimPlan {
    // The paper's per-node workload (§5.3): KNN train 8000x50 (4 fragments)
    // with 1.016Mx50 test per node (~128 blocks of 8000); K-means
    // 38.18Mx100 per node (~128 fragments of 300k); linreg 2.56Mx1000 per
    // node (128 fragments of 20k) + 640kx1000 predictions per node.
    let s = rcompss::apps::Shapes::paper_multi_node();
    match app {
        "knn" => plans::knn_plan_with(4, 128 * nodes, 8, s).unwrap(),
        "kmeans" => plans::kmeans_plan_with(128 * nodes, 3, 8, s).unwrap(),
        "linreg" => plans::linreg_plan_with(128 * nodes, 32 * nodes, 8, s).unwrap(),
        _ => unreachable!(),
    }
}

fn main() {
    banner(
        "Figure 8 — weak scalability, up to 32 nodes",
        "full worker count per node; problem grows with nodes; locality scheduler",
    );
    for profile in [MachineProfile::shaheen3(), MachineProfile::marenostrum5()] {
        let wpn = profile.workers_per_node as usize;
        println!("--- {} ({} workers/node) ---", profile.name, wpn);
        for app in ["knn", "kmeans", "linreg"] {
            let mut table = Table::new(&["nodes", "time", "efficiency"])
                .with_title(&format!("{app} @ {}", profile.name));
            let mut t1 = None;
            for nodes in nodes_sweep() {
                let spec = ClusterSpec::new(profile.clone(), nodes);
                let plan = plan_for(app, nodes as usize);
                let report = SimEngine::new(spec, CostModel::default())
                    .with_scheduler("locality")
                    .run(plan, &format!("{app}@{nodes}n"))
                    .unwrap();
                let t = report.makespan_s;
                let base = *t1.get_or_insert(t);
                let eff = weak_efficiency(base, t);
                table.row(vec![nodes.to_string(), fmt_secs(t), fmt_pct(eff)]);
                record_result(
                    "fig8",
                    vec![
                        ("machine", Json::Str(profile.name.clone())),
                        ("app", Json::Str(app.into())),
                        ("nodes", Json::Num(nodes as f64)),
                        ("time_s", Json::Num(t)),
                        ("efficiency", Json::Num(eff)),
                        ("transfer_s", Json::Num(report.total_transfer_s)),
                    ],
                );
            }
            table.print();
            println!();
        }
    }
    live_spot_check();
    println!(
        "paper shape: KNN ≥78%/95% @32 nodes; K-means 61%/64%; linreg poor on the\n\
         fast-BLAS profile, good on the slow-BLAS profile (GEMM cost hides I/O)."
    );
}

/// Tie the simulated weak-scaling sweep back to the live data plane: a real
/// 2-node (emulated) K-means run with the memory plane, asynchronous
/// transfers, and the version GC. The interesting numbers are how much of
/// the data movement overlapped with compute (prefetched vs waited), that
/// the claim paths never ran the codec synchronously, and that the run
/// ends with zero dead-version bytes.
fn live_spot_check() {
    println!("--- live 2-node spot check (memory plane, async transfers, version GC) ---");
    let config = RuntimeConfig::local(2)
        .with_nodes(2, 2)
        .with_scheduler("locality")
        .with_memory_budget(256 << 20)
        .with_gc(true);
    let rt = CompssRuntime::start(config).unwrap();
    let mut cfg = KmeansConfig::small(42);
    cfg.fragments = 8;
    cfg.iterations = 2;
    kmeans::run_kmeans(&rt, &cfg, Backend::auto()).unwrap();
    let stats = rt.stop().unwrap();
    println!(
        "  transfers: {} requested, {} prefetched, {} waited, {} failed; \
         sync claim decodes: {}; gc: {} versions reclaimed, dead bytes at exit: {}",
        stats.transfers_requested,
        stats.transfers_prefetched,
        stats.transfers_waited,
        stats.transfers_failed,
        stats.sync_transfer_decodes,
        stats.gc_collected,
        stats.dead_version_bytes,
    );
    record_result(
        "fig8_live_spotcheck",
        vec![
            ("transfers_requested", Json::Num(stats.transfers_requested as f64)),
            ("transfers_prefetched", Json::Num(stats.transfers_prefetched as f64)),
            ("transfers_waited", Json::Num(stats.transfers_waited as f64)),
            ("sync_transfer_decodes", Json::Num(stats.sync_transfer_decodes as f64)),
            ("gc_collected", Json::Num(stats.gc_collected as f64)),
            ("dead_version_bytes", Json::Num(stats.dead_version_bytes as f64)),
        ],
    );
    println!();
}
