//! Shared tagged-tree wire format, generic over byte order.
//!
//! Several codecs are "the same tree, different primitive encoding":
//! `rawbin` is this tree little-endian, `xdr` is it big-endian (R's
//! `serialize()` uses XDR, i.e. network order), `rds` is the XDR tree run
//! through gzip, `qs_like` is the LE tree run through shuffle+zstd.
//!
//! Layout (all lengths u64 in the codec's byte order):
//!
//! ```text
//! value   := tag:u8 body
//! body    := ()                      for Null       (tag 0)
//!          | len, i32[len]           for Logical    (tag 1)
//!          | len, i32[len]           for Int        (tag 2)
//!          | len, f64[len]           for Real       (tag 3)
//!          | len, (slen, utf8)[len]  for Str        (tag 4)
//!          | nrow, ncol, f64[n*c]    for Matrix     (tag 5)
//!          | len, (nlen, utf8, value)[len] for List (tag 6)
//!          | len, u8[len]            for Raw        (tag 7)
//! ```

use crate::value::RValue;
use anyhow::{bail, Result};

/// Byte-order behaviour for primitive packing. Implementations are
/// zero-sized; everything inlines.
pub trait ByteOrder: Send + Sync + 'static {
    fn put_u64(out: &mut Vec<u8>, v: u64);
    fn get_u64(buf: &[u8], off: &mut usize) -> Result<u64>;
    fn put_i32_slice(out: &mut Vec<u8>, xs: &[i32]);
    fn get_i32_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<i32>>;
    fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]);
    fn get_f64_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f64>>;
}

#[inline]
fn take<'a>(buf: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
    // Network input: `*off + n` must not be allowed to wrap — a hostile
    // length near usize::MAX would overflow the end bound into range and
    // hand back the wrong slice (or panic in debug builds). checked_add
    // turns it into the same clean truncation error.
    let end = off
        .checked_add(n)
        .ok_or_else(|| anyhow::anyhow!("corrupt input: offset overflow ({off} + {n})", off = *off))?;
    match buf.get(*off..end) {
        Some(s) => {
            *off += n;
            Ok(s)
        }
        None => bail!("truncated input: need {n} bytes at offset {off:?}", off = *off),
    }
}

/// Little-endian order. On the (little-endian) targets we build for, bulk
/// f64/i32 moves compile to straight memcpy.
pub struct Le;

impl ByteOrder for Le {
    #[inline]
    fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    fn get_u64(buf: &[u8], off: &mut usize) -> Result<u64> {
        let b = take(buf, off, 8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn put_i32_slice(out: &mut Vec<u8>, xs: &[i32]) {
        #[cfg(target_endian = "little")]
        {
            // Safe view: i32 has no padding; LE target matches wire order.
            let bytes = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
            };
            out.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn get_i32_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<i32>> {
        let b = take(buf, off, n * 4)?;
        let mut v = vec![0i32; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, n * 4);
        }
        #[cfg(not(target_endian = "little"))]
        for (i, c) in b.chunks_exact(4).enumerate() {
            v[i] = i32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(v)
    }

    fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
        #[cfg(target_endian = "little")]
        {
            let bytes = unsafe {
                std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8)
            };
            out.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn get_f64_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f64>> {
        let b = take(buf, off, n * 8)?;
        let mut v = vec![0f64; n];
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, n * 8);
        }
        #[cfg(not(target_endian = "little"))]
        for (i, c) in b.chunks_exact(8).enumerate() {
            v[i] = f64::from_le_bytes(c.try_into().unwrap());
        }
        Ok(v)
    }
}

/// Big-endian (XDR / network) order — what R's `serialize()` emits. The
/// per-element byte swap is the realistic cost the `serialize_Rcpp` Table-1
/// row pays relative to native-order codecs.
pub struct Be;

impl ByteOrder for Be {
    #[inline]
    fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_be_bytes());
    }

    #[inline]
    fn get_u64(buf: &[u8], off: &mut usize) -> Result<u64> {
        let b = take(buf, off, 8)?;
        Ok(u64::from_be_bytes(b.try_into().unwrap()))
    }

    fn put_i32_slice(out: &mut Vec<u8>, xs: &[i32]) {
        for x in xs {
            out.extend_from_slice(&x.to_be_bytes());
        }
    }

    fn get_i32_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<i32>> {
        let b = take(buf, off, n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_be_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
        for x in xs {
            out.extend_from_slice(&x.to_be_bytes());
        }
    }

    fn get_f64_vec(buf: &[u8], off: &mut usize, n: usize) -> Result<Vec<f64>> {
        let b = take(buf, off, n * 8)?;
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_be_bytes(c.try_into().unwrap()))
            .collect())
    }
}

const TAG_NULL: u8 = 0;
const TAG_LOGICAL: u8 = 1;
const TAG_INT: u8 = 2;
const TAG_REAL: u8 = 3;
const TAG_STR: u8 = 4;
const TAG_MATRIX: u8 = 5;
const TAG_LIST: u8 = 6;
const TAG_RAW: u8 = 7;

/// Serialize the tree into `out`.
pub fn encode_tree<B: ByteOrder>(v: &RValue, out: &mut Vec<u8>) {
    match v {
        RValue::Null => out.push(TAG_NULL),
        RValue::Logical(xs) => {
            out.push(TAG_LOGICAL);
            B::put_u64(out, xs.len() as u64);
            B::put_i32_slice(out, xs);
        }
        RValue::Int(xs) => {
            out.push(TAG_INT);
            B::put_u64(out, xs.len() as u64);
            B::put_i32_slice(out, xs);
        }
        RValue::Real(xs) => {
            out.push(TAG_REAL);
            B::put_u64(out, xs.len() as u64);
            B::put_f64_slice(out, xs);
        }
        RValue::Str(xs) => {
            out.push(TAG_STR);
            B::put_u64(out, xs.len() as u64);
            for s in xs {
                B::put_u64(out, s.len() as u64);
                out.extend_from_slice(s.as_bytes());
            }
        }
        RValue::Matrix { data, nrow, ncol } => {
            out.push(TAG_MATRIX);
            B::put_u64(out, *nrow as u64);
            B::put_u64(out, *ncol as u64);
            B::put_f64_slice(out, data);
        }
        RValue::List(items) => {
            out.push(TAG_LIST);
            B::put_u64(out, items.len() as u64);
            for (name, val) in items {
                B::put_u64(out, name.len() as u64);
                out.extend_from_slice(name.as_bytes());
                encode_tree::<B>(val, out);
            }
        }
        RValue::Raw(xs) => {
            out.push(TAG_RAW);
            B::put_u64(out, xs.len() as u64);
            out.extend_from_slice(xs);
        }
    }
}

/// Exact encoded size of the tree — lets encoders pre-allocate once.
pub fn encoded_size(v: &RValue) -> usize {
    match v {
        RValue::Null => 1,
        RValue::Logical(xs) | RValue::Int(xs) => 1 + 8 + xs.len() * 4,
        RValue::Real(xs) => 1 + 8 + xs.len() * 8,
        RValue::Str(xs) => 1 + 8 + xs.iter().map(|s| 8 + s.len()).sum::<usize>(),
        RValue::Matrix { data, .. } => 1 + 16 + data.len() * 8,
        RValue::List(items) => {
            1 + 8
                + items
                    .iter()
                    .map(|(n, v)| 8 + n.len() + encoded_size(v))
                    .sum::<usize>()
        }
        RValue::Raw(xs) => 1 + 8 + xs.len(),
    }
}

/// Guard against length fields that claim more data than the buffer holds
/// (corrupt or hostile input must not trigger huge allocations).
#[inline]
fn check_claim(buf: &[u8], off: usize, claimed_bytes: u64) -> Result<usize> {
    let remaining = (buf.len() - off) as u64;
    if claimed_bytes > remaining {
        bail!("corrupt input: claims {claimed_bytes} bytes but only {remaining} remain");
    }
    Ok(claimed_bytes as usize)
}

/// Deserialize a tree from `buf` starting at `off`.
pub fn decode_tree<B: ByteOrder>(buf: &[u8], off: &mut usize) -> Result<RValue> {
    let tag = *buf
        .get(*off)
        .ok_or_else(|| anyhow::anyhow!("truncated input: missing tag"))?;
    *off += 1;
    match tag {
        TAG_NULL => Ok(RValue::Null),
        TAG_LOGICAL | TAG_INT => {
            let n = B::get_u64(buf, off)?;
            let n = check_claim(buf, *off, n.saturating_mul(4))? / 4;
            let v = B::get_i32_vec(buf, off, n)?;
            Ok(if tag == TAG_LOGICAL {
                RValue::Logical(v)
            } else {
                RValue::Int(v)
            })
        }
        TAG_REAL => {
            let n = B::get_u64(buf, off)?;
            let n = check_claim(buf, *off, n.saturating_mul(8))? / 8;
            Ok(RValue::Real(B::get_f64_vec(buf, off, n)?))
        }
        TAG_STR => {
            let n = B::get_u64(buf, off)?;
            check_claim(buf, *off, n.saturating_mul(8))?;
            let mut v = Vec::with_capacity(n as usize);
            for _ in 0..n {
                let slen = B::get_u64(buf, off)?;
                let slen = check_claim(buf, *off, slen)?;
                let bytes = take(buf, off, slen)?;
                v.push(String::from_utf8(bytes.to_vec())?);
            }
            Ok(RValue::Str(v))
        }
        TAG_MATRIX => {
            let nrow = B::get_u64(buf, off)? as usize;
            let ncol = B::get_u64(buf, off)? as usize;
            let n = (nrow as u64).saturating_mul(ncol as u64);
            let n = check_claim(buf, *off, n.saturating_mul(8))? / 8;
            let data = B::get_f64_vec(buf, off, n)?;
            Ok(RValue::Matrix { data, nrow, ncol })
        }
        TAG_LIST => {
            let n = B::get_u64(buf, off)?;
            check_claim(buf, *off, n.saturating_mul(9))?; // ≥9 bytes/slot min
            let mut items = Vec::with_capacity(n as usize);
            for _ in 0..n {
                let nlen = B::get_u64(buf, off)?;
                let nlen = check_claim(buf, *off, nlen)?;
                let name = String::from_utf8(take(buf, off, nlen)?.to_vec())?;
                let val = decode_tree::<B>(buf, off)?;
                items.push((name, val));
            }
            Ok(RValue::List(items))
        }
        TAG_RAW => {
            let n = B::get_u64(buf, off)?;
            let n = check_claim(buf, *off, n)?;
            Ok(RValue::Raw(take(buf, off, n)?.to_vec()))
        }
        other => bail!("unknown value tag {other}"),
    }
}

/// Decode and insist the whole buffer was consumed.
pub fn decode_tree_exact<B: ByteOrder>(buf: &[u8]) -> Result<RValue> {
    let mut off = 0;
    let v = decode_tree::<B>(buf, &mut off)?;
    if off != buf.len() {
        bail!("trailing bytes after value: {} of {}", buf.len() - off, buf.len());
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Length-framed messages — the TCP transport's wire protocol.
//
// Every message between the coordinator and an `rcompss worker` process is
// one frame:
//
// ```text
// frame  := magic:u32(le) kind:u8 len:u64(le) payload[len]
// ```
//
// The 13-byte header is fixed little-endian regardless of the value codec in
// use — framing and value encoding are independent layers; the payload of a
// `Put`/`Blob` frame is the warm tier's already-encoded blob shipped
// verbatim (zero re-encode). `len` is capped at [`MAX_FRAME_BYTES`] and the
// payload is read through `Read::take`, so a truncated or hostile frame is a
// clean `Err` — never a panic, never an attacker-sized allocation.
// ---------------------------------------------------------------------------

/// Frame header magic: `"RCW1"` little-endian. A mismatch means the peer is
/// not speaking this protocol (or the stream lost sync) — fail fast.
pub const FRAME_MAGIC: u32 = 0x3157_4352;

/// Upper bound on a frame payload (1 GiB). A `len` field above this is
/// rejected before any allocation: the cap is what makes a hostile 2^64
/// length claim harmless.
pub const MAX_FRAME_BYTES: u64 = 1 << 30;

/// Message kinds of the replica-shipping protocol (see `ARCHITECTURE.md`
/// § Transport for the exchange diagram).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Worker → coordinator: register; payload = preferred node id
    /// (`u32` LE, `u32::MAX` = any free slot).
    Hello = 1,
    /// Coordinator → worker: registration verdict; payload = assigned
    /// node id (`u32` LE).
    Assign = 2,
    /// Coordinator → worker: store a replica; payload = key (12 bytes)
    /// followed by the serialized blob.
    Put = 3,
    /// Worker → coordinator: `Put` acknowledged.
    PutOk = 4,
    /// Coordinator → worker: serve a replica back; payload = key.
    Get = 5,
    /// Worker → coordinator: `Get` hit; payload = the blob.
    Blob = 6,
    /// Worker → coordinator: `Get` miss (evicted or never stored).
    NotFound = 7,
    /// Either side: protocol error; payload = UTF-8 description.
    Error = 8,
    /// Coordinator → worker: orderly shutdown, no reply expected.
    Shutdown = 9,
    /// Coordinator → source worker: stream a cached replica directly to a
    /// peer worker; payload = key (12 bytes) + destination node id
    /// (`u32` LE) + destination peer address (UTF-8).
    ShipTo = 10,
    /// Source worker → coordinator: `ShipTo` verdict; payload = key
    /// (12 bytes) + status byte (0 = failed, 1 = shipped over a fresh
    /// connection, 2 = shipped over a pooled connection, 3 = cache miss)
    /// + bytes shipped (`u64` LE) + wall nanos (`u64` LE).
    ShipDone = 11,
    /// Worker → worker: one bounded slice of a streamed replica; payload =
    /// chunk header (id + offset + total + CRC32, see [`decode_chunk`])
    /// + at most [`CHUNK_BYTES`] data bytes. The receiver acks the
    /// completed blob — not each chunk — with `PutOk`.
    BlobChunk = 12,
}

impl FrameKind {
    /// Parse a wire tag; `None` for unknown kinds (forward-compat reject).
    pub fn from_u8(tag: u8) -> Option<FrameKind> {
        Some(match tag {
            1 => FrameKind::Hello,
            2 => FrameKind::Assign,
            3 => FrameKind::Put,
            4 => FrameKind::PutOk,
            5 => FrameKind::Get,
            6 => FrameKind::Blob,
            7 => FrameKind::NotFound,
            8 => FrameKind::Error,
            9 => FrameKind::Shutdown,
            10 => FrameKind::ShipTo,
            11 => FrameKind::ShipDone,
            12 => FrameKind::BlobChunk,
            _ => return None,
        })
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

/// Write one frame (header + payload) and flush it to the peer.
pub fn write_frame<W: std::io::Write>(w: &mut W, kind: FrameKind, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u64;
    if len > MAX_FRAME_BYTES {
        bail!("frame payload of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte cap");
    }
    let mut header = [0u8; 13];
    header[..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4] = kind as u8;
    header[5..].copy_from_slice(&len.to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Truncation (EOF mid-header or mid-payload), a bad magic,
/// an unknown kind, or a length claim above [`MAX_FRAME_BYTES`] are all
/// clean errors. The payload is read through `Read::take` into a geometric-
/// growth buffer, so even an in-cap length claim never pre-allocates more
/// than the bytes actually on the stream.
pub fn read_frame<R: std::io::Read>(r: &mut R) -> Result<Frame> {
    let mut header = [0u8; 13];
    r.read_exact(&mut header)
        .map_err(|e| anyhow::anyhow!("truncated frame header: {e}"))?;
    let magic = u32::from_le_bytes(header[..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x})");
    }
    let kind = FrameKind::from_u8(header[4])
        .ok_or_else(|| anyhow::anyhow!("unknown frame kind {}", header[4]))?;
    let len = u64::from_le_bytes(header[5..].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        bail!("frame claims {len} bytes, above the {MAX_FRAME_BYTES}-byte cap");
    }
    let mut payload = Vec::new();
    r.take(len).read_to_end(&mut payload)?;
    if payload.len() as u64 != len {
        bail!("truncated frame payload: got {} of {len} bytes", payload.len());
    }
    Ok(Frame { kind, payload })
}

// ---------------------------------------------------------------------------
// Chunked blob streaming — the peer-to-peer direct-shipping codec.
//
// A replica streamed worker→worker is split into bounded `BlobChunk`
// frames so a large blob never has to materialize as one frame payload on
// either side: the sender writes straight out of the cached `Arc` slice,
// the receiver assembles straight into the single destination buffer.
// Each chunk carries a CRC32 over its entire payload prefix + data, so a
// flipped bit anywhere in the stream is a clean protocol error at the
// receiver (sockets already catch truncation; the CRC catches corruption
// the TCP checksum's 16 bits can miss at scale).
// ---------------------------------------------------------------------------

/// Bound on the data bytes of one `BlobChunk` frame (1 MiB): a 1 GiB
/// replica streams as ~1024 bounded frames instead of one giant payload.
pub const CHUNK_BYTES: usize = 1 << 20;

/// Wire size of a chunk header: stream id (12) + offset (8) + total (8) +
/// CRC32 (4).
pub const CHUNK_HEADER_BYTES: usize = 12 + 8 + 8 + 4;

/// One decoded, CRC-verified `BlobChunk` payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Chunk {
    /// Opaque stream id (the transport uses the 12-byte encoded
    /// `DataKey`).
    pub id: [u8; 12],
    /// Byte offset of `data` within the whole blob. Senders emit chunks
    /// in order; receivers reject gaps.
    pub offset: u64,
    /// Total blob size — the receiver knows completion without a
    /// trailer frame.
    pub total: u64,
    pub data: Vec<u8>,
}

/// CRC32 (IEEE 802.3, reflected polynomial `0xEDB8_8320`) over `data`.
/// Hand-rolled nibble-table implementation — small, dependency-free, and
/// fast enough that the stream stays socket-bound.
pub fn crc32(data: &[u8]) -> u32 {
    const POLY: u32 = 0xEDB8_8320;
    // 16-entry nibble table, computed at compile time.
    const TABLE: [u32; 16] = {
        let mut t = [0u32; 16];
        let mut i = 0;
        while i < 16 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 4 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    };
    let mut crc = !0u32;
    for b in data {
        crc = TABLE[((crc ^ (*b as u32)) & 0x0F) as usize] ^ (crc >> 4);
        crc = TABLE[((crc ^ ((*b as u32) >> 4)) & 0x0F) as usize] ^ (crc >> 4);
    }
    !crc
}

/// Encode one chunk payload: `id ‖ offset ‖ total ‖ crc ‖ data`, with the
/// CRC covering everything but its own field.
fn encode_chunk_payload(id: [u8; 12], offset: u64, total: u64, data: &[u8]) -> Vec<u8> {
    // CRC over the header prefix (id+offset+total) and the data, skipping
    // the CRC field itself — any corrupted payload byte is caught.
    let mut covered = Vec::with_capacity(28 + data.len());
    covered.extend_from_slice(&id);
    covered.extend_from_slice(&offset.to_le_bytes());
    covered.extend_from_slice(&total.to_le_bytes());
    covered.extend_from_slice(data);
    let crc = crc32(&covered[..]);
    let mut payload = Vec::with_capacity(CHUNK_HEADER_BYTES + data.len());
    payload.extend_from_slice(&covered[..28]);
    payload.extend_from_slice(&crc.to_le_bytes());
    payload.extend_from_slice(data);
    payload
}

/// Stream `blob` to `w` as in-order `BlobChunk` frames of at most
/// [`CHUNK_BYTES`] data bytes each. An empty blob still emits one chunk so
/// the receiver observes the (zero-length) stream completing.
pub fn write_blob_chunks<W: std::io::Write>(w: &mut W, id: [u8; 12], blob: &[u8]) -> Result<()> {
    let total = blob.len() as u64;
    let mut offset = 0usize;
    loop {
        let end = (offset + CHUNK_BYTES).min(blob.len());
        let payload = encode_chunk_payload(id, offset as u64, total, &blob[offset..end]);
        write_frame(w, FrameKind::BlobChunk, &payload)?;
        offset = end;
        if offset >= blob.len() {
            return Ok(());
        }
    }
}

/// Decode and CRC-verify one `BlobChunk` payload. Truncated headers,
/// oversized data, inconsistent offset/total claims, and any CRC mismatch
/// are clean errors — the receiving worker drops the stream and the
/// coordinator's relay fallback re-ships the blob.
pub fn decode_chunk(payload: &[u8]) -> Result<Chunk> {
    if payload.len() < CHUNK_HEADER_BYTES {
        bail!(
            "truncated chunk header: {} of {CHUNK_HEADER_BYTES} bytes",
            payload.len()
        );
    }
    let id: [u8; 12] = payload[..12].try_into().unwrap();
    let offset = u64::from_le_bytes(payload[12..20].try_into().unwrap());
    let total = u64::from_le_bytes(payload[20..28].try_into().unwrap());
    let want_crc = u32::from_le_bytes(payload[28..32].try_into().unwrap());
    let data = &payload[CHUNK_HEADER_BYTES..];
    let mut covered = Vec::with_capacity(28 + data.len());
    covered.extend_from_slice(&payload[..28]);
    covered.extend_from_slice(data);
    let got_crc = crc32(&covered);
    if got_crc != want_crc {
        bail!("chunk CRC mismatch: computed {got_crc:#010x}, frame claims {want_crc:#010x}");
    }
    if data.len() > CHUNK_BYTES {
        bail!("chunk data of {} bytes exceeds the {CHUNK_BYTES}-byte bound", data.len());
    }
    if total > MAX_FRAME_BYTES {
        bail!("chunk claims a {total}-byte blob, above the {MAX_FRAME_BYTES}-byte cap");
    }
    let end = offset
        .checked_add(data.len() as u64)
        .filter(|e| *e <= total)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "chunk range {offset}+{} overruns the {total}-byte blob",
                data.len()
            )
        })?;
    // Every non-final chunk must be full-sized: a short middle chunk means
    // the sender and receiver disagree about framing.
    if end < total && data.len() != CHUNK_BYTES {
        bail!("short non-final chunk: {} bytes at offset {offset} of {total}", data.len());
    }
    Ok(Chunk {
        id,
        offset,
        total,
        data: data.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::value::Gen;

    fn roundtrip<B: ByteOrder>(v: &RValue) {
        let mut buf = Vec::new();
        encode_tree::<B>(v, &mut buf);
        assert_eq!(buf.len(), encoded_size(v), "encoded_size mismatch for {v:?}");
        let back = decode_tree_exact::<B>(&buf).unwrap();
        assert!(v.identical(&back), "{v:?} != {back:?}");
    }

    #[test]
    fn both_orders_roundtrip_arbitrary() {
        let mut rng = Pcg64::seeded(11);
        let mut gen = Gen::new(&mut rng);
        for _ in 0..60 {
            let v = gen.arbitrary(3);
            roundtrip::<Le>(&v);
            roundtrip::<Be>(&v);
        }
    }

    #[test]
    fn orders_differ_on_the_wire() {
        let v = RValue::Real(vec![1.0]);
        let (mut le, mut be) = (Vec::new(), Vec::new());
        encode_tree::<Le>(&v, &mut le);
        encode_tree::<Be>(&v, &mut be);
        assert_ne!(le, be);
        assert_eq!(le.len(), be.len());
    }

    #[test]
    fn corrupt_length_fields_do_not_overallocate() {
        // Claim u64::MAX reals in a 32-byte buffer.
        let mut buf = vec![TAG_REAL];
        Le::put_u64(&mut buf, u64::MAX);
        buf.extend_from_slice(&[0u8; 16]);
        assert!(decode_tree_exact::<Le>(&buf).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut buf = Vec::new();
        encode_tree::<Le>(&RValue::Null, &mut buf);
        buf.push(0xFF);
        assert!(decode_tree_exact::<Le>(&buf).is_err());
    }

    #[test]
    fn invalid_utf8_in_strings_rejected() {
        let mut buf = vec![TAG_STR];
        Le::put_u64(&mut buf, 1);
        Le::put_u64(&mut buf, 2);
        buf.extend_from_slice(&[0xFF, 0xFE]);
        assert!(decode_tree_exact::<Le>(&buf).is_err());
    }

    #[test]
    fn tree_truncation_at_every_offset_is_a_clean_err() {
        let mut rng = Pcg64::seeded(23);
        let mut gen = Gen::new(&mut rng);
        for _ in 0..20 {
            let v = gen.arbitrary(3);
            let mut buf = Vec::new();
            encode_tree::<Le>(&v, &mut buf);
            for cut in 0..buf.len() {
                // Strict prefix: must be Err, must not panic.
                assert!(decode_tree_exact::<Le>(&buf[..cut]).is_err());
            }
        }
    }

    #[test]
    fn frame_roundtrip_every_kind() {
        let kinds = [
            FrameKind::Hello,
            FrameKind::Assign,
            FrameKind::Put,
            FrameKind::PutOk,
            FrameKind::Get,
            FrameKind::Blob,
            FrameKind::NotFound,
            FrameKind::Error,
            FrameKind::Shutdown,
            FrameKind::ShipTo,
            FrameKind::ShipDone,
            FrameKind::BlobChunk,
        ];
        for (i, kind) in kinds.into_iter().enumerate() {
            let payload: Vec<u8> = (0..i * 7).map(|b| b as u8).collect();
            let mut wire = Vec::new();
            write_frame(&mut wire, kind, &payload).unwrap();
            let frame = read_frame(&mut &wire[..]).unwrap();
            assert_eq!(frame.kind, kind);
            assert_eq!(frame.payload, payload);
        }
    }

    #[test]
    fn frame_truncation_at_every_offset_is_a_clean_err() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Put, b"0123456789abcdef").unwrap();
        for cut in 0..wire.len() {
            assert!(read_frame(&mut &wire[..cut]).is_err(), "cut at {cut}");
        }
        // The full frame still decodes after the sweep.
        assert!(read_frame(&mut &wire[..]).is_ok());
    }

    #[test]
    fn frame_bad_magic_rejected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Get, b"key").unwrap();
        wire[0] ^= 0x40;
        assert!(read_frame(&mut &wire[..]).is_err());
    }

    #[test]
    fn frame_unknown_kind_rejected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Get, b"key").unwrap();
        wire[4] = 0xEE;
        assert!(read_frame(&mut &wire[..]).is_err());
    }

    #[test]
    fn frame_hostile_length_claim_never_allocates() {
        // Header claims u64::MAX payload bytes: rejected by the cap before
        // any allocation happens.
        let mut wire = Vec::new();
        wire.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        wire.push(FrameKind::Blob as u8);
        wire.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame(&mut &wire[..]).is_err());

        // In-cap claim, truncated stream: `take` bounds the read to the
        // bytes present, so this is a clean truncation error, not an OOM.
        let mut wire = Vec::new();
        wire.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        wire.push(FrameKind::Blob as u8);
        wire.extend_from_slice(&MAX_FRAME_BYTES.to_le_bytes());
        wire.extend_from_slice(b"only a few bytes");
        assert!(read_frame(&mut &wire[..]).is_err());
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE 802.3 reference values ("check" vectors from the CRC
        // catalogue) pin the polynomial, reflection, and final XOR.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    /// Drain a chunk stream back into a blob, enforcing the receiver's
    /// in-order/completion rules — the same loop the worker's peer
    /// handler runs.
    fn assemble(wire: &[u8]) -> Result<Vec<u8>> {
        let mut r = wire;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            let frame = read_frame(&mut r)?;
            if frame.kind != FrameKind::BlobChunk {
                bail!("unexpected frame {:?} in chunk stream", frame.kind);
            }
            let c = decode_chunk(&frame.payload)?;
            if c.offset != buf.len() as u64 {
                bail!("out-of-order chunk at {} (have {})", c.offset, buf.len());
            }
            buf.extend_from_slice(&c.data);
            if buf.len() as u64 >= c.total {
                return Ok(buf);
            }
        }
    }

    #[test]
    fn blob_chunks_roundtrip_across_sizes() {
        // Empty, sub-chunk, exactly one chunk, chunk+1, and several
        // chunks with a ragged tail all reassemble byte-identically.
        for size in [0, 1, 100, CHUNK_BYTES, CHUNK_BYTES + 1, 3 * CHUNK_BYTES + 37] {
            let blob: Vec<u8> = (0..size).map(|i| (i * 31 + 7) as u8).collect();
            let mut wire = Vec::new();
            write_blob_chunks(&mut wire, [9u8; 12], &blob).unwrap();
            assert_eq!(assemble(&wire).unwrap(), blob, "size {size}");
        }
    }

    #[test]
    fn chunk_corruption_at_every_offset_is_detected() {
        // Flip one bit at every payload offset of a single-chunk stream:
        // the CRC (or, for the CRC field itself, the mismatch) must catch
        // all of them — no corrupted byte may reassemble silently.
        let blob: Vec<u8> = (0..257u32).map(|b| b as u8).collect();
        let payload = encode_chunk_payload([3u8; 12], 0, blob.len() as u64, &blob);
        assert!(decode_chunk(&payload).is_ok());
        for i in 0..payload.len() {
            let mut bad = payload.clone();
            bad[i] ^= 0x01;
            assert!(decode_chunk(&bad).is_err(), "flipped byte {i} went undetected");
        }
    }

    #[test]
    fn chunk_truncation_at_every_offset_is_a_clean_err() {
        let blob = vec![0xA5u8; 100];
        let mut wire = Vec::new();
        write_blob_chunks(&mut wire, [1u8; 12], &blob).unwrap();
        for cut in 0..wire.len() {
            assert!(assemble(&wire[..cut]).is_err(), "cut at {cut}");
        }
        assert_eq!(assemble(&wire).unwrap(), blob);
    }

    #[test]
    fn chunk_claims_are_bounded_and_consistent() {
        // Oversized data, blob totals above the frame cap, ranges that
        // overrun the total, and short middle chunks are all rejected
        // even with a valid CRC.
        let over = encode_chunk_payload([0u8; 12], 0, 2 * CHUNK_BYTES as u64, &[0u8; 10]);
        // 10 bytes at offset 0 of a 2 MiB blob: short non-final chunk.
        assert!(decode_chunk(&over).is_err());
        let overrun = encode_chunk_payload([0u8; 12], 90, 64, &[0u8; 10]);
        assert!(decode_chunk(&overrun).is_err());
        let too_big = encode_chunk_payload([0u8; 12], 0, MAX_FRAME_BYTES + 1, &[]);
        assert!(decode_chunk(&too_big).is_err());
        let fine = encode_chunk_payload([0u8; 12], 54, 64, &[0u8; 10]);
        assert_eq!(decode_chunk(&fine).unwrap().offset, 54);
    }
}
