//! `rds` codec — models R's `saveRDS`/`readRDS`: the XDR tree run through
//! gzip (R's default is gzip level 6). This reproduces the Table-1 RDS
//! signature: *serialization far slower than deserialization* (10K block:
//! S 31.85 s vs D 4.51 s) because deflate compression is much more
//! expensive than inflate on incompressible double data.

use super::wire::{decode_tree, encode_tree, encoded_size, Be};
use super::Codec;
use crate::value::RValue;
use anyhow::{bail, Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"RDX3"; // R's own rds v3 header tag

pub struct RdsCodec {
    /// gzip level; R's default is 6.
    pub level: u32,
}

impl Default for RdsCodec {
    fn default() -> Self {
        RdsCodec { level: 6 }
    }
}

impl Codec for RdsCodec {
    fn name(&self) -> &'static str {
        "rds"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut tree = Vec::with_capacity(encoded_size(v));
        encode_tree::<Be>(v, &mut tree);
        let mut out = Vec::with_capacity(tree.len() / 2 + 64);
        out.extend_from_slice(MAGIC);
        let mut enc = GzEncoder::new(&mut out, Compression::new(self.level));
        enc.write_all(&tree).context("gzip compress")?;
        enc.finish().context("gzip finish")?;
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = bytes
            .strip_prefix(MAGIC)
            .ok_or_else(|| anyhow::anyhow!("not an RDS payload (bad magic)"))?;
        let mut tree = Vec::new();
        GzDecoder::new(body)
            .read_to_end(&mut tree)
            .context("gzip decompress")?;
        let mut off = 0;
        let v = decode_tree::<Be>(&tree, &mut off)?;
        if off != tree.len() {
            bail!("trailing bytes inside rds payload");
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = RValue::List(vec![
            ("x".into(), RValue::Real(vec![1.0; 1000])),
            ("s".into(), RValue::string("hello")),
        ]);
        let c = RdsCodec::default();
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }

    #[test]
    fn compresses_repetitive_data() {
        // 1000 identical doubles should shrink well below 8000 bytes.
        let v = RValue::Real(vec![42.0; 1000]);
        let bytes = RdsCodec::default().encode(&v).unwrap();
        assert!(bytes.len() < 1000, "len = {}", bytes.len());
    }

    #[test]
    fn corrupted_stream_rejected() {
        let v = RValue::Real(vec![1.0; 64]);
        let mut bytes = RdsCodec::default().encode(&v).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(RdsCodec::default().decode(&bytes).is_err());
    }
}
