//! `fst_like` codec — models the `fst` R package: **columnar** storage with
//! per-column fast compression. fst's pitch is random access to columns of
//! a data frame; the relevant behaviour for Table 1 is that each column of
//! a matrix is compressed as an independent block (parallelizable,
//! cache-friendly) with a fast compressor, landing between `qs` and plain
//! `serialize` in speed.
//!
//! Matrices get the true columnar treatment; any other value falls back to
//! a compressed tree blob (fst itself only stores data frames — the
//! fallback keeps the codec total so the runtime can still select it).

use super::wire::{decode_tree_exact, encode_tree, encoded_size, Le};
use super::Codec;
use crate::value::RValue;
use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"FST1";
const KIND_MATRIX: u8 = 1;
const KIND_BLOB: u8 = 2;

pub struct FstCodec {
    pub level: i32,
}

impl Default for FstCodec {
    fn default() -> Self {
        FstCodec { level: 1 }
    }
}

impl Codec for FstCodec {
    fn name(&self) -> &'static str {
        "fst"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        match v {
            RValue::Matrix { data, nrow, ncol } => {
                out.push(KIND_MATRIX);
                out.extend_from_slice(&(*nrow as u64).to_le_bytes());
                out.extend_from_slice(&(*ncol as u64).to_le_bytes());
                // Column-major layout means each column is contiguous.
                for c in 0..*ncol {
                    let col = &data[c * nrow..(c + 1) * nrow];
                    let bytes = unsafe {
                        std::slice::from_raw_parts(col.as_ptr() as *const u8, col.len() * 8)
                    };
                    let comp = zstd::bulk::compress(bytes, self.level)
                        .context("zstd compress column")?;
                    out.extend_from_slice(&(comp.len() as u64).to_le_bytes());
                    out.extend_from_slice(&comp);
                }
            }
            other => {
                out.push(KIND_BLOB);
                let mut tree = Vec::with_capacity(encoded_size(other));
                encode_tree::<Le>(other, &mut tree);
                let comp = zstd::bulk::compress(&tree, self.level).context("zstd compress")?;
                out.extend_from_slice(&(tree.len() as u64).to_le_bytes());
                out.extend_from_slice(&comp);
            }
        }
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = bytes
            .strip_prefix(MAGIC)
            .ok_or_else(|| anyhow::anyhow!("not an fst payload (bad magic)"))?;
        let (&kind, rest) = body
            .split_first()
            .ok_or_else(|| anyhow::anyhow!("truncated fst payload"))?;
        match kind {
            KIND_MATRIX => {
                if rest.len() < 16 {
                    bail!("truncated fst matrix header");
                }
                let nrow = u64::from_le_bytes(rest[..8].try_into().unwrap()) as usize;
                let ncol = u64::from_le_bytes(rest[8..16].try_into().unwrap()) as usize;
                let mut off = 16;
                let mut data = vec![0f64; nrow.checked_mul(ncol).ok_or_else(|| {
                    anyhow::anyhow!("fst matrix dims overflow")
                })?];
                for c in 0..ncol {
                    if rest.len() < off + 8 {
                        bail!("truncated fst column header");
                    }
                    let clen =
                        u64::from_le_bytes(rest[off..off + 8].try_into().unwrap()) as usize;
                    off += 8;
                    if rest.len() < off + clen {
                        bail!("truncated fst column data");
                    }
                    let raw = zstd::bulk::decompress(&rest[off..off + clen], nrow * 8)
                        .context("zstd decompress column")?;
                    if raw.len() != nrow * 8 {
                        bail!("fst column length mismatch");
                    }
                    let col = &mut data[c * nrow..(c + 1) * nrow];
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            raw.as_ptr(),
                            col.as_mut_ptr() as *mut u8,
                            nrow * 8,
                        );
                    }
                    off += clen;
                }
                if off != rest.len() {
                    bail!("trailing bytes in fst payload");
                }
                Ok(RValue::Matrix { data, nrow, ncol })
            }
            KIND_BLOB => {
                if rest.len() < 8 {
                    bail!("truncated fst blob header");
                }
                let raw_len = u64::from_le_bytes(rest[..8].try_into().unwrap()) as usize;
                let tree = zstd::bulk::decompress(&rest[8..], raw_len)
                    .context("zstd decompress blob")?;
                if tree.len() != raw_len {
                    bail!("fst blob length mismatch");
                }
                decode_tree_exact::<Le>(&tree)
            }
            other => bail!("unknown fst kind {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::value::Gen;

    #[test]
    fn matrix_goes_columnar() {
        let mut rng = Pcg64::seeded(6);
        let v = Gen::new(&mut rng).normal_matrix(100, 10);
        let bytes = FstCodec::default().encode(&v).unwrap();
        assert_eq!(bytes[4], KIND_MATRIX);
        assert!(v.identical(&FstCodec::default().decode(&bytes).unwrap()));
    }

    #[test]
    fn non_matrix_falls_back_to_blob() {
        let v = RValue::Str(vec!["a".into(), "b".into()]);
        let bytes = FstCodec::default().encode(&v).unwrap();
        assert_eq!(bytes[4], KIND_BLOB);
        assert!(v.identical(&FstCodec::default().decode(&bytes).unwrap()));
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let v = RValue::zeros(0, 0);
        let c = FstCodec::default();
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }
}
