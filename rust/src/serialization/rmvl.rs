//! `rmvl` codec — models the RMVL R package ("Mappable Vector Library"),
//! the serialization backend the paper selects for RCOMPSs (§3.3.3).
//!
//! RMVL's design: a low-overhead binary format of machine-order vectors
//! that can be **memory-mapped** for reads, so deserialization is a page-in
//! plus a straight copy (no parsing, no byte swap, no decompression). We
//! reproduce that:
//!
//! * native little-endian payload with vectors padded to 8-byte alignment,
//! * a fixed header (magic, version) and a footer carrying the body length
//!   and a CRC32 of header+directory for torn-write detection,
//! * `read_file` overridden to `mmap(2)` the file (via the vendored `libc`)
//!   and decode directly out of the mapping.
//!
//! This codec is the runtime default; the Table-1 bench shows it at the top
//! of the ranking exactly as in the paper.

use super::wire::{decode_tree, encode_tree, encoded_size, Le};
use super::Codec;
use crate::util::bytes::crc32;
use crate::value::RValue;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MVL1\0\0\0\0";
const FOOTER_LEN: usize = 16; // body_len u64 + crc u32 + pad u32

pub struct RmvlCodec;

impl RmvlCodec {
    /// Append the footer to a buffer that already holds MAGIC + body.
    /// (Encoding writes the tree directly after the magic — framing in
    /// place avoids a full-payload copy; see EXPERIMENTS.md §Perf.)
    fn seal(mut out: Vec<u8>) -> Vec<u8> {
        let body_len = (out.len() - MAGIC.len()) as u64;
        out.extend_from_slice(&body_len.to_le_bytes());
        // CRC over header + first 256 bytes of body: cheap torn-write check
        // (full-body CRC would dominate deserialization cost, which RMVL —
        // and Table 1 — do not pay).
        let probe_end = MAGIC.len() + (body_len as usize).min(256);
        let crc = crc32(&out[..probe_end]);
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&[0u8; 4]);
        out
    }

    fn unframe(bytes: &[u8]) -> Result<&[u8]> {
        if bytes.len() < MAGIC.len() + FOOTER_LEN || &bytes[..8] != MAGIC {
            bail!("not an RMVL payload (bad magic or too short)");
        }
        let foot = &bytes[bytes.len() - FOOTER_LEN..];
        let body_len = u64::from_le_bytes(foot[..8].try_into().unwrap()) as usize;
        let stored_crc = u32::from_le_bytes(foot[8..12].try_into().unwrap());
        let expect_body = bytes.len() - MAGIC.len() - FOOTER_LEN;
        if body_len != expect_body {
            bail!("RMVL body length mismatch: footer says {body_len}, have {expect_body}");
        }
        let probe = &bytes[..MAGIC.len() + body_len.min(256)];
        if crc32(probe) != stored_crc {
            bail!("RMVL checksum mismatch (torn write?)");
        }
        Ok(&bytes[MAGIC.len()..MAGIC.len() + body_len])
    }
}

impl Codec for RmvlCodec {
    fn name(&self) -> &'static str {
        "rmvl"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(MAGIC.len() + encoded_size(v) + FOOTER_LEN);
        out.extend_from_slice(MAGIC);
        encode_tree::<Le>(v, &mut out);
        Ok(Self::seal(out))
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = Self::unframe(bytes)?;
        let mut off = 0;
        let v = decode_tree::<Le>(body, &mut off)?;
        if off != body.len() {
            bail!("trailing bytes in RMVL body");
        }
        Ok(v)
    }

    /// mmap-based read: map the file, validate the frame, decode straight
    /// out of the mapping. This is the RMVL selling point the paper cites —
    /// "memory-mapped persistence" — and it shows up as the best
    /// deserialization times in Table 1.
    fn read_file(&self, path: &Path) -> Result<RValue> {
        let map = Mmap::open(path)
            .with_context(|| format!("mmap {}", path.display()))?;
        self.decode(map.as_slice())
    }
}

/// Minimal read-only mmap wrapper over libc.
struct Mmap {
    ptr: *mut libc::c_void,
    len: usize,
}

impl Mmap {
    fn open(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len() as usize;
        if len == 0 {
            bail!("empty file");
        }
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ,
                libc::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        // Hint sequential access: decode walks the body front to back.
        unsafe {
            libc::madvise(ptr, len, libc::MADV_SEQUENTIAL);
        }
        Ok(Mmap { ptr, len })
    }

    fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        unsafe {
            libc::munmap(self.ptr, self.len);
        }
    }
}

// Mapping is read-only and private; safe to hand across threads.
unsafe impl Send for Mmap {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::value::Gen;

    #[test]
    fn roundtrip_in_memory() {
        let mut rng = Pcg64::seeded(8);
        let v = Gen::new(&mut rng).normal_matrix(33, 17);
        let c = RmvlCodec;
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }

    #[test]
    fn mmap_read_path() {
        let dir = std::env::temp_dir().join(format!("rcompss_rmvl_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.mvl");
        let mut rng = Pcg64::seeded(9);
        let v = Gen::new(&mut rng).normal_matrix(128, 64);
        let c = RmvlCodec;
        c.write_file(&v, &path).unwrap();
        let back = c.read_file(&path).unwrap();
        assert!(v.identical(&back));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_header_detected() {
        let v = RValue::Real(vec![1.0; 100]);
        let mut bytes = RmvlCodec.encode(&v).unwrap();
        bytes[10] ^= 0xFF; // corrupt inside the CRC probe window
        assert!(RmvlCodec.decode(&bytes).is_err());
    }

    #[test]
    fn footer_length_mismatch_detected() {
        let v = RValue::Real(vec![1.0; 4]);
        let mut bytes = RmvlCodec.encode(&v).unwrap();
        bytes.pop(); // shrink -> body/footer disagree
        assert!(RmvlCodec.decode(&bytes).is_err());
    }
}
