//! File-based parameter serialization — the COMPSs interchange layer.
//!
//! COMPSs passes every task parameter through a file so the runtime can move
//! data between processes and nodes without caring about the source
//! language (§3.3.3 of the paper). The paper benchmarks nine R
//! serialization methods and picks RMVL; this module rebuilds that design
//! space in Rust, one codec per module, all behind the [`Codec`] trait:
//!
//! | Codec       | Models (R)             | Technique                                 |
//! |-------------|------------------------|-------------------------------------------|
//! | `rawbin`    | writeBin/readBin       | little-endian tagged binary, no filter    |
//! | `xdr`       | serialize() ("_Rcpp")  | big-endian XDR binary (byte-swap cost)    |
//! | `rds`       | saveRDS/readRDS        | XDR + gzip (slow write, ok read)          |
//! | `qs_like`   | qs::qsave/qread        | byte-shuffle + fast zstd                  |
//! | `fst_like`  | fst::write.fst         | columnar blocks + per-column fast zstd    |
//! | `csv`       | data.table fwrite/fread| text (hex-float for lossless round-trip)  |
//! | `rmvl`      | RMVL (default)         | aligned little-endian + mmap read path    |
//!
//! Every codec must round-trip **any** [`RValue`] bit-exactly (including
//! `NA_real_` payloads); the shared property tests in this module enforce
//! that, and `benches/table1_serialization.rs` regenerates Table 1.
//!
//! With the in-memory data plane enabled
//! (`CoordinatorConfig::memory_budget > 0`), codecs are no longer on the
//! per-task hot path: node-local consumers receive zero-copy handles, and
//! the configured codec runs only at *spill boundaries* — memory pressure,
//! cross-node transfer, and reloads of spilled values (see
//! `coordinator/mod.rs` § *Data plane & locking*). With the plane disabled
//! (the default), every parameter goes through `write_file`/`read_file`
//! exactly as before, so these property tests cover both planes' byte
//! format.

pub mod csv;
pub mod fst_like;
pub mod qs_like;
pub mod rawbin;
pub mod rds;
pub mod rmvl;
pub mod wire;
pub mod xdr;

use crate::value::RValue;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// A serialization method for R values.
///
/// `encode`/`decode` work on byte buffers; `write_file`/`read_file` go
/// through the filesystem and may be overridden for codecs with special I/O
/// paths (RMVL uses mmap for reads).
pub trait Codec: Send + Sync {
    /// Short name used in configs, CLI flags, and Table 1 rows.
    fn name(&self) -> &'static str;

    /// Serialize a value into a fresh buffer.
    fn encode(&self, v: &RValue) -> Result<Vec<u8>>;

    /// Deserialize a value from a buffer.
    fn decode(&self, bytes: &[u8]) -> Result<RValue>;

    /// Serialize to a file (atomic enough for a single writer).
    fn write_file(&self, v: &RValue, path: &Path) -> Result<()> {
        let bytes = self.encode(v)?;
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&bytes)?;
        Ok(())
    }

    /// Deserialize from a file.
    fn read_file(&self, path: &Path) -> Result<RValue> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        self.decode(&bytes)
    }
}

/// All codecs, in Table-1 display order.
pub fn all_codecs() -> Vec<Box<dyn Codec>> {
    vec![
        Box::new(xdr::XdrCodec),
        Box::new(rds::RdsCodec::default()),
        Box::new(fst_like::FstCodec::default()),
        Box::new(qs_like::QsCodec::default()),
        Box::new(rmvl::RmvlCodec),
        Box::new(rawbin::RawBinCodec),
        Box::new(csv::CsvCodec),
    ]
}

/// Look a codec up by name (CLI / config entry point).
pub fn codec_by_name(name: &str) -> Option<Box<dyn Codec>> {
    let c: Box<dyn Codec> = match name {
        "xdr" | "serialize" | "serialize_rcpp" => Box::new(xdr::XdrCodec),
        "rds" => Box::new(rds::RdsCodec::default()),
        "fst" | "fst_like" => Box::new(fst_like::FstCodec::default()),
        "qs" | "qs_like" => Box::new(qs_like::QsCodec::default()),
        "rmvl" => Box::new(rmvl::RmvlCodec),
        "rawbin" | "writebin" => Box::new(rawbin::RawBinCodec),
        "csv" | "data.table" => Box::new(csv::CsvCodec),
        _ => return None,
    };
    Some(c)
}

/// The default codec — the paper selects RMVL (§3.3.3).
pub fn default_codec() -> Box<dyn Codec> {
    Box::new(rmvl::RmvlCodec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::value::{Gen, NA_INTEGER, NA_REAL};

    fn corpus() -> Vec<RValue> {
        let mut vals = vec![
            RValue::Null,
            RValue::Logical(vec![0, 1, NA_INTEGER]),
            RValue::Int(vec![i32::MAX, i32::MIN + 1, 0, NA_INTEGER]),
            RValue::Real(vec![
                0.0,
                -0.0,
                1.5,
                f64::MAX,
                f64::MIN_POSITIVE,
                f64::INFINITY,
                f64::NEG_INFINITY,
                f64::NAN,
                NA_REAL,
            ]),
            RValue::Str(vec!["".into(), "héllo, \"wörld\"\n".into(), "x,y".into()]),
            RValue::matrix(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3),
            RValue::Raw(vec![0, 255, 128, 7]),
            RValue::List(vec![
                ("a".into(), RValue::scalar(1.0)),
                ("".into(), RValue::Null),
                (
                    "nested".into(),
                    RValue::List(vec![("m".into(), RValue::zeros(3, 3))]),
                ),
            ]),
            RValue::Real(vec![]),
            RValue::Str(vec![]),
            RValue::List(vec![]),
        ];
        let mut rng = Pcg64::seeded(0xABCD);
        let mut gen = Gen::new(&mut rng);
        for _ in 0..40 {
            vals.push(gen.arbitrary(3));
        }
        vals
    }

    #[test]
    fn every_codec_roundtrips_corpus() {
        for codec in all_codecs() {
            for (i, v) in corpus().iter().enumerate() {
                let bytes = codec
                    .encode(v)
                    .unwrap_or_else(|e| panic!("{} encode case {i}: {e}", codec.name()));
                let back = codec
                    .decode(&bytes)
                    .unwrap_or_else(|e| panic!("{} decode case {i}: {e}", codec.name()));
                assert!(
                    v.identical(&back),
                    "{} failed roundtrip on case {i}: {v:?} -> {back:?}",
                    codec.name()
                );
            }
        }
    }

    #[test]
    fn every_codec_roundtrips_via_file() {
        let dir = std::env::temp_dir().join(format!("rcompss_codec_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let v = {
            let mut rng = Pcg64::seeded(7);
            Gen::new(&mut rng).normal_matrix(64, 32)
        };
        for codec in all_codecs() {
            let path = dir.join(format!("x.{}", codec.name()));
            codec.write_file(&v, &path).unwrap();
            let back = codec.read_file(&path).unwrap();
            assert!(v.identical(&back), "{} file roundtrip", codec.name());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn codec_by_name_resolves_aliases() {
        for name in ["rmvl", "qs", "fst", "rds", "serialize_rcpp", "csv", "rawbin"] {
            assert!(codec_by_name(name).is_some(), "{name}");
        }
        assert!(codec_by_name("nope").is_none());
        assert_eq!(default_codec().name(), "rmvl");
    }

    #[test]
    fn decode_rejects_truncated_input() {
        let v = RValue::Real(vec![1.0; 100]);
        for codec in all_codecs() {
            let bytes = codec.encode(&v).unwrap();
            let cut = &bytes[..bytes.len() / 2];
            assert!(
                codec.decode(cut).is_err(),
                "{} accepted truncated input",
                codec.name()
            );
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        let garbage = vec![0xA5u8; 64];
        for codec in all_codecs() {
            assert!(
                codec.decode(&garbage).is_err(),
                "{} accepted garbage",
                codec.name()
            );
        }
    }
}
