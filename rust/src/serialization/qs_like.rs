//! `qs_like` codec — models the `qs` R package ("quick serialization"):
//! a byte-shuffle filter over the native-order tree followed by a *fast*
//! LZ compressor (qs uses lz4/zstd at low levels; we use zstd level 1 from
//! the vendored crate). Shuffling groups the repetitive exponent bytes of
//! doubles together, so fast LZ gets real compression at near-memcpy speed
//! — which is why qs lands next to RMVL at the top of Table 1.

use super::wire::{decode_tree_exact, encode_tree, encoded_size, Le};
use super::Codec;
use crate::util::bytes::{shuffle, unshuffle};
use crate::value::RValue;
use anyhow::{Context, Result};

const MAGIC: &[u8; 4] = b"QS01";
/// Shuffle width: 8 bytes, the element size of the dominant payload (f64).
const SHUFFLE_WIDTH: usize = 8;

pub struct QsCodec {
    /// zstd level; qs defaults to a fast preset.
    pub level: i32,
}

impl Default for QsCodec {
    fn default() -> Self {
        QsCodec { level: 1 }
    }
}

impl Codec for QsCodec {
    fn name(&self) -> &'static str {
        "qs"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut tree = Vec::with_capacity(encoded_size(v));
        encode_tree::<Le>(v, &mut tree);
        let shuffled = shuffle(&tree, SHUFFLE_WIDTH);
        let compressed =
            zstd::bulk::compress(&shuffled, self.level).context("zstd compress")?;
        let mut out = Vec::with_capacity(compressed.len() + 12);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(shuffled.len() as u64).to_le_bytes());
        out.extend_from_slice(&compressed);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = bytes
            .strip_prefix(MAGIC)
            .ok_or_else(|| anyhow::anyhow!("not a qs payload (bad magic)"))?;
        if body.len() < 8 {
            anyhow::bail!("truncated qs payload");
        }
        let raw_len = u64::from_le_bytes(body[..8].try_into().unwrap()) as usize;
        let shuffled =
            zstd::bulk::decompress(&body[8..], raw_len).context("zstd decompress")?;
        if shuffled.len() != raw_len {
            anyhow::bail!("qs payload length mismatch");
        }
        let tree = unshuffle(&shuffled, SHUFFLE_WIDTH);
        decode_tree_exact::<Le>(&tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;
    use crate::value::Gen;

    #[test]
    fn roundtrip_random_matrix() {
        let mut rng = Pcg64::seeded(5);
        let v = Gen::new(&mut rng).normal_matrix(50, 40);
        let c = QsCodec::default();
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }

    #[test]
    fn shuffle_beats_plain_lz_on_doubles() {
        // Smooth data: exponents repeat; shuffle should expose that.
        let v = RValue::Real((0..10_000).map(|i| 1.0 + i as f64 * 1e-6).collect());
        let qs = QsCodec::default().encode(&v).unwrap();
        let mut tree = Vec::new();
        encode_tree::<Le>(&v, &mut tree);
        let plain = zstd::bulk::compress(&tree, 1).unwrap();
        assert!(
            qs.len() < plain.len(),
            "shuffled {} vs plain {}",
            qs.len(),
            plain.len()
        );
    }

    #[test]
    fn length_mismatch_rejected() {
        let v = RValue::Real(vec![1.0; 32]);
        let mut bytes = QsCodec::default().encode(&v).unwrap();
        // Lie about the raw length.
        bytes[4] ^= 0x01;
        assert!(QsCodec::default().decode(&bytes).is_err());
    }
}
