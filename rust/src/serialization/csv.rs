//! `csv` codec — models `data.table::fwrite`/`fread`: text I/O. The paper
//! benchmarked data.table's text path among its nine candidates; text is
//! human-inspectable but pays formatting/parsing costs on numeric data.
//!
//! To satisfy the crate-wide codec contract (bit-exact round-trip of every
//! `RValue`, including `NA_real_` payload bits), doubles are written as C99
//! hex-floats with NA/NaN/Inf sentinels, and strings are RFC-4180 quoted.
//! The container format is a line-oriented header (`#rcsv <type> <dims>`)
//! followed by CSV rows; lists nest via an indented block count.

use super::Codec;
use crate::value::{is_na_real, RValue, NA_INTEGER, NA_REAL};
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;

pub struct CsvCodec;

impl Codec for CsvCodec {
    fn name(&self) -> &'static str {
        "csv"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut out = String::new();
        out.push_str("#rcsv v1\n");
        write_value(&mut out, v)?;
        Ok(out.into_bytes())
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let text = std::str::from_utf8(bytes).context("csv payload is not utf-8")?;
        let mut lines = text.lines().peekable();
        match lines.next() {
            Some("#rcsv v1") => {}
            _ => bail!("not an rcsv payload (bad header)"),
        }
        let v = read_value(&mut lines)?;
        if lines.next().is_some() {
            bail!("trailing lines after value");
        }
        Ok(v)
    }
}

// ---- doubles: lossless text ------------------------------------------------

fn fmt_f64(x: f64) -> String {
    if is_na_real(x) {
        "NA".to_string()
    } else if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        // Hex float: exact round-trip without 17-digit parsing subtleties.
        format!("{:x}", HexF64(x))
    }
}

struct HexF64(f64);

impl std::fmt::LowerHex for HexF64 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let bits = self.0.to_bits();
        write!(f, "0x{bits:016x}")
    }
}

fn parse_f64(s: &str) -> Result<f64> {
    Ok(match s {
        "NA" => NA_REAL,
        "NaN" => f64::NAN,
        "Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        hex if hex.starts_with("0x") => {
            let bits = u64::from_str_radix(&hex[2..], 16).context("bad hex double")?;
            f64::from_bits(bits)
        }
        dec => dec.parse::<f64>().context("bad double")?,
    })
}

fn fmt_i32(x: i32) -> String {
    if x == NA_INTEGER {
        "NA".to_string()
    } else {
        x.to_string()
    }
}

fn parse_i32(s: &str) -> Result<i32> {
    if s == "NA" {
        Ok(NA_INTEGER)
    } else {
        s.parse::<i32>().context("bad integer")
    }
}

// ---- strings: RFC-4180 quoting ---------------------------------------------

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\"\""),
            '\n' => out.push_str("\\n"),
            '\\' => out.push_str("\\\\"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn unquote(s: &str) -> Result<String> {
    let inner = s
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| anyhow::anyhow!("unquoted string field: {s}"))?;
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                // Doubled quote inside a quoted field.
                match chars.next() {
                    Some('"') => out.push('"'),
                    _ => bail!("stray quote in string field"),
                }
            }
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('r') => out.push('\r'),
                Some('\\') => out.push('\\'),
                other => bail!("bad escape {other:?}"),
            },
            c => out.push(c),
        }
    }
    Ok(out)
}

/// Split a CSV line honoring quoted fields.
fn split_csv(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                in_quotes = !in_quotes;
                cur.push('"');
            }
            '\\' if in_quotes => {
                cur.push('\\');
                if let Some(n) = chars.next() {
                    cur.push(n);
                }
            }
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

// ---- value writer / reader --------------------------------------------------

fn write_value(out: &mut String, v: &RValue) -> Result<()> {
    match v {
        RValue::Null => out.push_str("null\n"),
        RValue::Logical(xs) => {
            writeln!(out, "logical {}", xs.len()).unwrap();
            writeln!(out, "{}", xs.iter().map(|x| fmt_i32(*x)).collect::<Vec<_>>().join(","))
                .unwrap();
        }
        RValue::Int(xs) => {
            writeln!(out, "integer {}", xs.len()).unwrap();
            writeln!(out, "{}", xs.iter().map(|x| fmt_i32(*x)).collect::<Vec<_>>().join(","))
                .unwrap();
        }
        RValue::Real(xs) => {
            writeln!(out, "double {}", xs.len()).unwrap();
            writeln!(out, "{}", xs.iter().map(|x| fmt_f64(*x)).collect::<Vec<_>>().join(","))
                .unwrap();
        }
        RValue::Str(xs) => {
            writeln!(out, "character {}", xs.len()).unwrap();
            writeln!(out, "{}", xs.iter().map(|s| quote(s)).collect::<Vec<_>>().join(","))
                .unwrap();
        }
        RValue::Matrix { data, nrow, ncol } => {
            writeln!(out, "matrix {nrow} {ncol}").unwrap();
            // One CSV row per matrix row — the natural fwrite layout.
            for r in 0..*nrow {
                let row: Vec<String> =
                    (0..*ncol).map(|c| fmt_f64(data[c * nrow + r])).collect();
                writeln!(out, "{}", row.join(",")).unwrap();
            }
        }
        RValue::List(items) => {
            writeln!(out, "list {}", items.len()).unwrap();
            for (name, val) in items {
                writeln!(out, "{}", quote(name)).unwrap();
                write_value(out, val)?;
            }
        }
        RValue::Raw(xs) => {
            writeln!(out, "raw {}", xs.len()).unwrap();
            let hex: String = xs.iter().map(|b| format!("{b:02x}")).collect();
            writeln!(out, "{hex}").unwrap();
        }
    }
    Ok(())
}

fn read_value<'a, I: Iterator<Item = &'a str>>(
    lines: &mut std::iter::Peekable<I>,
) -> Result<RValue> {
    let header = lines.next().ok_or_else(|| anyhow::anyhow!("missing value header"))?;
    let mut parts = header.split(' ');
    let kind = parts.next().unwrap_or("");
    match kind {
        "null" => Ok(RValue::Null),
        "logical" | "integer" => {
            let n: usize = parts.next().unwrap_or("x").parse().context("bad length")?;
            let xs = read_scalar_row(lines, n, parse_i32)?;
            Ok(if kind == "logical" {
                RValue::Logical(xs)
            } else {
                RValue::Int(xs)
            })
        }
        "double" => {
            let n: usize = parts.next().unwrap_or("x").parse().context("bad length")?;
            Ok(RValue::Real(read_scalar_row(lines, n, parse_f64)?))
        }
        "character" => {
            let n: usize = parts.next().unwrap_or("x").parse().context("bad length")?;
            if n == 0 {
                lines.next(); // consume the (empty) data line
                return Ok(RValue::Str(vec![]));
            }
            let line = lines.next().ok_or_else(|| anyhow::anyhow!("missing row"))?;
            let fields = split_csv(line);
            if fields.len() != n {
                bail!("character row has {} fields, expected {n}", fields.len());
            }
            Ok(RValue::Str(
                fields.iter().map(|f| unquote(f)).collect::<Result<_>>()?,
            ))
        }
        "matrix" => {
            let nrow: usize = parts.next().unwrap_or("x").parse().context("bad nrow")?;
            let ncol: usize = parts.next().unwrap_or("x").parse().context("bad ncol")?;
            let mut data = vec![0f64; nrow * ncol];
            for r in 0..nrow {
                let line = lines.next().ok_or_else(|| anyhow::anyhow!("missing matrix row"))?;
                let fields: Vec<&str> = line.split(',').collect();
                if fields.len() != ncol {
                    bail!("matrix row has {} fields, expected {ncol}", fields.len());
                }
                for (c, f) in fields.iter().enumerate() {
                    data[c * nrow + r] = parse_f64(f)?;
                }
            }
            Ok(RValue::Matrix { data, nrow, ncol })
        }
        "list" => {
            let n: usize = parts.next().unwrap_or("x").parse().context("bad length")?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let name_line =
                    lines.next().ok_or_else(|| anyhow::anyhow!("missing list name"))?;
                let name = unquote(name_line)?;
                let val = read_value(lines)?;
                items.push((name, val));
            }
            Ok(RValue::List(items))
        }
        "raw" => {
            let n: usize = parts.next().unwrap_or("x").parse().context("bad length")?;
            let line = lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("missing raw data line"))?;
            if line.len() != n * 2 {
                bail!("raw line has {} hex chars, expected {}", line.len(), n * 2);
            }
            let mut xs = Vec::with_capacity(n);
            for i in 0..n {
                xs.push(
                    u8::from_str_radix(&line[i * 2..i * 2 + 2], 16).context("bad raw hex")?,
                );
            }
            Ok(RValue::Raw(xs))
        }
        other => bail!("unknown rcsv kind {other:?}"),
    }
}

fn read_scalar_row<'a, I: Iterator<Item = &'a str>, T>(
    lines: &mut std::iter::Peekable<I>,
    n: usize,
    parse: impl Fn(&str) -> Result<T>,
) -> Result<Vec<T>> {
    let line = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("missing data row"))?;
    if n == 0 {
        if !line.is_empty() {
            bail!("expected empty row for zero-length vector");
        }
        return Ok(vec![]);
    }
    let fields: Vec<&str> = line.split(',').collect();
    if fields.len() != n {
        bail!("row has {} fields, expected {n}", fields.len());
    }
    fields.iter().map(|f| parse(f)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_floats_are_bit_exact() {
        for x in [0.1, -0.0, f64::MAX, f64::MIN_POSITIVE, 1.0 / 3.0] {
            let s = fmt_f64(x);
            assert_eq!(parse_f64(&s).unwrap().to_bits(), x.to_bits(), "{s}");
        }
    }

    #[test]
    fn na_sentinels_roundtrip() {
        assert!(is_na_real(parse_f64("NA").unwrap()));
        assert!(parse_f64("NaN").unwrap().is_nan());
        assert_eq!(parse_f64("Inf").unwrap(), f64::INFINITY);
        assert_eq!(parse_i32("NA").unwrap(), NA_INTEGER);
    }

    #[test]
    fn strings_with_commas_and_quotes() {
        let v = RValue::Str(vec!["a,b".into(), "say \"hi\"".into(), "new\nline".into()]);
        let c = CsvCodec;
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }

    #[test]
    fn matrix_row_layout() {
        let v = RValue::matrix(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let text = String::from_utf8(CsvCodec.encode(&v).unwrap()).unwrap();
        // Row 0 is (1,3) in column-major storage.
        assert!(text.lines().nth(2).unwrap().starts_with("0x3ff0"));
        assert!(v.identical(&CsvCodec.decode(text.as_bytes()).unwrap()));
    }

    #[test]
    fn wrong_field_count_rejected() {
        let text = "#rcsv v1\ndouble 3\n0x0,0x0\n";
        assert!(CsvCodec.decode(text.as_bytes()).is_err());
    }
}
