//! `xdr` codec — models R's `serialize()` (the paper's `serialize_Rcpp`
//! row): XDR, i.e. big-endian/network byte order, uncompressed. On
//! little-endian hardware every element pays a byte swap, which is exactly
//! why this row sits mid-table in Table 1 — structurally identical to
//! `rawbin`, slower purely from the per-element swap.

use super::wire::{decode_tree_exact, encode_tree, encoded_size, Be};
use super::Codec;
use crate::value::RValue;
use anyhow::Result;

/// R's serialize() starts with a format header; ours is "XDR2" in that
/// spirit.
const MAGIC: &[u8; 4] = b"XDR2";

pub struct XdrCodec;

impl Codec for XdrCodec {
    fn name(&self) -> &'static str {
        "serialize_rcpp"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(4 + encoded_size(v));
        out.extend_from_slice(MAGIC);
        encode_tree::<Be>(v, &mut out);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = bytes
            .strip_prefix(MAGIC)
            .ok_or_else(|| anyhow::anyhow!("not an XDR payload (bad magic)"))?;
        decode_tree_exact::<Be>(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serialization::rawbin::RawBinCodec;

    #[test]
    fn roundtrip() {
        let v = RValue::Real(vec![1.0, -2.5, 1e300]);
        let c = XdrCodec;
        assert!(v.identical(&c.decode(&c.encode(&v).unwrap()).unwrap()));
    }

    #[test]
    fn wire_is_big_endian() {
        // Same value, different bytes vs rawbin (beyond the magic).
        let v = RValue::Real(vec![1.0]);
        let xdr = XdrCodec.encode(&v).unwrap();
        let raw = RawBinCodec.encode(&v).unwrap();
        assert_eq!(xdr.len(), raw.len());
        assert_ne!(xdr[4..], raw[4..]);
    }
}
