//! `rawbin` codec — models R's `writeBin`/`readBin`: the little-endian
//! tagged tree with no filtering or compression. This is the "as fast as a
//! memcpy" floor that the RMVL codec competes with (RMVL adds alignment,
//! a directory, and an mmap read path on top).

use super::wire::{decode_tree_exact, encode_tree, encoded_size, Le};
use super::Codec;
use crate::value::RValue;
use anyhow::Result;

/// Magic prefix so garbage input is detected instead of misparsed.
const MAGIC: &[u8; 4] = b"RBN1";

pub struct RawBinCodec;

impl Codec for RawBinCodec {
    fn name(&self) -> &'static str {
        "rawbin"
    }

    fn encode(&self, v: &RValue) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(4 + encoded_size(v));
        out.extend_from_slice(MAGIC);
        encode_tree::<Le>(v, &mut out);
        Ok(out)
    }

    fn decode(&self, bytes: &[u8]) -> Result<RValue> {
        let body = bytes
            .strip_prefix(MAGIC)
            .ok_or_else(|| anyhow::anyhow!("not a rawbin payload (bad magic)"))?;
        decode_tree_exact::<Le>(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_matrix() {
        let v = RValue::matrix(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let c = RawBinCodec;
        let bytes = c.encode(&v).unwrap();
        assert!(bytes.starts_with(MAGIC));
        assert!(v.identical(&c.decode(&bytes).unwrap()));
    }

    #[test]
    fn encode_is_compact() {
        // 4 magic + 1 tag + 16 dims + 32 payload.
        let v = RValue::zeros(2, 2);
        assert_eq!(RawBinCodec.encode(&v).unwrap().len(), 4 + 1 + 16 + 32);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(RawBinCodec.decode(b"XXXX\x00").is_err());
    }
}
