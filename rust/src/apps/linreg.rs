//! Parallel linear regression with prediction (§4.3, Figure 5).
//!
//! Nine task types, as the paper enumerates: `LR_fill_fragment` generates
//! data fragments; `partial_ztz` / `partial_zty` compute per-fragment
//! contributions to X^T X and X^T y; `merge_ztz` / `merge_zty` combine them
//! in binary trees; `compute_model_parameters` solves the normal equations;
//! `LR_genpred` generates prediction blocks and `compute_prediction`
//! applies the model. This DAG is the *deepest* of the three apps —
//! fill → partial → log2(f) merges → solve → predict — which is exactly
//! why the paper sees linear regression scale worst (§5.2: "deeper task
//! dependencies amplify the impact of runtime overheads").

use anyhow::Result;

use crate::api::{CompssRuntime, RuntimeConfig};
use crate::apps::backend::{self, Backend};
use crate::apps::{mat_bytes, vec_bytes, LiveSink, Shapes, SinkRef, SubmitSpec, TaskSink};
use crate::value::RValue;

#[derive(Clone, Copy, Debug)]
pub struct LinregConfig {
    /// Fitting fragments (n_total = fragments * lr_frag_n rows).
    pub fragments: usize,
    /// Prediction blocks.
    pub pred_blocks: usize,
    pub seed: u64,
    pub shapes: Shapes,
}

impl LinregConfig {
    pub fn small(seed: u64) -> LinregConfig {
        LinregConfig {
            fragments: 4,
            pred_blocks: 2,
            seed,
            shapes: Shapes::from_manifest(),
        }
    }
}

pub struct LinregPlan {
    pub beta: SinkRef,
    /// (prediction, ground truth) per prediction block.
    pub predictions: Vec<(SinkRef, SinkRef)>,
}

/// Emit the Figure-5 DAG through a sink.
pub fn plan_linreg(sink: &mut dyn TaskSink, cfg: &LinregConfig) -> Result<LinregPlan> {
    let s = cfg.shapes;
    let (n, p, pn) = (s.lr_frag_n, s.lr_p, s.lr_pred_block);

    // Fill fragments (blue). GEMM-class per §5.2's trace discussion
    // (fill includes the X beta product for y). Batched: one control-lock
    // acquisition for the whole generation loop on the live runtime.
    let fill_specs: Vec<SubmitSpec> = (0..cfg.fragments)
        .map(|f| SubmitSpec {
            ty: "LR_fill_fragment",
            args: vec![(cfg.seed as i32).into(), (f as i32).into()],
            n_outputs: 2,
            out_bytes: vec![mat_bytes(n, p), vec_bytes(n)],
            cost_units: (n * p) as f64,
            gemm_class: true,
        })
        .collect();
    let frags: Vec<(SinkRef, SinkRef)> = sink
        .submit_batch(fill_specs)?
        .into_iter()
        .map(|outs| (outs[0], outs[1]))
        .collect();

    // Partial moments (red partial_ztz, pink partial_zty), batched as one
    // interleaved loop: [ztz(f0), zty(f0), ztz(f1), zty(f1), ...] — the
    // submission order (and so the DAG) is identical to the seed's.
    let mut partial_specs: Vec<SubmitSpec> = Vec::with_capacity(2 * frags.len());
    for (x, y) in &frags {
        partial_specs.push(SubmitSpec {
            ty: "partial_ztz",
            args: vec![(*x).into()],
            n_outputs: 1,
            out_bytes: vec![mat_bytes(p, p)],
            cost_units: (n * p * p) as f64,
            gemm_class: true,
        });
        partial_specs.push(SubmitSpec {
            ty: "partial_zty",
            args: vec![(*x).into(), (*y).into()],
            n_outputs: 1,
            out_bytes: vec![vec_bytes(p)],
            cost_units: (n * p) as f64,
            gemm_class: true,
        });
    }
    let partial_refs = sink.submit_batch(partial_specs)?;
    let mut ztzs: Vec<SinkRef> = Vec::with_capacity(cfg.fragments);
    let mut ztys: Vec<SinkRef> = Vec::with_capacity(cfg.fragments);
    for pair in partial_refs.chunks(2) {
        ztzs.push(pair[0][0]);
        ztys.push(pair[1][0]);
    }

    // Merge trees (dark red).
    let merge_tree = |sink: &mut dyn TaskSink,
                      mut parts: Vec<SinkRef>,
                      ty: &'static str,
                      bytes: u64,
                      units: f64|
     -> Result<SinkRef> {
        while parts.len() > 1 {
            let mut next = Vec::with_capacity(parts.len().div_ceil(2));
            let mut it = parts.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(
                        sink.submit(SubmitSpec {
                            ty,
                            args: vec![a.into(), b.into()],
                            n_outputs: 1,
                            out_bytes: vec![bytes],
                            cost_units: units,
                            gemm_class: false,
                        })?[0],
                    ),
                    None => next.push(a),
                }
            }
            parts = next;
        }
        Ok(parts[0])
    };
    let ztz = merge_tree(sink, ztzs, "merge_ztz", mat_bytes(p, p), (p * p) as f64)?;
    let zty = merge_tree(sink, ztys, "merge_zty", vec_bytes(p), p as f64)?;

    // Solve (green).
    let beta = sink.submit(SubmitSpec {
        ty: "compute_model_parameters",
        args: vec![ztz.into(), zty.into()],
        n_outputs: 1,
        out_bytes: vec![vec_bytes(p)],
        cost_units: (p * p * p) as f64,
        gemm_class: true,
    })?[0];

    // Beta is consumed by every prediction task below *and* fetched by the
    // application afterwards: pin it before the consumers are submitted,
    // or the version GC could reclaim it the moment the last prediction
    // finishes (racing the fetch).
    sink.pin(beta)?;

    // Prediction blocks (white LR_genpred, yellow compute_prediction).
    let mut predictions = Vec::with_capacity(cfg.pred_blocks);
    for b in 0..cfg.pred_blocks {
        let gp = sink.submit(SubmitSpec {
            ty: "LR_genpred",
            args: vec![(cfg.seed as i32).into(), (b as i32).into()],
            n_outputs: 2,
            out_bytes: vec![mat_bytes(pn, p), vec_bytes(pn)],
            cost_units: (pn * p) as f64,
            gemm_class: true,
        })?;
        let (xp, ytrue) = (gp[0], gp[1]);
        let yhat = sink.submit(SubmitSpec {
            ty: "compute_prediction",
            args: vec![xp.into(), beta.into()],
            n_outputs: 1,
            out_bytes: vec![vec_bytes(pn)],
            cost_units: (pn * p) as f64,
            gemm_class: true,
        })?[0];
        predictions.push((yhat, ytrue));
    }

    sink.sync(beta)?;
    sink.barrier()?;
    Ok(LinregPlan { beta, predictions })
}

pub struct LinregResult {
    pub beta: RValue,
    /// Max |beta - beta_true|.
    pub beta_max_err: f64,
    /// R^2 of the predictions against ground truth.
    pub r2: f64,
}

pub fn run_linreg(
    rt: &CompssRuntime,
    cfg: &LinregConfig,
    backend: Backend,
) -> Result<LinregResult> {
    let mut sink = LiveSink::new(rt, backend::linreg_task_defs(cfg.shapes, backend));
    let plan = plan_linreg(&mut sink, cfg)?;

    let beta = sink.fetch(plan.beta)?;
    let bvals = beta
        .as_real()
        .ok_or_else(|| anyhow::anyhow!("beta not real"))?;
    let truth = backend::lr_beta_true(cfg.shapes.lr_p);
    let beta_max_err = bvals
        .iter()
        .zip(truth.iter())
        .map(|(b, t)| (b - t).abs())
        .fold(0.0, f64::max);

    // R^2 over all prediction blocks.
    let (mut ss_res, mut ss_tot, mut mean_acc, mut count) = (0.0, 0.0, 0.0, 0usize);
    let mut pairs: Vec<(f64, f64)> = Vec::new();
    for (yhat_ref, ytrue_ref) in &plan.predictions {
        let yhat = sink.fetch(*yhat_ref)?;
        let ytrue = sink.fetch(*ytrue_ref)?;
        for (a, b) in yhat
            .as_real()
            .ok_or_else(|| anyhow::anyhow!("yhat not real"))?
            .iter()
            .zip(ytrue.as_real().ok_or_else(|| anyhow::anyhow!("ytrue"))?)
        {
            pairs.push((*a, *b));
            mean_acc += *b;
            count += 1;
        }
    }
    let mean = mean_acc / count.max(1) as f64;
    for (a, b) in &pairs {
        ss_res += (b - a).powi(2);
        ss_tot += (b - mean).powi(2);
    }
    let r2 = 1.0 - ss_res / ss_tot.max(1e-300);
    Ok(LinregResult {
        beta,
        beta_max_err,
        r2,
    })
}

pub fn run_linreg_local(
    cfg: &LinregConfig,
    workers: u32,
    backend: Backend,
) -> Result<LinregResult> {
    let rt = CompssRuntime::start(RuntimeConfig::local(workers))?;
    let out = run_linreg(&rt, cfg, backend);
    rt.stop()?;
    out
}

/// Expected task counts (DAG-parity tests).
pub fn expected_task_counts(cfg: &LinregConfig) -> Vec<(&'static str, usize)> {
    let merges = cfg.fragments.saturating_sub(1);
    vec![
        ("LR_fill_fragment", cfg.fragments),
        ("partial_ztz", cfg.fragments),
        ("partial_zty", cfg.fragments),
        ("merge_ztz", merges),
        ("merge_zty", merges),
        ("compute_model_parameters", 1),
        ("LR_genpred", cfg.pred_blocks),
        ("compute_prediction", cfg.pred_blocks),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linreg_native_recovers_model() {
        let mut cfg = LinregConfig::small(11);
        cfg.shapes = Shapes {
            lr_frag_n: 200,
            lr_p: 16,
            lr_pred_block: 64,
            ..Shapes::default()
        };
        cfg.fragments = 3;
        cfg.pred_blocks = 2;
        let res = run_linreg_local(&cfg, 4, Backend::Native).unwrap();
        assert!(res.beta_max_err < 0.01, "beta err {}", res.beta_max_err);
        assert!(res.r2 > 0.95, "r2 = {}", res.r2);
    }

    #[test]
    fn nine_task_types_as_figure5() {
        let cfg = LinregConfig::small(1);
        // 8 listed types + the implicit sync = the paper's "nine task types
        // for data loading, partial computation, merging, model fitting,
        // and prediction".
        assert_eq!(expected_task_counts(&cfg).len(), 8);
    }

    #[test]
    fn counts_scale_with_fragments() {
        let mut cfg = LinregConfig::small(1);
        cfg.fragments = 8;
        cfg.pred_blocks = 3;
        let counts = expected_task_counts(&cfg);
        let get = |ty: &str| counts.iter().find(|(t, _)| *t == ty).unwrap().1;
        assert_eq!(get("partial_ztz"), 8);
        assert_eq!(get("merge_ztz"), 7);
        assert_eq!(get("compute_prediction"), 3);
    }
}
