//! Task bodies for the benchmark apps, over two compute backends:
//!
//! * [`Backend::Pjrt`] — the AOT path: jax/Pallas-lowered HLO artifacts
//!   executed through the PJRT runtime (the "Intel MKL" class of §5.2);
//! * [`Backend::Native`] — the reference path: `crate::blas` single-thread
//!   kernels (the "RBLAS" class).
//!
//! Both backends implement identical task semantics; the integration tests
//! cross-check them against each other, and `runtime_hotpath` measures
//! their GEMM ratio (the paper's ≈100x observation).
//!
//! Synthetic data generation lives here too — the paper's apps generate
//! fragments *inside* tasks ("the data is generated on the fly and not read
//! from files", §4.2), so fill tasks take `(seed, index)` literals and are
//! perfectly reproducible.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::api::TaskDef;
use crate::apps::Shapes;
use crate::blas;
use crate::cluster::BlasClass;
use crate::runtime;
use crate::util::prng::Pcg64;
use crate::value::RValue;

use pjrt_bodies::*;

/// Which compute implementation the task bodies use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT HLO artifacts via PJRT (requires `make artifacts`).
    Pjrt,
    /// Pure-Rust reference BLAS.
    Native,
}

impl Backend {
    /// PJRT when artifacts are present, native otherwise.
    pub fn auto() -> Backend {
        if runtime::artifacts_available() {
            Backend::Pjrt
        } else {
            Backend::Native
        }
    }

    /// Map a machine profile's BLAS class to a backend.
    pub fn for_class(class: BlasClass) -> Backend {
        match class {
            BlasClass::Fast => Backend::auto(),
            BlasClass::Reference => Backend::Native,
        }
    }
}

// ---------------------------------------------------------------------------
// Layout helpers (RValue column-major f64 <-> blas row-major f32).
// ---------------------------------------------------------------------------

fn rmat_to_native(v: &RValue) -> Result<blas::Mat> {
    let (data, nrow, ncol) = v
        .as_matrix()
        .ok_or_else(|| anyhow!("expected matrix, got {}", v.type_name()))?;
    let mut m = blas::Mat::new(nrow, ncol);
    for c in 0..ncol {
        for r in 0..nrow {
            m.data[r * ncol + c] = data[c * nrow + r] as f32;
        }
    }
    Ok(m)
}

fn native_to_rmat(m: &blas::Mat) -> RValue {
    let mut col = vec![0f64; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            col[c * m.rows + r] = m.data[r * m.cols + c] as f64;
        }
    }
    RValue::matrix(col, m.rows, m.cols)
}

fn real_vec_f32(v: &RValue) -> Result<Vec<f32>> {
    Ok(v.as_real()
        .ok_or_else(|| anyhow!("expected double vector, got {}", v.type_name()))?
        .iter()
        .map(|x| *x as f32)
        .collect())
}

// ---------------------------------------------------------------------------
// Synthetic data generation (shared by both backends).
// ---------------------------------------------------------------------------

/// KNN training fragment: Gaussian blobs, one center per class.
/// Returns (X (n, d), labels (n,) as doubles 0..classes).
pub fn gen_knn_points(seed: u64, stream: u64, n: usize, d: usize, classes: usize)
    -> (RValue, RValue)
{
    let mut rng = Pcg64::new(seed, stream);
    let mut x = vec![0f64; n * d];
    let mut y = vec![0f64; n];
    for i in 0..n {
        let cls = rng.below(classes as u64) as usize;
        y[i] = cls as f64;
        for j in 0..d {
            let center = if j % classes == cls { 3.0 } else { 0.0 };
            // Column-major store.
            x[j * n + i] = center + rng.normal();
        }
    }
    (RValue::matrix(x, n, d), RValue::Real(y))
}

/// K-means fragment: mixture of `k` unit blobs at spread-out centers.
pub fn gen_kmeans_points(seed: u64, stream: u64, n: usize, d: usize, k: usize) -> RValue {
    let mut rng = Pcg64::new(seed, stream);
    let mut x = vec![0f64; n * d];
    for i in 0..n {
        let blob = rng.below(k as u64) as usize;
        for j in 0..d {
            let center = 6.0 * (((blob * 31 + j * 17) % 13) as f64 - 6.0) / 6.0;
            x[j * n + i] = center + rng.normal();
        }
    }
    RValue::matrix(x, n, d)
}

/// Deterministic initial centroids (first k synthetic points of stream 0).
pub fn gen_kmeans_init(seed: u64, k: usize, d: usize) -> RValue {
    let pts = gen_kmeans_points(seed, u64::MAX, k, d, k);
    pts
}

/// Ground-truth regression coefficients (deterministic, size p).
pub fn lr_beta_true(p: usize) -> Vec<f64> {
    (0..p).map(|j| 0.05 * (j as f64 * 0.7).sin()).collect()
}

/// Linear-regression fragment: X ~ N(0,1), y = X beta + 0.01 noise.
pub fn gen_lr_fragment(seed: u64, stream: u64, n: usize, p: usize) -> (RValue, RValue) {
    let mut rng = Pcg64::new(seed, stream);
    let beta = lr_beta_true(p);
    let mut x = vec![0f64; n * p];
    for i in 0..n {
        for j in 0..p {
            x[j * n + i] = rng.normal();
        }
    }
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..p {
            s += x[j * n + i] * beta[j];
        }
        y[i] = s + 0.01 * rng.normal();
    }
    (RValue::matrix(x, n, p), RValue::Real(y))
}

// ---------------------------------------------------------------------------
// Native compute kernels for the app semantics.
// ---------------------------------------------------------------------------

/// Brute-force k smallest distances per test row.
/// Returns (dists (tb, k) col-major matrix, labels flat row-major Int).
fn native_knn_frag(
    test: &RValue,
    train_x: &RValue,
    train_y: &RValue,
    k: usize,
) -> Result<(RValue, RValue)> {
    let t = rmat_to_native(test)?;
    let tr = rmat_to_native(train_x)?;
    let ty = real_vec_f32(train_y)?;
    anyhow::ensure!(t.cols == tr.cols, "feature dims differ");
    let (tb, tn, d) = (t.rows, tr.rows, t.cols);
    let mut dists = vec![0f64; tb * k];
    let mut labels = vec![0i32; tb * k];
    let mut best: Vec<(f32, i32)> = Vec::with_capacity(k + 1);
    for i in 0..tb {
        best.clear();
        let trow = &t.data[i * d..(i + 1) * d];
        for j in 0..tn {
            let rrow = &tr.data[j * d..(j + 1) * d];
            let mut s = 0f32;
            for (a, b) in trow.iter().zip(rrow.iter()) {
                let diff = a - b;
                s += diff * diff;
            }
            if best.len() < k || s < best[best.len() - 1].0 {
                let pos = best.partition_point(|(bd, _)| *bd <= s);
                best.insert(pos, (s, ty[j] as i32));
                if best.len() > k {
                    best.pop();
                }
            }
        }
        for (r, (bd, bl)) in best.iter().enumerate() {
            dists[r * tb + i] = *bd as f64; // column-major (tb, k)
            labels[i * k + r] = *bl; // row-major flat (tb, k)
        }
    }
    Ok((RValue::matrix(dists, tb, k), RValue::Int(labels)))
}

/// Merge two sorted k-lists per row.
fn native_knn_merge(
    d1: &RValue,
    l1: &RValue,
    d2: &RValue,
    l2: &RValue,
) -> Result<(RValue, RValue)> {
    let (dd1, tb, k) = d1.as_matrix().ok_or_else(|| anyhow!("d1 not matrix"))?;
    let (dd2, tb2, k2) = d2.as_matrix().ok_or_else(|| anyhow!("d2 not matrix"))?;
    anyhow::ensure!(tb == tb2 && k == k2, "merge shape mismatch");
    let ll1 = l1.as_int().ok_or_else(|| anyhow!("l1 not int"))?;
    let ll2 = l2.as_int().ok_or_else(|| anyhow!("l2 not int"))?;
    let mut dists = vec![0f64; tb * k];
    let mut labels = vec![0i32; tb * k];
    for i in 0..tb {
        let (mut a, mut b) = (0usize, 0usize);
        for r in 0..k {
            let da = dd1[a * tb + i];
            let db = dd2[b * tb + i];
            if da <= db {
                dists[r * tb + i] = da;
                labels[i * k + r] = ll1[i * k + a];
                a += 1;
            } else {
                dists[r * tb + i] = db;
                labels[i * k + r] = ll2[i * k + b];
                b += 1;
            }
        }
    }
    Ok((RValue::matrix(dists, tb, k), RValue::Int(labels)))
}

fn native_knn_classify(labels: &RValue, tb: usize, k: usize, classes: usize) -> Result<RValue> {
    let ll = labels.as_int().ok_or_else(|| anyhow!("labels not int"))?;
    anyhow::ensure!(ll.len() == tb * k, "labels length");
    let mut out = vec![0i32; tb];
    let mut votes = vec![0u32; classes];
    for i in 0..tb {
        votes.iter_mut().for_each(|v| *v = 0);
        for r in 0..k {
            let c = ll[i * k + r];
            if (0..classes as i32).contains(&c) {
                votes[c as usize] += 1;
            }
        }
        out[i] = votes
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| **v)
            .map(|(c, _)| c as i32)
            .unwrap_or(0);
    }
    Ok(RValue::Int(out))
}

fn native_kmeans_partial(points: &RValue, centroids: &RValue) -> Result<(RValue, RValue)> {
    let p = rmat_to_native(points)?;
    let c = rmat_to_native(centroids)?;
    anyhow::ensure!(p.cols == c.cols, "dims differ");
    let (n, d, k) = (p.rows, p.cols, c.rows);
    let mut sums = vec![0f64; k * d]; // row-major accumulation
    let mut counts = vec![0f64; k];
    for i in 0..n {
        let row = &p.data[i * d..(i + 1) * d];
        let mut best = (f32::INFINITY, 0usize);
        for j in 0..k {
            let crow = &c.data[j * d..(j + 1) * d];
            let mut s = 0f32;
            for (a, b) in row.iter().zip(crow.iter()) {
                let diff = a - b;
                s += diff * diff;
            }
            if s < best.0 {
                best = (s, j);
            }
        }
        counts[best.1] += 1.0;
        let srow = &mut sums[best.1 * d..(best.1 + 1) * d];
        for (sv, pv) in srow.iter_mut().zip(row.iter()) {
            *sv += *pv as f64;
        }
    }
    // Row-major -> column-major matrix.
    let mut col = vec![0f64; k * d];
    for r in 0..k {
        for cc in 0..d {
            col[cc * k + r] = sums[r * d + cc];
        }
    }
    Ok((RValue::matrix(col, k, d), RValue::Real(counts)))
}

fn native_kmeans_update(sums: &RValue, counts: &RValue, old: &RValue) -> Result<RValue> {
    let (s, k, d) = sums.as_matrix().ok_or_else(|| anyhow!("sums not matrix"))?;
    let c = counts.as_real().ok_or_else(|| anyhow!("counts not real"))?;
    let (o, k2, d2) = old.as_matrix().ok_or_else(|| anyhow!("old not matrix"))?;
    anyhow::ensure!(k == k2 && d == d2 && c.len() == k, "update shape mismatch");
    let mut out = vec![0f64; k * d];
    for r in 0..k {
        for cc in 0..d {
            out[cc * k + r] = if c[r] > 0.0 {
                s[cc * k + r] / c[r]
            } else {
                o[cc * k + r]
            };
        }
    }
    Ok(RValue::matrix(out, k, d))
}

fn elementwise_add(a: &RValue, b: &RValue) -> Result<RValue> {
    match (a, b) {
        (
            RValue::Matrix { data: x, nrow, ncol },
            RValue::Matrix { data: y, nrow: n2, ncol: c2 },
        ) => {
            anyhow::ensure!(nrow == n2 && ncol == c2, "matrix add shape mismatch");
            Ok(RValue::matrix(
                x.iter().zip(y).map(|(p, q)| p + q).collect(),
                *nrow,
                *ncol,
            ))
        }
        (RValue::Real(x), RValue::Real(y)) => {
            anyhow::ensure!(x.len() == y.len(), "vector add length mismatch");
            Ok(RValue::Real(x.iter().zip(y).map(|(p, q)| p + q).collect()))
        }
        _ => anyhow::bail!("cannot add {} and {}", a.type_name(), b.type_name()),
    }
}

// ---------------------------------------------------------------------------
// PJRT bodies. Gated: the `xla` crate only exists in toolchains with the
// artifact pipeline; without the `pjrt` feature the same signatures bail,
// and `Backend::auto()` never selects them (`artifacts_available` is false).
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_bodies {
    use super::*;
    use crate::runtime::tensor;

    pub(super) fn pjrt_knn_frag(
        test: &RValue,
        train_x: &RValue,
        train_y: &RValue,
        tb: usize,
        k: usize,
    ) -> Result<(RValue, RValue)> {
        runtime::with_engine(|eng| {
        let t = tensor::matrix_to_f32_literal(test)?;
        let x = tensor::matrix_to_f32_literal(train_x)?;
        let y = tensor::real_to_f32_literal(train_y)?;
        let outs = eng.execute("knn_frag", &[t, x, y])?;
        Ok((
            tensor::literal_to_matrix(&outs[0], tb, k)?,
            tensor::literal_to_int(&outs[1])?,
        ))
    })
}

pub(super) fn pjrt_knn_merge(
    d1: &RValue,
    l1: &RValue,
    d2: &RValue,
    l2: &RValue,
    tb: usize,
    k: usize,
) -> Result<(RValue, RValue)> {
    runtime::with_engine(|eng| {
        let a = tensor::matrix_to_f32_literal(d1)?;
        let la = tensor::int_to_i32_literal_shaped(l1, &[tb, k])?;
        let b = tensor::matrix_to_f32_literal(d2)?;
        let lb = tensor::int_to_i32_literal_shaped(l2, &[tb, k])?;
        let outs = eng.execute("knn_merge", &[a, la, b, lb])?;
        Ok((
            tensor::literal_to_matrix(&outs[0], tb, k)?,
            tensor::literal_to_int(&outs[1])?,
        ))
    })
}

pub(super) fn pjrt_knn_classify(labels: &RValue, tb: usize, k: usize) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let l = tensor::int_to_i32_literal_shaped(labels, &[tb, k])?;
        let outs = eng.execute("knn_classify", &[l])?;
        tensor::literal_to_int(&outs[0])
    })
}

pub(super) fn pjrt_kmeans_partial(
    points: &RValue,
    centroids: &RValue,
    k: usize,
    d: usize,
) -> Result<(RValue, RValue)> {
    runtime::with_engine(|eng| {
        let p = tensor::matrix_to_f32_literal(points)?;
        let c = tensor::matrix_to_f32_literal(centroids)?;
        let outs = eng.execute("kmeans_partial", &[p, c])?;
        Ok((
            tensor::literal_to_matrix(&outs[0], k, d)?,
            tensor::literal_to_real(&outs[1])?,
        ))
    })
}

pub(super) fn pjrt_kmeans_update(
    sums: &RValue,
    counts: &RValue,
    old: &RValue,
    k: usize,
    d: usize,
) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let s = tensor::matrix_to_f32_literal(sums)?;
        let c = tensor::real_to_f32_literal(counts)?;
        let o = tensor::matrix_to_f32_literal(old)?;
        let outs = eng.execute("kmeans_update", &[s, c, o])?;
        tensor::literal_to_matrix(&outs[0], k, d)
    })
}

pub(super) fn pjrt_merge_add(task: &'static str, a: &RValue, b: &RValue) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let to_lit = |v: &RValue| -> Result<xla::Literal> {
            match v {
                RValue::Matrix { .. } => tensor::matrix_to_f32_literal(v),
                _ => tensor::real_to_f32_literal(v),
            }
        };
        let la = to_lit(a)?;
        let lb = to_lit(b)?;
        let outs = eng.execute(task, &[la, lb])?;
        match a {
            RValue::Matrix { nrow, ncol, .. } => {
                tensor::literal_to_matrix(&outs[0], *nrow, *ncol)
            }
            _ => tensor::literal_to_real(&outs[0]),
        }
    })
}

pub(super) fn pjrt_lr_ztz(x: &RValue, p: usize) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let lx = tensor::matrix_to_f32_literal(x)?;
        let outs = eng.execute("lr_ztz", &[lx])?;
        tensor::literal_to_matrix(&outs[0], p, p)
    })
}

pub(super) fn pjrt_lr_zty(x: &RValue, y: &RValue) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let lx = tensor::matrix_to_f32_literal(x)?;
        let ly = tensor::real_to_f32_literal(y)?;
        let outs = eng.execute("lr_zty", &[lx, ly])?;
        tensor::literal_to_real(&outs[0])
    })
}

pub(super) fn pjrt_lr_solve(ztz: &RValue, zty: &RValue) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let a = tensor::matrix_to_f32_literal(ztz)?;
        let b = tensor::real_to_f32_literal(zty)?;
        let outs = eng.execute("lr_solve", &[a, b])?;
        tensor::literal_to_real(&outs[0])
    })
}

pub(super) fn pjrt_lr_predict(x: &RValue, beta: &RValue) -> Result<RValue> {
    runtime::with_engine(|eng| {
        let lx = tensor::matrix_to_f32_literal(x)?;
        let lb = tensor::real_to_f32_literal(beta)?;
        let outs = eng.execute("lr_predict", &[lx, lb])?;
        tensor::literal_to_real(&outs[0])
    })
}
} // mod pjrt_bodies (feature = "pjrt")

/// Stubs with matching signatures so the task tables compile without the
/// `xla` dependency; unreachable in practice because `Backend::auto()`
/// reports artifacts unavailable when the feature is off.
#[cfg(not(feature = "pjrt"))]
mod pjrt_bodies {
    use super::*;

    fn off<T>() -> Result<T> {
        anyhow::bail!("PJRT support not compiled in (enable the `pjrt` feature)")
    }

    pub(super) fn pjrt_knn_frag(
        _test: &RValue,
        _train_x: &RValue,
        _train_y: &RValue,
        _tb: usize,
        _k: usize,
    ) -> Result<(RValue, RValue)> {
        off()
    }

    pub(super) fn pjrt_knn_merge(
        _d1: &RValue,
        _l1: &RValue,
        _d2: &RValue,
        _l2: &RValue,
        _tb: usize,
        _k: usize,
    ) -> Result<(RValue, RValue)> {
        off()
    }

    pub(super) fn pjrt_knn_classify(_labels: &RValue, _tb: usize, _k: usize) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_kmeans_partial(
        _points: &RValue,
        _centroids: &RValue,
        _k: usize,
        _d: usize,
    ) -> Result<(RValue, RValue)> {
        off()
    }

    pub(super) fn pjrt_kmeans_update(
        _sums: &RValue,
        _counts: &RValue,
        _old: &RValue,
        _k: usize,
        _d: usize,
    ) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_merge_add(_task: &'static str, _a: &RValue, _b: &RValue) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_lr_ztz(_x: &RValue, _p: usize) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_lr_zty(_x: &RValue, _y: &RValue) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_lr_solve(_ztz: &RValue, _zty: &RValue) -> Result<RValue> {
        off()
    }

    pub(super) fn pjrt_lr_predict(_x: &RValue, _beta: &RValue) -> Result<RValue> {
        off()
    }
}

// ---------------------------------------------------------------------------
// Task definition tables (planner type name -> body).
// ---------------------------------------------------------------------------

fn arg_u64(args: &[Arc<RValue>], i: usize) -> Result<u64> {
    args[i]
        .as_f64()
        .map(|x| x as u64)
        .ok_or_else(|| anyhow!("argument {i} is not a scalar"))
}

/// Bodies for the KNN planner's task types.
pub fn knn_task_defs(s: Shapes, backend: Backend) -> Vec<(&'static str, TaskDef)> {
    let (tb, tn, d, k, classes) =
        (s.knn_test_block, s.knn_train_n, s.knn_d, s.knn_k, s.knn_classes);
    vec![
        (
            "KNN_fill_fragment",
            TaskDef::new("KNN_fill_fragment", 2, move |a| {
                let (x, y) = gen_knn_points(arg_u64(a, 0)?, arg_u64(a, 1)?, tn, d, classes);
                Ok(vec![x, y])
            })
            .with_outputs(2),
        ),
        (
            "KNN_fill_test",
            TaskDef::new("KNN_fill_test", 2, move |a| {
                let seed = arg_u64(a, 0)?.wrapping_add(0xF00D);
                let (x, y) = gen_knn_points(seed, arg_u64(a, 1)?, tb, d, classes);
                Ok(vec![x, y])
            })
            .with_outputs(2),
        ),
        (
            "KNN_frag",
            TaskDef::new("KNN_frag", 3, move |a| {
                let (dd, ll) = match backend {
                    Backend::Pjrt => {
                        pjrt_knn_frag(a[0].as_ref(), a[1].as_ref(), a[2].as_ref(), tb, k)?
                    }
                    Backend::Native => {
                        native_knn_frag(a[0].as_ref(), a[1].as_ref(), a[2].as_ref(), k)?
                    }
                };
                Ok(vec![dd, ll])
            })
            .with_outputs(2),
        ),
        (
            "KNN_merge",
            TaskDef::new("KNN_merge", 4, move |a| {
                let (dd, ll) = match backend {
                    Backend::Pjrt => pjrt_knn_merge(
                        a[0].as_ref(),
                        a[1].as_ref(),
                        a[2].as_ref(),
                        a[3].as_ref(),
                        tb,
                        k,
                    )?,
                    Backend::Native => native_knn_merge(
                        a[0].as_ref(),
                        a[1].as_ref(),
                        a[2].as_ref(),
                        a[3].as_ref(),
                    )?,
                };
                Ok(vec![dd, ll])
            })
            .with_outputs(2),
        ),
        (
            "KNN_classify",
            TaskDef::new("KNN_classify", 1, move |a| {
                let out = match backend {
                    Backend::Pjrt => pjrt_knn_classify(a[0].as_ref(), tb, k)?,
                    Backend::Native => native_knn_classify(a[0].as_ref(), tb, k, classes)?,
                };
                Ok(vec![out])
            }),
        ),
    ]
}

/// Bodies for the K-means planner's task types.
pub fn kmeans_task_defs(s: Shapes, backend: Backend) -> Vec<(&'static str, TaskDef)> {
    let (n, d, k) = (s.km_frag_n, s.km_d, s.km_k);
    vec![
        (
            "fill_fragment",
            TaskDef::new("fill_fragment", 2, move |a| {
                Ok(vec![gen_kmeans_points(arg_u64(a, 0)?, arg_u64(a, 1)?, n, d, k)])
            }),
        ),
        (
            "partial_sum",
            TaskDef::new("partial_sum", 2, move |a| {
                let (sums, counts) = match backend {
                    Backend::Pjrt => pjrt_kmeans_partial(a[0].as_ref(), a[1].as_ref(), k, d)?,
                    Backend::Native => native_kmeans_partial(a[0].as_ref(), a[1].as_ref())?,
                };
                Ok(vec![sums, counts])
            })
            .with_outputs(2),
        ),
        (
            "merge",
            TaskDef::new("merge", 4, move |a| {
                let (s2, c2) = match backend {
                    Backend::Pjrt => (
                        pjrt_merge_add("merge_add2_kmsums", a[0].as_ref(), a[2].as_ref())?,
                        pjrt_merge_add("merge_add2_kmcounts", a[1].as_ref(), a[3].as_ref())?,
                    ),
                    Backend::Native => (
                        elementwise_add(a[0].as_ref(), a[2].as_ref())?,
                        elementwise_add(a[1].as_ref(), a[3].as_ref())?,
                    ),
                };
                Ok(vec![s2, c2])
            })
            .with_outputs(2),
        ),
        (
            "update_centroids",
            TaskDef::new("update_centroids", 3, move |a| {
                let out = match backend {
                    Backend::Pjrt => {
                        pjrt_kmeans_update(a[0].as_ref(), a[1].as_ref(), a[2].as_ref(), k, d)?
                    }
                    Backend::Native => {
                        native_kmeans_update(a[0].as_ref(), a[1].as_ref(), a[2].as_ref())?
                    }
                };
                Ok(vec![out])
            }),
        ),
    ]
}

/// Bodies for the linear-regression planner's task types.
pub fn linreg_task_defs(s: Shapes, backend: Backend) -> Vec<(&'static str, TaskDef)> {
    let (n, p, pn) = (s.lr_frag_n, s.lr_p, s.lr_pred_block);
    vec![
        (
            "LR_fill_fragment",
            TaskDef::new("LR_fill_fragment", 2, move |a| {
                let (x, y) = gen_lr_fragment(arg_u64(a, 0)?, arg_u64(a, 1)?, n, p);
                Ok(vec![x, y])
            })
            .with_outputs(2),
        ),
        (
            "partial_ztz",
            TaskDef::new("partial_ztz", 1, move |a| {
                let out = match backend {
                    Backend::Pjrt => pjrt_lr_ztz(a[0].as_ref(), p)?,
                    Backend::Native => {
                        let x = rmat_to_native(a[0].as_ref())?;
                        native_to_rmat(&blas::syrk_t(&x))
                    }
                };
                Ok(vec![out])
            }),
        ),
        (
            "partial_zty",
            TaskDef::new("partial_zty", 2, move |a| {
                let out = match backend {
                    Backend::Pjrt => pjrt_lr_zty(a[0].as_ref(), a[1].as_ref())?,
                    Backend::Native => {
                        let x = rmat_to_native(a[0].as_ref())?;
                        let y = real_vec_f32(a[1].as_ref())?;
                        RValue::Real(
                            blas::gemv_t(&x, &y)?.into_iter().map(|v| v as f64).collect(),
                        )
                    }
                };
                Ok(vec![out])
            }),
        ),
        (
            "merge_ztz",
            TaskDef::new("merge_ztz", 2, move |a| {
                let out = match backend {
                    Backend::Pjrt => {
                        pjrt_merge_add("merge_add2_ztz", a[0].as_ref(), a[1].as_ref())?
                    }
                    Backend::Native => elementwise_add(a[0].as_ref(), a[1].as_ref())?,
                };
                Ok(vec![out])
            }),
        ),
        (
            "merge_zty",
            TaskDef::new("merge_zty", 2, move |a| {
                let out = match backend {
                    Backend::Pjrt => {
                        pjrt_merge_add("merge_add2_zty", a[0].as_ref(), a[1].as_ref())?
                    }
                    Backend::Native => elementwise_add(a[0].as_ref(), a[1].as_ref())?,
                };
                Ok(vec![out])
            }),
        ),
        (
            "compute_model_parameters",
            TaskDef::new("compute_model_parameters", 2, move |a| {
                let out = match backend {
                    Backend::Pjrt => pjrt_lr_solve(a[0].as_ref(), a[1].as_ref())?,
                    Backend::Native => {
                        let ztz = rmat_to_native(a[0].as_ref())?;
                        let zty = real_vec_f32(a[1].as_ref())?;
                        RValue::Real(
                            blas::solve_normal_eqs(&ztz, &zty, 1e-6)?
                                .into_iter()
                                .map(|v| v as f64)
                                .collect(),
                        )
                    }
                };
                Ok(vec![out])
            }),
        ),
        (
            "LR_genpred",
            TaskDef::new("LR_genpred", 2, move |a| {
                let (x, y) = gen_lr_fragment(
                    arg_u64(a, 0)?.wrapping_add(0xBEEF),
                    arg_u64(a, 1)?,
                    pn,
                    p,
                );
                Ok(vec![x, y])
            })
            .with_outputs(2),
        ),
        (
            "compute_prediction",
            TaskDef::new("compute_prediction", 2, move |a| {
                let out = match backend {
                    Backend::Pjrt => pjrt_lr_predict(a[0].as_ref(), a[1].as_ref())?,
                    Backend::Native => {
                        let x = rmat_to_native(a[0].as_ref())?;
                        let b = real_vec_f32(a[1].as_ref())?;
                        RValue::Real(
                            blas::gemv(&x, &b)?.into_iter().map(|v| v as f64).collect(),
                        )
                    }
                };
                Ok(vec![out])
            }),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes_small() -> Shapes {
        Shapes {
            knn_train_n: 64,
            knn_test_block: 16,
            knn_d: 8,
            knn_k: 4,
            knn_classes: 3,
            km_frag_n: 128,
            km_d: 6,
            km_k: 4,
            lr_frag_n: 96,
            lr_p: 12,
            lr_pred_block: 32,
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let (x1, y1) = gen_knn_points(7, 3, 32, 4, 3);
        let (x2, y2) = gen_knn_points(7, 3, 32, 4, 3);
        assert!(x1.identical(&x2) && y1.identical(&y2));
        let (x3, _) = gen_knn_points(7, 4, 32, 4, 3);
        assert!(!x1.identical(&x3), "different streams differ");
    }

    #[test]
    fn native_knn_frag_finds_true_neighbours() {
        let s = shapes_small();
        let (tx, ty) = gen_knn_points(1, 0, s.knn_train_n, s.knn_d, s.knn_classes);
        // Query the training points themselves: nearest neighbour distance 0,
        // nearest label == own label.
        let (d, l) = native_knn_frag(&tx, &tx, &ty, s.knn_k).unwrap();
        let (dd, n, _) = d.as_matrix().unwrap();
        let ll = l.as_int().unwrap();
        let y = ty.as_real().unwrap();
        for i in 0..n {
            assert!(dd[i] < 1e-6, "self-distance row {i}: {}", dd[i]);
            assert_eq!(ll[i * s.knn_k], y[i] as i32);
        }
    }

    #[test]
    fn native_merge_keeps_k_smallest_sorted() {
        let s = shapes_small();
        let (tx, ty) = gen_knn_points(2, 0, s.knn_train_n, s.knn_d, s.knn_classes);
        let (qx, _) = gen_knn_points(2, 9, s.knn_test_block, s.knn_d, s.knn_classes);
        let (d1, l1) = native_knn_frag(&qx, &tx, &ty, s.knn_k).unwrap();
        let (tx2, ty2) = gen_knn_points(2, 1, s.knn_train_n, s.knn_d, s.knn_classes);
        let (d2, l2) = native_knn_frag(&qx, &tx2, &ty2, s.knn_k).unwrap();
        let (dm, _lm) = native_knn_merge(&d1, &l1, &d2, &l2).unwrap();
        let (dd, tb, k) = dm.as_matrix().unwrap();
        let (a1, ..) = d1.as_matrix().unwrap();
        let (a2, ..) = d2.as_matrix().unwrap();
        for i in 0..tb {
            // Rows sorted ascending.
            for r in 1..k {
                assert!(dd[r * tb + i] >= dd[(r - 1) * tb + i]);
            }
            // Global min preserved.
            let m = a1[i].min(a2[i]);
            assert_eq!(dd[i], m);
        }
    }

    #[test]
    fn native_kmeans_partial_counts_everything() {
        let s = shapes_small();
        let pts = gen_kmeans_points(3, 0, s.km_frag_n, s.km_d, s.km_k);
        let init = gen_kmeans_init(3, s.km_k, s.km_d);
        let (sums, counts) = native_kmeans_partial(&pts, &init).unwrap();
        let total: f64 = counts.as_real().unwrap().iter().sum();
        assert_eq!(total as usize, s.km_frag_n);
        let (sm, k, d) = sums.as_matrix().unwrap();
        assert_eq!((k, d), (s.km_k, s.km_d));
        assert!(sm.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn native_linreg_pipeline_recovers_beta() {
        let s = shapes_small();
        let (x, y) = gen_lr_fragment(4, 0, s.lr_frag_n, s.lr_p);
        let xm = rmat_to_native(&x).unwrap();
        let ztz = blas::syrk_t(&xm);
        let zty = blas::gemv_t(&xm, &real_vec_f32(&y).unwrap()).unwrap();
        let beta = blas::solve_normal_eqs(&ztz, &zty, 1e-6).unwrap();
        let truth = lr_beta_true(s.lr_p);
        for (b, t) in beta.iter().zip(truth.iter()) {
            assert!((*b as f64 - t).abs() < 0.02, "{b} vs {t}");
        }
    }

    #[test]
    fn layout_roundtrip() {
        let v = RValue::matrix(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let m = rmat_to_native(&v).unwrap();
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 0), 2.0);
        assert_eq!(m.at(0, 2), 5.0);
        let back = native_to_rmat(&m);
        assert!(back.all_equal(&v, 1e-6));
    }

    #[test]
    fn elementwise_add_checks_shapes() {
        let a = RValue::zeros(2, 2);
        let b = RValue::zeros(2, 3);
        assert!(elementwise_add(&a, &b).is_err());
        let ok = elementwise_add(&RValue::Real(vec![1.0]), &RValue::Real(vec![2.0])).unwrap();
        assert_eq!(ok.as_f64(), Some(3.0));
    }

    #[test]
    fn backend_auto_matches_artifact_presence() {
        let b = Backend::auto();
        if runtime::artifacts_available() {
            assert_eq!(b, Backend::Pjrt);
        } else {
            assert_eq!(b, Backend::Native);
        }
        assert_eq!(Backend::for_class(BlasClass::Reference), Backend::Native);
    }
}
