//! Parallel K-means clustering (§4.2, Figure 4).
//!
//! Fragments are generated independently (`fill_fragment`); each iteration
//! runs `partial_sum` per fragment in parallel, combines the partial
//! (sums, counts) through a hierarchical binary `merge` tree, and updates
//! the global centroids (`update_centroids`). Convergence is decided on the
//! master by comparing successive centroid matrices (`converged` in the
//! paper) — a per-iteration synchronization visible as the black gap in the
//! Figure-10b trace.

use anyhow::Result;

use crate::api::{CompssRuntime, RuntimeConfig};
use crate::apps::backend::{self, Backend};
use crate::apps::{mat_bytes, vec_bytes, LiveSink, Shapes, SinkRef, SubmitSpec, TaskSink};
use crate::value::RValue;

#[derive(Clone, Copy, Debug)]
pub struct KmeansConfig {
    pub fragments: usize,
    /// Fixed iteration count (the scaling benches fix iterations so the
    /// simulated and live DAGs are identical; live runs may stop earlier
    /// when `tol` is reached).
    pub iterations: usize,
    /// Early-stop tolerance on centroid movement (live mode only;
    /// `None` always runs `iterations`).
    pub tol: Option<f64>,
    pub seed: u64,
    pub shapes: Shapes,
}

impl KmeansConfig {
    pub fn small(seed: u64) -> KmeansConfig {
        KmeansConfig {
            fragments: 4,
            iterations: 3,
            tol: None,
            seed,
            shapes: Shapes::from_manifest(),
        }
    }
}

/// Plan one K-means iteration over existing fragment refs; returns the new
/// centroids ref. (Figure 4 is exactly this subgraph.)
pub fn plan_kmeans_iteration(
    sink: &mut dyn TaskSink,
    cfg: &KmeansConfig,
    fragments: &[SinkRef],
    centroids: SinkRef,
) -> Result<SinkRef> {
    let s = cfg.shapes;
    let (k, d, n) = (s.km_k, s.km_d, s.km_frag_n);

    // partial_sum per fragment (white nodes) — one batched submission for
    // the whole partition loop (a single control-lock acquisition on the
    // live runtime).
    let partial_specs: Vec<SubmitSpec> = fragments
        .iter()
        .map(|f| SubmitSpec {
            ty: "partial_sum",
            args: vec![(*f).into(), centroids.into()],
            n_outputs: 2,
            out_bytes: vec![mat_bytes(k, d), vec_bytes(k)],
            cost_units: (n * k * d) as f64,
            gemm_class: false,
        })
        .collect();
    let mut partials: Vec<(SinkRef, SinkRef)> = sink
        .submit_batch(partial_specs)?
        .into_iter()
        .map(|outs| (outs[0], outs[1]))
        .collect();

    // Hierarchical merge tree (red nodes).
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(2));
        let mut it = partials.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => {
                    let outs = sink.submit(SubmitSpec {
                        ty: "merge",
                        args: vec![a.0.into(), a.1.into(), b.0.into(), b.1.into()],
                        n_outputs: 2,
                        out_bytes: vec![mat_bytes(k, d), vec_bytes(k)],
                        cost_units: (k * d) as f64,
                        gemm_class: false,
                    })?;
                    next.push((outs[0], outs[1]));
                }
                None => next.push(a),
            }
        }
        partials = next;
    }
    let (sums, counts) = partials[0];

    // Centroid update.
    let new_centroids = sink.submit(SubmitSpec {
        ty: "update_centroids",
        args: vec![sums.into(), counts.into(), centroids.into()],
        n_outputs: 1,
        out_bytes: vec![mat_bytes(k, d)],
        cost_units: (k * d) as f64,
        gemm_class: false,
    })?[0];
    Ok(new_centroids)
}

/// Plan data generation + `iterations` rounds. Returns (fragments, final
/// centroids).
pub fn plan_kmeans(
    sink: &mut dyn TaskSink,
    cfg: &KmeansConfig,
) -> Result<(Vec<SinkRef>, SinkRef)> {
    let s = cfg.shapes;
    let (k, d, n) = (s.km_k, s.km_d, s.km_frag_n);

    // Fragment generation (blue nodes), batched.
    let fill_specs: Vec<SubmitSpec> = (0..cfg.fragments)
        .map(|f| SubmitSpec {
            ty: "fill_fragment",
            args: vec![(cfg.seed as i32).into(), (f as i32).into()],
            n_outputs: 1,
            out_bytes: vec![mat_bytes(n, d)],
            cost_units: (n * d) as f64,
            gemm_class: false,
        })
        .collect();
    let fragments: Vec<SinkRef> = sink
        .submit_batch(fill_specs)?
        .into_iter()
        .map(|outs| outs[0])
        .collect();

    // Initial centroids: a small fill task of its own.
    let mut centroids = sink.submit(SubmitSpec {
        ty: "init_centroids",
        args: vec![(cfg.seed as i32).into(), 0.into()],
        n_outputs: 1,
        out_bytes: vec![mat_bytes(k, d)],
        cost_units: (k * d) as f64,
        gemm_class: false,
    })?[0];

    for _ in 0..cfg.iterations {
        centroids = plan_kmeans_iteration(sink, cfg, &fragments, centroids)?;
        // The paper's `converged` check synchronizes the centroids each
        // round on the master.
        sink.sync(centroids)?;
    }
    sink.barrier()?;
    Ok((fragments, centroids))
}

pub struct KmeansResult {
    pub centroids: RValue,
    pub iterations_run: usize,
    /// Mean within-cluster movement of the final iteration (live runs).
    pub last_shift: f64,
}

/// Live execution with optional early stopping via `tol`.
pub fn run_kmeans(
    rt: &CompssRuntime,
    cfg: &KmeansConfig,
    backend: Backend,
) -> Result<KmeansResult> {
    let mut defs = backend::kmeans_task_defs(cfg.shapes, backend);
    // init_centroids body (shared generation, deterministic).
    let s = cfg.shapes;
    defs.push((
        "init_centroids",
        crate::api::TaskDef::new("init_centroids", 2, move |a| {
            let seed = a[0].as_f64().unwrap_or(0.0) as u64;
            Ok(vec![backend::gen_kmeans_init(seed, s.km_k, s.km_d)])
        }),
    ));
    let mut sink = LiveSink::new(rt, defs);

    // Mirror plan_kmeans but consult the synced centroids for early stop.
    let (fragments, mut centroids) = {
        // generation + init only (first part of plan_kmeans without loops)
        let fill_specs: Vec<SubmitSpec> = (0..cfg.fragments)
            .map(|f| SubmitSpec {
                ty: "fill_fragment",
                args: vec![(cfg.seed as i32).into(), (f as i32).into()],
                n_outputs: 1,
                out_bytes: vec![mat_bytes(s.km_frag_n, s.km_d)],
                cost_units: (s.km_frag_n * s.km_d) as f64,
                gemm_class: false,
            })
            .collect();
        let frags: Vec<SinkRef> = sink
            .submit_batch(fill_specs)?
            .into_iter()
            .map(|outs| outs[0])
            .collect();
        let init = sink.submit(SubmitSpec {
            ty: "init_centroids",
            args: vec![(cfg.seed as i32).into(), 0.into()],
            n_outputs: 1,
            out_bytes: vec![mat_bytes(s.km_k, s.km_d)],
            cost_units: (s.km_k * s.km_d) as f64,
            gemm_class: false,
        })?[0];
        (frags, init)
    };

    let mut prev: Option<RValue> = None;
    let mut last_shift = f64::INFINITY;
    let mut iterations_run = 0;
    for _ in 0..cfg.iterations {
        centroids = plan_kmeans_iteration(&mut sink, cfg, &fragments, centroids)?;
        sink.sync(centroids)?;
        iterations_run += 1;
        let current = sink.fetch(centroids)?;
        if let Some(p) = &prev {
            last_shift = centroid_shift(p, &current)?;
            if let Some(tol) = cfg.tol {
                if last_shift < tol {
                    break;
                }
            }
        }
        prev = Some(current);
    }
    sink.barrier()?;
    Ok(KmeansResult {
        centroids: sink.fetch(centroids)?,
        iterations_run,
        last_shift,
    })
}

/// Mean Euclidean movement between two centroid matrices — the `converged`
/// criterion.
pub fn centroid_shift(a: &RValue, b: &RValue) -> Result<f64> {
    let (x, k, d) = a.as_matrix().ok_or_else(|| anyhow::anyhow!("a not matrix"))?;
    let (y, k2, d2) = b.as_matrix().ok_or_else(|| anyhow::anyhow!("b not matrix"))?;
    anyhow::ensure!(k == k2 && d == d2, "centroid shapes differ");
    let mut total = 0.0;
    for r in 0..k {
        let mut s = 0.0;
        for c in 0..d {
            let diff = x[c * k + r] - y[c * k + r];
            s += diff * diff;
        }
        total += s.sqrt();
    }
    Ok(total / k as f64)
}

pub fn run_kmeans_local(
    cfg: &KmeansConfig,
    workers: u32,
    backend: Backend,
) -> Result<KmeansResult> {
    let rt = CompssRuntime::start(RuntimeConfig::local(workers))?;
    let out = run_kmeans(&rt, cfg, backend);
    rt.stop()?;
    out
}

/// Expected task counts per config (DAG-parity tests).
pub fn expected_task_counts(cfg: &KmeansConfig) -> Vec<(&'static str, usize)> {
    let merges_per_iter = cfg.fragments.saturating_sub(1);
    vec![
        ("fill_fragment", cfg.fragments),
        ("init_centroids", 1),
        ("partial_sum", cfg.iterations * cfg.fragments),
        ("merge", cfg.iterations * merges_per_iter),
        ("update_centroids", cfg.iterations),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_shapes() -> Shapes {
        Shapes {
            km_frag_n: 256,
            km_d: 8,
            km_k: 4,
            ..Shapes::default()
        }
    }

    #[test]
    fn kmeans_native_converges_on_blobs() {
        let mut cfg = KmeansConfig::small(7);
        cfg.shapes = small_shapes();
        cfg.fragments = 3;
        cfg.iterations = 8;
        cfg.tol = Some(1e-3);
        let res = run_kmeans_local(&cfg, 4, Backend::Native).unwrap();
        assert!(res.iterations_run <= 8);
        assert!(
            res.last_shift < 0.05,
            "did not converge: shift = {}",
            res.last_shift
        );
        let (_, k, d) = res.centroids.as_matrix().unwrap();
        assert_eq!((k, d), (4, 8));
    }

    #[test]
    fn task_counts_match_figure4_pattern() {
        let mut cfg = KmeansConfig::small(1);
        cfg.fragments = 8;
        cfg.iterations = 1;
        let counts = expected_task_counts(&cfg);
        let get = |ty: &str| counts.iter().find(|(t, _)| *t == ty).unwrap().1;
        assert_eq!(get("partial_sum"), 8);
        assert_eq!(get("merge"), 7);
        assert_eq!(get("update_centroids"), 1);
    }

    #[test]
    fn centroid_shift_zero_for_identical() {
        let c = RValue::zeros(3, 2);
        assert_eq!(centroid_shift(&c, &c).unwrap(), 0.0);
    }
}
