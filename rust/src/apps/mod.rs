//! The paper's three benchmark applications (§4), written against the
//! RCOMPSs programming model: K-nearest-neighbours classification, K-means
//! clustering, and linear regression with prediction.
//!
//! ## Planner / sink split
//!
//! Each app is written once as a *planner* — a function that emits task
//! submissions through the [`TaskSink`] trait following exactly the task
//! decomposition of Figures 3-5 (`KNN_fill_fragment` → `KNN_frag` →
//! `KNN_merge` tree → `KNN_classify`, etc.). Two sinks consume planners:
//!
//! * [`LiveSink`] binds task types to real bodies (PJRT artifacts or native
//!   BLAS) and submits to the live [`CompssRuntime`];
//! * `crate::sim::SimSink` materializes the same DAG inside the
//!   discrete-event simulator with calibrated costs.
//!
//! The scale-out numbers of Figures 6-9 therefore run the *same* dependency
//! structure and scheduler decisions as the real executions that validate
//! correctness — the central fidelity property of this reproduction
//! (DESIGN.md §7).

pub mod backend;
pub mod kmeans;
pub mod knn;
pub mod linreg;

use crate::api::{CompssRuntime, DataRef, RegisteredTask, TaskArg, TaskDef};
use crate::value::RValue;
use anyhow::Result;
use std::collections::HashMap;

/// Opaque handle to a planner-level datum.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SinkRef(pub u64);

/// Planner argument: literal or reference.
#[derive(Clone)]
pub enum SinkArg {
    Lit(RValue),
    Ref(SinkRef),
}

impl From<SinkRef> for SinkArg {
    fn from(r: SinkRef) -> SinkArg {
        SinkArg::Ref(r)
    }
}

impl From<f64> for SinkArg {
    fn from(x: f64) -> SinkArg {
        SinkArg::Lit(RValue::scalar(x))
    }
}

impl From<i32> for SinkArg {
    fn from(x: i32) -> SinkArg {
        SinkArg::Lit(RValue::int_scalar(x))
    }
}

/// One task submission as the planners describe it.
pub struct SubmitSpec {
    /// Task type name — drives body lookup, trace colors, DOT labels.
    pub ty: &'static str,
    pub args: Vec<SinkArg>,
    pub n_outputs: usize,
    /// Estimated serialized size of each output (bytes) — the simulator's
    /// I/O model and the locality scheduler need sizes before execution.
    pub out_bytes: Vec<u64>,
    /// Abstract work units (≈ flop count) for the simulator's cost model.
    pub cost_units: f64,
    /// GEMM-heavy task class — the MKL/RBLAS multiplier applies (§5.2).
    pub gemm_class: bool,
}

/// Where planners send their task graph.
pub trait TaskSink {
    fn submit(&mut self, spec: SubmitSpec) -> Result<Vec<SinkRef>>;
    /// Submit a whole partition loop at once, in order. The default simply
    /// loops [`TaskSink::submit`] (so the simulator's DAG is identical);
    /// the live sink overrides it to amortize the runtime's control lock
    /// across the batch.
    fn submit_batch(&mut self, specs: Vec<SubmitSpec>) -> Result<Vec<Vec<SinkRef>>> {
        specs.into_iter().map(|s| self.submit(s)).collect()
    }
    /// Synchronization point on one datum (`compss_wait_on` in the DAGs).
    fn sync(&mut self, r: SinkRef) -> Result<()>;
    /// Declare that `r` will be fetched after planning. The live sink pins
    /// the version so the (default-on) version GC never reclaims it, even
    /// once every task consumer has drained; pure-DAG sinks ignore it, so
    /// live/sim DAG parity is unaffected. Call it *before* submitting the
    /// consumers — a later `sync`/`fetch` pins too late to be safe.
    fn pin(&mut self, _r: SinkRef) -> Result<()> {
        Ok(())
    }
    /// Global barrier (end-of-app `sync` node).
    fn barrier(&mut self) -> Result<()>;
}

/// Live sink: executes planners on a [`CompssRuntime`] with real bodies.
pub struct LiveSink<'rt> {
    rt: &'rt CompssRuntime,
    tasks: HashMap<&'static str, RegisteredTask>,
    refs: HashMap<SinkRef, DataRef>,
    next: u64,
    /// Values fetched by `sync`, retrievable after planning.
    pub fetched: HashMap<SinkRef, RValue>,
}

impl<'rt> LiveSink<'rt> {
    /// Build a live sink with the given task bodies (type name -> def).
    pub fn new(rt: &'rt CompssRuntime, defs: Vec<(&'static str, TaskDef)>) -> LiveSink<'rt> {
        let tasks = defs
            .into_iter()
            .map(|(name, def)| (name, rt.register_task(def)))
            .collect();
        LiveSink {
            rt,
            tasks,
            refs: HashMap::new(),
            next: 0,
            fetched: HashMap::new(),
        }
    }

    /// Fetch a value produced by the plan (waits if still running).
    pub fn fetch(&self, r: SinkRef) -> Result<RValue> {
        if let Some(v) = self.fetched.get(&r) {
            return Ok(v.clone());
        }
        let dref = self
            .refs
            .get(&r)
            .ok_or_else(|| anyhow::anyhow!("unknown sink ref {r:?}"))?;
        self.rt.wait_on(dref)
    }
}

impl TaskSink for LiveSink<'_> {
    fn submit(&mut self, spec: SubmitSpec) -> Result<Vec<SinkRef>> {
        let task = self
            .tasks
            .get(spec.ty)
            .ok_or_else(|| anyhow::anyhow!("no body registered for task type '{}'", spec.ty))?;
        let args: Vec<TaskArg> = spec
            .args
            .iter()
            .map(|a| match a {
                SinkArg::Lit(v) => Ok(TaskArg::Value(v.clone())),
                SinkArg::Ref(r) => {
                    let dref = self
                        .refs
                        .get(r)
                        .ok_or_else(|| anyhow::anyhow!("dangling sink ref {r:?}"))?;
                    Ok(TaskArg::Future(*dref))
                }
            })
            .collect::<Result<_>>()?;
        let outs = self.rt.submit_multi(task, &args)?;
        anyhow::ensure!(
            outs.len() == spec.n_outputs,
            "task '{}': planner declared {} outputs, runtime produced {}",
            spec.ty,
            spec.n_outputs,
            outs.len()
        );
        let mut sink_refs = Vec::with_capacity(outs.len());
        for dref in outs {
            self.next += 1;
            let sr = SinkRef(self.next);
            self.refs.insert(sr, dref);
            sink_refs.push(sr);
        }
        Ok(sink_refs)
    }

    fn submit_batch(&mut self, specs: Vec<SubmitSpec>) -> Result<Vec<Vec<SinkRef>>> {
        // Resolve every argument first (errors surface before anything is
        // submitted), then hand the whole batch to the runtime under one
        // control-lock acquisition.
        let mut calls: Vec<(&RegisteredTask, Vec<TaskArg>)> = Vec::with_capacity(specs.len());
        for spec in &specs {
            let task = self
                .tasks
                .get(spec.ty)
                .ok_or_else(|| anyhow::anyhow!("no body registered for task type '{}'", spec.ty))?;
            let args: Vec<TaskArg> = spec
                .args
                .iter()
                .map(|a| match a {
                    SinkArg::Lit(v) => Ok(TaskArg::Value(v.clone())),
                    SinkArg::Ref(r) => {
                        let dref = self
                            .refs
                            .get(r)
                            .ok_or_else(|| anyhow::anyhow!("dangling sink ref {r:?}"))?;
                        Ok(TaskArg::Future(*dref))
                    }
                })
                .collect::<Result<_>>()?;
            calls.push((task, args));
        }
        let batched = self.rt.submit_batch(&calls)?;
        drop(calls);
        let mut all_refs = Vec::with_capacity(batched.len());
        for (spec, outs) in specs.iter().zip(batched) {
            anyhow::ensure!(
                outs.len() == spec.n_outputs,
                "task '{}': planner declared {} outputs, runtime produced {}",
                spec.ty,
                spec.n_outputs,
                outs.len()
            );
            let mut sink_refs = Vec::with_capacity(outs.len());
            for dref in outs {
                self.next += 1;
                let sr = SinkRef(self.next);
                self.refs.insert(sr, dref);
                sink_refs.push(sr);
            }
            all_refs.push(sink_refs);
        }
        Ok(all_refs)
    }

    fn sync(&mut self, r: SinkRef) -> Result<()> {
        let v = self.fetch(r)?;
        self.fetched.insert(r, v);
        Ok(())
    }

    fn pin(&mut self, r: SinkRef) -> Result<()> {
        let dref = self
            .refs
            .get(&r)
            .ok_or_else(|| anyhow::anyhow!("unknown sink ref {r:?}"))?;
        self.rt.pin(dref)
    }

    fn barrier(&mut self) -> Result<()> {
        self.rt.barrier()
    }
}

/// Shared canonical fragment shapes (mirrors python model.SHAPES; read from
/// the artifact manifest when present so the two sides cannot drift).
#[derive(Clone, Copy, Debug)]
pub struct Shapes {
    pub knn_train_n: usize,
    pub knn_test_block: usize,
    pub knn_d: usize,
    pub knn_k: usize,
    pub knn_classes: usize,
    pub km_frag_n: usize,
    pub km_d: usize,
    pub km_k: usize,
    pub lr_frag_n: usize,
    pub lr_p: usize,
    pub lr_pred_block: usize,
}

impl Default for Shapes {
    fn default() -> Shapes {
        Shapes {
            knn_train_n: 2048,
            knn_test_block: 512,
            knn_d: 64,
            knn_k: 8,
            knn_classes: 10,
            km_frag_n: 4096,
            km_d: 64,
            km_k: 16,
            lr_frag_n: 2048,
            lr_p: 256,
            lr_pred_block: 2048,
        }
    }
}

impl Shapes {
    /// The paper's single-node workload shapes (§5.2): KNN training fixed
    /// at 2000x50 with 2000x50 test per core; K-means 864,000x50 per core;
    /// linreg 80,000x1000 fitting + 20,000x1000 prediction per core. Used
    /// by the simulated Figure-6/7 sweeps (structure is identical to the
    /// artifact shapes; only byte/flop weights differ).
    pub fn paper_single_node() -> Shapes {
        Shapes {
            knn_train_n: 2000,
            knn_test_block: 2000,
            knn_d: 50,
            knn_k: 8,
            knn_classes: 10,
            km_frag_n: 864_000,
            km_d: 50,
            km_k: 16,
            lr_frag_n: 80_000,
            lr_p: 1000,
            lr_pred_block: 20_000,
        }
    }

    /// The paper's multi-node workload shapes (§5.3): KNN test 1.016Mx50
    /// per node (≈8000 per worker), K-means 38.18Mx100 per node (≈300k per
    /// worker), linreg 2.56Mx1000 per node (=20k per worker). Figure-8/9
    /// sweeps.
    pub fn paper_multi_node() -> Shapes {
        Shapes {
            knn_train_n: 2000,
            knn_test_block: 8000,
            knn_d: 50,
            knn_k: 8,
            knn_classes: 10,
            km_frag_n: 300_000,
            km_d: 100,
            km_k: 16,
            lr_frag_n: 20_000,
            lr_p: 1000,
            lr_pred_block: 20_000,
        }
    }

    /// Load from the artifact manifest, falling back to defaults.
    pub fn from_manifest() -> Shapes {
        let mut s = Shapes::default();
        if let Ok(m) = crate::runtime::Manifest::load(&crate::runtime::artifacts_dir()) {
            let get = |k: &str, slot: &mut usize| {
                if let Ok(v) = m.shape(k) {
                    *slot = v;
                }
            };
            get("knn_train_n", &mut s.knn_train_n);
            get("knn_test_block", &mut s.knn_test_block);
            get("knn_d", &mut s.knn_d);
            get("knn_k", &mut s.knn_k);
            get("knn_classes", &mut s.knn_classes);
            get("km_frag_n", &mut s.km_frag_n);
            get("km_d", &mut s.km_d);
            get("km_k", &mut s.km_k);
            get("lr_frag_n", &mut s.lr_frag_n);
            get("lr_p", &mut s.lr_p);
            get("lr_pred_block", &mut s.lr_pred_block);
        }
        s
    }
}

/// Bytes of an f64 matrix payload plus codec overhead (≈ wire size).
pub(crate) fn mat_bytes(nrow: usize, ncol: usize) -> u64 {
    (nrow * ncol * 8 + 64) as u64
}

pub(crate) fn vec_bytes(len: usize) -> u64 {
    (len * 8 + 64) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_default_matches_python_model() {
        let s = Shapes::default();
        assert_eq!(s.knn_train_n, 2048);
        assert_eq!(s.km_k, 16);
        assert_eq!(s.lr_p, 256);
    }

    #[test]
    fn shapes_from_manifest_agrees_when_present() {
        // When artifacts exist, manifest values must equal the defaults
        // (drift between python SHAPES and Shapes::default is a bug).
        if crate::runtime::artifacts_available() {
            let m = Shapes::from_manifest();
            let d = Shapes::default();
            assert_eq!(m.knn_train_n, d.knn_train_n);
            assert_eq!(m.knn_test_block, d.knn_test_block);
            assert_eq!(m.km_frag_n, d.km_frag_n);
            assert_eq!(m.lr_frag_n, d.lr_frag_n);
            assert_eq!(m.lr_p, d.lr_p);
        }
    }

    #[test]
    fn sink_arg_conversions() {
        let a: SinkArg = 3.5f64.into();
        assert!(matches!(a, SinkArg::Lit(_)));
        let r: SinkArg = SinkRef(7).into();
        assert!(matches!(r, SinkArg::Ref(SinkRef(7))));
    }
}
