//! The virtual-time execution engine.
//!
//! A list-scheduling discrete-event simulation: ready tasks are routed to
//! per-node shards by the *same* [`PlacementModel`] the live dispatch
//! fabric runs (via [`RoutedReady`], the single-threaded sibling of
//! `ShardedReady`); workers become available per the profile's staggered
//! init; when a worker idles, the *real* `Scheduler` policy picks the next
//! ready task from its node's shard (stealing in the live fabric's ring
//! order); the task's timeline is assembled from the cost model (transfers
//! for non-local inputs, FCFS per-node disk I/O for
//! deserialization/serialization, compute scaled by BLAS class);
//! completions feed the *real* `TaskGraph` readiness propagation. Every
//! interval is recorded through the ordinary tracer, so
//! `Trace::ascii_timeline` renders simulated Figure-10 views. Because
//! routing goes through the shared placement engine, a simulated placement
//! is exactly what the live runtime would decide for the same push
//! sequence *and the same signals* — the equivalence the placement
//! property test pins. One signal differs by construction: the simulator
//! charges transfers at claim time, so its in-flight pressure reads as
//! zero, and a live `cost` run with movers mid-transfer can prefer the
//! transfer's destination where the sim sees a tie.
//!
//! Tasks are simulated in two phases so the per-node disk server is only
//! reserved when I/O actually happens: the read+compute phase is scheduled
//! at claim time (reads begin immediately), and the write phase is
//! scheduled by an `ExecDone` event at compute completion — otherwise a
//! claim would pre-reserve the disk far into the future and serialize
//! every other worker on the node behind it.
//!
//! # Schedule fuzzing
//!
//! The event heap breaks timestamp ties by insertion order — one arbitrary
//! schedule out of the many the live runtime's threads could realize.
//! [`SimEngine::with_fuzz_seed`] installs a `SchedulePerturbation` layer
//! on the heap: events with equal timestamps (and, under
//! [`SimEngine::with_fuzz_jitter`], events within a bounded virtual-time
//! window) are delivered in a seeded-PRNG permutation instead. Every seed
//! is a distinct but *fully deterministic* schedule — re-running the same
//! plan with the same seed replays a byte-identical event order — and
//! [`SimEngine::fuzz_sweep`] drives a whole set of seeds through one plan,
//! asserting schedule-independence invariants (every task completes, no
//! dead version bytes, the final data-plane digest is byte-identical
//! across seeds) and naming the minimal failing seed on violation.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::sync::Arc;

use anyhow::Result;

use crate::cluster::ClusterSpec;
use crate::coordinator::compile::{self, WindowCtx, WindowTask};
use crate::coordinator::dag::{TaskGraph, TaskId, TaskState};
use crate::coordinator::feedback::FeedbackStats;
use crate::coordinator::placement::{placement_by_name, PlacementModel, RoutedReady};
use crate::coordinator::registry::{DataKey, DataRegistry, NodeId};
use crate::coordinator::scheduler::ReadyTask;
use crate::sim::cost::CostModel;
use crate::sim::sink::{SimPlan, SimTaskMeta};
use crate::trace::{EventKind, Trace, Tracer, WorkerId};
use crate::util::prng::Pcg64;

/// Totally-ordered f64 for the event heap. The engine validates the cost
/// model up front ([`CostModel::validate`]), so the `expect` below is a
/// backstop, not the user-facing failure mode for a poisoned model.
#[derive(Clone, Copy, PartialEq)]
struct Time(f64);

impl Eq for Time {}

impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time in simulator")
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Event {
    /// Worker finished init or its current task's write phase. Carries
    /// the node's liveness epoch at scheduling time: an event from before
    /// a kill/join transition is stale and is dropped on arrival.
    WorkerIdle(WorkerId, u32),
    /// A task's compute finished; reserve its output I/O now.
    ExecDone(TaskId, WorkerId),
    /// Task fully finished (outputs on disk): propagate readiness.
    TaskDone(TaskId, WorkerId),
    /// Node-loss chaos: the node's workers vanish, its replicas are
    /// dropped, lost sole-replica versions are re-derived from lineage.
    NodeKill(NodeId),
    /// Elasticity: a previously-killed node rejoins (workers re-init).
    NodeJoin(NodeId),
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

/// Simulation outcome.
pub struct SimReport {
    pub makespan_s: f64,
    pub tasks_done: usize,
    /// Per task type: (count, total compute seconds).
    pub per_type: HashMap<String, (usize, f64)>,
    pub total_io_s: f64,
    pub total_transfer_s: f64,
    /// Transfers staged from the warm tier's cached blob (2nd..Nth replica
    /// of a fan-out): link time only, no disk materialization charged.
    pub transfer_warm_hits: usize,
    pub trace: Trace,
    /// Mean worker utilization (busy / span).
    pub utilization: f64,
    /// The schedule-fuzz seed this run executed under (`None` = the
    /// deterministic insertion-order schedule).
    pub fuzz_seed: Option<u64>,
    /// Fully-consumed version bytes left unreclaimed at quiescence. The
    /// simulator never registers consumer releases, so anything nonzero is
    /// a registry bookkeeping bug; the live transfer/GC accounting twin of
    /// this invariant is asserted by `tests/fuzz_schedules.rs`.
    pub dead_version_bytes: u64,
    /// Order-insensitive digest of the final data-plane state
    /// ([`SimPlan::result_digest`]): byte-identical across fuzz seeds when
    /// the schedule only reordered legal ties.
    pub result_digest: u64,
    /// Tasks retired by the window compiler's dead-task cull, counted
    /// into `tasks_done` (culled work is work no schedule has to run).
    pub window_culled: usize,
    /// Fusion links the window compiler applied (member count).
    pub window_fused: usize,
    /// Placement-model verdicts issued: one per greedy push, one per
    /// compiled window — the compiler's N→1 collapse shows up here.
    pub placement_verdicts: u64,
}

/// The engine.
#[derive(Clone)]
pub struct SimEngine {
    pub cluster: ClusterSpec,
    pub cost: CostModel,
    pub scheduler_name: String,
    /// Placement model routing ready tasks to node shards — the same
    /// engine the live runtime's `--router` selects.
    pub router_name: String,
    /// Model the live runtime's warm (serialized-blob) tier in the
    /// virtual transfer timing (default on, matching the live default):
    /// the first transfer of a version is a cold miss — it materializes
    /// the serialized bytes, charged against the destination's disk
    /// server, exactly the file-staging round-trip — while every later
    /// transfer of the same version ships the cached blob and pays link
    /// time only. Off = every transfer pays the file-staging cost (the
    /// pre-tier behavior, `--warm-budget 0`).
    pub warm_staging: bool,
    /// Collect a trace (disable for big sweeps to save memory).
    pub trace: bool,
    /// Chaos: kill this node at the given virtual time (`(seconds, node)`).
    pub node_kill: Option<(f64, u32)>,
    /// Elasticity: rejoin this node at the given virtual time (its workers
    /// pay the init stagger again).
    pub node_join: Option<(f64, u32)>,
    /// Schedule fuzzing: pop timestamp-tied events in a seeded permutation
    /// (see the module docs). `None` = insertion order.
    pub fuzz_seed: Option<u64>,
    /// Fuzz reorder window in virtual seconds (default 0.0: permute exact
    /// ties only, which is always a legal schedule). A nonzero window
    /// additionally swaps events up to that far apart, deliberately
    /// exploring bounded non-monotonic delivery — the engine's FCFS
    /// servers all advance with `max()`, so a robust plan must still
    /// drain.
    pub fuzz_jitter_s: f64,
    /// Run the window compiler over the static plan before execution —
    /// the simulated twin of the live `--compile window` (see
    /// [`crate::coordinator::compile`]): dead-task culling, sub-threshold
    /// chain fusion (members run master-dispatch-free on their head's
    /// shard, the intermediate never publishes), and whole-window
    /// placement (one model verdict per 64-task window).
    pub compile: bool,
}

/// Seeded tie-permutation layer over the event heap. When armed, the heap
/// is popped through this: the front event plus everything within
/// `jitter_s` of it is drained into a batch, shuffled by the seeded PRNG,
/// and delivered in that order. Pop order is a pure function of
/// (plan, seed), so any violation a seed uncovers replays byte-identically
/// from that seed. `scratch` is reused across batches: the fuzz layer adds
/// no steady-state allocations to the hot heap path.
struct SchedulePerturbation {
    rng: Pcg64,
    jitter_s: f64,
    batch: VecDeque<(Time, u64, Event)>,
    scratch: Vec<(Time, u64, Event)>,
}

struct RunState<'a> {
    /// The plan, borrow-split so task metadata can be read (`meta` is
    /// immutable for the whole run) while the graph and registry mutate —
    /// this is what lets the hot path hand out `&SimTaskMeta` references
    /// instead of deep-cloning every task's input/output vectors twice.
    graph: &'a mut TaskGraph,
    registry: &'a mut DataRegistry,
    meta: &'a HashMap<TaskId, SimTaskMeta>,
    router: RoutedReady,
    events: BinaryHeap<Reverse<(Time, u64, Event)>>,
    seq: u64,
    fuzz: Option<SchedulePerturbation>,
    disk_free: Vec<f64>,
    /// Shared parallel-filesystem backend (writes funnel through it).
    fs_free: f64,
    /// Global FCFS master dispatch server (single COMPSs master process).
    master_free: f64,
    busy: Vec<f64>,
    /// Interned type-name keys: one `Arc` clone per *type*, not one
    /// `String` allocation per *task*.
    per_type: HashMap<Arc<str>, (usize, f64)>,
    total_io: f64,
    total_transfer: f64,
    /// Claim start per running task, indexed by dense `TaskId` (NaN = not
    /// running). Task ids are allocated sequentially from 1, so a flat
    /// vector replaces the per-task hash insert/remove pair on the hot
    /// path.
    started_at: Vec<f64>,
    /// Worker owning each in-flight task (same dense indexing); the kill
    /// handler resubmits what the dead node was running, and stale
    /// ExecDone/TaskDone events (their task no longer maps to them) are
    /// dropped on arrival.
    running_on: Vec<Option<WorkerId>>,
    /// Per-node liveness (chaos); dead nodes take no pops and no pushes.
    dead: Vec<bool>,
    /// Per-node liveness epoch, bumped at every kill/join: worker events
    /// scheduled under an older epoch are stale.
    epoch: Vec<u32>,
    idle: Vec<WorkerId>,
    tracer: Tracer,
    wpn: usize,
    /// Versions whose serialized blob already exists (first transfer done):
    /// the sim's stand-in for the live warm tier's lazy fill.
    warm_staged: HashSet<DataKey>,
    warm_hits: usize,
    /// Observation sink of an `adaptive` router: the simulator feeds it
    /// its *virtual* transfer timings and task durations, so the model
    /// learns in simulation exactly as it does live.
    feedback: Option<Arc<FeedbackStats>>,
    /// Window-compiler shard assignments, consumed on first push (the
    /// sim's `core.placement`); a resubmission after chaos re-routes
    /// greedily, exactly like the live fabric.
    placement_plan: HashMap<TaskId, usize>,
    /// Fused chain members: claimed inline by their head's worker live,
    /// so the sim charges them no master-dispatch round-trip.
    fused_member: HashSet<TaskId>,
    /// Fused intermediates: handed worker-local, never published — no
    /// write I/O, no read staging, no registry availability.
    fused_keys: HashSet<DataKey>,
    /// Placement-model verdicts (greedy pushes + window anchors).
    placement_verdicts: u64,
}

/// Dense vector index for a `TaskId` (ids are allocated from 1).
#[inline]
fn tix(id: TaskId) -> usize {
    id.0 as usize
}

impl RunState<'_> {
    fn push_event(&mut self, t: f64, e: Event) {
        self.seq += 1;
        self.events.push(Reverse((Time(t), self.seq, e)));
    }

    /// Pop the next event, optionally through the fuzz permutation layer.
    fn next_event(&mut self) -> Option<(f64, Event)> {
        let events = &mut self.events;
        let Some(fz) = self.fuzz.as_mut() else {
            return events.pop().map(|Reverse((Time(t), _, e))| (t, e));
        };
        if let Some((Time(t), _, e)) = fz.batch.pop_front() {
            return Some((t, e));
        }
        let Reverse(first) = events.pop()?;
        let horizon = first.0 .0 + fz.jitter_s;
        fz.scratch.clear();
        fz.scratch.push(first);
        while let Some(Reverse((t, _, _))) = events.peek() {
            if t.0 <= horizon {
                let Reverse(next) = events.pop().expect("peeked event");
                fz.scratch.push(next);
            } else {
                break;
            }
        }
        if fz.scratch.len() > 1 {
            fz.rng.shuffle(&mut fz.scratch);
        }
        fz.batch.extend(fz.scratch.drain(..));
        fz.batch.pop_front().map(|(Time(t), _, e)| (t, e))
    }
}

impl SimEngine {
    pub fn new(cluster: ClusterSpec, cost: CostModel) -> SimEngine {
        SimEngine {
            cluster,
            cost,
            scheduler_name: "fifo".into(),
            router_name: "bytes".into(),
            warm_staging: true,
            trace: false,
            node_kill: None,
            node_join: None,
            fuzz_seed: None,
            fuzz_jitter_s: 0.0,
            compile: false,
        }
    }

    /// Kill `node` at virtual time `at_s`: its workers vanish, running
    /// tasks resubmit, lost sole-replica versions re-derive from lineage —
    /// the simulated twin of the live `--chaos node-kill`.
    pub fn with_node_kill(mut self, at_s: f64, node: u32) -> SimEngine {
        self.node_kill = Some((at_s, node));
        self
    }

    /// Rejoin a previously-killed `node` at virtual time `at_s` (the live
    /// `Coordinator::add_node`).
    pub fn with_node_join(mut self, at_s: f64, node: u32) -> SimEngine {
        self.node_join = Some((at_s, node));
        self
    }

    pub fn with_scheduler(mut self, name: &str) -> SimEngine {
        self.scheduler_name = name.into();
        self
    }

    /// Placement model: "bytes" | "cost" | "roundrobin" | "adaptive" (the
    /// live `--router` knob). The adaptive model learns from the
    /// simulator's virtual transfer timings and task durations.
    pub fn with_router(mut self, name: &str) -> SimEngine {
        self.router_name = name.into();
        self
    }

    /// Warm-tier transfer staging (the live `--warm-budget` knob's timing
    /// consequence): `false` reproduces file-backed staging for every
    /// transfer.
    pub fn with_warm(mut self, on: bool) -> SimEngine {
        self.warm_staging = on;
        self
    }

    pub fn with_trace(mut self, on: bool) -> SimEngine {
        self.trace = on;
        self
    }

    /// Arm the schedule fuzzer: timestamp-tied events pop in a permutation
    /// drawn from this seed (see the module docs). The same (plan, seed)
    /// pair replays a byte-identical event order, so a violation found in
    /// a sweep reproduces from its printed seed alone. The CLI spelling is
    /// `rcompss sim --fuzz-seed N`.
    pub fn with_fuzz_seed(mut self, seed: u64) -> SimEngine {
        self.fuzz_seed = Some(seed);
        self
    }

    /// Widen the fuzz permutation from exact ties to a virtual-time window
    /// of `seconds`: events up to that far apart may be delivered out of
    /// order (bounded non-monotonic delivery — the live runtime's threads
    /// have no global clock either). Only meaningful with a fuzz seed.
    pub fn with_fuzz_jitter(mut self, seconds: f64) -> SimEngine {
        self.fuzz_jitter_s = seconds.max(0.0);
        self
    }

    /// Arm the window compiler (the live `--compile window` knob): the
    /// static plan is compiled in 64-task windows before virtual time
    /// starts — dead tasks culled, sub-threshold chains fused, one
    /// placement verdict per window.
    pub fn with_compile(mut self, on: bool) -> SimEngine {
        self.compile = on;
        self
    }

    /// Drive one plan through a whole set of fuzz seeds, asserting the
    /// invariants a schedule permutation must never break:
    ///
    /// * the run drains (no stuck tasks — `run` itself enforces
    ///   quiescence);
    /// * every structural task completed (`tasks_done >=` the plan size;
    ///   strictly more only under chaos re-runs);
    /// * `dead_version_bytes == 0` (no unreclaimed fully-consumed
    ///   versions);
    /// * the final data-plane digest is byte-identical across seeds
    ///   (skipped when node kill/join chaos is armed: recovery re-runs
    ///   legitimately vary per schedule). The live-plane twin of this
    ///   sweep — transfer-board accounting,
    ///   `prefetched + waited + dropped + failed == requested` — is
    ///   asserted by `tests/fuzz_schedules.rs` through the yield-point
    ///   hooks.
    ///
    /// `make_plan` rebuilds the plan for each seed (a run consumes its
    /// plan); the plan builders are deterministic, so every rebuild is the
    /// same DAG. On any violation the error names the **minimal failing
    /// seed** — re-run `with_fuzz_seed(that_seed)` on the same plan to
    /// replay the identical event order and violation.
    pub fn fuzz_sweep(
        &self,
        seeds: &[u64],
        mut make_plan: impl FnMut() -> Result<SimPlan>,
        label: &str,
    ) -> Result<Vec<SimReport>> {
        anyhow::ensure!(!seeds.is_empty(), "fuzz_sweep needs at least one seed");
        let chaos = self.node_kill.is_some() || self.node_join.is_some();
        let mut reports = Vec::with_capacity(seeds.len());
        let mut failures: Vec<(u64, String)> = Vec::new();
        let mut baseline: Option<(u64, u64)> = None;
        for &seed in seeds {
            let plan = make_plan()?;
            let expected = plan.graph.len();
            let mut engine = self.clone();
            engine.fuzz_seed = Some(seed);
            match engine.run(plan, &format!("{label}#fuzz{seed}")) {
                Err(e) => failures.push((seed, format!("run failed: {e:#}"))),
                Ok(report) => {
                    if report.dead_version_bytes != 0 {
                        failures.push((
                            seed,
                            format!("dead_version_bytes = {}", report.dead_version_bytes),
                        ));
                    } else if report.tasks_done < expected {
                        failures.push((
                            seed,
                            format!("only {} of {expected} tasks completed", report.tasks_done),
                        ));
                    } else if !chaos {
                        match baseline {
                            None => baseline = Some((seed, report.result_digest)),
                            Some((s0, d0)) if report.result_digest != d0 => {
                                failures.push((
                                    seed,
                                    format!(
                                        "result digest {:#018x} diverged from seed {s0}'s {d0:#018x}",
                                        report.result_digest
                                    ),
                                ));
                            }
                            Some(_) => {}
                        }
                    }
                    reports.push(report);
                }
            }
        }
        if !failures.is_empty() {
            failures.sort_by_key(|(s, _)| *s);
            let (min_seed, msg) = &failures[0];
            anyhow::bail!(
                "schedule fuzz '{label}': {}/{} seeds violated invariants; \
                 minimal failing seed {min_seed} ({msg}). Replay with \
                 SimEngine::with_fuzz_seed({min_seed}) on the same plan — \
                 the event order is byte-identical run over run.",
                failures.len(),
                seeds.len()
            );
        }
        Ok(reports)
    }

    /// Execute a plan to completion in virtual time.
    pub fn run(&self, mut plan: SimPlan, label: &str) -> Result<SimReport> {
        // A NaN/negative constant anywhere in the cost model would
        // otherwise surface as a `Time` ordering panic deep in the event
        // heap; reject it here with the offending field named.
        self.cost
            .validate()
            .map_err(|e| anyhow::anyhow!("invalid cost model: {e}"))?;
        let profile = &self.cluster.profile;
        let nodes = self.cluster.nodes as usize;
        let wpn = self.cluster.workers_per_node as usize;
        let model: Arc<dyn PlacementModel> =
            placement_by_name(&self.router_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown router '{}' (bytes|cost|roundrobin|adaptive)",
                    self.router_name
                )
            })?;
        let feedback = model.feedback();
        let mut router = RoutedReady::new(&self.scheduler_name, nodes as u32, model)
            .ok_or_else(|| anyhow::anyhow!("unknown scheduler '{}'", self.scheduler_name))?;

        let SimPlan {
            graph,
            registry,
            meta,
            initially_ready,
            ..
        } = &mut plan;
        let meta: &HashMap<TaskId, SimTaskMeta> = meta;
        let n_tasks = graph.len();
        let init: Vec<TaskId> = initially_ready.clone();

        // ---- window compilation (the live `--compile window` twin) ------
        // The sim driver "submits" the whole plan before the first wait,
        // so consumer counts and supersession are exact over the full
        // read set — the static analogue of the live flush-time
        // version-table snapshot.
        let mut placement_plan: HashMap<TaskId, usize> = HashMap::new();
        let mut fused_member: HashSet<TaskId> = HashSet::new();
        let mut fused_keys: HashSet<DataKey> = HashSet::new();
        let mut window_culled = 0usize;
        let mut window_fused = 0usize;
        let mut compile_verdicts = 0u64;
        if self.compile {
            let mut consumers: HashMap<DataKey, u32> = HashMap::new();
            let mut out_bytes: HashMap<DataKey, u64> = HashMap::new();
            for m in meta.values() {
                for k in &m.inputs {
                    *consumers.entry(*k).or_insert(0) += 1;
                }
                for (k, b) in &m.outputs {
                    if *b > 0 {
                        out_bytes.insert(*k, *b);
                    }
                }
            }
            let order: Vec<TaskId> = graph.tasks_in_order().map(|t| t.id).collect();
            for chunk in order.chunks(compile::WINDOW_CAP) {
                let mut tasks: Vec<WindowTask> = Vec::with_capacity(chunk.len());
                let mut ctx = WindowCtx::default();
                for id in chunk {
                    let m = meta.get(id).expect("task meta");
                    for k in &m.inputs {
                        ctx.consumers
                            .insert(*k, consumers.get(k).copied().unwrap_or(0));
                        if let Some(b) = out_bytes.get(k) {
                            ctx.bytes.insert(*k, *b);
                        } else if let Some(info) = registry.info(*k) {
                            if info.bytes > 0 {
                                ctx.bytes.insert(*k, info.bytes);
                            }
                        }
                    }
                    for (k, _) in &m.outputs {
                        ctx.consumers
                            .insert(*k, consumers.get(k).copied().unwrap_or(0));
                        if let Some(b) = out_bytes.get(k) {
                            ctx.bytes.insert(*k, *b);
                        }
                        if registry.latest_key(k.data) != Some(*k) {
                            ctx.superseded.insert(*k);
                        }
                    }
                    let node = graph.node(*id).expect("window task in graph");
                    for d in &node.dependents {
                        if graph.node(*d).map_or(false, |dn| dn.pending_deps == 1) {
                            ctx.sole_gate.insert((*d, *id));
                        }
                    }
                    tasks.push(WindowTask {
                        id: *id,
                        type_name: Arc::clone(&m.ty),
                        inputs: m.inputs.clone(),
                        outputs: m.outputs.iter().map(|(k, _)| *k).collect(),
                    });
                }
                let wplan = compile::compile_window(&tasks, &ctx);
                // No waiters exist in the sim, so every cull commits.
                for id in &wplan.culled {
                    graph.cull(*id);
                }
                window_culled += wplan.culled.len();
                for l in &wplan.fused {
                    fused_member.insert(l.member);
                    fused_keys.insert(l.key);
                }
                window_fused += wplan.fused.len();
                // One placement verdict anchors the window; units spread
                // round-robin from it and members ride their head's shard
                // transitively down the chain.
                if !wplan.units.is_empty() {
                    let agg_inputs: Vec<(u64, Vec<NodeId>)> = wplan
                        .units
                        .iter()
                        .flat_map(|u| meta.get(u).expect("unit meta").inputs.iter())
                        .filter_map(|k| {
                            registry.info(*k).map(|i| (i.bytes, i.locations))
                        })
                        .collect();
                    let anchor = router.place_window(&ReadyTask {
                        id: wplan.units[0],
                        inputs: agg_inputs,
                        type_name: Arc::clone(
                            &meta.get(&wplan.units[0]).expect("unit meta").ty,
                        ),
                    });
                    compile_verdicts += 1;
                    let mut shard = anchor;
                    for u in &wplan.units {
                        placement_plan.insert(*u, shard);
                        let mut h = *u;
                        while let Some(l) = wplan.fused.iter().find(|l| l.head == h) {
                            placement_plan.insert(l.member, shard);
                            h = l.member;
                        }
                        shard = (shard + 1) % nodes;
                    }
                }
            }
        }
        let mut st = RunState {
            graph,
            registry,
            meta,
            router,
            events: BinaryHeap::new(),
            seq: 0,
            fuzz: self.fuzz_seed.map(|seed| SchedulePerturbation {
                rng: Pcg64::new(seed, 0x5EED),
                jitter_s: self.fuzz_jitter_s.max(0.0),
                batch: VecDeque::new(),
                scratch: Vec::new(),
            }),
            disk_free: vec![0.0; nodes],
            fs_free: 0.0,
            master_free: 0.0,
            busy: vec![0.0; nodes * wpn],
            per_type: HashMap::new(),
            total_io: 0.0,
            total_transfer: 0.0,
            started_at: vec![f64::NAN; n_tasks + 1],
            running_on: vec![None; n_tasks + 1],
            dead: vec![false; nodes],
            epoch: vec![0; nodes],
            idle: Vec::new(),
            tracer: Tracer::new(self.trace),
            wpn,
            warm_staged: HashSet::new(),
            warm_hits: 0,
            feedback,
            placement_plan,
            fused_member,
            fused_keys,
            placement_verdicts: compile_verdicts,
        };
        if self.compile {
            // Culls may have promoted downstream tasks: route everything
            // Ready after compilation, not just the plan's original
            // frontier.
            let ready_now: Vec<TaskId> = st
                .graph
                .tasks_in_order()
                .filter(|t| t.state == TaskState::Ready)
                .map(|t| t.id)
                .collect();
            for id in ready_now {
                push_ready(&mut st, id);
            }
        } else {
            for id in init {
                push_ready(&mut st, id);
            }
        }
        for node in 0..nodes {
            for slot in 0..wpn {
                let wid = WorkerId {
                    node: NodeId(node as u32),
                    slot: slot as u32,
                };
                let ready_at = profile.worker_ready_at(slot as u32);
                st.tracer.record_at(wid, EventKind::WorkerInit, None, 0.0, ready_at);
                st.push_event(ready_at, Event::WorkerIdle(wid, 0));
            }
        }
        if let Some((t, node)) = self.node_kill {
            st.push_event(t.max(0.0), Event::NodeKill(NodeId(node)));
        }
        if let Some((t, node)) = self.node_join {
            st.push_event(t.max(0.0), Event::NodeJoin(NodeId(node)));
        }

        let mut tasks_done = 0usize;
        let mut makespan = 0.0f64;

        while let Some((now, ev)) = st.next_event() {
            makespan = makespan.max(now);
            match ev {
                Event::WorkerIdle(wid, epoch) => {
                    let node = wid.node.0 as usize;
                    if st.dead[node] || st.epoch[node] != epoch {
                        continue; // the worker died with its node
                    }
                    if let Some(tid) = pop_live(&mut st, wid.node) {
                        self.begin_task(&mut st, tid, wid, now);
                    } else {
                        st.idle.push(wid);
                    }
                }
                Event::ExecDone(tid, wid) => {
                    if st.running_on[tix(tid)] != Some(wid) {
                        continue; // stale: the attempt died with its node
                    }
                    self.finish_task(&mut st, tid, wid, now);
                }
                Event::TaskDone(tid, wid) => {
                    if st.running_on[tix(tid)] != Some(wid) {
                        continue; // stale: the attempt died with its node
                    }
                    st.running_on[tix(tid)] = None;
                    tasks_done += 1;
                    let newly = st.graph.complete(tid);
                    for t in newly {
                        push_ready(&mut st, t);
                    }
                    // Put parked workers onto the fresh tasks.
                    let parked: Vec<WorkerId> = std::mem::take(&mut st.idle);
                    for wid in parked {
                        if let Some(next) = pop_live(&mut st, wid.node) {
                            self.begin_task(&mut st, next, wid, now);
                        } else {
                            st.idle.push(wid);
                        }
                    }
                }
                Event::NodeKill(node) => {
                    self.kill_node(&mut st, node, now);
                }
                Event::NodeJoin(node) => {
                    let n = node.0 as usize;
                    if n < st.dead.len() && st.dead[n] {
                        st.dead[n] = false;
                        st.epoch[n] += 1;
                        st.router.set_alive(node, true);
                        // Rejoining workers pay the init stagger again.
                        for slot in 0..wpn {
                            let wid = WorkerId {
                                node,
                                slot: slot as u32,
                            };
                            let ready_at = now + profile.worker_ready_at(slot as u32);
                            st.tracer
                                .record_at(wid, EventKind::WorkerInit, None, now, ready_at);
                            st.push_event(ready_at, Event::WorkerIdle(wid, st.epoch[n]));
                        }
                    }
                }
            }
        }

        anyhow::ensure!(
            st.graph.quiescent(),
            "simulation ended with {} unfinished tasks (deadlock in plan?)",
            st.graph.len() - st.graph.done_count()
        );
        let total_busy: f64 = st.busy.iter().sum();
        let utilization = if makespan > 0.0 {
            total_busy / (makespan * (nodes * wpn) as f64)
        } else {
            0.0
        };
        let per_type: HashMap<String, (usize, f64)> = st
            .per_type
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        let total_io_s = st.total_io;
        let total_transfer_s = st.total_transfer;
        let transfer_warm_hits = st.warm_hits;
        let placement_verdicts = st.placement_verdicts;
        let trace = st.tracer.finish(label);
        let dead_version_bytes = plan.registry.table().dead_bytes();
        let result_digest = plan.result_digest();
        Ok(SimReport {
            makespan_s: makespan,
            // Culled tasks are retired without running: from the
            // schedule-invariant point of view they are done work.
            tasks_done: tasks_done + window_culled,
            per_type,
            total_io_s,
            total_transfer_s,
            transfer_warm_hits,
            trace,
            utilization,
            fuzz_seed: self.fuzz_seed,
            dead_version_bytes,
            result_digest,
            window_culled,
            window_fused,
            placement_verdicts,
        })
    }

    /// Claim a task: transfers + input reads (disk reserved now, they start
    /// immediately) + compute. Schedules `ExecDone`.
    fn begin_task(&self, st: &mut RunState<'_>, id: TaskId, wid: WorkerId, now: f64) {
        let profile = &self.cluster.profile;
        let meta_map = st.meta;
        let meta = meta_map.get(&id).expect("task meta");
        st.graph.start(id);
        st.started_at[tix(id)] = now;
        st.running_on[tix(id)] = Some(wid);
        let node = wid.node.0 as usize;
        // Dispatch goes through the single master: FCFS serial resource —
        // except a fused chain member, which the head's worker claims
        // inline without a master round-trip (the fusion pass's win).
        let dispatch_end = if st.fused_member.contains(&id) {
            now
        } else {
            let end = now.max(st.master_free) + self.cost.master_dispatch_s;
            st.master_free = end;
            end
        };
        let mut t = dispatch_end;

        let deser_start = t;
        for key in &meta.inputs {
            if st.fused_keys.contains(key) {
                // Fused intermediate: already in the worker's hands —
                // no read, no transfer, no staging.
                continue;
            }
            let info = st.registry.info(*key).expect("input info");
            let bytes = info.bytes;
            if st.registry.is_local(*key, wid.node) {
                // Node already holds the file: served from the page cache
                // (fragments re-read every K-means iteration never touch
                // the filesystem again).
                let io = self.cost.cached_read_time(bytes);
                st.total_io += io;
                t += io;
            } else {
                // Remote version: inter-node transfer, then staging on the
                // destination.
                let tr = self.cost.transfer_time(bytes, profile);
                st.tracer
                    .record_at(wid, EventKind::Transfer, Some(id), t, t + tr);
                // The adaptive model observes simulated transfer timings —
                // the same signal the live movers would record.
                if let Some(fb) = &st.feedback {
                    fb.record_transfer(wid.node, bytes, tr);
                }
                t += tr;
                st.total_transfer += tr;
                st.registry.add_location(*key, wid.node);
                if self.warm_staging && st.warm_staged.contains(key) {
                    // Warm hit: the cached serialized blob ships as-is —
                    // no file materialization, no disk-server time (the
                    // live mover decodes the blob straight into the hot
                    // tier).
                    st.warm_hits += 1;
                } else {
                    // Cold miss (or warm tier off): the serialized bytes
                    // are materialized through the destination's I/O
                    // server — the file-staging round-trip. The first
                    // transfer also fills the warm blob for later fan-out
                    // replicas.
                    if self.warm_staging {
                        st.warm_staged.insert(*key);
                    }
                    let io = self.cost.io_time(bytes, profile);
                    let start = t.max(st.disk_free[node]);
                    let end = start + io;
                    st.disk_free[node] = end;
                    st.total_io += io;
                    t = end;
                }
            }
        }
        if !meta.inputs.is_empty() && t > deser_start {
            st.tracer
                .record_at(wid, EventKind::Deserialize, Some(id), deser_start, t);
        }

        // Node occupancy: configured workers vs the node's core budget
        // (drives the DRAM-saturation penalty on GEMM-class tasks).
        let occupancy =
            self.cluster.workers_per_node as f64 / profile.workers_per_node.max(1) as f64;
        let exec = self.cost.exec_time(
            &meta.ty,
            meta.cost_units,
            meta.gemm_class,
            profile,
            occupancy,
        );
        st.tracer.record_at(
            wid,
            EventKind::TaskExec(meta.ty.clone()),
            Some(id),
            t,
            t + exec,
        );
        if let Some(fb) = &st.feedback {
            fb.record_task(&meta.ty, exec);
        }
        t += exec;
        // Interned Arc<str> keys: allocate only on the first completion of
        // each type (big DES sweeps run millions of tasks through here).
        if !st.per_type.contains_key(meta.ty.as_ref()) {
            st.per_type.insert(Arc::clone(&meta.ty), (0, 0.0));
        }
        let e = st
            .per_type
            .get_mut(meta.ty.as_ref())
            .expect("per-type entry just ensured");
        e.0 += 1;
        e.1 += exec;
        st.push_event(t, Event::ExecDone(id, wid));
    }

    /// Compute finished: reserve output writes *now*, free the worker and
    /// complete the task at write end.
    fn finish_task(&self, st: &mut RunState<'_>, id: TaskId, wid: WorkerId, now: f64) {
        let profile = &self.cluster.profile;
        let meta_map = st.meta;
        let meta = meta_map.get(&id).expect("task meta");
        let node = wid.node.0 as usize;
        let mut t = now;
        let ser_start = t;
        for (key, bytes) in &meta.outputs {
            if st.fused_keys.contains(key) {
                // Fused intermediate: handed to the member worker-local,
                // never serialized, never published.
                continue;
            }
            // Client-link write on this node...
            let io = self.cost.io_time(*bytes, profile);
            let start = t.max(st.disk_free[node]);
            let end = start + io;
            st.disk_free[node] = end;
            // ... that must also be absorbed by the shared FS backend.
            let fs = self.cost.fs_write_time(*bytes, profile);
            let fs_end = end.max(st.fs_free) + fs;
            st.fs_free = fs_end;
            let end = end.max(fs_end);
            st.total_io += io + fs;
            t = end;
            st.registry
                .mark_available(*key, wid.node, *bytes, std::path::PathBuf::new());
        }
        if !meta.outputs.is_empty() && t > ser_start {
            st.tracer
                .record_at(wid, EventKind::Serialize, Some(id), ser_start, t);
        }
        let started = st.started_at[tix(id)];
        let start = if started.is_nan() { now } else { started };
        st.started_at[tix(id)] = f64::NAN;
        st.busy[node * st.wpn + wid.slot as usize] += t - start;
        st.push_event(t, Event::WorkerIdle(wid, st.epoch[node]));
        st.push_event(t, Event::TaskDone(id, wid));
    }

    /// Chaos node kill in virtual time — the simulated twin of the live
    /// recovery pipeline: the node's shard closes (`set_alive`), its idle
    /// workers vanish, its running attempts resubmit, its replicas drop,
    /// and sole-replica versions it held are re-derived by reopening their
    /// (transitive) producers. Master-materialized inputs re-read from the
    /// shared filesystem onto the first alive node. The last alive node is
    /// never killed.
    fn kill_node(&self, st: &mut RunState<'_>, node: NodeId, now: f64) {
        let n = node.0 as usize;
        if n >= st.dead.len() || st.dead[n] || st.dead.iter().filter(|d| !**d).count() <= 1 {
            return;
        }
        st.dead[n] = true;
        st.epoch[n] += 1;
        st.router.set_alive(node, false);
        st.idle.retain(|w| w.node != node);
        // Running attempts on the node are lost: back to the ready queues
        // (their pending ExecDone/TaskDone events go stale).
        let lost_tasks: Vec<TaskId> = st
            .running_on
            .iter()
            .enumerate()
            .filter(|(_, w)| w.map_or(false, |w| w.node == node))
            .map(|(i, _)| TaskId(i as u64))
            .collect();
        for tid in lost_tasks {
            st.running_on[tix(tid)] = None;
            st.started_at[tix(tid)] = f64::NAN;
            st.graph.resubmit(tid);
            push_ready(st, tid);
        }
        // Sole-replica versions die with the node: lineage re-execution,
        // exactly the live `recover_lost_versions` walk.
        let meta_map = st.meta;
        let report = st.registry.table().drop_node(node);
        let home = NodeId(
            st.dead
                .iter()
                .position(|d| !*d)
                .expect("an alive node remains") as u32,
        );
        let mut stack: Vec<DataKey> = report.lost.clone();
        let mut seen: HashSet<DataKey> = stack.iter().copied().collect();
        let mut reopen: HashSet<TaskId> = HashSet::new();
        while let Some(key) = stack.pop() {
            st.warm_staged.remove(&key);
            let Some(info) = st.registry.info(key) else {
                continue;
            };
            match info.producer {
                None => {
                    // Master-materialized input: survives on the shared
                    // filesystem — re-read it onto an alive node.
                    st.registry
                        .mark_available(key, home, info.bytes, std::path::PathBuf::new());
                }
                Some(tid) => {
                    if st.graph.state(tid) == Some(TaskState::Done) && reopen.insert(tid) {
                        for input in &meta_map.get(&tid).expect("task meta").inputs {
                            if !seen.contains(input)
                                && st.registry.info(*input).map_or(true, |i| !i.available)
                            {
                                seen.insert(*input);
                                stack.push(*input);
                            }
                        }
                    }
                }
            }
        }
        if !reopen.is_empty() {
            for tid in &reopen {
                for (key, _) in &meta_map.get(tid).expect("task meta").outputs {
                    let still = st
                        .registry
                        .info(*key)
                        .map_or(false, |i| i.available && !i.locations.is_empty());
                    if !still {
                        st.registry.table().reset_for_recovery(*key);
                        st.warm_staged.remove(key);
                    }
                }
            }
            let ready = st.graph.reopen(&reopen);
            for t in ready {
                push_ready(st, t);
            }
        }
        // Survivors parked with nothing to do may now have work (reopened
        // tasks, rerouted queue entries).
        let parked: Vec<WorkerId> = std::mem::take(&mut st.idle);
        for wid in parked {
            if let Some(next) = pop_live(st, wid.node) {
                self.begin_task(st, next, wid, now);
            } else {
                st.idle.push(wid);
            }
        }
    }
}

/// Pop the next *claimable* task for a node's worker: a `reopen` re-gate
/// (node-loss recovery) demotes a queued Ready task back to Pending and
/// leaves its queue entry behind — exactly the live fabric's stale-entry
/// protocol, discarded at claim time by this state check.
fn pop_live(st: &mut RunState<'_>, node: NodeId) -> Option<TaskId> {
    while let Some(tid) = st.router.pop_for(node) {
        if st.graph.state(tid) == Some(TaskState::Ready) {
            return Some(tid);
        }
    }
    None
}

/// Route one newly-ready task through the shared placement engine, with
/// the same locality snapshot the live `enqueue_ready` would take. A
/// window-compiled shard assignment is consumed here in place of a
/// greedy model verdict — the live `core.placement` consult.
fn push_ready(st: &mut RunState<'_>, id: TaskId) {
    let meta = st.meta.get(&id).expect("meta for ready task");
    let inputs = meta
        .inputs
        .iter()
        .map(|k| {
            if st.fused_keys.contains(k) {
                // Handed worker-local by the fused head: no bytes to
                // weigh, no locations to prefer.
                return (0, Vec::new());
            }
            let info = st.registry.info(*k).expect("input info");
            (info.bytes, info.locations)
        })
        .collect();
    let task = ReadyTask {
        id,
        inputs,
        type_name: Arc::clone(&meta.ty),
    };
    match st.placement_plan.remove(&id) {
        Some(shard) => {
            st.router.push_routed(shard, task);
        }
        None => {
            st.placement_verdicts += 1;
            st.router.push(task);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kmeans::{plan_kmeans, KmeansConfig};
    use crate::apps::knn::{plan_knn, KnnConfig};
    use crate::cluster::MachineProfile;
    use crate::sim::SimSink;

    fn knn_plan(frags: usize, blocks: usize) -> SimPlan {
        let mut cfg = KnnConfig::small(5);
        cfg.train_fragments = frags;
        cfg.test_blocks = blocks;
        let mut sink = SimSink::new();
        plan_knn(&mut sink, &cfg).unwrap();
        sink.finish()
    }

    #[test]
    fn simulation_completes_all_tasks() {
        let plan = knn_plan(8, 4);
        let n_tasks = plan.graph.len();
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(16);
        let report = SimEngine::new(spec, CostModel::default())
            .run(plan, "knn sim")
            .unwrap();
        assert_eq!(report.tasks_done, n_tasks);
        assert!(report.makespan_s > 0.0);
        assert!(report.utilization > 0.0 && report.utilization <= 1.0);
        assert_eq!(report.fuzz_seed, None);
        assert_eq!(report.dead_version_bytes, 0);
    }

    #[test]
    fn more_workers_is_not_slower() {
        let spec1 = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(1);
        let spec8 = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(8);
        let t1 = SimEngine::new(spec1, CostModel::default())
            .run(knn_plan(16, 2), "w1")
            .unwrap()
            .makespan_s;
        let t8 = SimEngine::new(spec8, CostModel::default())
            .run(knn_plan(16, 2), "w8")
            .unwrap()
            .makespan_s;
        assert!(t8 < t1, "8 workers {t8} vs 1 worker {t1}");
        // And meaningfully so, for an embarrassingly-parallel phase.
        assert!(t1 / t8 > 2.0, "speedup {}", t1 / t8);
    }

    #[test]
    fn mn5_worker_init_delays_small_runs() {
        let plan_a = knn_plan(4, 1);
        let plan_b = knn_plan(4, 1);
        let sh = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(4);
        let mn = ClusterSpec::new(MachineProfile::marenostrum5(), 1).with_workers_per_node(4);
        let t_sh = SimEngine::new(sh, CostModel::default())
            .run(plan_a, "sh")
            .unwrap()
            .makespan_s;
        let t_mn = SimEngine::new(mn, CostModel::default())
            .run(plan_b, "mn")
            .unwrap()
            .makespan_s;
        assert!(
            t_mn > t_sh,
            "MN5 worker-init stagger must show: {t_mn} vs {t_sh}"
        );
    }

    #[test]
    fn gemm_slowdown_dominates_linreg_on_mn5() {
        use crate::apps::linreg::{plan_linreg, LinregConfig};
        let make = || {
            let mut cfg = LinregConfig::small(9);
            cfg.fragments = 8;
            cfg.pred_blocks = 2;
            let mut sink = SimSink::new();
            plan_linreg(&mut sink, &cfg).unwrap();
            sink.finish()
        };
        let sh = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(8);
        let mn = ClusterSpec::new(MachineProfile::marenostrum5(), 1).with_workers_per_node(8);
        let t_sh = SimEngine::new(sh, CostModel::default()).run(make(), "sh").unwrap();
        let t_mn = SimEngine::new(mn, CostModel::default()).run(make(), "mn").unwrap();
        // The paper saw ~100x on linreg end-to-end; with I/O and non-GEMM
        // tasks in the mix, demand at least ~10x here.
        assert!(
            t_mn.makespan_s / t_sh.makespan_s > 10.0,
            "ratio {}",
            t_mn.makespan_s / t_sh.makespan_s
        );
    }

    #[test]
    fn kmeans_iterations_serialize() {
        let make = |iters: usize| {
            let mut cfg = KmeansConfig::small(2);
            cfg.fragments = 4;
            cfg.iterations = iters;
            let mut sink = SimSink::new();
            plan_kmeans(&mut sink, &cfg).unwrap();
            sink.finish()
        };
        // Zero worker-init so the iteration chain is the whole makespan.
        let mut profile = MachineProfile::shaheen3();
        profile.worker_init_base_s = 0.0;
        profile.worker_init_stagger_s = 0.0;
        let spec = ClusterSpec::new(profile, 1).with_workers_per_node(8);
        let t1 = SimEngine::new(spec.clone(), CostModel::default())
            .run(make(1), "i1")
            .unwrap()
            .makespan_s;
        let t3 = SimEngine::new(spec, CostModel::default())
            .run(make(3), "i3")
            .unwrap()
            .makespan_s;
        assert!(t3 > t1 * 1.8, "iterations must serialize: {t1} vs {t3}");
    }

    #[test]
    fn trace_contains_simulated_events() {
        let plan = knn_plan(4, 1);
        let spec = ClusterSpec::new(MachineProfile::marenostrum5(), 1).with_workers_per_node(4);
        let report = SimEngine::new(spec, CostModel::default())
            .with_trace(true)
            .run(plan, "traced")
            .unwrap();
        assert!(!report.trace.events.is_empty());
        let art = report.trace.ascii_timeline(60);
        assert!(art.contains('#'), "worker init visible:\n{art}");
        assert!(art.contains('A'), "task letters visible:\n{art}");
        let prv = report.trace.to_prv();
        assert!(prv.starts_with("#Paraver"));
    }

    #[test]
    fn every_router_model_runs_to_completion() {
        // The simulator drives the shared placement engine: every model
        // must drain the same DAG, whatever it decides — including the
        // adaptive model warming up from simulated transfer timings.
        for router in ["bytes", "cost", "roundrobin", "adaptive"] {
            let plan = knn_plan(8, 2);
            let n = plan.graph.len();
            let spec = ClusterSpec::new(MachineProfile::shaheen3(), 3).with_workers_per_node(2);
            let report = SimEngine::new(spec, CostModel::default())
                .with_router(router)
                .run(plan, router)
                .unwrap();
            assert_eq!(report.tasks_done, n, "router {router}");
        }
        let plan = knn_plan(2, 1);
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 1);
        assert!(SimEngine::new(spec, CostModel::default())
            .with_router("zzz")
            .run(plan, "bad")
            .is_err());
    }

    #[test]
    fn warm_staging_distinguishes_hits_from_cold_misses() {
        // K-means broadcasts each centroid version to every node per
        // iteration — a fan-out. With warm staging (the default) only the
        // first replica of a version materializes the serialized bytes
        // through the disk server; later replicas ship the cached blob and
        // count as warm hits. With the tier off (the live
        // `--warm-budget 0`), every transfer pays the file-staging cost
        // and the counter stays zero.
        let make = || {
            let mut cfg = KmeansConfig::small(3);
            cfg.fragments = 8;
            cfg.iterations = 2;
            let mut sink = SimSink::new();
            plan_kmeans(&mut sink, &cfg).unwrap();
            sink.finish()
        };
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4).with_workers_per_node(2);
        let n = make().graph.len();
        let warm = SimEngine::new(spec.clone(), CostModel::default())
            .run(make(), "warm")
            .unwrap();
        let cold = SimEngine::new(spec, CostModel::default())
            .with_warm(false)
            .run(make(), "cold")
            .unwrap();
        assert_eq!(warm.tasks_done, n);
        assert_eq!(cold.tasks_done, n);
        assert!(warm.total_transfer_s > 0.0, "multi-node run must transfer");
        assert!(
            warm.transfer_warm_hits > 0,
            "fan-out must produce warm-hit stagings"
        );
        assert_eq!(cold.transfer_warm_hits, 0, "warm off never counts a hit");
    }

    #[test]
    fn node_kill_mid_sim_recovers_and_completes() {
        let make = || knn_plan(8, 4);
        let n = make().graph.len();
        let spec = || ClusterSpec::new(MachineProfile::shaheen3(), 4).with_workers_per_node(2);
        let base = SimEngine::new(spec(), CostModel::default())
            .run(make(), "base")
            .unwrap();
        assert_eq!(base.tasks_done, n);
        // Kill node 3 mid-run: the DAG still drains — the engine's
        // `ensure!(quiescent)` would fail otherwise — and lost work
        // re-executes, so completions can only grow.
        let kill_at = base.makespan_s * 0.5;
        let killed = SimEngine::new(spec(), CostModel::default())
            .with_node_kill(kill_at, 3)
            .run(make(), "killed")
            .unwrap();
        assert!(
            killed.tasks_done >= n,
            "all tasks complete, re-runs included: {} vs {n}",
            killed.tasks_done
        );
        // Kill + rejoin: the node comes back (workers re-init) and the
        // run still drains.
        let rejoined = SimEngine::new(spec(), CostModel::default())
            .with_node_kill(kill_at, 3)
            .with_node_join(kill_at + base.makespan_s * 0.2, 3)
            .run(make(), "rejoined")
            .unwrap();
        assert!(rejoined.tasks_done >= n);
        // Killing the only node is refused — the run completes untouched.
        let solo = ClusterSpec::new(MachineProfile::shaheen3(), 1).with_workers_per_node(2);
        let report = SimEngine::new(solo, CostModel::default())
            .with_node_kill(0.001, 0)
            .run(knn_plan(4, 2), "solo")
            .unwrap();
        assert!(report.tasks_done > 0);
    }

    #[test]
    fn locality_scheduler_runs_to_completion() {
        let plan = knn_plan(8, 2);
        let n = plan.graph.len();
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4).with_workers_per_node(4);
        let report = SimEngine::new(spec, CostModel::default())
            .with_scheduler("locality")
            .run(plan, "loc")
            .unwrap();
        assert_eq!(report.tasks_done, n);
        assert!(report.total_transfer_s >= 0.0);
    }

    #[test]
    fn io_contention_caps_scaling() {
        // With a deliberately tiny disk bandwidth, adding workers should
        // stop helping: the node disk serializes I/O (the paper's >32-core
        // MN5 effect).
        let mut profile = MachineProfile::shaheen3();
        profile.disk_bw_bytes_per_s = 2e6; // pathological
        let mk = |w: u32| ClusterSpec::new(profile.clone(), 1).with_workers_per_node(w);
        let t4 = SimEngine::new(mk(4), CostModel::default())
            .run(knn_plan(16, 2), "io4")
            .unwrap()
            .makespan_s;
        let t64 = SimEngine::new(mk(64), CostModel::default())
            .run(knn_plan(16, 2), "io64")
            .unwrap()
            .makespan_s;
        assert!(
            t64 > t4 * 0.5,
            "disk-bound: 16x workers must not give 2x speedup ({t4} vs {t64})"
        );
    }

    #[test]
    fn fuzz_seed_replays_byte_identical_runs() {
        // The reproducibility contract: one (plan, seed) pair, one event
        // order. Every timing in the report must match to the bit.
        let spec = || ClusterSpec::new(MachineProfile::shaheen3(), 3).with_workers_per_node(2);
        let run = || {
            SimEngine::new(spec(), CostModel::default())
                .with_router("cost")
                .with_fuzz_seed(42)
                .run(knn_plan(8, 2), "replay")
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.fuzz_seed, Some(42));
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.total_io_s.to_bits(), b.total_io_s.to_bits());
        assert_eq!(a.total_transfer_s.to_bits(), b.total_transfer_s.to_bits());
        assert_eq!(a.result_digest, b.result_digest);
        assert_eq!(a.tasks_done, b.tasks_done);
    }

    #[test]
    fn fuzz_sweep_holds_invariants_on_healthy_plans() {
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 2).with_workers_per_node(2);
        let engine = SimEngine::new(spec, CostModel::default()).with_router("cost");
        let reports = engine
            .fuzz_sweep(&[1, 2, 3, 4], || Ok(knn_plan(4, 2)), "mini")
            .unwrap();
        assert_eq!(reports.len(), 4);
        let d0 = reports[0].result_digest;
        assert!(reports.iter().all(|r| r.result_digest == d0));
    }

    #[test]
    fn fuzz_sweep_names_the_minimal_failing_seed() {
        // Poison the plan: nothing initially ready, so no schedule can
        // drain it — every seed fails, and the error must name the
        // smallest one (CI greps for exactly this phrase).
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 2).with_workers_per_node(2);
        let engine = SimEngine::new(spec, CostModel::default());
        let err = engine
            .fuzz_sweep(
                &[13, 7, 29],
                || {
                    let mut p = knn_plan(2, 1);
                    p.initially_ready.clear();
                    Ok(p)
                },
                "stuck",
            )
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("minimal failing seed 7"), "{msg}");
    }

    #[test]
    fn fuzz_jitter_window_still_drains() {
        // A nonzero window delivers events up to 100 µs apart out of
        // order; the FCFS servers absorb it and every seed still drains
        // with an identical final data plane.
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 3).with_workers_per_node(2);
        let engine = SimEngine::new(spec, CostModel::default())
            .with_router("bytes")
            .with_fuzz_jitter(1e-4);
        let reports = engine
            .fuzz_sweep(&[5, 6, 7, 8], || Ok(knn_plan(6, 2)), "jitter")
            .unwrap();
        let d0 = reports[0].result_digest;
        assert!(reports.iter().all(|r| r.result_digest == d0));
    }

    #[test]
    fn nonfinite_cost_model_is_rejected_before_the_heap() {
        // A poisoned constant inserted directly (bypassing
        // `set_unit_cost`'s assert) must fail at run start with the field
        // named, not as a NaN ordering panic mid-heap.
        let mut model = CostModel::default();
        model.unit_costs.insert("KNN_frag".into(), f64::NAN);
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 1);
        let err = SimEngine::new(spec, model)
            .run(knn_plan(2, 1), "nan")
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("invalid cost model"), "{msg}");
        assert!(msg.contains("KNN_frag"), "{msg}");
    }
}
