//! [`SimSink`]: materializes a planner's task graph for the simulator,
//! using the *same* dependency machinery as the live coordinator
//! (`DataRegistry` versioning + `TaskGraph` insertion). The result is a
//! `SimPlan` the engine executes in virtual time.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::apps::{SinkArg, SinkRef, SubmitSpec, TaskSink};
use crate::coordinator::dag::{EdgeKind, TaskGraph, TaskId};
use crate::coordinator::registry::{DataKey, DataRegistry, NodeId};

/// Per-task metadata the engine needs.
#[derive(Clone, Debug)]
pub struct SimTaskMeta {
    /// Interned task type name, shared with every `ReadyTask` and trace
    /// event the engine emits for this task.
    pub ty: Arc<str>,
    pub cost_units: f64,
    pub gemm_class: bool,
    pub inputs: Vec<DataKey>,
    /// (key, serialized bytes) per output.
    pub outputs: Vec<(DataKey, u64)>,
}

/// The materialized plan.
pub struct SimPlan {
    pub graph: TaskGraph,
    pub registry: DataRegistry,
    pub meta: HashMap<TaskId, SimTaskMeta>,
    /// Tasks ready at time zero.
    pub initially_ready: Vec<TaskId>,
    /// Count of master sync points (stats only).
    pub sync_count: usize,
}

impl SimPlan {
    /// Task count per type — checked against the live runs for DAG parity.
    pub fn type_counts(&self) -> HashMap<String, usize> {
        let mut m = HashMap::new();
        for t in self.graph.tasks_in_order() {
            *m.entry(t.type_name.clone()).or_insert(0) += 1;
        }
        m
    }

    /// Order-insensitive digest of the plan's final data-plane state: the
    /// task population (type names folded commutatively, so iteration
    /// order cannot matter), completion count, and the registry's version
    /// and byte totals. Two runs of the same DAG that produced the same
    /// data agree on this digest regardless of the schedule that got them
    /// there — the "byte-identical results" invariant the schedule fuzzer
    /// checks across seeds. Deliberately excludes anything
    /// schedule-dependent (timings, placements, re-execution counts).
    pub fn result_digest(&self) -> u64 {
        fn fnv(s: &str) -> u64 {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for b in s.as_bytes() {
                h ^= *b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            h
        }
        fn mix(mut h: u64) -> u64 {
            // splitmix64 finalizer.
            h ^= h >> 30;
            h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
            h ^= h >> 27;
            h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
            h ^ (h >> 31)
        }
        let mut acc = 0u64;
        for t in self.graph.tasks_in_order() {
            // Wrapping add keeps the fold commutative.
            acc = acc.wrapping_add(mix(fnv(&t.type_name)));
        }
        let mut h = acc;
        for x in [
            self.graph.len() as u64,
            self.graph.done_count() as u64,
            self.registry.datum_count() as u64,
            self.registry.version_count() as u64,
            self.registry.total_bytes(),
        ] {
            h = mix(h ^ x);
        }
        h
    }
}

/// Sink that builds a [`SimPlan`].
pub struct SimSink {
    graph: TaskGraph,
    registry: DataRegistry,
    meta: HashMap<TaskId, SimTaskMeta>,
    refs: HashMap<SinkRef, DataKey>,
    next_ref: u64,
    ready: Vec<TaskId>,
    sync_count: usize,
}

impl Default for SimSink {
    fn default() -> Self {
        Self::new()
    }
}

impl SimSink {
    pub fn new() -> SimSink {
        SimSink {
            graph: TaskGraph::new(),
            registry: DataRegistry::new(),
            meta: HashMap::new(),
            refs: HashMap::new(),
            next_ref: 0,
            ready: Vec::new(),
            sync_count: 0,
        }
    }

    pub fn finish(self) -> SimPlan {
        SimPlan {
            graph: self.graph,
            registry: self.registry,
            meta: self.meta,
            initially_ready: self.ready,
            sync_count: self.sync_count,
        }
    }
}

impl TaskSink for SimSink {
    fn submit(&mut self, spec: SubmitSpec) -> Result<Vec<SinkRef>> {
        anyhow::ensure!(
            spec.out_bytes.len() == spec.n_outputs,
            "task '{}': out_bytes length {} != n_outputs {}",
            spec.ty,
            spec.out_bytes.len(),
            spec.n_outputs
        );
        let id = self.graph.next_task_id();
        // Same dependency analysis as Coordinator::submit, minus the I/O.
        let mut deps: Vec<(TaskId, EdgeKind, DataKey)> = Vec::new();
        let mut reads: Vec<DataKey> = Vec::new();
        for arg in &spec.args {
            match arg {
                SinkArg::Lit(v) => {
                    // Literal materialized by the master on node 0.
                    let bytes = (v.byte_size() + 64) as u64;
                    let key = self.registry.new_literal(bytes, NodeId(0));
                    reads.push(key);
                }
                SinkArg::Ref(r) => {
                    let key = self
                        .refs
                        .get(r)
                        .copied()
                        .ok_or_else(|| anyhow::anyhow!("dangling sink ref {r:?}"))?;
                    let (read_key, raw) = self.registry.record_read(key.data, id);
                    if let Some(p) = raw {
                        deps.push((p, EdgeKind::Raw, read_key));
                    }
                    reads.push(read_key);
                }
            }
        }
        let mut writes = Vec::with_capacity(spec.n_outputs);
        let mut out_refs = Vec::with_capacity(spec.n_outputs);
        let mut outputs = Vec::with_capacity(spec.n_outputs);
        for b in &spec.out_bytes {
            let key = self.registry.new_future(id);
            writes.push(key);
            outputs.push((key, *b));
            self.next_ref += 1;
            let sr = SinkRef(self.next_ref);
            self.refs.insert(sr, key);
            out_refs.push(sr);
        }
        self.meta.insert(
            id,
            SimTaskMeta {
                ty: spec.ty.into(),
                cost_units: spec.cost_units,
                gemm_class: spec.gemm_class,
                inputs: reads.clone(),
                outputs,
            },
        );
        let ready = self.graph.insert_task(id, spec.ty, reads, writes, deps);
        if ready {
            self.ready.push(id);
        }
        Ok(out_refs)
    }

    fn sync(&mut self, _r: SinkRef) -> Result<()> {
        self.sync_count += 1;
        Ok(())
    }

    fn barrier(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::kmeans::{expected_task_counts, plan_kmeans, KmeansConfig};
    use crate::apps::knn::{self, KnnConfig};
    use crate::apps::linreg::{self, LinregConfig};

    #[test]
    fn knn_plan_counts_match_expectation() {
        let mut cfg = KnnConfig::small(3);
        cfg.train_fragments = 5;
        cfg.test_blocks = 2;
        let mut sink = SimSink::new();
        knn::plan_knn(&mut sink, &cfg).unwrap();
        let plan = sink.finish();
        let counts = plan.type_counts();
        for (ty, n) in knn::expected_task_counts(&cfg) {
            assert_eq!(counts.get(ty).copied().unwrap_or(0), n, "type {ty}");
        }
        assert!(plan.graph.critical_path_len() >= 4);
    }

    #[test]
    fn kmeans_plan_counts_match_expectation() {
        let mut cfg = KmeansConfig::small(3);
        cfg.fragments = 8;
        cfg.iterations = 2;
        let mut sink = SimSink::new();
        plan_kmeans(&mut sink, &cfg).unwrap();
        let plan = sink.finish();
        let counts = plan.type_counts();
        for (ty, n) in expected_task_counts(&cfg) {
            assert_eq!(counts.get(ty).copied().unwrap_or(0), n, "type {ty}");
        }
        // Iterations serialize through centroids: the critical path must
        // grow with iterations: fill, then per iteration
        // partial -> 3 merge levels (8 fragments) -> update.
        assert!(plan.graph.critical_path_len() >= 1 + 2 * (1 + 3 + 1));
    }

    #[test]
    fn linreg_plan_counts_match_expectation() {
        let mut cfg = LinregConfig::small(3);
        cfg.fragments = 6;
        cfg.pred_blocks = 2;
        let mut sink = SimSink::new();
        linreg::plan_linreg(&mut sink, &cfg).unwrap();
        let plan = sink.finish();
        let counts = plan.type_counts();
        for (ty, n) in linreg::expected_task_counts(&cfg) {
            assert_eq!(counts.get(ty).copied().unwrap_or(0), n, "type {ty}");
        }
    }

    #[test]
    fn result_digest_tracks_plan_identity() {
        let make = |frags: usize| {
            let mut cfg = KnnConfig::small(3);
            cfg.train_fragments = frags;
            cfg.test_blocks = 2;
            let mut sink = SimSink::new();
            knn::plan_knn(&mut sink, &cfg).unwrap();
            sink.finish()
        };
        // Deterministic builders: the same plan digests identically...
        assert_eq!(make(5).result_digest(), make(5).result_digest());
        // ... and a structurally different plan does not.
        assert_ne!(make(5).result_digest(), make(6).result_digest());
    }

    #[test]
    fn fill_tasks_are_initially_ready() {
        let mut cfg = KnnConfig::small(1);
        cfg.train_fragments = 3;
        cfg.test_blocks = 1;
        let mut sink = SimSink::new();
        knn::plan_knn(&mut sink, &cfg).unwrap();
        let plan = sink.finish();
        // 3 train fills + 1 test fill ready at t=0.
        assert_eq!(plan.initially_ready.len(), 4);
    }
}
