//! Discrete-event cluster simulation — the scale-out substrate.
//!
//! The paper's headline experiments run on up to 32 nodes x 128 workers;
//! this box has a handful of cores. Per the reproduction rules (DESIGN.md
//! §3) we *simulate* the cluster: the same planners emit the same DAG into
//! [`SimSink`], the same `Scheduler` policies make the same placement
//! decisions, and a virtual-time engine ([`engine::SimEngine`]) replays
//! execution against a machine profile with a **calibrated** cost model:
//!
//! * per-task-type compute costs measured on this box ([`cost::CostModel`]),
//!   scaled by the profile's core speed and (for GEMM-class tasks) the
//!   measured MKL/RBLAS ratio;
//! * serialization I/O charged against a per-node FCFS disk server
//!   (bandwidth + latency), which reproduces the paper's I/O contention at
//!   high core counts;
//! * staggered worker initialization (the MareNostrum-5 bring-up skew);
//! * inter-node transfers for non-local inputs (bandwidth + latency).
//!
//! The engine emits ordinary `trace::Trace` events, so Figure-10-style
//! timelines come out of simulated runs exactly as they do from live ones.
//!
//! The engine doubles as a race-hunting harness: `with_fuzz_seed` pops
//! timestamp-tied events in a seeded permutation (a distinct, replayable
//! schedule per seed) and [`engine::SimEngine::fuzz_sweep`] drives many
//! seeds through one plan, asserting schedule-independence invariants and
//! naming the minimal failing seed. [`plans::fleet_plan`] builds the
//! synthetic 10^6-task workloads those sweeps (and the fleet-sim bench)
//! run at 1,000-node scale.

pub mod cost;
pub mod engine;
pub mod plans;
pub mod sink;

pub use cost::CostModel;
pub use engine::{SimEngine, SimReport};
pub use plans::fleet_plan;
pub use sink::SimSink;
