//! Convenience plan builders shared by the CLI, benches, and tests.
//!
//! The `*_plan` functions use the artifact (live-run) shapes; the
//! `*_plan_with` variants take explicit [`Shapes`] so the figure benches
//! can weight the DAG with the **paper's** workload sizes
//! (`Shapes::paper_single_node` / `paper_multi_node`). Shapes never change
//! the DAG structure — only per-task byte sizes and cost units.

use anyhow::Result;

use crate::apps::kmeans::{plan_kmeans, KmeansConfig};
use crate::apps::knn::{plan_knn, KnnConfig};
use crate::apps::linreg::{plan_linreg, LinregConfig};
use crate::apps::Shapes;
use crate::sim::sink::{SimPlan, SimSink};

/// KNN plan: `train_fragments` x `test_blocks` (Figure 3 pattern).
pub fn knn_plan(train_fragments: usize, test_blocks: usize, seed: u64) -> Result<SimPlan> {
    knn_plan_with(train_fragments, test_blocks, seed, Shapes::from_manifest())
}

pub fn knn_plan_with(
    train_fragments: usize,
    test_blocks: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = KnnConfig::small(seed);
    cfg.train_fragments = train_fragments;
    cfg.test_blocks = test_blocks;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_knn(&mut sink, &cfg)?;
    Ok(sink.finish())
}

/// K-means plan: `fragments` x `iterations` (Figure 4 pattern).
pub fn kmeans_plan(fragments: usize, iterations: usize, seed: u64) -> Result<SimPlan> {
    kmeans_plan_with(fragments, iterations, seed, Shapes::from_manifest())
}

pub fn kmeans_plan_with(
    fragments: usize,
    iterations: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = KmeansConfig::small(seed);
    cfg.fragments = fragments;
    cfg.iterations = iterations;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_kmeans(&mut sink, &cfg)?;
    Ok(sink.finish())
}

/// Linear-regression plan: `fragments` + `pred_blocks` (Figure 5 pattern).
pub fn linreg_plan(fragments: usize, pred_blocks: usize, seed: u64) -> Result<SimPlan> {
    linreg_plan_with(fragments, pred_blocks, seed, Shapes::from_manifest())
}

pub fn linreg_plan_with(
    fragments: usize,
    pred_blocks: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = LinregConfig::small(seed);
    cfg.fragments = fragments;
    cfg.pred_blocks = pred_blocks;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_linreg(&mut sink, &cfg)?;
    Ok(sink.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_produce_nonempty_plans() {
        assert!(knn_plan(4, 2, 1).unwrap().graph.len() > 10);
        assert!(kmeans_plan(4, 2, 1).unwrap().graph.len() > 10);
        assert!(linreg_plan(4, 2, 1).unwrap().graph.len() > 10);
    }

    #[test]
    fn paper_shapes_change_weights_not_structure() {
        let a = knn_plan_with(4, 2, 1, Shapes::default()).unwrap();
        let b = knn_plan_with(4, 2, 1, Shapes::paper_single_node()).unwrap();
        assert_eq!(a.graph.len(), b.graph.len());
        assert_eq!(a.type_counts(), b.type_counts());
        // but the paper shapes carry heavier fragments
        let bytes = |p: &SimPlan| -> u64 {
            p.meta.values().flat_map(|m| m.outputs.iter().map(|(_, b)| *b)).sum()
        };
        assert!(bytes(&b) > bytes(&a));
    }
}
