//! Convenience plan builders shared by the CLI, benches, and tests.
//!
//! The `*_plan` functions use the artifact (live-run) shapes; the
//! `*_plan_with` variants take explicit [`Shapes`] so the figure benches
//! can weight the DAG with the **paper's** workload sizes
//! (`Shapes::paper_single_node` / `paper_multi_node`). Shapes never change
//! the DAG structure — only per-task byte sizes and cost units.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::apps::kmeans::{plan_kmeans, KmeansConfig};
use crate::apps::knn::{plan_knn, KnnConfig};
use crate::apps::linreg::{plan_linreg, LinregConfig};
use crate::apps::Shapes;
use crate::coordinator::dag::{EdgeKind, TaskGraph, TaskId};
use crate::coordinator::registry::{DataKey, DataRegistry};
use crate::sim::sink::{SimPlan, SimSink, SimTaskMeta};

/// KNN plan: `train_fragments` x `test_blocks` (Figure 3 pattern).
pub fn knn_plan(train_fragments: usize, test_blocks: usize, seed: u64) -> Result<SimPlan> {
    knn_plan_with(train_fragments, test_blocks, seed, Shapes::from_manifest())
}

pub fn knn_plan_with(
    train_fragments: usize,
    test_blocks: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = KnnConfig::small(seed);
    cfg.train_fragments = train_fragments;
    cfg.test_blocks = test_blocks;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_knn(&mut sink, &cfg)?;
    Ok(sink.finish())
}

/// K-means plan: `fragments` x `iterations` (Figure 4 pattern).
pub fn kmeans_plan(fragments: usize, iterations: usize, seed: u64) -> Result<SimPlan> {
    kmeans_plan_with(fragments, iterations, seed, Shapes::from_manifest())
}

pub fn kmeans_plan_with(
    fragments: usize,
    iterations: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = KmeansConfig::small(seed);
    cfg.fragments = fragments;
    cfg.iterations = iterations;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_kmeans(&mut sink, &cfg)?;
    Ok(sink.finish())
}

/// Linear-regression plan: `fragments` + `pred_blocks` (Figure 5 pattern).
pub fn linreg_plan(fragments: usize, pred_blocks: usize, seed: u64) -> Result<SimPlan> {
    linreg_plan_with(fragments, pred_blocks, seed, Shapes::from_manifest())
}

pub fn linreg_plan_with(
    fragments: usize,
    pred_blocks: usize,
    seed: u64,
    shapes: Shapes,
) -> Result<SimPlan> {
    let mut cfg = LinregConfig::small(seed);
    cfg.fragments = fragments;
    cfg.pred_blocks = pred_blocks;
    cfg.shapes = shapes;
    let mut sink = SimSink::new();
    plan_linreg(&mut sink, &cfg)?;
    Ok(sink.finish())
}

/// Synthetic fleet-scale plan: `width` independent chains of `depth`
/// small tasks (each task reads its predecessor's output). Built straight
/// against the registry and graph — no planner, no literal
/// materialization — because at the 10^6-task scale this feeds (1,000-node
/// capacity sweeps, schedule-fuzz sweeps, the fleet-sim bench case) the
/// app planners' per-task bookkeeping would dominate the measurement.
/// `width` roots are ready at time zero; ~3 heap events per task.
pub fn fleet_plan(width: usize, depth: usize) -> SimPlan {
    let width = width.max(1);
    let depth = depth.max(1);
    let mut graph = TaskGraph::new();
    let mut registry = DataRegistry::new();
    let mut meta: HashMap<TaskId, SimTaskMeta> = HashMap::with_capacity(width * depth);
    let mut initially_ready = Vec::with_capacity(width);
    let root_ty: Arc<str> = Arc::from("fleet_root");
    let link_ty: Arc<str> = Arc::from("fleet_link");
    for _ in 0..width {
        let mut prev: Option<DataKey> = None;
        for d in 0..depth {
            let id = graph.next_task_id();
            let mut deps: Vec<(TaskId, EdgeKind, DataKey)> = Vec::new();
            let mut reads: Vec<DataKey> = Vec::new();
            if let Some(p) = prev {
                let (key, raw) = registry.record_read(p.data, id);
                if let Some(producer) = raw {
                    deps.push((producer, EdgeKind::Raw, key));
                }
                reads.push(key);
            }
            let out = registry.new_future(id);
            let (ty, name) = if d == 0 {
                (Arc::clone(&root_ty), "fleet_root")
            } else {
                (Arc::clone(&link_ty), "fleet_link")
            };
            meta.insert(
                id,
                SimTaskMeta {
                    ty,
                    cost_units: 1e4,
                    gemm_class: false,
                    inputs: reads.clone(),
                    outputs: vec![(out, 1024)],
                },
            );
            if graph.insert_task(id, name, reads, vec![out], deps) {
                initially_ready.push(id);
            }
            prev = Some(out);
        }
    }
    SimPlan {
        graph,
        registry,
        meta,
        initially_ready,
        sync_count: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_produce_nonempty_plans() {
        assert!(knn_plan(4, 2, 1).unwrap().graph.len() > 10);
        assert!(kmeans_plan(4, 2, 1).unwrap().graph.len() > 10);
        assert!(linreg_plan(4, 2, 1).unwrap().graph.len() > 10);
    }

    #[test]
    fn paper_shapes_change_weights_not_structure() {
        let a = knn_plan_with(4, 2, 1, Shapes::default()).unwrap();
        let b = knn_plan_with(4, 2, 1, Shapes::paper_single_node()).unwrap();
        assert_eq!(a.graph.len(), b.graph.len());
        assert_eq!(a.type_counts(), b.type_counts());
        // but the paper shapes carry heavier fragments
        let bytes = |p: &SimPlan| -> u64 {
            p.meta.values().flat_map(|m| m.outputs.iter().map(|(_, b)| *b)).sum()
        };
        assert!(bytes(&b) > bytes(&a));
    }

    #[test]
    fn fleet_plan_builds_independent_chains() {
        let plan = fleet_plan(4, 3);
        assert_eq!(plan.graph.len(), 12);
        assert_eq!(plan.initially_ready.len(), 4, "one ready root per chain");
        let counts = plan.type_counts();
        assert_eq!(counts.get("fleet_root").copied(), Some(4));
        assert_eq!(counts.get("fleet_link").copied(), Some(8));
        // Chains serialize: the critical path is the chain depth.
        assert!(plan.graph.critical_path_len() >= 3);
    }

    #[test]
    fn fleet_plan_runs_to_completion() {
        use crate::cluster::{ClusterSpec, MachineProfile};
        use crate::sim::{CostModel, SimEngine};
        let plan = fleet_plan(8, 5);
        let n = plan.graph.len();
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4).with_workers_per_node(2);
        let report = SimEngine::new(spec, CostModel::default())
            .with_router("roundrobin")
            .run(plan, "fleet")
            .unwrap();
        assert_eq!(report.tasks_done, n);
    }
}
