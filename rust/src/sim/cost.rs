//! Task cost model for the simulator.
//!
//! Costs are expressed as seconds per *cost unit* (the planners attach a
//! work measure — roughly a flop count — to every submission). The defaults
//! below were measured on this box with the PJRT backend
//! (`cargo bench --bench runtime_hotpath` prints a fresh calibration); the
//! profile then scales them: `core_speed` for general compute and
//! `gemm_slowdown` for GEMM-class tasks on `Reference`-BLAS machines —
//! reproducing the paper's MKL/RBLAS dichotomy without inventing numbers.

use std::collections::HashMap;

use crate::cluster::{BlasClass, MachineProfile};

/// Seconds-per-unit defaults, measured on the calibration box (PJRT
/// backend). Keys are task type names; anything absent uses
/// `default_unit_cost`.
pub const DEFAULT_UNIT_COSTS: &[(&str, f64)] = &[
    // Generation tasks are PRNG-bound (few ops per element).
    ("KNN_fill_fragment", 9.0e-9),
    ("KNN_fill_test", 9.0e-9),
    ("fill_fragment", 9.0e-9),
    ("init_centroids", 9.0e-9),
    ("LR_fill_fragment", 1.2e-8),
    ("LR_genpred", 1.2e-8),
    // Dense compute through XLA.
    ("KNN_frag", 8.0e-10),
    ("partial_sum", 9.0e-10),
    ("partial_ztz", 6.0e-10),
    ("partial_zty", 1.5e-9),
    ("compute_model_parameters", 2.0e-9),
    ("compute_prediction", 1.5e-9),
    // Small merge/vote tasks: per-element cost dominated by call overhead.
    ("KNN_merge", 2.0e-8),
    ("KNN_classify", 2.0e-8),
    ("merge", 2.0e-8),
    ("merge_ztz", 6.0e-9),
    ("merge_zty", 2.0e-8),
    ("update_centroids", 2.0e-8),
];

/// The cost model: unit costs + serialization throughput.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub unit_costs: HashMap<String, f64>,
    pub default_unit_cost: f64,
    /// Fixed per-task dispatch overhead on a worker (claim, bookkeeping).
    pub dispatch_overhead_s: f64,
    /// Serial per-task cost at the *master*: COMPSs runs one master process
    /// that analyzes, schedules, and launches every task. Dispatch is a
    /// global FCFS resource in the engine; as the cluster grows, the
    /// master's task rate becomes the scaling ceiling — the paper's
    /// "increased overhead from task scheduling" at high core/node counts.
    pub master_dispatch_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            unit_costs: DEFAULT_UNIT_COSTS
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
            default_unit_cost: 2.0e-9,
            dispatch_overhead_s: 250e-6,
            master_dispatch_s: 2.5e-3,
        }
    }
}

impl CostModel {
    /// Override one task type's unit cost (calibration). A non-finite or
    /// negative cost is rejected *here*, with the type named — it would
    /// otherwise surface as a NaN `Time` ordering panic deep inside the
    /// engine's event heap, far from the bad calibration that caused it.
    pub fn set_unit_cost(&mut self, ty: &str, seconds_per_unit: f64) {
        assert!(
            seconds_per_unit.is_finite() && seconds_per_unit >= 0.0,
            "cost model: unit cost for '{ty}' must be finite and >= 0, got {seconds_per_unit}"
        );
        self.unit_costs.insert(ty.to_string(), seconds_per_unit);
    }

    /// Check every constant for non-finite or negative values. Hand-built
    /// models can poison fields directly (they are `pub`), bypassing
    /// [`CostModel::set_unit_cost`]'s assert; the engine calls this at run
    /// start so such a model fails with the offending field named instead
    /// of panicking on a NaN time comparison mid-heap.
    pub fn validate(&self) -> Result<(), String> {
        let bad = |v: f64| !v.is_finite() || v < 0.0;
        if bad(self.default_unit_cost) {
            return Err(format!("default_unit_cost is {}", self.default_unit_cost));
        }
        if bad(self.dispatch_overhead_s) {
            return Err(format!("dispatch_overhead_s is {}", self.dispatch_overhead_s));
        }
        if bad(self.master_dispatch_s) {
            return Err(format!("master_dispatch_s is {}", self.master_dispatch_s));
        }
        for (ty, v) in &self.unit_costs {
            if bad(*v) {
                return Err(format!("unit cost for '{ty}' is {v}"));
            }
        }
        Ok(())
    }

    pub fn unit_cost(&self, ty: &str) -> f64 {
        self.unit_costs
            .get(ty)
            .copied()
            .unwrap_or(self.default_unit_cost)
    }

    /// Execution time of a task on a machine profile. `occupancy` in
    /// [0, 1] is the fraction of the node's cores running workers; GEMM
    /// tasks pay the profile's DRAM-saturation penalty proportionally.
    pub fn exec_time(
        &self,
        ty: &str,
        cost_units: f64,
        gemm_class: bool,
        profile: &MachineProfile,
        occupancy: f64,
    ) -> f64 {
        // GEMM-class tasks are native BLAS calls even from R, so the
        // interpreter factor applies only to non-GEMM (R-level) compute.
        let mut t = cost_units * self.unit_cost(ty) / profile.core_speed;
        if gemm_class {
            if profile.blas == BlasClass::Reference {
                t *= profile.gemm_slowdown;
            }
            t *= 1.0 + profile.mem_sat_gemm * occupancy.clamp(0.0, 1.0);
        } else {
            t *= profile.interpreter_factor;
        }
        t + self.dispatch_overhead_s
    }

    /// Disk I/O time for one serialized file on a node, *excluding*
    /// queueing (the engine's per-node disk server adds that).
    pub fn io_time(&self, bytes: u64, profile: &MachineProfile) -> f64 {
        profile.disk_latency_s + bytes as f64 / profile.disk_bw_bytes_per_s
    }

    /// Cached re-read: a file this node already holds is served from the
    /// page cache (the paper's systems have hundreds of GB of RAM per
    /// node; K-means re-reads its fragments every iteration from cache).
    pub fn cached_read_time(&self, bytes: u64) -> f64 {
        10e-6 + bytes as f64 / 25e9
    }

    /// Backend service time at the shared filesystem for a write.
    pub fn fs_write_time(&self, bytes: u64, profile: &MachineProfile) -> f64 {
        bytes as f64 / profile.fs_bw_bytes_per_s
    }

    /// Inter-node transfer time.
    pub fn transfer_time(&self, bytes: u64, profile: &MachineProfile) -> f64 {
        profile.net_latency_s + bytes as f64 / profile.net_bw_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::MachineProfile;

    #[test]
    fn gemm_slowdown_applies_only_to_gemm_class_on_reference() {
        let m = CostModel::default();
        let sh = MachineProfile::shaheen3();
        let mn = MachineProfile::marenostrum5();
        let fast = m.exec_time("partial_ztz", 1e9, true, &sh, 0.0);
        let slow = m.exec_time("partial_ztz", 1e9, true, &mn, 0.0);
        // ~100x modulo core_speed.
        assert!(slow / fast > 50.0, "ratio {}", slow / fast);
        let non_gemm_fast = m.exec_time("partial_sum", 1e9, false, &sh, 0.0);
        let non_gemm_slow = m.exec_time("partial_sum", 1e9, false, &mn, 0.0);
        assert!(non_gemm_slow / non_gemm_fast < 2.0);
    }

    #[test]
    fn memory_saturation_penalizes_gemm_at_full_occupancy() {
        let m = CostModel::default();
        let sh = MachineProfile::shaheen3();
        let alone = m.exec_time("partial_ztz", 1e9, true, &sh, 0.0);
        let packed = m.exec_time("partial_ztz", 1e9, true, &sh, 1.0);
        assert!((packed / alone - (1.0 + sh.mem_sat_gemm)).abs() < 0.01);
        // Non-GEMM tasks are unaffected.
        let a = m.exec_time("partial_sum", 1e9, false, &sh, 0.0);
        let b = m.exec_time("partial_sum", 1e9, false, &sh, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn io_time_scales_with_bytes() {
        let m = CostModel::default();
        let p = MachineProfile::shaheen3();
        let small = m.io_time(1_000, &p);
        let big = m.io_time(1_000_000_000, &p);
        assert!(big > small * 100.0);
        assert!(small >= p.disk_latency_s);
    }

    #[test]
    fn unknown_types_use_default() {
        let mut m = CostModel::default();
        assert_eq!(m.unit_cost("mystery"), m.default_unit_cost);
        m.set_unit_cost("mystery", 1e-6);
        assert_eq!(m.unit_cost("mystery"), 1e-6);
    }

    #[test]
    #[should_panic(expected = "unit cost for 'bad_type' must be finite")]
    fn set_unit_cost_rejects_nan_at_construction() {
        CostModel::default().set_unit_cost("bad_type", f64::NAN);
    }

    #[test]
    #[should_panic(expected = "must be finite and >= 0")]
    fn set_unit_cost_rejects_negative_costs() {
        CostModel::default().set_unit_cost("bad_type", -1.0);
    }

    #[test]
    fn validate_names_the_poisoned_field() {
        assert!(CostModel::default().validate().is_ok());
        let mut m = CostModel::default();
        m.master_dispatch_s = f64::INFINITY;
        assert!(m.validate().unwrap_err().contains("master_dispatch_s"));
        let mut m = CostModel::default();
        m.unit_costs.insert("poisoned".into(), f64::NAN);
        assert!(m.validate().unwrap_err().contains("poisoned"));
    }
}
