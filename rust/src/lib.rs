//! # RCOMPSs — a scalable task-based runtime system (paper reproduction)
//!
//! This crate reproduces *RCOMPSs: A Scalable Runtime System for R Code
//! Execution on Manycore Systems* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the COMPSs-style task runtime: a versioned
//!   data registry, automatic dependency detection, dynamic DAG
//!   construction, pluggable schedulers, persistent worker executors,
//!   file-based parameter serialization (the paper's Table-1 codec set),
//!   fault tolerance, Extrae-like tracing, and a discrete-event cluster
//!   simulator for scale-out experiments.
//! * **Layer 2 (python/compile/model.py)** — the benchmark task bodies
//!   (KNN / K-means / linear regression fragments) as jax functions,
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, lowered inside the L2 functions.
//!
//! Python runs only at build time (`make artifacts`); the Rust binary loads
//! the artifacts through PJRT (`runtime` module) and is self-contained.
//!
//! ## Quickstart
//!
//! ```no_run
//! use rcompss::prelude::*;
//!
//! let rt = CompssRuntime::start(RuntimeConfig::local(4)).unwrap();
//! let add = rt.register_task(TaskDef::new("add", 2, |args| {
//!     let x = args[0].as_f64().unwrap();
//!     let y = args[1].as_f64().unwrap();
//!     Ok(vec![RValue::scalar(x + y)])
//! }));
//! let a = rt.submit(&add, &[RValue::scalar(4.0).into(), RValue::scalar(5.0).into()]).unwrap();
//! let b = rt.submit(&add, &[RValue::scalar(6.0).into(), RValue::scalar(7.0).into()]).unwrap();
//! let c = rt.submit(&add, &[a.into(), b.into()]).unwrap();
//! let res = rt.wait_on(&c).unwrap();
//! assert_eq!(res.as_f64().unwrap(), 22.0);
//! rt.stop().unwrap();
//! ```

pub mod api;
pub mod apps;
pub mod bench_harness;
pub mod blas;
pub mod cluster;
pub mod coordinator;
pub mod runtime;
pub mod serialization;
pub mod sim;
pub mod trace;
pub mod util;
pub mod value;

/// Convenience re-exports covering the public programming model —
/// the analog of `library(RCOMPSs)`.
pub mod prelude {
    pub use crate::api::{CompssRuntime, DataRef, RuntimeConfig, TaskArg, TaskDef};
    pub use crate::coordinator::access::Direction;
    pub use crate::value::RValue;
}

/// Crate version, reported by the CLI (`rcompss --version`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// COMPSs version the paper built against; reported for parity.
pub const COMPSS_COMPAT: &str = "3.3.2";
