//! Random `RValue` generators: deterministic synthetic data for the codec
//! benchmarks (Table 1 uses "square blocks" of doubles) and arbitrary nested
//! values for property tests.

use crate::util::prng::Pcg64;
use crate::value::{RValue, NA_INTEGER, NA_REAL};

/// Generator facade over a PRNG.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
}

impl<'a> Gen<'a> {
    pub fn new(rng: &'a mut Pcg64) -> Gen<'a> {
        Gen { rng }
    }

    /// Square numeric block of side `n` — the Table-1 payload shape
    /// ("10K" in the paper = a 10000x10000 double matrix).
    pub fn square_block(&mut self, n: usize) -> RValue {
        let mut data = vec![0.0f64; n * n];
        self.rng.fill_f64(&mut data);
        RValue::matrix(data, n, n)
    }

    /// Numeric matrix with standard-normal entries.
    pub fn normal_matrix(&mut self, nrow: usize, ncol: usize) -> RValue {
        let mut data = Vec::with_capacity(nrow * ncol);
        for _ in 0..nrow * ncol {
            data.push(self.rng.normal());
        }
        RValue::matrix(data, nrow, ncol)
    }

    /// Real vector with a fraction of NA_real_ entries — exercises codec NA
    /// fidelity.
    pub fn real_vec_with_na(&mut self, len: usize, na_frac: f64) -> RValue {
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            if self.rng.chance(na_frac) {
                v.push(NA_REAL);
            } else {
                v.push(self.rng.uniform(-1e6, 1e6));
            }
        }
        RValue::Real(v)
    }

    /// Integer vector with NAs.
    pub fn int_vec_with_na(&mut self, len: usize, na_frac: f64) -> RValue {
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            if self.rng.chance(na_frac) {
                v.push(NA_INTEGER);
            } else {
                v.push(self.rng.next_u64() as i32);
            }
        }
        RValue::Int(v)
    }

    /// Character vector of plausible tokens (mixed ASCII + a few multibyte).
    pub fn str_vec(&mut self, len: usize) -> RValue {
        const WORDS: [&str; 8] = [
            "alpha", "beta", "gamma", "delta", "épsilon", "θeta", "fragment", "centroid",
        ];
        let v = (0..len)
            .map(|_| {
                let w = WORDS[self.rng.below_usize(WORDS.len())];
                format!("{w}_{}", self.rng.below(1000))
            })
            .collect();
        RValue::Str(v)
    }

    /// Arbitrary nested value up to `depth`; used by the codec property
    /// tests — every codec must round-trip anything this produces.
    pub fn arbitrary(&mut self, depth: usize) -> RValue {
        let top = if depth == 0 { 6 } else { 8 };
        match self.rng.below(top) {
            0 => RValue::Null,
            1 => {
                let len = self.rng.below_usize(20);
                RValue::Logical(
                    (0..len)
                        .map(|_| match self.rng.below(3) {
                            0 => 0,
                            1 => 1,
                            _ => NA_INTEGER,
                        })
                        .collect(),
                )
            }
            2 => {
                let len = self.rng.below_usize(30);
                self.int_vec_with_na(len, 0.1)
            }
            3 => {
                let len = self.rng.below_usize(30);
                self.real_vec_with_na(len, 0.1)
            }
            4 => {
                let len = self.rng.below_usize(10);
                self.str_vec(len)
            }
            5 => RValue::Raw(
                (0..self.rng.below_usize(40))
                    .map(|_| self.rng.next_u64() as u8)
                    .collect(),
            ),
            6 => {
                let nrow = 1 + self.rng.below_usize(6);
                let ncol = 1 + self.rng.below_usize(6);
                self.normal_matrix(nrow, ncol)
            }
            _ => {
                let slots = self.rng.below_usize(4);
                RValue::List(
                    (0..slots)
                        .map(|i| {
                            let name = if self.rng.chance(0.5) {
                                format!("slot{i}")
                            } else {
                                String::new()
                            };
                            (name, self.arbitrary(depth - 1))
                        })
                        .collect(),
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_block_dims() {
        let mut rng = Pcg64::seeded(1);
        let b = Gen::new(&mut rng).square_block(16);
        let (_, r, c) = b.as_matrix().unwrap();
        assert_eq!((r, c), (16, 16));
    }

    #[test]
    fn na_fraction_roughly_respected() {
        let mut rng = Pcg64::seeded(2);
        let v = Gen::new(&mut rng).real_vec_with_na(10_000, 0.2);
        let nas = v
            .as_real()
            .unwrap()
            .iter()
            .filter(|x| crate::value::is_na_real(**x))
            .count();
        assert!((1500..2500).contains(&nas), "nas={nas}");
    }

    #[test]
    fn arbitrary_is_deterministic() {
        let mut r1 = Pcg64::seeded(3);
        let mut r2 = Pcg64::seeded(3);
        let a = Gen::new(&mut r1).arbitrary(3);
        let b = Gen::new(&mut r2).arbitrary(3);
        assert!(a.identical(&b));
    }

    #[test]
    fn arbitrary_depth_zero_is_flat() {
        let mut rng = Pcg64::seeded(4);
        for _ in 0..50 {
            let v = Gen::new(&mut rng).arbitrary(0);
            assert!(!matches!(v, RValue::List(_) | RValue::Matrix { .. }));
        }
    }
}
