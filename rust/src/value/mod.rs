//! The R value model.
//!
//! RCOMPSs moves *R objects* between tasks; this module is the Rust stand-in
//! for R's SEXP universe. It models the types that actually cross task
//! boundaries in the paper's three applications (numeric vectors/matrices,
//! integer and logical vectors, strings, named lists, raw byte vectors) plus
//! R's NA semantics, since every Table-1 codec has to round-trip them
//! faithfully.
//!
//! Design notes:
//! * Numeric data is `f64` (R "double"); R's `NA_real_` is a specific quiet
//!   NaN payload, modelled here by [`NA_REAL`] with bit-exact round-trips.
//! * Integer NA is `i32::MIN`, exactly as in R.
//! * Matrices are column-major with explicit `nrow`/`ncol` — R layout — so
//!   codec output is byte-comparable with what an R process would write.

mod generate;

pub use generate::Gen;

use std::fmt;

/// R's `NA_real_`: a quiet NaN with the low word 1954 (the year R's authors
/// chose; this is the actual bit pattern R uses).
pub const NA_REAL: f64 = f64::from_bits(0x7FF0_0000_0000_07A2);

/// R's integer NA.
pub const NA_INTEGER: i32 = i32::MIN;

/// R's logical NA (logicals are ints in R).
pub const NA_LOGICAL: i32 = i32::MIN;

/// Returns true iff `x` is R's NA_real_ (bit-exact, distinct from plain NaN).
#[inline]
pub fn is_na_real(x: f64) -> bool {
    x.to_bits() == NA_REAL.to_bits()
}

/// A value in the R object model.
#[derive(Clone, Debug, PartialEq)]
pub enum RValue {
    /// R `NULL`.
    Null,
    /// Logical vector; elements are 0/1/NA_LOGICAL as in R.
    Logical(Vec<i32>),
    /// Integer vector.
    Int(Vec<i32>),
    /// Double vector.
    Real(Vec<f64>),
    /// Character vector.
    Str(Vec<String>),
    /// Numeric matrix, column-major (R layout).
    Matrix {
        data: Vec<f64>,
        nrow: usize,
        ncol: usize,
    },
    /// Named list (R `list`); names may be empty strings for unnamed slots.
    List(Vec<(String, RValue)>),
    /// Raw byte vector.
    Raw(Vec<u8>),
}

impl RValue {
    // ---- constructors ----------------------------------------------------

    /// Length-1 double vector — R's scalar.
    pub fn scalar(x: f64) -> RValue {
        RValue::Real(vec![x])
    }

    /// Length-1 integer vector.
    pub fn int_scalar(x: i32) -> RValue {
        RValue::Int(vec![x])
    }

    /// Length-1 character vector.
    pub fn string(s: &str) -> RValue {
        RValue::Str(vec![s.to_string()])
    }

    /// Column-major matrix from parts; panics unless `data.len() == nrow*ncol`.
    pub fn matrix(data: Vec<f64>, nrow: usize, ncol: usize) -> RValue {
        assert_eq!(data.len(), nrow * ncol, "matrix dims do not match data");
        RValue::Matrix { data, nrow, ncol }
    }

    /// Zero-filled matrix.
    pub fn zeros(nrow: usize, ncol: usize) -> RValue {
        RValue::Matrix {
            data: vec![0.0; nrow * ncol],
            nrow,
            ncol,
        }
    }

    // ---- accessors -------------------------------------------------------

    /// Scalar double out of a length-1 Real/Int/Logical vector.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            RValue::Real(v) if v.len() == 1 => Some(v[0]),
            RValue::Int(v) if v.len() == 1 && v[0] != NA_INTEGER => Some(v[0] as f64),
            RValue::Logical(v) if v.len() == 1 && v[0] != NA_LOGICAL => Some(v[0] as f64),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<i32> {
        match self {
            RValue::Int(v) if v.len() == 1 => Some(v[0]),
            _ => None,
        }
    }

    pub fn as_real(&self) -> Option<&[f64]> {
        match self {
            RValue::Real(v) => Some(v),
            RValue::Matrix { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<&[i32]> {
        match self {
            RValue::Int(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str_vec(&self) -> Option<&[String]> {
        match self {
            RValue::Str(v) => Some(v),
            _ => None,
        }
    }

    /// Matrix view: (data, nrow, ncol).
    pub fn as_matrix(&self) -> Option<(&[f64], usize, usize)> {
        match self {
            RValue::Matrix { data, nrow, ncol } => Some((data, *nrow, *ncol)),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[(String, RValue)]> {
        match self {
            RValue::List(items) => Some(items),
            _ => None,
        }
    }

    /// Look up a list element by name.
    pub fn list_get(&self, name: &str) -> Option<&RValue> {
        self.as_list()?.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// Number of elements at the top level (R `length()` semantics:
    /// matrices count elements, lists count slots, NULL is 0).
    pub fn len(&self) -> usize {
        match self {
            RValue::Null => 0,
            RValue::Logical(v) => v.len(),
            RValue::Int(v) => v.len(),
            RValue::Real(v) => v.len(),
            RValue::Str(v) => v.len(),
            RValue::Matrix { data, .. } => data.len(),
            RValue::List(v) => v.len(),
            RValue::Raw(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate payload size in bytes — used by the schedulers for
    /// locality decisions and by the simulator's transfer model.
    pub fn byte_size(&self) -> usize {
        match self {
            RValue::Null => 0,
            RValue::Logical(v) | RValue::Int(v) => v.len() * 4,
            RValue::Real(v) => v.len() * 8,
            RValue::Str(v) => v.iter().map(|s| s.len() + 8).sum(),
            RValue::Matrix { data, .. } => data.len() * 8,
            RValue::List(v) => v
                .iter()
                .map(|(n, x)| n.len() + 8 + x.byte_size())
                .sum::<usize>(),
            RValue::Raw(v) => v.len(),
        }
    }

    /// R-ish type name, used in logs and trace metadata.
    pub fn type_name(&self) -> &'static str {
        match self {
            RValue::Null => "NULL",
            RValue::Logical(_) => "logical",
            RValue::Int(_) => "integer",
            RValue::Real(_) => "double",
            RValue::Str(_) => "character",
            RValue::Matrix { .. } => "matrix",
            RValue::List(_) => "list",
            RValue::Raw(_) => "raw",
        }
    }

    /// Structural equality with bit-exact NA handling and exact float
    /// compare — what "the codec round-tripped correctly" means.
    pub fn identical(&self, other: &RValue) -> bool {
        fn f64_ident(a: f64, b: f64) -> bool {
            a.to_bits() == b.to_bits() || (a == b)
        }
        match (self, other) {
            (RValue::Null, RValue::Null) => true,
            (RValue::Logical(a), RValue::Logical(b)) | (RValue::Int(a), RValue::Int(b)) => a == b,
            (RValue::Real(a), RValue::Real(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| f64_ident(*x, *y))
            }
            (RValue::Str(a), RValue::Str(b)) => a == b,
            (
                RValue::Matrix { data: a, nrow: r1, ncol: c1 },
                RValue::Matrix { data: b, nrow: r2, ncol: c2 },
            ) => r1 == r2 && c1 == c2 && a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| f64_ident(*x, *y)),
            (RValue::List(a), RValue::List(b)) => {
                a.len() == b.len()
                    && a.iter()
                        .zip(b)
                        .all(|((n1, v1), (n2, v2))| n1 == n2 && v1.identical(v2))
            }
            (RValue::Raw(a), RValue::Raw(b)) => a == b,
            _ => false,
        }
    }

    /// Approximate numeric equality (`all.equal` style) for compute results.
    pub fn all_equal(&self, other: &RValue, tol: f64) -> bool {
        fn close(a: f64, b: f64, tol: f64) -> bool {
            if is_na_real(a) && is_na_real(b) {
                return true;
            }
            if a.is_nan() || b.is_nan() {
                return a.is_nan() && b.is_nan();
            }
            let scale = a.abs().max(b.abs()).max(1.0);
            (a - b).abs() <= tol * scale
        }
        match (self, other) {
            (RValue::Real(a), RValue::Real(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| close(*x, *y, tol))
            }
            (
                RValue::Matrix { data: a, nrow: r1, ncol: c1 },
                RValue::Matrix { data: b, nrow: r2, ncol: c2 },
            ) => r1 == r2 && c1 == c2 && a.iter().zip(b).all(|(x, y)| close(*x, *y, tol)),
            (RValue::List(a), RValue::List(b)) => {
                a.len() == b.len()
                    && a.iter()
                        .zip(b)
                        .all(|((n1, v1), (n2, v2))| n1 == n2 && v1.all_equal(v2, tol))
            }
            _ => self.identical(other),
        }
    }

    /// Matrix element (row-major index math over column-major storage).
    #[inline]
    pub fn mat_get(&self, r: usize, c: usize) -> Option<f64> {
        match self {
            RValue::Matrix { data, nrow, ncol } if r < *nrow && c < *ncol => {
                Some(data[c * nrow + r])
            }
            _ => None,
        }
    }
}

impl fmt::Display for RValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RValue::Null => write!(f, "NULL"),
            RValue::Matrix { nrow, ncol, .. } => write!(f, "matrix[{nrow}x{ncol}]"),
            RValue::List(items) => write!(f, "list({} slots)", items.len()),
            RValue::Real(v) if v.len() == 1 => write!(f, "{}", v[0]),
            other => write!(f, "{}[{}]", other.type_name(), other.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn na_real_is_bit_exact_nan() {
        assert!(NA_REAL.is_nan());
        assert!(is_na_real(NA_REAL));
        assert!(!is_na_real(f64::NAN));
        assert!(!is_na_real(1.0));
    }

    #[test]
    fn scalar_accessors() {
        assert_eq!(RValue::scalar(3.5).as_f64(), Some(3.5));
        assert_eq!(RValue::int_scalar(7).as_f64(), Some(7.0));
        assert_eq!(RValue::int_scalar(NA_INTEGER).as_f64(), None);
        assert_eq!(RValue::Real(vec![1.0, 2.0]).as_f64(), None);
    }

    #[test]
    fn matrix_layout_is_column_major() {
        // 2x3 matrix, columns [1,2], [3,4], [5,6].
        let m = RValue::matrix(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        assert_eq!(m.mat_get(0, 0), Some(1.0));
        assert_eq!(m.mat_get(1, 0), Some(2.0));
        assert_eq!(m.mat_get(0, 2), Some(5.0));
        assert_eq!(m.mat_get(2, 0), None);
    }

    #[test]
    #[should_panic(expected = "matrix dims")]
    fn matrix_dim_mismatch_panics() {
        RValue::matrix(vec![1.0], 2, 2);
    }

    #[test]
    fn identical_distinguishes_na_and_nan() {
        let a = RValue::Real(vec![NA_REAL]);
        let b = RValue::Real(vec![f64::NAN]);
        assert!(a.identical(&a.clone()));
        assert!(!a.identical(&b));
    }

    #[test]
    fn list_get_by_name() {
        let l = RValue::List(vec![
            ("beta".into(), RValue::scalar(2.0)),
            ("rss".into(), RValue::scalar(0.5)),
        ]);
        assert_eq!(l.list_get("rss").unwrap().as_f64(), Some(0.5));
        assert!(l.list_get("zzz").is_none());
    }

    #[test]
    fn byte_size_accounts_payload() {
        assert_eq!(RValue::Real(vec![0.0; 10]).byte_size(), 80);
        assert_eq!(RValue::Int(vec![0; 10]).byte_size(), 40);
        assert_eq!(RValue::zeros(4, 4).byte_size(), 128);
    }

    #[test]
    fn all_equal_tolerates_small_error() {
        let a = RValue::Real(vec![1.0, 2.0]);
        let b = RValue::Real(vec![1.0 + 1e-12, 2.0 - 1e-12]);
        assert!(a.all_equal(&b, 1e-9));
        assert!(!a.all_equal(&RValue::Real(vec![1.1, 2.0]), 1e-9));
    }
}
