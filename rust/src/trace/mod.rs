//! Extrae-style tracing and Paraver-style rendering (§3.3.4, Figure 10).
//!
//! RCOMPSs integrates Extrae to record R-level execution events and renders
//! them post-mortem with Paraver. This module is that substrate: the
//! [`Tracer`] collects timestamped per-worker events from the live executor
//! *and* from the discrete-event simulator (same event vocabulary), then:
//!
//! * [`Trace::to_prv`] writes a Paraver-like `.prv` state-record file, and
//! * [`Trace::ascii_timeline`] renders the Figure-10 view — one row per
//!   worker, one glyph per time bucket, colored/lettered by task type —
//!   directly on the terminal.
//!
//! Event kinds mirror what the paper's traces distinguish: worker
//! initialization (the MareNostrum-5 stagger!), task execution by type,
//! (de)serialization, and inter-node transfers.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;

/// A worker slot: node + executor index within the node.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId {
    pub node: NodeId,
    pub slot: u32,
}

impl std::fmt::Display for WorkerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}w{}", self.node.0, self.slot)
    }
}

/// What happened during an interval.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Worker process/interpreter initialization.
    WorkerInit,
    /// Task body execution; payload is the (interned) task type name — the
    /// executor and the simulator share the spec's allocation instead of
    /// cloning a `String` per event.
    TaskExec(Arc<str>),
    /// Parameter serialization (master or worker side).
    Serialize,
    /// Parameter deserialization.
    Deserialize,
    /// Inter-node file transfer.
    Transfer,
}

impl EventKind {
    /// Paraver state id (arbitrary but stable).
    fn state_id(&self) -> u32 {
        match self {
            EventKind::WorkerInit => 1,
            EventKind::TaskExec(_) => 2,
            EventKind::Serialize => 3,
            EventKind::Deserialize => 4,
            EventKind::Transfer => 5,
        }
    }
}

/// One timed interval on one worker.
#[derive(Clone, Debug)]
pub struct Event {
    pub worker: WorkerId,
    pub kind: EventKind,
    pub task: Option<TaskId>,
    /// Seconds since run start.
    pub start: f64,
    pub end: f64,
}

/// A completed trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Label for headers ("knn@shaheen3, 4 nodes").
    pub label: String,
}

/// Thread-safe collector. The live executor stamps times from a monotonic
/// clock; the simulator passes virtual times through [`Tracer::record_at`].
pub struct Tracer {
    inner: Mutex<Vec<Event>>,
    epoch: Instant,
    enabled: bool,
}

impl Tracer {
    pub fn new(enabled: bool) -> Tracer {
        Tracer {
            inner: Mutex::new(Vec::new()),
            epoch: Instant::now(),
            enabled,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Seconds since tracer creation — the live-mode clock.
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record an interval with explicit times (virtual or measured).
    pub fn record_at(
        &self,
        worker: WorkerId,
        kind: EventKind,
        task: Option<TaskId>,
        start: f64,
        end: f64,
    ) {
        if !self.enabled {
            return;
        }
        self.inner.lock().unwrap().push(Event {
            worker,
            kind,
            task,
            start,
            end,
        });
    }

    /// Convenience for live mode: run `f`, recording its wall-time extent.
    pub fn timed<T>(
        &self,
        worker: WorkerId,
        kind: EventKind,
        task: Option<TaskId>,
        f: impl FnOnce() -> T,
    ) -> T {
        if !self.enabled {
            return f();
        }
        let start = self.now();
        let out = f();
        self.record_at(worker, kind, task, start, self.now());
        out
    }

    /// Snapshot into an immutable trace.
    pub fn finish(&self, label: &str) -> Trace {
        let mut events = self.inner.lock().unwrap().clone();
        events.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        Trace {
            events,
            label: label.to_string(),
        }
    }
}

impl Trace {
    /// Total span in seconds.
    pub fn makespan(&self) -> f64 {
        self.events.iter().map(|e| e.end).fold(0.0, f64::max)
    }

    /// Busy time per worker (task execution only).
    pub fn busy_per_worker(&self) -> BTreeMap<WorkerId, f64> {
        let mut m = BTreeMap::new();
        for e in &self.events {
            if matches!(e.kind, EventKind::TaskExec(_)) {
                *m.entry(e.worker).or_insert(0.0) += e.end - e.start;
            }
        }
        m
    }

    /// Fraction of worker-time spent executing tasks (a load-balance /
    /// overhead summary the paper discusses qualitatively on Figure 10).
    pub fn utilization(&self) -> f64 {
        let span = self.makespan();
        if span == 0.0 {
            return 0.0;
        }
        let workers: std::collections::BTreeSet<WorkerId> =
            self.events.iter().map(|e| e.worker).collect();
        if workers.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.busy_per_worker().values().sum();
        busy / (span * workers.len() as f64)
    }

    /// Paraver-style `.prv` state records:
    /// `1:node:1:1:worker:start_ns:end_ns:state`.
    pub fn to_prv(&self) -> String {
        let mut out = String::new();
        let span_ns = (self.makespan() * 1e9) as u64;
        let workers: std::collections::BTreeSet<WorkerId> =
            self.events.iter().map(|e| e.worker).collect();
        writeln!(
            out,
            "#Paraver (rcompss '{label}'):{span}_ns:1:{n}:{n}",
            label = self.label,
            span = span_ns,
            n = workers.len()
        )
        .unwrap();
        for e in &self.events {
            writeln!(
                out,
                "1:{}:1:1:{}:{}:{}:{}",
                e.worker.node.0 + 1,
                e.worker.slot + 1,
                (e.start * 1e9) as u64,
                (e.end * 1e9) as u64,
                e.kind.state_id()
            )
            .unwrap();
        }
        out
    }

    /// ASCII rendering of the Figure-10 timeline: one row per worker,
    /// `width` buckets across the makespan, each bucket showing the
    /// dominant event kind (task types get stable letters, `#` init,
    /// `s`/`d` serialization, `>` transfer, `.` idle).
    pub fn ascii_timeline(&self, width: usize) -> String {
        assert!(width > 0);
        let span = self.makespan().max(1e-12);
        let workers: Vec<WorkerId> = {
            let set: std::collections::BTreeSet<WorkerId> =
                self.events.iter().map(|e| e.worker).collect();
            set.into_iter().collect()
        };
        let widx: BTreeMap<WorkerId, usize> =
            workers.iter().enumerate().map(|(i, w)| (*w, i)).collect();

        // Stable letter per task type, in first-seen order: A, B, C ...
        let mut letters: BTreeMap<String, char> = BTreeMap::new();
        for e in &self.events {
            if let EventKind::TaskExec(ty) = &e.kind {
                if !letters.contains_key(ty.as_ref()) {
                    let c = (b'A' + (letters.len() as u8 % 26)) as char;
                    letters.insert(ty.to_string(), c);
                }
            }
        }

        // Dominant kind per (worker, bucket) by covered time.
        let mut cover = vec![vec![(0.0f64, ' '); width]; workers.len()];
        for e in &self.events {
            let row = widx[&e.worker];
            let glyph = match &e.kind {
                EventKind::WorkerInit => '#',
                EventKind::TaskExec(ty) => letters[ty.as_ref()],
                EventKind::Serialize => 's',
                EventKind::Deserialize => 'd',
                EventKind::Transfer => '>',
            };
            let b0 = ((e.start / span) * width as f64).floor() as usize;
            let b1 = (((e.end / span) * width as f64).ceil() as usize).min(width);
            for (b, slot) in cover[row].iter_mut().enumerate().take(b1).skip(b0.min(width)) {
                let lo = span * b as f64 / width as f64;
                let hi = span * (b + 1) as f64 / width as f64;
                let overlap = (e.end.min(hi) - e.start.max(lo)).max(0.0);
                if overlap > slot.0 {
                    *slot = (overlap, glyph);
                }
            }
        }

        let mut out = String::new();
        writeln!(
            out,
            "trace: {}  span={:.3}s  util={:.0}%",
            self.label,
            span,
            self.utilization() * 100.0
        )
        .unwrap();
        for (ty, c) in &letters {
            writeln!(out, "  {c} = {ty}").unwrap();
        }
        writeln!(out, "  # = worker init, s/d = ser/deser, > = transfer, . = idle").unwrap();
        for (i, w) in workers.iter().enumerate() {
            let row: String = cover[i]
                .iter()
                .map(|(t, c)| if *t > 0.0 { *c } else { '.' })
                .collect();
            writeln!(out, "{w:>8} |{row}|").unwrap();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(node: u32, slot: u32) -> WorkerId {
        WorkerId {
            node: NodeId(node),
            slot,
        }
    }

    fn sample_trace() -> Trace {
        let t = Tracer::new(true);
        t.record_at(w(0, 0), EventKind::WorkerInit, None, 0.0, 0.1);
        t.record_at(
            w(0, 0),
            EventKind::TaskExec("fill".into()),
            Some(TaskId(1)),
            0.1,
            0.6,
        );
        t.record_at(
            w(0, 1),
            EventKind::TaskExec("merge".into()),
            Some(TaskId(2)),
            0.3,
            1.0,
        );
        t.record_at(w(0, 1), EventKind::Serialize, Some(TaskId(2)), 1.0, 1.1);
        t.finish("unit")
    }

    #[test]
    fn makespan_and_busy() {
        let tr = sample_trace();
        assert!((tr.makespan() - 1.1).abs() < 1e-9);
        let busy = tr.busy_per_worker();
        assert!((busy[&w(0, 0)] - 0.5).abs() < 1e-9);
        assert!((busy[&w(0, 1)] - 0.7).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        let tr = sample_trace();
        let u = tr.utilization();
        assert!(u > 0.0 && u <= 1.0, "u={u}");
    }

    #[test]
    fn prv_has_header_and_records() {
        let tr = sample_trace();
        let prv = tr.to_prv();
        assert!(prv.starts_with("#Paraver"));
        assert_eq!(prv.lines().count(), 1 + tr.events.len());
        // A task record carries state 2.
        assert!(prv.lines().any(|l| l.ends_with(":2")));
    }

    #[test]
    fn ascii_timeline_shape() {
        let tr = sample_trace();
        let art = tr.ascii_timeline(40);
        // Two worker rows with 40-char lanes.
        let lanes: Vec<&str> = art.lines().filter(|l| l.contains('|')).collect();
        assert_eq!(lanes.len(), 2);
        assert!(lanes[0].contains('#'), "init glyph: {}", lanes[0]);
        assert!(lanes[0].contains('A'), "first task letter: {}", lanes[0]);
        assert!(lanes[1].contains('B'));
        assert!(lanes[1].contains('s'));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(false);
        t.record_at(w(0, 0), EventKind::Serialize, None, 0.0, 1.0);
        let out = t.timed(w(0, 0), EventKind::Transfer, None, || 42);
        assert_eq!(out, 42);
        assert!(t.finish("x").events.is_empty());
    }

    #[test]
    fn timed_records_interval() {
        let t = Tracer::new(true);
        t.timed(w(1, 0), EventKind::Deserialize, None, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        let tr = t.finish("x");
        assert_eq!(tr.events.len(), 1);
        assert!(tr.events[0].end > tr.events[0].start);
    }
}
