//! PJRT runtime: load and execute the AOT artifacts from the worker hot
//! path.
//!
//! The bridge pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled once per
//! thread and cached (the `xla` crate's client is `Rc`-based, so each
//! persistent worker thread owns a thread-local engine — compile cost is
//! paid once per worker per task type, consistent with the persistent
//! worker model).
//!
//! This is the "Intel MKL" side of the paper's BLAS dichotomy: XLA's
//! vectorized CPU kernels play MKL, `crate::blas` plays reference RBLAS,
//! and `benches/runtime_hotpath.rs` measures the actual ratio that the
//! simulator's cost model consumes.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod tensor;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};

/// Where the artifacts live: `$RCOMPSS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("RCOMPSS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Quick availability probe (apps fall back to native BLAS when absent).
/// Always false without the `pjrt` feature: the artifacts cannot be
/// executed, so the backends must not select them.
pub fn artifacts_available() -> bool {
    cfg!(feature = "pjrt") && artifacts_dir().join("manifest.json").exists()
}

/// A per-thread PJRT engine: client + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Create an engine over an artifact directory.
    pub fn new(dir: &std::path::Path) -> Result<PjrtEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtEngine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a task type.
    fn executable(&self, task: &str) -> Result<()> {
        if self.cache.borrow().contains_key(task) {
            return Ok(());
        }
        let spec = self.manifest.task(task)?;
        let path_str = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parse HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact '{task}'"))?;
        self.cache.borrow_mut().insert(task.to_string(), exe);
        Ok(())
    }

    /// Execute a task artifact on literals. Inputs are validated against
    /// the manifest; the tuple output is flattened to one literal per
    /// declared output.
    pub fn execute(&self, task: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.task(task)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "task '{task}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (lit, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let have = lit.element_count();
            let want = ts.element_count();
            if have != want {
                bail!(
                    "task '{task}' input {i}: {have} elements, manifest says {want} \
                     (shape {:?})",
                    ts.shape
                );
            }
        }
        self.executable(task)?;
        let cache = self.cache.borrow();
        let exe = cache.get(task).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute '{task}'"))?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow::anyhow!("no output buffer from '{task}'"))?;
        let lit = first
            .to_literal_sync()
            .with_context(|| format!("fetch result of '{task}'"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = lit.to_tuple().context("decompose result tuple")?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "task '{task}' produced {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Number of compiled executables in this thread's cache.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(feature = "pjrt")]
thread_local! {
    static ENGINE: RefCell<Option<PjrtEngine>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's engine, creating it on first use.
/// Fails if artifacts are missing — call [`artifacts_available`] first.
#[cfg(feature = "pjrt")]
pub fn with_engine<T>(f: impl FnOnce(&PjrtEngine) -> Result<T>) -> Result<T> {
    ENGINE.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(PjrtEngine::new(&artifacts_dir())?);
        }
        f(slot.as_ref().unwrap())
    })
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        // Tests run from the crate root, where `artifacts/` lives.
        artifacts_available()
    }

    #[test]
    fn merge_add2_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        with_engine(|eng| {
            let k = eng.manifest().shape("km_k")?; // 16
            let a = xla::Literal::vec1(&vec![1.5f32; k]);
            let b = xla::Literal::vec1(&vec![2.5f32; k]);
            let outs = eng.execute("merge_add2_kmcounts", &[a, b])?;
            assert_eq!(outs.len(), 1);
            let v = outs[0].to_vec::<f32>()?;
            assert!(v.iter().all(|x| (*x - 4.0).abs() < 1e-6));
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn input_arity_and_shape_validated() {
        if !have_artifacts() {
            return;
        }
        with_engine(|eng| {
            let a = xla::Literal::vec1(&vec![1.0f32; 16]);
            assert!(eng.execute("merge_add2_kmcounts", &[a]).is_err());
            let small = xla::Literal::vec1(&vec![1.0f32; 3]);
            let b = xla::Literal::vec1(&vec![1.0f32; 16]);
            assert!(eng.execute("merge_add2_kmcounts", &[small, b]).is_err());
            assert!(eng
                .execute("not_a_task", &[xla::Literal::vec1(&[0f32])])
                .is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn executables_are_cached() {
        if !have_artifacts() {
            return;
        }
        with_engine(|eng| {
            let k = eng.manifest().shape("km_k")?;
            let before = eng.compiled_count();
            let a = xla::Literal::vec1(&vec![0f32; k]);
            let b = xla::Literal::vec1(&vec![0f32; k]);
            eng.execute("merge_add2_kmcounts", &[a, b])?;
            let after_first = eng.compiled_count();
            let a = xla::Literal::vec1(&vec![0f32; k]);
            let b = xla::Literal::vec1(&vec![0f32; k]);
            eng.execute("merge_add2_kmcounts", &[a, b])?;
            assert!(after_first >= before);
            assert_eq!(eng.compiled_count(), after_first, "second call reuses cache");
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn every_artifact_compiles() {
        // Catches HLO the Rust-side XLA cannot run (e.g. LAPACK typed-FFI
        // custom-calls) the moment an artifact regresses.
        if !have_artifacts() {
            return;
        }
        with_engine(|eng| {
            let names: Vec<String> = eng.manifest().tasks.keys().cloned().collect();
            for name in names {
                eng.executable(&name)
                    .unwrap_or_else(|e| panic!("artifact '{name}' failed to compile: {e:#}"));
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn lr_solve_solves_identity_system() {
        if !have_artifacts() {
            return;
        }
        with_engine(|eng| {
            let p = eng.manifest().shape("lr_p")?; // 256
            // ztz = I, zty = e -> beta = e (up to the 1e-6 ridge).
            let mut eye = vec![0f32; p * p];
            for i in 0..p {
                eye[i * p + i] = 1.0;
            }
            let rhs: Vec<f32> = (0..p).map(|i| (i % 7) as f32).collect();
            let ztz = xla::Literal::vec1(&eye).reshape(&[p as i64, p as i64])?;
            let zty = xla::Literal::vec1(&rhs);
            let outs = eng.execute("lr_solve", &[ztz, zty])?;
            let beta = outs[0].to_vec::<f32>()?;
            for (b, r) in beta.iter().zip(rhs.iter()) {
                assert!((b - r).abs() < 1e-3, "{b} vs {r}");
            }
            Ok(())
        })
        .unwrap();
    }
}
