//! Conversions between the R value model and PJRT literals.
//!
//! `RValue` matrices are column-major f64 (R layout); the L2 jax functions
//! take row-major f32/i32 arrays. These helpers do the layout + dtype
//! conversion at the app/runtime boundary in one pass.

use anyhow::{anyhow, Result};

use crate::value::RValue;

/// Column-major f64 matrix -> row-major f32 literal of shape (nrow, ncol).
pub fn matrix_to_f32_literal(v: &RValue) -> Result<xla::Literal> {
    let (data, nrow, ncol) = v
        .as_matrix()
        .ok_or_else(|| anyhow!("expected matrix, got {}", v.type_name()))?;
    let mut row_major = vec![0f32; nrow * ncol];
    for c in 0..ncol {
        let col = &data[c * nrow..(c + 1) * nrow];
        for (r, x) in col.iter().enumerate() {
            row_major[r * ncol + c] = *x as f32;
        }
    }
    Ok(xla::Literal::vec1(&row_major).reshape(&[nrow as i64, ncol as i64])?)
}

/// Real vector -> f32 literal (1-D).
pub fn real_to_f32_literal(v: &RValue) -> Result<xla::Literal> {
    let xs = v
        .as_real()
        .ok_or_else(|| anyhow!("expected double vector, got {}", v.type_name()))?;
    let f: Vec<f32> = xs.iter().map(|x| *x as f32).collect();
    Ok(xla::Literal::vec1(&f))
}

/// Real vector (flat, row-major order) -> f32 literal reshaped to dims.
pub fn real_to_f32_literal_shaped(v: &RValue, dims: &[usize]) -> Result<xla::Literal> {
    let xs = v
        .as_real()
        .ok_or_else(|| anyhow!("expected double vector, got {}", v.type_name()))?;
    let want: usize = dims.iter().product();
    if xs.len() != want {
        anyhow::bail!("shape mismatch: {} elements for dims {:?}", xs.len(), dims);
    }
    let f: Vec<f32> = xs.iter().map(|x| *x as f32).collect();
    let dims_i: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(&f).reshape(&dims_i)?)
}

/// Int vector -> i32 literal reshaped to dims.
pub fn int_to_i32_literal_shaped(v: &RValue, dims: &[usize]) -> Result<xla::Literal> {
    let xs = v
        .as_int()
        .ok_or_else(|| anyhow!("expected integer vector, got {}", v.type_name()))?;
    let want: usize = dims.iter().product();
    if xs.len() != want {
        anyhow::bail!("shape mismatch: {} elements for dims {:?}", xs.len(), dims);
    }
    let dims_i: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(xs).reshape(&dims_i)?)
}

/// f32 literal -> Real vector (row-major flat order preserved).
pub fn literal_to_real(lit: &xla::Literal) -> Result<RValue> {
    let v = lit.to_vec::<f32>()?;
    Ok(RValue::Real(v.into_iter().map(|x| x as f64).collect()))
}

/// f32 literal of shape (nrow, ncol) -> column-major RValue matrix.
pub fn literal_to_matrix(lit: &xla::Literal, nrow: usize, ncol: usize) -> Result<RValue> {
    let row_major = lit.to_vec::<f32>()?;
    if row_major.len() != nrow * ncol {
        anyhow::bail!(
            "literal has {} elements, expected {}x{}",
            row_major.len(),
            nrow,
            ncol
        );
    }
    let mut col_major = vec![0f64; nrow * ncol];
    for r in 0..nrow {
        for c in 0..ncol {
            col_major[c * nrow + r] = row_major[r * ncol + c] as f64;
        }
    }
    Ok(RValue::matrix(col_major, nrow, ncol))
}

/// i32 literal -> Int vector.
pub fn literal_to_int(lit: &xla::Literal) -> Result<RValue> {
    Ok(RValue::Int(lit.to_vec::<i32>()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip_transposes_layout() {
        // Column-major 2x3: columns [1,2],[3,4],[5,6].
        let m = RValue::matrix(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = matrix_to_f32_literal(&m).unwrap();
        // Row-major order must be 1,3,5,2,4,6.
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1., 3., 5., 2., 4., 6.]);
        let back = literal_to_matrix(&lit, 2, 3).unwrap();
        assert!(back.identical(&m));
    }

    #[test]
    fn vector_conversions() {
        let v = RValue::Real(vec![1.5, -2.5]);
        let lit = real_to_f32_literal(&v).unwrap();
        assert!(literal_to_real(&lit).unwrap().identical(&v));

        let iv = RValue::Int(vec![1, 2, 3, 4, 5, 6]);
        let lit = int_to_i32_literal_shaped(&iv, &[2, 3]).unwrap();
        assert!(literal_to_int(&lit).unwrap().identical(&iv));
    }

    #[test]
    fn shape_mismatches_rejected() {
        let v = RValue::Real(vec![1.0; 5]);
        assert!(real_to_f32_literal_shaped(&v, &[2, 3]).is_err());
        let iv = RValue::Int(vec![1; 5]);
        assert!(int_to_i32_literal_shaped(&iv, &[2, 3]).is_err());
        assert!(matrix_to_f32_literal(&RValue::Null).is_err());
    }
}
