//! Artifact manifest: what `python/compile/aot.py` produced.
//!
//! `artifacts/manifest.json` records, per task type, the HLO file name and
//! the input/output tensor specs the function was lowered for. The runtime
//! validates every execution against these specs — shape bugs surface as
//! errors at the call site instead of PJRT aborts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Tensor shape + dtype as lowered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .as_str()
            .ok_or_else(|| anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One lowered task function.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// The canonical fragment shape constants (model.py SHAPES).
    pub shapes: BTreeMap<String, usize>,
    pub tasks: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let doc = Json::parse(text).context("parse manifest.json")?;
        let mut shapes = BTreeMap::new();
        if let Some(obj) = doc.get("shapes").as_obj() {
            for (k, v) in obj {
                if let Some(n) = v.as_usize() {
                    shapes.insert(k.clone(), n);
                }
            }
        }
        let mut tasks = BTreeMap::new();
        let tasks_obj = doc
            .get("tasks")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing tasks"))?;
        for (name, t) in tasks_obj {
            let file = t
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("task {name} missing file"))?;
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                t.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("task {name} missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            tasks.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        if tasks.is_empty() {
            bail!("manifest has no tasks");
        }
        Ok(Manifest { shapes, tasks })
    }

    pub fn task(&self, name: &str) -> Result<&ArtifactSpec> {
        self.tasks
            .get(name)
            .ok_or_else(|| anyhow!("no artifact for task '{name}'"))
    }

    /// Shape constant lookup (e.g. "knn_k").
    pub fn shape(&self, key: &str) -> Result<usize> {
        self.shapes
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("manifest missing shape constant '{key}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "shapes": {"knn_k": 8, "km_k": 16},
      "tasks": {
        "knn_merge": {
          "file": "knn_merge.hlo.txt",
          "sha256_16": "abc",
          "inputs": [
            {"shape": [512, 8], "dtype": "float32"},
            {"shape": [512, 8], "dtype": "int32"},
            {"shape": [512, 8], "dtype": "float32"},
            {"shape": [512, 8], "dtype": "int32"}
          ],
          "outputs": [
            {"shape": [512, 8], "dtype": "float32"},
            {"shape": [512, 8], "dtype": "int32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.shape("knn_k").unwrap(), 8);
        let t = m.task("knn_merge").unwrap();
        assert_eq!(t.inputs.len(), 4);
        assert_eq!(t.outputs[1].dtype, "int32");
        assert_eq!(t.file, PathBuf::from("/art/knn_merge.hlo.txt"));
        assert_eq!(t.inputs[0].element_count(), 4096);
    }

    #[test]
    fn missing_task_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert!(m.task("nope").is_err());
        assert!(m.shape("nope").is_err());
    }

    #[test]
    fn rejects_empty_manifest() {
        assert!(Manifest::parse(r#"{"tasks": {}}"#, Path::new("/")).is_err());
        assert!(Manifest::parse("{", Path::new("/")).is_err());
    }
}
