//! Cluster topology and machine profiles.
//!
//! The paper evaluates on two systems whose hardware differences drive
//! every scaling result: KAUST **Shaheen-III** (192-core AMD EPYC Genoa
//! nodes, R linked against Intel MKL, IOPS-tier Lustre) and BSC
//! **MareNostrum 5** (112-core Intel Sapphire Rapids nodes, single-thread
//! reference RBLAS, slower worker initialization). We model each system as
//! a [`MachineProfile`]: worker counts, worker-init behaviour, storage and
//! network bandwidths, and the BLAS backend class. The live executor uses
//! profiles only for worker counts; the discrete-event simulator
//! (`crate::sim`) uses every field.
//!
//! Substitution note (DESIGN.md §3): per-task compute costs are calibrated
//! on the local box and *scaled* by profile (e.g. the MKL↔RBLAS GEMM ratio
//! measured between the PJRT artifact path and the naive native GEMM), so
//! the simulated machines inherit measured — not invented — constants.

use crate::util::json::Json;

/// BLAS backend class, the decisive linreg variable in §5.2-5.3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlasClass {
    /// Vectorized, compiled BLAS (Intel MKL on Shaheen-III). Maps to the
    /// PJRT/XLA artifact path in this repo.
    Fast,
    /// Reference single-thread RBLAS (MareNostrum 5). Maps to the naive
    /// native Rust GEMM.
    Reference,
}

/// Everything the runtime and simulator need to know about a machine.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    pub name: String,
    /// Worker executors per node (paper: 128 on Shaheen-III, 80 on MN5 —
    /// the remaining cores are reserved for the master/runtime threads).
    pub workers_per_node: u32,
    /// Fixed cost to start a worker executor.
    pub worker_init_base_s: f64,
    /// Additional per-slot stagger: slot `k` is ready at
    /// `base + k * stagger`. The paper's MN5 traces show a visibly slower,
    /// near-sequential worker bring-up; Shaheen's is fast.
    pub worker_init_stagger_s: f64,
    /// Per-node storage bandwidth for serialized parameter files, shared
    /// by concurrent I/O on the node (contention divides it).
    pub disk_bw_bytes_per_s: f64,
    /// Per-file I/O latency.
    pub disk_latency_s: f64,
    /// Shared parallel-filesystem backend bandwidth (Lustre/GPFS): all
    /// nodes' parameter-file *writes* funnel through this job-wide
    /// capacity. Per-node `disk_bw` models the client link; this models
    /// the OST/NSD backend that multi-node runs saturate (§5.3).
    pub fs_bw_bytes_per_s: f64,
    /// Inter-node network bandwidth per transfer.
    pub net_bw_bytes_per_s: f64,
    pub net_latency_s: f64,
    /// BLAS class — selects the compute backend and the simulator's GEMM
    /// cost multiplier.
    pub blas: BlasClass,
    /// Measured-on-this-box multiplier applied to GEMM-heavy task compute
    /// when `blas == Reference` (the paper observed ≈100x between MKL and
    /// RBLAS on linear regression's four GEMM tasks).
    pub gemm_slowdown: f64,
    /// Generic per-core relative speed vs the calibration box (1.0 = same).
    pub core_speed: f64,
    /// R-interpreter overhead multiplier on task compute. The paper's
    /// workers execute *R* task bodies; our calibrated unit costs come from
    /// compiled XLA/Rust bodies, which are roughly this much faster per
    /// element. Applying the factor restores the paper's compute-to-I/O
    /// ratio, which is what the scaling knees depend on (DESIGN.md §3).
    pub interpreter_factor: f64,
    /// DRAM-bandwidth saturation coefficient for GEMM-class tasks: with
    /// the node fully occupied, a memory-bound GEMM task runs
    /// `1 + mem_sat_gemm` times slower than alone (dual-socket EPYC/SPR
    /// nodes saturate memory long before 128 cores of GEMM). This is what
    /// bends linear regression's single-node weak-scaling curve to the
    /// paper's ≈41% at 128 cores.
    pub mem_sat_gemm: f64,
}

impl MachineProfile {
    /// Shaheen-III-like profile: many workers, fast BLAS, fast IOPS tier,
    /// quick worker bring-up.
    pub fn shaheen3() -> MachineProfile {
        MachineProfile {
            name: "shaheen3".into(),
            workers_per_node: 128,
            worker_init_base_s: 0.5,
            worker_init_stagger_s: 0.012,
            // IOPS tier of /scratch (up to 2.5 TB/s aggregate, striped):
            // a single client sustains multi-GB/s on small random I/O.
            disk_bw_bytes_per_s: 6.0e9,
            disk_latency_s: 0.5e-3,
            fs_bw_bytes_per_s: 40.0e9,
            net_bw_bytes_per_s: 12e9, // Slingshot-class per-NIC
            net_latency_s: 5e-6,
            blas: BlasClass::Fast,
            gemm_slowdown: 1.0,
            core_speed: 1.0,
            interpreter_factor: 25.0,
            mem_sat_gemm: 1.44,
        }
    }

    /// MareNostrum-5-like profile: fewer workers, reference BLAS, slower
    /// worker bring-up (the paper's traces show initialization skew), GPFS
    /// at lower small-file bandwidth.
    pub fn marenostrum5() -> MachineProfile {
        MachineProfile {
            name: "marenostrum5".into(),
            workers_per_node: 80,
            worker_init_base_s: 1.6,
            worker_init_stagger_s: 0.22,
            disk_bw_bytes_per_s: 1.0e9,
            disk_latency_s: 2.0e-3,
            fs_bw_bytes_per_s: 5.0e9,
            net_bw_bytes_per_s: 10e9,
            net_latency_s: 6e-6,
            blas: BlasClass::Reference,
            gemm_slowdown: 100.0,
            core_speed: 0.92,
            interpreter_factor: 25.0,
            // Reference-BLAS cores run ~100x slower, so even a fully packed
            // node generates little aggregate DRAM traffic: GEMM barely
            // saturates. This is what makes MN5's linreg *scale* well while
            // being ~100x slower in absolute time (§5.2-5.3).
            mem_sat_gemm: 0.15,
        }
    }

    /// The local box: used by examples, tests and calibration runs.
    pub fn localbox() -> MachineProfile {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get() as u32)
            .unwrap_or(4);
        MachineProfile {
            name: "localbox".into(),
            workers_per_node: cores.saturating_sub(1).max(1),
            worker_init_base_s: 0.0,
            worker_init_stagger_s: 0.0,
            disk_bw_bytes_per_s: 2.0e9,
            disk_latency_s: 0.1e-3,
            fs_bw_bytes_per_s: 1.0e12,
            net_bw_bytes_per_s: 2.0e9,
            net_latency_s: 1e-6,
            blas: BlasClass::Fast,
            gemm_slowdown: 1.0,
            core_speed: 1.0,
            interpreter_factor: 1.0,
            mem_sat_gemm: 0.0,
        }
    }

    pub fn by_name(name: &str) -> Option<MachineProfile> {
        match name {
            "shaheen3" => Some(Self::shaheen3()),
            "marenostrum5" | "mn5" => Some(Self::marenostrum5()),
            "localbox" | "local" => Some(Self::localbox()),
            _ => None,
        }
    }

    /// When a worker slot becomes available, relative to run start.
    pub fn worker_ready_at(&self, slot: u32) -> f64 {
        self.worker_init_base_s + self.worker_init_stagger_s * slot as f64
    }

    /// Serialize for run manifests.
    pub fn to_json(&self) -> Json {
        crate::util::json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("workers_per_node", Json::Num(self.workers_per_node as f64)),
            ("worker_init_base_s", Json::Num(self.worker_init_base_s)),
            ("worker_init_stagger_s", Json::Num(self.worker_init_stagger_s)),
            ("disk_bw_bytes_per_s", Json::Num(self.disk_bw_bytes_per_s)),
            ("disk_latency_s", Json::Num(self.disk_latency_s)),
            ("fs_bw_bytes_per_s", Json::Num(self.fs_bw_bytes_per_s)),
            ("net_bw_bytes_per_s", Json::Num(self.net_bw_bytes_per_s)),
            ("net_latency_s", Json::Num(self.net_latency_s)),
            (
                "blas",
                Json::Str(
                    match self.blas {
                        BlasClass::Fast => "fast",
                        BlasClass::Reference => "reference",
                    }
                    .into(),
                ),
            ),
            ("gemm_slowdown", Json::Num(self.gemm_slowdown)),
            ("core_speed", Json::Num(self.core_speed)),
            ("interpreter_factor", Json::Num(self.interpreter_factor)),
            ("mem_sat_gemm", Json::Num(self.mem_sat_gemm)),
        ])
    }
}

/// A concrete deployment: a machine profile times a node count, with an
/// optional worker-per-node override (the scaling sweeps vary this).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub profile: MachineProfile,
    pub nodes: u32,
    pub workers_per_node: u32,
}

impl ClusterSpec {
    pub fn new(profile: MachineProfile, nodes: u32) -> ClusterSpec {
        let wpn = profile.workers_per_node;
        ClusterSpec {
            profile,
            nodes,
            workers_per_node: wpn,
        }
    }

    pub fn with_workers_per_node(mut self, wpn: u32) -> ClusterSpec {
        self.workers_per_node = wpn;
        self
    }

    pub fn total_workers(&self) -> u32 {
        self.nodes * self.workers_per_node
    }

    /// Join commands for a TCP-transport run: one `rcompss worker` line per
    /// non-coordinator node slot (node 0 is the coordinator itself). The
    /// operator runs each line on the machine that should own that slot,
    /// substituting a routable address for `listen_addr` where needed.
    pub fn worker_commands(&self, listen_addr: &str) -> Vec<String> {
        (1..self.nodes)
            .map(|n| format!("rcompss worker --connect {listen_addr} --node {n}"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worker_counts() {
        assert_eq!(MachineProfile::shaheen3().workers_per_node, 128);
        assert_eq!(MachineProfile::marenostrum5().workers_per_node, 80);
    }

    #[test]
    fn mn5_worker_init_is_slower() {
        let sh = MachineProfile::shaheen3();
        let mn = MachineProfile::marenostrum5();
        assert!(mn.worker_ready_at(79) > sh.worker_ready_at(127) * 5.0);
    }

    #[test]
    fn blas_classes_match_paper() {
        assert_eq!(MachineProfile::shaheen3().blas, BlasClass::Fast);
        assert_eq!(MachineProfile::marenostrum5().blas, BlasClass::Reference);
        assert!(MachineProfile::marenostrum5().gemm_slowdown >= 50.0);
    }

    #[test]
    fn by_name_and_aliases() {
        assert!(MachineProfile::by_name("shaheen3").is_some());
        assert!(MachineProfile::by_name("mn5").is_some());
        assert!(MachineProfile::by_name("local").is_some());
        assert!(MachineProfile::by_name("cray").is_none());
    }

    #[test]
    fn cluster_spec_math() {
        let spec = ClusterSpec::new(MachineProfile::shaheen3(), 4).with_workers_per_node(32);
        assert_eq!(spec.total_workers(), 128);
    }

    #[test]
    fn worker_commands_skip_the_coordinator_slot() {
        let spec = ClusterSpec::new(MachineProfile::localbox(), 3);
        let cmds = spec.worker_commands("10.0.0.1:7077");
        assert_eq!(
            cmds,
            vec![
                "rcompss worker --connect 10.0.0.1:7077 --node 1".to_string(),
                "rcompss worker --connect 10.0.0.1:7077 --node 2".to_string(),
            ]
        );
        assert!(ClusterSpec::new(MachineProfile::localbox(), 1)
            .worker_commands("127.0.0.1:0")
            .is_empty());
    }

    #[test]
    fn profile_json_roundtrips_name() {
        let j = MachineProfile::mn5_json_probe();
        assert_eq!(j.get("name").as_str(), Some("marenostrum5"));
        assert_eq!(j.get("workers_per_node").as_usize(), Some(80));
    }
}

#[cfg(test)]
impl MachineProfile {
    fn mn5_json_probe() -> Json {
        Self::marenostrum5().to_json()
    }
}
