//! Shared bench harness (criterion is not in the offline vendor set).
//!
//! Every file in `rust/benches/` is a plain `harness = false` binary that
//! uses these helpers to time workloads, compute the paper's efficiency
//! metrics, and print the same rows/series the paper reports. Each bench
//! also appends a machine-readable JSON line to
//! `target/bench_results.jsonl` so EXPERIMENTS.md can be assembled from
//! real outputs.

use std::io::Write as _;
use std::time::Instant;

use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Time one closure invocation in seconds.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// Time `reps` invocations and summarize.
pub fn time_reps<T>(reps: usize, mut f: impl FnMut() -> T) -> Summary {
    assert!(reps > 0);
    let samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            let out = f();
            std::hint::black_box(&out);
            t0.elapsed().as_secs_f64()
        })
        .collect();
    Summary::of(&samples)
}

/// Environment knob: `RCOMPSS_BENCH_REPS` (default given).
pub fn reps(default: usize) -> usize {
    std::env::var("RCOMPSS_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Environment knob: quick mode trims sweeps for CI (`RCOMPSS_BENCH_QUICK=1`).
pub fn quick() -> bool {
    std::env::var("RCOMPSS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Append a JSON record to `target/bench_results.jsonl`.
pub fn record_result(bench: &str, fields: Vec<(&str, Json)>) {
    let mut all = vec![("bench", Json::Str(bench.to_string()))];
    all.extend(fields);
    let line = obj(all).to_string_compact();
    let path = std::path::Path::new("target").join("bench_results.jsonl");
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = writeln!(f, "{line}");
    }
}

/// Write a named bench summary as pretty JSON to `BENCH_<name>.json` in the
/// crate root (committed alongside the code so the perf trajectory is
/// tracked in-repo). Entries are the same `(key, value)` rows that
/// [`record_result`] appends to the JSONL stream.
///
/// Files written by an actual bench run are stamped `"projected": false` /
/// `"status": "measured"`. A committed copy that was estimated by hand (no
/// toolchain on the authoring machine) must carry `"projected": true`
/// instead, so stale committed numbers can never be mistaken for measured
/// ones — see README § Benchmarks.
pub fn write_json_summary(name: &str, entries: Vec<Json>) {
    let doc = obj(vec![
        ("bench", Json::Str(name.to_string())),
        ("projected", Json::Bool(false)),
        ("status", Json::Str("measured".to_string())),
        ("results", Json::Arr(entries)),
    ]);
    let path = format!("BENCH_{name}.json");
    if let Err(e) = std::fs::write(&path, doc.to_string_pretty() + "\n") {
        eprintln!("[bench] could not write {path}: {e}");
    } else {
        println!("  wrote {path}");
    }
}

/// Standard header for a bench binary.
pub fn banner(title: &str, detail: &str) {
    println!("\n=== {title} ===");
    if !detail.is_empty() {
        println!("{detail}");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reps_counts() {
        let s = time_reps(5, || std::thread::sleep(std::time::Duration::from_micros(200)));
        assert_eq!(s.n, 5);
        assert!(s.min >= 0.0002);
    }

    #[test]
    fn record_result_appends_parseable_json() {
        record_result("unit_test", vec![("x", Json::Num(1.0))]);
        let text = std::fs::read_to_string("target/bench_results.jsonl").unwrap();
        let last = text.lines().last().unwrap();
        let v = Json::parse(last).unwrap();
        assert_eq!(v.get("bench").as_str(), Some("unit_test"));
    }
}
