//! `rcompss` — the launcher CLI (the `runcompss` analog).
//!
//! Subcommands:
//!
//! * `run`   — execute one of the benchmark apps on the live runtime;
//! * `sim`   — execute an app's DAG on the simulated cluster;
//! * `dag`   — export an app's DAG as Graphviz DOT (Figures 2-5);
//! * `trace` — run (live or simulated) and render a Figure-10 timeline;
//! * `codecs`— list the Table-1 serialization codecs;
//! * `info`  — environment report (artifacts, profiles, versions).
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`), since the
//! offline vendor set has no clap.

use std::collections::HashMap;
use std::process::ExitCode;

use rcompss::api::{run_tcp_worker, CompssRuntime, RuntimeConfig};
use rcompss::apps::backend::Backend;
use rcompss::apps::kmeans::{self, KmeansConfig};
use rcompss::apps::knn::{self, KnnConfig};
use rcompss::apps::linreg::{self, LinregConfig};
use rcompss::apps::{LiveSink, TaskSink};
use rcompss::cluster::{ClusterSpec, MachineProfile};
use rcompss::sim::{CostModel, SimEngine, SimSink};
use rcompss::value::RValue;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("rcompss: error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

struct Opts {
    flags: HashMap<String, String>,
}

impl Opts {
    fn parse(args: &[String]) -> anyhow::Result<Opts> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                anyhow::bail!("unexpected positional argument '{a}'");
            }
        }
        Ok(Opts { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn backend_from(opts: &Opts) -> anyhow::Result<Backend> {
    match opts.get("backend", "auto").as_str() {
        "auto" => Ok(Backend::auto()),
        "pjrt" => Ok(Backend::Pjrt),
        "native" => Ok(Backend::Native),
        other => anyhow::bail!("unknown backend '{other}' (auto|pjrt|native)"),
    }
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };
    if cmd == "--version" || cmd == "version" {
        println!(
            "rcompss {} (COMPSs-compatible runtime, paper reproduction; COMPSs {})",
            rcompss::VERSION,
            rcompss::COMPSS_COMPAT
        );
        return Ok(());
    }
    let opts = Opts::parse(&args[1..])?;
    match cmd {
        "run" => cmd_run(&opts),
        "worker" => cmd_worker(&opts),
        "sim" => cmd_sim(&opts),
        "dag" => cmd_dag(&opts),
        "trace" => cmd_trace(&opts),
        "codecs" => cmd_codecs(),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `rcompss help`)"),
    }
}

fn print_usage() {
    println!(
        "rcompss {} — task-based runtime for R-style workloads (RCOMPSs reproduction)

USAGE:
  rcompss run    --app knn|kmeans|linreg [--workers N] [--fragments F]
                 [--backend auto|pjrt|native] [--codec rmvl|qs|fst|rds|...]
                 [--scheduler fifo|lifo|locality] [--router bytes|cost|roundrobin|adaptive]
                 [--trace] [--memory-budget BYTES (default 256 MiB; 0 = file plane)]
                 [--warm-budget BYTES (default 64 MiB; 0 = file-backed staging)]
                 [--store tiered|hot|file (tier preset for A/B runs)]
                 [--spill lru|largest] [--nodes N] [--transfer-threads T]
                 [--gc on|off (default on)] [--max-retries N (default 3)]
                 [--chaos task-fail:<p>,node-kill[:<seed>],seed:<n>|none]
                 [--checkpoint none|cold (proactive sole-replica spills)]
                 [--compile off|window (DAG window compiler: cull/fuse/alias/place)]
                 [--transport inproc|tcp (replica shipping; default inproc)]
                 [--listen ADDR (tcp: accept external worker registrations)]
                 [--token SECRET (tcp: shared registration secret; RCOMPSS_TOKEN)]
                 [--p2p on|off (tcp: direct worker-to-worker shipping; default on)]
  rcompss worker --connect ADDR (join a coordinator as a replica-serving node)
                 [--node N (preferred node slot)] [--budget BYTES (replica cache)]
                 [--token SECRET (must match the coordinator's; RCOMPSS_TOKEN)]
  rcompss sim    --app knn|kmeans|linreg --machine shaheen3|marenostrum5
                 [--nodes N] [--workers-per-node W] [--fragments F]
                 [--scheduler fifo|lifo|locality] [--router bytes|cost|roundrobin|adaptive]
                 [--warm on|off (warm-tier transfer staging, default on)]
                 [--fuzz-seed N (seeded permutation of timestamp-tied events)]
                 [--compile off|window (window-compile the static plan)]
  rcompss dag    --app add|knn|kmeans|linreg [--fragments F] [--out FILE.dot]
  rcompss trace  --app knn|kmeans|linreg --machine shaheen3|marenostrum5
                 [--nodes N] [--workers-per-node W] [--width COLS]
  rcompss codecs
  rcompss info
  rcompss --version",
        rcompss::VERSION
    );
}

fn cmd_run(opts: &Opts) -> anyhow::Result<()> {
    let app = opts.get("app", "knn");
    let workers = opts.get_usize("workers", 4)? as u32;
    let fragments = opts.get_usize("fragments", 4)?;
    let backend = backend_from(opts)?;
    let memory_budget = opts.get_usize(
        "memory-budget",
        rcompss::coordinator::runtime::DEFAULT_MEMORY_BUDGET as usize,
    )? as u64;
    let nodes = opts.get_usize("nodes", 1)?.max(1) as u32;
    let transfer_threads = opts.get_usize("transfer-threads", 1)? as u32;
    // Default on; `--gc off` restores the seed behavior. (Bare `--gc`
    // parses as "true".)
    let gc = match opts.get("gc", "on").as_str() {
        "on" | "true" => true,
        "off" | "false" => false,
        other => anyhow::bail!("--gc expects on|off, got '{other}'"),
    };
    let mut config = RuntimeConfig::local(workers)
        .with_codec(&opts.get("codec", "rmvl"))
        .with_trace(opts.has("trace"))
        .with_memory_budget(memory_budget)
        .with_spill(&opts.get("spill", "lru"))
        .with_transfer_threads(transfer_threads)
        .with_gc(gc);
    // Scheduler/router/warm flags override the config defaults (which
    // already honor the RCOMPSS_SCHEDULER / RCOMPSS_ROUTER /
    // RCOMPSS_WARM_BUDGET environment matrix).
    if opts.has("scheduler") {
        config = config.with_scheduler(&opts.get("scheduler", "fifo"));
    }
    if opts.has("router") {
        config = config.with_router(&opts.get("router", "bytes"));
    }
    if opts.has("warm-budget") {
        config = config.with_warm_budget(opts.get_usize("warm-budget", 0)? as u64);
    }
    if opts.has("store") {
        config = config.with_store(&opts.get("store", "tiered"));
    }
    if nodes > 1 {
        config = config.with_nodes(nodes, workers);
    }
    if opts.has("max-retries") {
        config = config.with_max_retries(opts.get_usize("max-retries", 3)? as u32);
    }
    if opts.has("checkpoint") {
        config = config.with_checkpoint(&opts.get("checkpoint", "none"));
    }
    if opts.has("chaos") {
        let spec = rcompss::coordinator::fault::ChaosSpec::parse(&opts.get("chaos", "none"))
            .map_err(|e| anyhow::anyhow!("--chaos: {e}"))?;
        config = config.with_chaos(spec);
    }
    // Overrides the RCOMPSS_COMPILE default; unknown modes error at start.
    if opts.has("compile") {
        config = config.with_compile(&opts.get("compile", "off"));
    }
    // Overrides the RCOMPSS_TRANSPORT default; a bare `--listen` implies
    // tcp (listening makes no sense in-process).
    if opts.has("transport") {
        config = config.with_transport(&opts.get("transport", "inproc"));
    }
    if opts.has("token") {
        let token = opts.get("token", "");
        if token.is_empty() || token == "true" {
            anyhow::bail!("--token expects a non-empty shared secret");
        }
        config = config.with_token(&token);
    }
    // Overrides the RCOMPSS_P2P default (on).
    if opts.has("p2p") {
        config = config.with_p2p(match opts.get("p2p", "on").as_str() {
            "on" | "true" => true,
            "off" | "false" => false,
            other => anyhow::bail!("--p2p expects on|off, got '{other}'"),
        });
    }
    if opts.has("listen") {
        let addr = opts.get("listen", "");
        if addr.is_empty() || addr == "true" {
            anyhow::bail!("--listen expects an address, e.g. --listen 0.0.0.0:7077");
        }
        if !opts.has("transport") && config.transport == "inproc" {
            config = config.with_transport("tcp");
        }
        config = config.with_listen(&addr);
        // Print the join commands before start() blocks waiting for the
        // workers to register (localbox profile: host names are moot, the
        // operator substitutes real ones on a cluster).
        if nodes > 1 {
            let spec = ClusterSpec::new(MachineProfile::localbox(), nodes);
            println!("rcompss run: cluster of {nodes} node(s); join the coordinator with:");
            for cmd in spec.worker_commands(&addr) {
                println!("  {cmd}");
            }
        }
    }
    let transport = config.transport.clone();
    let compile = config.compile.clone();
    let scheduler = config.scheduler.clone();
    let router = config.router.clone();
    let store = config.store.clone();
    // Report the budgets the runtime actually runs with: the `--store`
    // preset overrides them at startup (same resolution as
    // `Coordinator::start`; unknown presets error there, before this).
    let (memory_budget, warm_budget) = match store.as_str() {
        "hot" => (config.memory_budget, 0),
        "file" => (0, 0),
        _ => (config.memory_budget, config.warm_budget),
    };
    let rt = CompssRuntime::start(config)?;
    println!(
        "rcompss run: app={app} nodes={nodes} workers/node={workers} fragments={fragments} \
         backend={backend:?} data-plane={} store={store} warm-budget={warm_budget} \
         scheduler={scheduler} router={router} transfer-threads={transfer_threads} gc={gc} \
         compile={compile} transport={transport}",
        if memory_budget > 0 { "memory" } else { "file" }
    );
    let t0 = std::time::Instant::now();
    match app.as_str() {
        "knn" => {
            let mut cfg = KnnConfig::small(42);
            cfg.train_fragments = fragments;
            cfg.test_blocks = opts.get_usize("test-blocks", 2)?;
            let res = knn::run_knn(&rt, &cfg, backend)?;
            println!(
                "KNN: {} test points classified, accuracy {:.1}%",
                res.total_test_points,
                res.accuracy * 100.0
            );
        }
        "kmeans" => {
            let mut cfg = KmeansConfig::small(42);
            cfg.fragments = fragments;
            cfg.iterations = opts.get_usize("iterations", 3)?;
            let res = kmeans::run_kmeans(&rt, &cfg, backend)?;
            println!(
                "K-means: {} iterations, final centroid shift {:.5}",
                res.iterations_run, res.last_shift
            );
        }
        "linreg" => {
            let mut cfg = LinregConfig::small(42);
            cfg.fragments = fragments;
            cfg.pred_blocks = opts.get_usize("pred-blocks", 2)?;
            let res = linreg::run_linreg(&rt, &cfg, backend)?;
            println!(
                "Linear regression: max |beta error| {:.5}, prediction R^2 {:.4}",
                res.beta_max_err, res.r2
            );
        }
        other => anyhow::bail!("unknown app '{other}'"),
    }
    let elapsed = t0.elapsed().as_secs_f64();
    if opts.has("trace") {
        let trace = rt.trace(&format!("{app} live"));
        println!("\n{}", trace.ascii_timeline(opts.get_usize("width", 100)?));
    }
    let stats = rt.stop()?;
    println!(
        "elapsed {:.3}s | tasks: {} done, {} failed, {} resubmitted | ser {:.3}s / {} | deser {:.3}s / {}",
        elapsed,
        stats.tasks_done,
        stats.tasks_failed,
        stats.resubmissions,
        stats.serialize_s,
        rcompss::util::table::fmt_bytes(stats.bytes_serialized as usize),
        stats.deserialize_s,
        rcompss::util::table::fmt_bytes(stats.bytes_deserialized as usize),
    );
    if memory_budget > 0 {
        println!(
            "store: {} hits, {} misses, {} spills / {}",
            stats.store_hits,
            stats.store_misses,
            stats.spills,
            rcompss::util::table::fmt_bytes(stats.spill_bytes as usize),
        );
        println!(
            "tiers: warm {} hits / {} fills / {} evictions ({} resident), \
             {} encodes, {} file reads, {} file writes",
            stats.warm_hits,
            stats.warm_fills,
            stats.warm_evictions,
            rcompss::util::table::fmt_bytes(stats.warm_resident_bytes as usize),
            stats.store_encodes,
            stats.store_file_reads,
            stats.store_file_writes,
        );
        println!(
            "transfers: {} requested, {} prefetched, {} waited, {} dropped, {} failed, {} retried, {} moved, {} sync claim decodes",
            stats.transfers_requested,
            stats.transfers_prefetched,
            stats.transfers_waited,
            stats.transfers_dropped,
            stats.transfers_failed,
            stats.transfers_retried,
            rcompss::util::table::fmt_bytes(stats.transfer_bytes as usize),
            stats.sync_transfer_decodes,
        );
    }
    if transport == "tcp" {
        println!(
            "p2p: {} direct, {} relay, {} seed ships, {} pool hits, coordinator egress {}",
            stats.direct_ships,
            stats.relay_ships,
            stats.seed_ships,
            stats.pool_hits,
            rcompss::util::table::fmt_bytes(stats.coord_egress_bytes as usize),
        );
    }
    if gc {
        println!(
            "gc: {} versions reclaimed / {}, {} spill files deleted, dead bytes at exit: {}",
            stats.gc_collected,
            rcompss::util::table::fmt_bytes(stats.gc_bytes as usize),
            stats.gc_files_deleted,
            stats.dead_version_bytes,
        );
    }
    if stats.windows_flushed > 0 {
        println!(
            "compiler: {} windows, {} culled, {} fused, {} aot frees, {} alias reuses, \
             {} placement verdicts, hot peak {}",
            stats.windows_flushed,
            stats.window_culled,
            stats.window_fused,
            stats.aot_frees,
            stats.alias_reuses,
            stats.placement_verdicts,
            rcompss::util::table::fmt_bytes(stats.hot_peak_bytes as usize),
        );
    }
    if stats.nodes_killed > 0
        || stats.nodes_joined > 0
        || stats.lineage_resubmissions > 0
        || stats.checkpoints_written > 0
    {
        println!(
            "recovery: {} node(s) killed, {} rejoined, {} lineage resubmissions, \
             {} checkpoints / {}",
            stats.nodes_killed,
            stats.nodes_joined,
            stats.lineage_resubmissions,
            stats.checkpoints_written,
            rcompss::util::table::fmt_bytes(stats.checkpoint_bytes as usize),
        );
    }
    Ok(())
}

/// `rcompss worker --connect <addr>`: join a TCP-transport coordinator as
/// a replica-serving node and block until it shuts the cluster down (or
/// the socket dies). The process is stateless — restart it to rejoin.
fn cmd_worker(opts: &Opts) -> anyhow::Result<()> {
    let addr = opts.get("connect", "");
    if addr.is_empty() || addr == "true" {
        anyhow::bail!("--connect expects the coordinator address, e.g. --connect 10.0.0.1:7077");
    }
    let preferred = if opts.has("node") {
        Some(opts.get_usize("node", 0)? as u32)
    } else {
        None
    };
    let budget = opts.get_usize("budget", 64 << 20)? as u64;
    // `--token` wins over the RCOMPSS_TOKEN environment fallback.
    let token = if opts.has("token") {
        let t = opts.get("token", "");
        if t.is_empty() || t == "true" {
            anyhow::bail!("--token expects a non-empty shared secret");
        }
        Some(t)
    } else {
        std::env::var("RCOMPSS_TOKEN").ok().filter(|t| !t.is_empty())
    };
    run_tcp_worker(&addr, preferred, budget, false, token.as_deref())
}

fn build_plan(
    app: &str,
    fragments: usize,
    opts: &Opts,
) -> anyhow::Result<rcompss::sim::sink::SimPlan> {
    let mut sink = SimSink::new();
    match app {
        "knn" => {
            let mut cfg = KnnConfig::small(42);
            cfg.train_fragments = fragments;
            cfg.test_blocks = opts.get_usize("test-blocks", 2)?;
            knn::plan_knn(&mut sink, &cfg)?;
        }
        "kmeans" => {
            let mut cfg = KmeansConfig::small(42);
            cfg.fragments = fragments;
            cfg.iterations = opts.get_usize("iterations", 3)?;
            kmeans::plan_kmeans(&mut sink, &cfg)?;
        }
        "linreg" => {
            let mut cfg = LinregConfig::small(42);
            cfg.fragments = fragments;
            cfg.pred_blocks = opts.get_usize("pred-blocks", 2)?;
            linreg::plan_linreg(&mut sink, &cfg)?;
        }
        other => anyhow::bail!("unknown app '{other}'"),
    }
    Ok(sink.finish())
}

fn cluster_from(opts: &Opts) -> anyhow::Result<ClusterSpec> {
    let machine = opts.get("machine", "shaheen3");
    let profile = MachineProfile::by_name(&machine)
        .ok_or_else(|| anyhow::anyhow!("unknown machine '{machine}'"))?;
    let nodes = opts.get_usize("nodes", 1)? as u32;
    let mut spec = ClusterSpec::new(profile, nodes);
    if opts.has("workers-per-node") {
        spec = spec.with_workers_per_node(opts.get_usize("workers-per-node", 0)? as u32);
    }
    Ok(spec)
}

fn cmd_sim(opts: &Opts) -> anyhow::Result<()> {
    let app = opts.get("app", "knn");
    let fragments = opts.get_usize("fragments", 64)?;
    let spec = cluster_from(opts)?;
    let plan = build_plan(&app, fragments, opts)?;
    let n_tasks = plan.graph.len();
    let cp = plan.graph.critical_path_len();
    let mut engine = SimEngine::new(spec.clone(), CostModel::default())
        .with_scheduler(&opts.get("scheduler", "fifo"))
        .with_router(&opts.get("router", "bytes"))
        .with_warm(opts.get("warm", "on") != "off");
    if opts.has("fuzz-seed") {
        engine = engine.with_fuzz_seed(opts.get_usize("fuzz-seed", 0)? as u64);
    }
    let compile = match opts.get("compile", "off").as_str() {
        "off" => false,
        "window" => true,
        other => anyhow::bail!("--compile expects off|window, got '{other}'"),
    };
    engine = engine.with_compile(compile);
    let report = engine.run(plan, &format!("{app}@{}", spec.profile.name))?;
    println!(
        "sim: app={app} machine={} nodes={} workers/node={} scheduler={} router={} warm={}{}",
        spec.profile.name,
        spec.nodes,
        spec.workers_per_node,
        opts.get("scheduler", "fifo"),
        opts.get("router", "bytes"),
        opts.get("warm", "on"),
        report
            .fuzz_seed
            .map(|s| format!(" fuzz-seed={s}"))
            .unwrap_or_default()
    );
    println!(
        "  tasks={n_tasks} critical_path={cp} makespan={:.3}s utilization={:.0}% io={:.3}s \
         transfer={:.3}s warm-hits={}",
        report.makespan_s,
        report.utilization * 100.0,
        report.total_io_s,
        report.total_transfer_s,
        report.transfer_warm_hits
    );
    if compile {
        println!(
            "  compiler: culled={} fused={} placement-verdicts={}",
            report.window_culled, report.window_fused, report.placement_verdicts
        );
    }
    let mut types: Vec<_> = report.per_type.iter().collect();
    types.sort_by(|a, b| b.1 .1.partial_cmp(&a.1 .1).unwrap());
    for (ty, (count, secs)) in types {
        println!("  {ty:28} x{count:<6} {secs:9.3}s compute");
    }
    Ok(())
}

fn cmd_dag(opts: &Opts) -> anyhow::Result<()> {
    let app = opts.get("app", "add");
    let fragments = opts.get_usize("fragments", 5)?;
    let dot = if app == "add" {
        // Figure 2: add four numbers.
        let rt = CompssRuntime::start(RuntimeConfig::local(2))?;
        let add = rt.register_task(rcompss::api::TaskDef::new("add", 2, |a| {
            Ok(vec![RValue::scalar(
                a[0].as_f64().unwrap_or(0.0) + a[1].as_f64().unwrap_or(0.0),
            )])
        }));
        let r1 = rt.submit(&add, &[4.0.into(), 5.0.into()])?;
        let r2 = rt.submit(&add, &[6.0.into(), 7.0.into()])?;
        let r3 = rt.submit(&add, &[r1.into(), r2.into()])?;
        rt.wait_on(&r3)?;
        let dot = rt.dag_dot("Figure 2: add four numbers");
        rt.stop()?;
        dot
    } else {
        let plan = build_plan(&app, fragments, opts)?;
        plan.graph.to_dot(&format!("{app} ({fragments} fragments)"))
    };
    match opts.flags.get("out") {
        Some(path) => {
            std::fs::write(path, &dot)?;
            println!("wrote {path}");
        }
        None => println!("{dot}"),
    }
    Ok(())
}

fn cmd_trace(opts: &Opts) -> anyhow::Result<()> {
    let app = opts.get("app", "knn");
    let fragments = opts.get_usize("fragments", 16)?;
    let spec = cluster_from(opts)?;
    let plan = build_plan(&app, fragments, opts)?;
    let engine = SimEngine::new(spec.clone(), CostModel::default())
        .with_scheduler(&opts.get("scheduler", "fifo"))
        .with_router(&opts.get("router", "bytes"))
        .with_warm(opts.get("warm", "on") != "off")
        .with_trace(true);
    let report = engine.run(plan, &format!("{app}@{}", spec.profile.name))?;
    println!("{}", report.trace.ascii_timeline(opts.get_usize("width", 110)?));
    if let Some(out) = opts.flags.get("prv") {
        std::fs::write(out, report.trace.to_prv())?;
        println!("wrote Paraver trace to {out}");
    }
    Ok(())
}

fn cmd_codecs() -> anyhow::Result<()> {
    println!("Table-1 serialization codecs (default: rmvl):");
    for codec in rcompss::serialization::all_codecs() {
        println!("  {}", codec.name());
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("rcompss {}", rcompss::VERSION);
    println!(
        "artifacts: {} ({})",
        rcompss::runtime::artifacts_dir().display(),
        if rcompss::runtime::artifacts_available() {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
    if rcompss::runtime::artifacts_available() {
        let m = rcompss::runtime::Manifest::load(&rcompss::runtime::artifacts_dir())?;
        println!("  {} task artifacts", m.tasks.len());
    }
    for name in ["shaheen3", "marenostrum5", "localbox"] {
        let p = MachineProfile::by_name(name).unwrap();
        println!(
            "profile {:14} workers/node={:3} blas={:?} gemm_slowdown={}x",
            p.name, p.workers_per_node, p.blas, p.gemm_slowdown
        );
    }
    // Exercise a LiveSink-independent sanity path so `info` doubles as a
    // smoke test in CI.
    let rt = CompssRuntime::start(RuntimeConfig::local(1))?;
    let ok = rt.register_task(rcompss::api::TaskDef::new("probe", 0, |_| {
        Ok(vec![RValue::scalar(1.0)])
    }));
    let r = rt.submit(&ok, &[])?;
    let v = rt.wait_on(&r)?;
    rt.stop()?;
    println!(
        "runtime smoke: {}",
        if v.as_f64() == Some(1.0) { "ok" } else { "BROKEN" }
    );
    Ok(())
}

// Silence "unused import" for LiveSink/TaskSink used only in some builds.
#[allow(unused)]
fn _keep(_: Option<(LiveSink<'static>, &dyn TaskSink)>) {}
