//! Native dense linear algebra — the "reference RBLAS" substrate.
//!
//! The paper's decisive single-node observation (§5.2) is that R on
//! Shaheen-III links Intel MKL while R on MareNostrum 5 uses single-thread
//! reference RBLAS, a ≈100x GEMM gap that flips linear regression's
//! scalability story. This module is our RBLAS stand-in: correct,
//! deliberately straightforward single-threaded kernels (triple-loop GEMM
//! with only the classic ikj ordering for cache sanity, unblocked
//! Cholesky), used (a) as the compute backend for the `Reference` BLAS
//! machine profile and (b) as the fallback when PJRT artifacts are absent.
//! The PJRT/XLA path plays the MKL role; `runtime_hotpath` measures the
//! actual ratio on this box and feeds it to the simulator's cost model.
//!
//! Matrices are **row-major** here (the compute layer's layout; `RValue`
//! matrices are column-major R-style and get converted at the app
//! boundary).

use anyhow::{bail, Result};

/// Row-major matrix view for the native kernels.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub data: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl Mat {
    pub fn new(rows: usize, cols: usize) -> Mat {
        Mat {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { data, rows, cols }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }
}

/// C = A @ B, single-threaded ikj triple loop (reference-BLAS class).
pub fn gemm(a: &Mat, b: &Mat) -> Result<Mat> {
    if a.cols != b.rows {
        bail!("gemm dims: ({}x{}) @ ({}x{})", a.rows, a.cols, b.rows, b.cols);
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::new(m, n);
    for i in 0..m {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a.data[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
        }
    }
    Ok(c)
}

/// C = A^T @ A (Gram matrix), exploiting symmetry.
pub fn syrk_t(a: &Mat) -> Mat {
    let (n, p) = (a.rows, a.cols);
    let mut c = Mat::new(p, p);
    for r in 0..n {
        let row = &a.data[r * p..(r + 1) * p];
        for i in 0..p {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            let ci = &mut c.data[i * p..(i + 1) * p];
            for j in i..p {
                ci[j] += v * row[j];
            }
        }
    }
    // Mirror the upper triangle.
    for i in 0..p {
        for j in 0..i {
            c.data[i * p + j] = c.data[j * p + i];
        }
    }
    c
}

/// y = A^T @ x.
pub fn gemv_t(a: &Mat, x: &[f32]) -> Result<Vec<f32>> {
    if x.len() != a.rows {
        bail!("gemv_t dims: ({}x{})^T @ {}", a.rows, a.cols, x.len());
    }
    let mut y = vec![0.0f32; a.cols];
    for r in 0..a.rows {
        let xr = x[r];
        if xr == 0.0 {
            continue;
        }
        let row = &a.data[r * a.cols..(r + 1) * a.cols];
        for (yv, av) in y.iter_mut().zip(row.iter()) {
            *yv += xr * av;
        }
    }
    Ok(y)
}

/// y = A @ x.
pub fn gemv(a: &Mat, x: &[f32]) -> Result<Vec<f32>> {
    if x.len() != a.cols {
        bail!("gemv dims: ({}x{}) @ {}", a.rows, a.cols, x.len());
    }
    let mut y = vec![0.0f32; a.rows];
    for r in 0..a.rows {
        let row = &a.data[r * a.cols..(r + 1) * a.cols];
        y[r] = row.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
    }
    Ok(y)
}

/// Unblocked Cholesky factorization (lower), in place on a copy.
/// Fails on non-SPD input.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky needs a square matrix, got {}x{}", a.rows, a.cols);
    }
    let n = a.rows;
    let mut l = Mat::new(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    bail!("matrix is not positive definite (pivot {i}: {s})");
                }
                l.set(i, j, s.sqrt() as f32);
            } else {
                l.set(i, j, (s / l.at(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Solve A x = b for SPD A via Cholesky (two triangular sweeps).
pub fn cho_solve(a: &Mat, b: &[f32]) -> Result<Vec<f32>> {
    let n = a.rows;
    if b.len() != n {
        bail!("cho_solve dims: A is {}x{}, b has {}", n, a.cols, b.len());
    }
    let l = cholesky(a)?;
    // Forward: L z = b.
    let mut z = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * z[k] as f64;
        }
        z[i] = (s / l.at(i, i) as f64) as f32;
    }
    // Backward: L^T x = z.
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = z[i] as f64;
        for k in i + 1..n {
            s -= l.at(k, i) as f64 * x[k] as f64;
        }
        x[i] = (s / l.at(i, i) as f64) as f32;
    }
    Ok(x)
}

/// Solve the ridge-stabilized normal equations (X^T X + eps I) beta = X^T y
/// given precomputed Gram/moment inputs — the native path for
/// `compute_model_parameters`.
pub fn solve_normal_eqs(ztz: &Mat, zty: &[f32], eps: f32) -> Result<Vec<f32>> {
    let n = ztz.rows;
    let mut a = ztz.clone();
    for i in 0..n {
        a.data[i * n + i] += eps;
    }
    cho_solve(&a, zty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        let data = (0..r * c).map(|_| rng.normal() as f32).collect();
        Mat::from_vec(data, r, c)
    }

    #[test]
    fn gemm_small_known() {
        let a = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let b = Mat::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2);
        let c = gemm(&a, &b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_rejects_bad_dims() {
        let a = Mat::new(2, 3);
        let b = Mat::new(2, 3);
        assert!(gemm(&a, &b).is_err());
    }

    #[test]
    fn syrk_matches_gemm() {
        let mut rng = Pcg64::seeded(1);
        let a = random_mat(&mut rng, 17, 9);
        let at = {
            let mut t = Mat::new(a.cols, a.rows);
            for i in 0..a.rows {
                for j in 0..a.cols {
                    t.set(j, i, a.at(i, j));
                }
            }
            t
        };
        let want = gemm(&at, &a).unwrap();
        let got = syrk_t(&a);
        for (x, y) in got.data.iter().zip(want.data.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemv_pair_consistent_with_gemm() {
        let mut rng = Pcg64::seeded(2);
        let a = random_mat(&mut rng, 8, 5);
        let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
        let y = gemv(&a, &x).unwrap();
        for (i, yi) in y.iter().enumerate() {
            let want: f32 = (0..5).map(|j| a.at(i, j) * x[j]).sum();
            assert!((yi - want).abs() < 1e-5);
        }
        let xt: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let yt = gemv_t(&a, &xt).unwrap();
        for (j, yj) in yt.iter().enumerate() {
            let want: f32 = (0..8).map(|i| a.at(i, j) * xt[i]).sum();
            assert!((yj - want).abs() < 1e-5);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seeded(3);
        let x = random_mat(&mut rng, 20, 6);
        let mut a = syrk_t(&x);
        for i in 0..6 {
            a.data[i * 6 + i] += 1.0; // well-conditioned SPD
        }
        let l = cholesky(&a).unwrap();
        // L L^T == A.
        for i in 0..6 {
            for j in 0..6 {
                let mut s = 0.0f64;
                for k in 0..6 {
                    s += l.at(i, k) as f64 * l.at(j, k) as f64;
                }
                assert!((s - a.at(i, j) as f64).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(vec![1.0, 2.0, 2.0, 1.0], 2, 2); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn normal_equations_recover_beta() {
        let mut rng = Pcg64::seeded(4);
        let n = 200;
        let p = 8;
        let x = random_mat(&mut rng, n, p);
        let beta_true: Vec<f32> = (0..p).map(|i| 0.1 * (i as f32 + 1.0)).collect();
        let y = gemv(&x, &beta_true).unwrap();
        let ztz = syrk_t(&x);
        let zty = gemv_t(&x, &y).unwrap();
        let beta = solve_normal_eqs(&ztz, &zty, 1e-6).unwrap();
        for (b, t) in beta.iter().zip(beta_true.iter()) {
            assert!((b - t).abs() < 1e-3, "{b} vs {t}");
        }
    }
}
