//! The RCOMPSs programming model — the five-call API of §3.2.
//!
//! | Paper (R)            | Here                                   |
//! |----------------------|----------------------------------------|
//! | `compss_start()`     | [`CompssRuntime::start`]               |
//! | `task(f, ...)`       | [`CompssRuntime::register_task`]       |
//! | calling `f.dec(...)` | [`CompssRuntime::submit`]              |
//! | `compss_wait_on(x)`  | [`CompssRuntime::wait_on`]             |
//! | `compss_barrier()`   | [`CompssRuntime::barrier`]             |
//! | `compss_stop()`      | [`CompssRuntime::stop`]                |
//!
//! The Figure-2 example (adding four numbers with a two-argument `add`)
//! reads almost identically — see `examples/quickstart.rs`.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::access::Direction;
use crate::coordinator::feedback::FeedbackStats;
use crate::coordinator::registry::DataKey;
use crate::coordinator::runtime::{Arg, Coordinator, CoordinatorConfig, TaskSpec};
use crate::value::RValue;

pub use crate::coordinator::runtime::RuntimeStats;

/// Body of `rcompss worker --connect <addr>`: register with a coordinator
/// listening on `addr` (preferring node slot `preferred` when given) and
/// serve a `budget`-bounded replica cache until the coordinator shuts the
/// cluster down. Facade re-export of the crate-internal TCP transport's
/// worker loop — see `ARCHITECTURE.md` § Transport.
pub use crate::coordinator::transport::tcp::run_worker as run_tcp_worker;

/// Runtime configuration (re-exported coordinator config with API-level
/// constructors).
pub type RuntimeConfig = CoordinatorConfig;

/// A future handle to data produced by a task — what the paper's R binding
/// returns from a decorated call before synchronization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DataRef(pub(crate) DataKey);

impl DataRef {
    /// The `dXvY` label of this handle (diagnostics, DOT cross-reference).
    pub fn label(&self) -> String {
        self.0.to_string()
    }
}

/// An argument to a submitted task: a literal value or a [`DataRef`].
#[derive(Clone)]
pub enum TaskArg {
    Value(RValue),
    Future(DataRef),
}

impl From<RValue> for TaskArg {
    fn from(v: RValue) -> TaskArg {
        TaskArg::Value(v)
    }
}

impl From<DataRef> for TaskArg {
    fn from(r: DataRef) -> TaskArg {
        TaskArg::Future(r)
    }
}

impl From<f64> for TaskArg {
    fn from(x: f64) -> TaskArg {
        TaskArg::Value(RValue::scalar(x))
    }
}

impl From<i32> for TaskArg {
    fn from(x: i32) -> TaskArg {
        TaskArg::Value(RValue::int_scalar(x))
    }
}

/// A task definition: the analog of `task(add, "add.R", return_value=TRUE)`.
pub struct TaskDef {
    pub(crate) spec: Arc<TaskSpec>,
}

impl TaskDef {
    /// Define a task with `arity` IN arguments and one return value.
    ///
    /// Arguments arrive as `Arc<RValue>` handles: the in-memory data plane
    /// hands every node-local consumer the producer's allocation without a
    /// copy. `Arc<RValue>` derefs to [`RValue`], so accessors read as
    /// before (`args[0].as_f64()`); use `args[0].as_ref()` where a plain
    /// `&RValue` is needed.
    pub fn new(
        name: &str,
        arity: usize,
        body: impl Fn(&[Arc<RValue>]) -> Result<Vec<RValue>> + Send + Sync + 'static,
    ) -> TaskDef {
        TaskDef {
            spec: Arc::new(TaskSpec {
                name: name.into(),
                arity,
                n_outputs: 1,
                directions: vec![Direction::In; arity],
                body: Arc::new(body),
            }),
        }
    }

    /// Override the number of return values (0 for side-effect-only tasks
    /// whose completion is awaited via `barrier`).
    pub fn with_outputs(mut self, n: usize) -> TaskDef {
        Arc::get_mut(&mut self.spec)
            .expect("with_outputs after registration")
            .n_outputs = n;
        self
    }

    /// Override per-argument directions (INOUT support).
    pub fn with_directions(mut self, dirs: Vec<Direction>) -> TaskDef {
        let spec = Arc::get_mut(&mut self.spec).expect("with_directions after registration");
        assert_eq!(dirs.len(), spec.arity, "directions must match arity");
        spec.directions = dirs;
        self
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }
}

/// A registered task, bound to a runtime — calling it submits executions.
#[derive(Clone)]
pub struct RegisteredTask {
    spec: Arc<TaskSpec>,
}

/// The runtime handle (`library(RCOMPSs)` + `compss_start()`).
pub struct CompssRuntime {
    coord: Coordinator,
}

impl CompssRuntime {
    /// Initialize the COMPSs runtime (spawns the persistent worker pool).
    pub fn start(config: RuntimeConfig) -> Result<CompssRuntime> {
        Ok(CompssRuntime {
            coord: Coordinator::start(config)?,
        })
    }

    /// Register a task definition (the `task()` call).
    pub fn register_task(&self, def: TaskDef) -> RegisteredTask {
        RegisteredTask { spec: def.spec }
    }

    /// Submit an asynchronous execution; returns the handle to its single
    /// return value. (Use [`CompssRuntime::submit_multi`] for multi-output
    /// tasks.)
    pub fn submit(&self, task: &RegisteredTask, args: &[TaskArg]) -> Result<DataRef> {
        let out = self.submit_multi(task, args)?;
        out.into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("task '{}' declares no outputs", task.spec.name))
    }

    /// Submit and get every output handle.
    pub fn submit_multi(&self, task: &RegisteredTask, args: &[TaskArg]) -> Result<Vec<DataRef>> {
        let coord_args: Vec<Arg> = args
            .iter()
            .map(|a| match a {
                TaskArg::Value(v) => Arg::Value(v.clone()),
                TaskArg::Future(r) => Arg::Ref(r.0),
            })
            .collect();
        let outcome = self.coord.submit(&task.spec, &coord_args)?;
        Ok(outcome
            .returns
            .into_iter()
            .chain(outcome.updated)
            .map(DataRef)
            .collect())
    }

    /// Submit a batch of task calls under a *single* acquisition of the
    /// runtime's control lock, amortizing per-task dispatch overhead
    /// across the batch. Semantically identical to calling
    /// [`CompssRuntime::submit_multi`] once per element, in order; the
    /// apps' partition loops (fragment generation, per-fragment partials)
    /// use this. Returns one output-handle vector per call.
    ///
    /// ```
    /// use rcompss::prelude::*;
    ///
    /// let rt = CompssRuntime::start(RuntimeConfig::local_in_memory(2)).unwrap();
    /// let double = rt.register_task(TaskDef::new("double", 1, |args| {
    ///     Ok(vec![RValue::scalar(2.0 * args[0].as_f64().unwrap())])
    /// }));
    /// // A whole partition loop in one control-lock acquisition.
    /// let calls: Vec<_> = (0..4)
    ///     .map(|i| (&double, vec![TaskArg::from(i as f64)]))
    ///     .collect();
    /// let outs = rt.submit_batch(&calls).unwrap();
    /// let total: f64 = outs
    ///     .iter()
    ///     .map(|o| rt.wait_on(&o[0]).unwrap().as_f64().unwrap())
    ///     .sum();
    /// assert_eq!(total, 12.0);
    /// rt.stop().unwrap();
    /// ```
    pub fn submit_batch(
        &self,
        calls: &[(&RegisteredTask, Vec<TaskArg>)],
    ) -> Result<Vec<Vec<DataRef>>> {
        let coord_calls: Vec<(Arc<TaskSpec>, Vec<Arg>)> = calls
            .iter()
            .map(|(task, args)| {
                let a: Vec<Arg> = args
                    .iter()
                    .map(|x| match x {
                        TaskArg::Value(v) => Arg::Value(v.clone()),
                        TaskArg::Future(r) => Arg::Ref(r.0),
                    })
                    .collect();
                (Arc::clone(&task.spec), a)
            })
            .collect();
        let outcomes = self.coord.submit_batch(&coord_calls)?;
        Ok(outcomes
            .into_iter()
            .map(|o| o.returns.into_iter().chain(o.updated).map(DataRef).collect())
            .collect())
    }

    /// `compss_wait_on`: block for and fetch a value.
    pub fn wait_on(&self, r: &DataRef) -> Result<RValue> {
        self.coord.wait_on(r.0)
    }

    /// Pin a handle so the version GC never reclaims it, without waiting.
    /// `wait_on` pins implicitly — but only at fetch time. If the program
    /// submits consumers of a value and fetches the same handle *after*
    /// they may have finished, pin it first (at submission time), or the
    /// GC may legitimately reclaim it the moment its last consumer drains.
    pub fn pin(&self, r: &DataRef) -> Result<()> {
        self.coord.pin(r.0)
    }

    /// `compss_barrier`: block until all submitted tasks finished.
    pub fn barrier(&self) -> Result<()> {
        self.coord.barrier()
    }

    /// `compss_stop`: drain, shut the pool down, and report statistics.
    pub fn stop(self) -> Result<RuntimeStats> {
        let workdir = self.coord.config.workdir.clone();
        let stats = self.coord.stop()?;
        let _ = std::fs::remove_dir_all(workdir);
        Ok(stats)
    }

    /// Current DAG in Graphviz DOT (Figures 2-5).
    pub fn dag_dot(&self, title: &str) -> String {
        self.coord.dag_dot(title)
    }

    /// Trace snapshot (Figure 10).
    pub fn trace(&self, label: &str) -> crate::trace::Trace {
        self.coord.trace(label)
    }

    /// Runtime statistics snapshot.
    pub fn stats(&self) -> RuntimeStats {
        self.coord.stats()
    }

    /// The observation sink behind `--router adaptive` (`None` for the
    /// static models): per-destination transfer-bandwidth and
    /// per-task-type duration EWMAs fed by the mover threads and the
    /// executors. Benches and tests use it to pre-seed skewed
    /// observations or inspect what the model has learned.
    pub fn feedback_stats(&self) -> Option<Arc<FeedbackStats>> {
        self.coord.feedback_stats()
    }

    /// DAG critical-path length.
    pub fn critical_path_len(&self) -> usize {
        self.coord.critical_path_len()
    }

    /// Kill an emulated node mid-run (fault injection / chaos testing):
    /// its workers park, in-flight transfers toward it fail fast, and
    /// every version it solely held is re-derived by lineage re-execution.
    /// The last alive node is never killed. Returns `true` if the node was
    /// alive.
    pub fn kill_node(&self, node: u32) -> bool {
        self.coord.kill_node(crate::coordinator::registry::NodeId(node))
    }

    /// Re-admit a previously-killed node (elasticity): its shard re-opens
    /// for placement and stealing and its workers resume. Returns `true`
    /// if the node was dead.
    pub fn add_node(&self, node: u32) -> bool {
        self.coord.add_node(crate::coordinator::registry::NodeId(node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add_task() -> TaskDef {
        TaskDef::new("add", 2, |args| {
            let x = args[0].as_f64().ok_or_else(|| anyhow::anyhow!("x"))?;
            let y = args[1].as_f64().ok_or_else(|| anyhow::anyhow!("y"))?;
            Ok(vec![RValue::scalar(x + y)])
        })
    }

    #[test]
    fn figure2_add_four_numbers() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let add = rt.register_task(add_task());
        // Task(1), Task(2), Task(3) as in Figure 2.
        let r1 = rt.submit(&add, &[4.0.into(), 5.0.into()]).unwrap();
        let r2 = rt.submit(&add, &[6.0.into(), 7.0.into()]).unwrap();
        let r3 = rt.submit(&add, &[r1.into(), r2.into()]).unwrap();
        let v = rt.wait_on(&r3).unwrap();
        assert_eq!(v.as_f64(), Some(22.0));
        let stats = rt.stop().unwrap();
        assert_eq!(stats.tasks_done, 3);
        assert_eq!(stats.tasks_failed, 0);
    }

    #[test]
    fn dag_of_figure2_has_diamond_shape() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let add = rt.register_task(add_task());
        let r1 = rt.submit(&add, &[1.0.into(), 2.0.into()]).unwrap();
        let r2 = rt.submit(&add, &[3.0.into(), 4.0.into()]).unwrap();
        let r3 = rt.submit(&add, &[r1.into(), r2.into()]).unwrap();
        rt.wait_on(&r3).unwrap();
        let dot = rt.dag_dot("fig2");
        assert!(dot.contains("main ->"));
        assert!(dot.contains("-> sync"));
        // Two RAW edges into task 3.
        assert_eq!(dot.matches("-> 3 [label=").count(), 2);
        rt.stop().unwrap();
    }

    #[test]
    fn barrier_waits_for_everything() {
        let rt = CompssRuntime::start(RuntimeConfig::local(4)).unwrap();
        let slow = rt.register_task(TaskDef::new("slow", 1, |args| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            Ok(vec![args[0].as_ref().clone()])
        }));
        for i in 0..16 {
            rt.submit(&slow, &[(i as f64).into()]).unwrap();
        }
        rt.barrier().unwrap();
        assert_eq!(rt.stats().tasks_done, 16);
        rt.stop().unwrap();
    }

    #[test]
    fn failing_task_surfaces_in_wait_on() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let boom = rt.register_task(TaskDef::new("boom", 0, |_| {
            anyhow::bail!("kaboom")
        }));
        let r = rt.submit(&boom, &[]).unwrap();
        let err = rt.wait_on(&r).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        assert!(rt.barrier().is_err());
        // stop() still succeeds after failures.
        let stats = rt.stop().unwrap();
        assert_eq!(stats.tasks_failed, 1);
        // Default retry policy ran it 1 + 2 times.
        assert_eq!(stats.resubmissions, 2);
    }

    #[test]
    fn figure2_add_four_numbers_on_memory_plane() {
        // Same program as `figure2_add_four_numbers`, but through the
        // in-memory data plane: identical result, all consumptions served
        // zero-copy from the store, no spills at this budget.
        let rt = CompssRuntime::start(RuntimeConfig::local_in_memory(2)).unwrap();
        let add = rt.register_task(add_task());
        let r1 = rt.submit(&add, &[4.0.into(), 5.0.into()]).unwrap();
        let r2 = rt.submit(&add, &[6.0.into(), 7.0.into()]).unwrap();
        let r3 = rt.submit(&add, &[r1.into(), r2.into()]).unwrap();
        let v = rt.wait_on(&r3).unwrap();
        assert_eq!(v.as_f64(), Some(22.0));
        let stats = rt.stop().unwrap();
        assert_eq!(stats.tasks_done, 3);
        assert!(stats.store_hits >= 7, "6 task inputs + 1 wait_on: {stats:?}");
        assert_eq!(stats.store_misses, 0);
        assert_eq!(stats.spills, 0);
        assert_eq!(stats.bytes_serialized, 0, "no codec on a node-local chain");
    }

    #[test]
    fn memory_plane_spills_under_pressure_and_reloads() {
        // A budget far below the working set forces LRU spills through the
        // codec; reloads must still produce exact results. GC pinned off:
        // with it on, drained intermediates would be reclaimed instead of
        // spilled and the pressure this test depends on would vanish.
        let config = RuntimeConfig::local(2)
            .with_memory_budget(64)
            .with_spill("lru")
            .with_gc(false);
        let rt = CompssRuntime::start(config).unwrap();
        let add = rt.register_task(add_task());
        let mut acc = rt.submit(&add, &[0.0.into(), 0.0.into()]).unwrap();
        for i in 1..=10 {
            acc = rt.submit(&add, &[acc.into(), (i as f64).into()]).unwrap();
        }
        let v = rt.wait_on(&acc).unwrap();
        assert_eq!(v.as_f64(), Some(55.0));
        let stats = rt.stop().unwrap();
        assert!(stats.spills > 0, "tiny budget must spill: {stats:?}");
    }

    #[test]
    fn unknown_spill_policy_is_rejected() {
        let config = RuntimeConfig::local(1).with_memory_budget(1024).with_spill("nope");
        assert!(CompssRuntime::start(config).is_err());
    }

    #[test]
    fn submit_batch_matches_sequential_submission() {
        let rt = CompssRuntime::start(RuntimeConfig::local_in_memory(3)).unwrap();
        let add = rt.register_task(add_task());
        let calls: Vec<_> = (0..6)
            .map(|i| (&add, vec![TaskArg::from(i as f64), TaskArg::from(1.0)]))
            .collect();
        let outs = rt.submit_batch(&calls).unwrap();
        assert_eq!(outs.len(), 6);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.len(), 1);
            assert_eq!(rt.wait_on(&o[0]).unwrap().as_f64(), Some(i as f64 + 1.0));
        }
        let stats = rt.stop().unwrap();
        assert_eq!(stats.tasks_done, 6);
        assert_eq!(stats.tasks_failed, 0);
    }

    #[test]
    fn submit_batch_rejects_bad_arity_before_submitting() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let add = rt.register_task(add_task());
        // Second call has the wrong arity: the whole batch is rejected
        // up-front, nothing enters the DAG.
        let calls = vec![
            (&add, vec![TaskArg::from(1.0), TaskArg::from(2.0)]),
            (&add, vec![TaskArg::from(1.0)]),
        ];
        assert!(rt.submit_batch(&calls).is_err());
        assert_eq!(rt.stats().tasks_submitted, 0);
        rt.stop().unwrap();
    }

    #[test]
    fn gc_runtime_reclaims_chain_intermediates() {
        // A RAW chain under the version GC: every intermediate is
        // reclaimed as its single consumer finishes; only the pinned
        // (waited-on) final value stays resident.
        let config = RuntimeConfig::local_in_memory(2).with_gc(true);
        let rt = CompssRuntime::start(config).unwrap();
        let add = rt.register_task(add_task());
        let mut acc = rt.submit(&add, &[0.0.into(), 1.0.into()]).unwrap();
        for i in 2..=8 {
            acc = rt.submit(&add, &[acc.into(), (i as f64).into()]).unwrap();
        }
        let v = rt.wait_on(&acc).unwrap();
        assert_eq!(v.as_f64(), Some(36.0));
        let stats = rt.stop().unwrap();
        assert_eq!(stats.dead_version_bytes, 0, "{stats:?}");
        assert!(stats.gc_collected >= 7, "chain intermediates reclaimed: {stats:?}");
        // Only the final pinned scalar remains resident.
        assert!(
            stats.store_resident_bytes <= 64,
            "store should end nearly empty: {stats:?}"
        );
    }

    #[test]
    fn zero_output_tasks_via_barrier() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let sink = rt.register_task(TaskDef::new("sink", 1, |_| Ok(vec![])).with_outputs(0));
        let refs = rt
            .submit_multi(&sink, &[RValue::scalar(1.0).into()])
            .unwrap();
        assert!(refs.is_empty());
        rt.barrier().unwrap();
        rt.stop().unwrap();
    }

    #[test]
    fn inout_argument_chains_versions() {
        let rt = CompssRuntime::start(RuntimeConfig::local(2)).unwrap();
        let init = rt.register_task(TaskDef::new("init", 0, |_| {
            Ok(vec![RValue::scalar(0.0)])
        }));
        let bump = rt.register_task(
            TaskDef::new("bump", 1, |args| {
                let x = args[0].as_f64().unwrap();
                Ok(vec![RValue::scalar(x + 1.0)])
            })
            .with_outputs(0)
            .with_directions(vec![Direction::InOut]),
        );
        let counter = rt.submit(&init, &[]).unwrap();
        // Three INOUT bumps must serialize (WAW/RAW chain) and the final
        // version must be 3.
        let mut latest = counter;
        for _ in 0..3 {
            let outs = rt.submit_multi(&bump, &[latest.into()]).unwrap();
            assert_eq!(outs.len(), 1); // the updated INOUT handle
            latest = outs[0];
        }
        let v = rt.wait_on(&latest).unwrap();
        assert_eq!(v.as_f64(), Some(3.0));
        rt.stop().unwrap();
    }
}
