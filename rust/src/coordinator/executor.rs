//! The persistent worker executor loop.
//!
//! RCOMPSs deploys one worker process per node with "as many executor
//! processes as available cores; each executor lives during the entire
//! application execution time" (§3.2). Here each executor is a thread,
//! pinned logically to a (node, slot) pair. The loop:
//!
//! 1. waits for the scheduler to offer a ready task for its node,
//! 2. deserializes the task's input files through the configured codec
//!    (recording a transfer if the file was produced on another node),
//! 3. executes the task body (with failure injection if configured),
//! 4. serializes the outputs and marks them available, and
//! 5. completes the task, which unblocks dependents and waiters —
//!    or, on failure, resubmits it within the retry budget.

use std::sync::Arc;

use crate::coordinator::dag::TaskState;
use crate::coordinator::runtime::{Claim, Shared};
use crate::trace::{EventKind, WorkerId};
use crate::value::RValue;

/// Body of every persistent worker thread.
pub(crate) fn worker_loop(shared: Arc<Shared>, wid: WorkerId) {
    loop {
        // ---- acquire work ------------------------------------------------
        let claim: Claim = {
            let mut core = shared.core.lock().unwrap();
            loop {
                if let Some(id) = core.scheduler.pop_for(wid.node) {
                    core.graph.start(id);
                    // Locality accounting is resolved here, under the claim
                    // lock, instead of re-locking per input on the read
                    // path (2 lock round-trips per input saved — see
                    // EXPERIMENTS.md §Perf).
                    let input_keys = core.meta[&id].inputs.clone();
                    let inputs: Vec<(crate::coordinator::registry::DataKey, std::path::PathBuf, bool)> =
                        input_keys
                            .iter()
                            .map(|k| {
                                let local = core.registry.is_local(*k, wid.node);
                                if !local {
                                    core.registry.add_location(*k, wid.node);
                                }
                                (*k, shared.path_for(*k), local)
                            })
                            .collect();
                    let meta = &core.meta[&id];
                    // Only return-value / INOUT-new versions are produced
                    // here; `outputs` already holds exactly those.
                    let claim = Claim {
                        id,
                        spec: Arc::clone(&meta.spec),
                        inputs,
                        outputs: meta.outputs.clone(),
                    };
                    break claim;
                }
                if core.shutdown {
                    return;
                }
                core = shared.cv_work.wait(core).unwrap();
            }
        };

        // ---- deserialize inputs (outside the lock) ------------------------
        let mut args: Vec<RValue> = Vec::with_capacity(claim.inputs.len());
        let mut input_bytes = 0u64;
        let deser_start = shared.tracer.now();
        let mut io_error: Option<anyhow::Error> = None;
        for (key, path, was_local) in &claim.inputs {
            // Locality accounting was resolved at claim time: a read of a
            // version not resident on this node counts as a transfer (live
            // mode shares one filesystem, so the "transfer" is free, but
            // the event keeps live traces comparable with simulated ones).
            if !was_local {
                let t = shared.tracer.now();
                shared
                    .tracer
                    .record_at(wid, EventKind::Transfer, Some(claim.id), t, t);
            }
            match shared.codec.read_file(path) {
                Ok(v) => {
                    input_bytes += std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                    args.push(v);
                }
                Err(e) => {
                    io_error = Some(e.context(format!("deserialize {key}")));
                    break;
                }
            }
        }
        let deser_end = shared.tracer.now();
        if !claim.inputs.is_empty() {
            shared.tracer.record_at(
                wid,
                EventKind::Deserialize,
                Some(claim.id),
                deser_start,
                deser_end,
            );
        }

        // ---- execute -------------------------------------------------------
        let exec_start = shared.tracer.now();
        let result: anyhow::Result<Vec<RValue>> = match io_error {
            Some(e) => Err(e),
            None => {
                if shared.injector.should_fail(&claim.spec.name) {
                    Err(anyhow::anyhow!(
                        "injected failure in '{}' (attempt on {wid})",
                        claim.spec.name
                    ))
                } else {
                    (claim.spec.body)(&args)
                }
            }
        };
        let exec_end = shared.tracer.now();
        shared.tracer.record_at(
            wid,
            EventKind::TaskExec(claim.spec.name.clone()),
            Some(claim.id),
            exec_start,
            exec_end,
        );

        match result {
            Ok(outputs) => {
                // ---- serialize outputs (outside the lock) -----------------
                let ser_start = shared.tracer.now();
                let mut produced = Vec::with_capacity(claim.outputs.len());
                let mut ser_error: Option<anyhow::Error> = None;
                if outputs.len() != claim.outputs.len() {
                    ser_error = Some(anyhow::anyhow!(
                        "task '{}' returned {} values, declared {}",
                        claim.spec.name,
                        outputs.len(),
                        claim.outputs.len()
                    ));
                } else {
                    for (key, value) in claim.outputs.iter().zip(outputs.iter()) {
                        let path = shared.path_for(*key);
                        match shared.codec.write_file(value, &path) {
                            Ok(()) => {
                                let bytes =
                                    std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                                produced.push((*key, bytes, path));
                            }
                            Err(e) => {
                                ser_error = Some(e.context(format!("serialize {key}")));
                                break;
                            }
                        }
                    }
                }
                let ser_end = shared.tracer.now();
                if !claim.outputs.is_empty() {
                    shared.tracer.record_at(
                        wid,
                        EventKind::Serialize,
                        Some(claim.id),
                        ser_start,
                        ser_end,
                    );
                }

                let mut core = shared.core.lock().unwrap();
                if let Some(e) = ser_error {
                    handle_failure(&shared, &mut core, &claim, wid, e);
                } else {
                    for (key, bytes, path) in produced {
                        core.registry.mark_available(key, wid.node, bytes, path);
                        core.stats.bytes_serialized += bytes;
                    }
                    core.stats.bytes_deserialized += input_bytes;
                    core.stats.deserialize_s += deser_end - deser_start;
                    core.stats.serialize_s += ser_end - ser_start;
                    core.stats.exec_s += exec_end - exec_start;
                    let per = core
                        .stats
                        .per_type
                        .entry(claim.spec.name.clone())
                        .or_insert((0, 0.0));
                    per.0 += 1;
                    per.1 += exec_end - exec_start;
                    core.stats.tasks_done += 1;
                    let newly_ready = core.graph.complete(claim.id);
                    for t in newly_ready {
                        core.enqueue_ready(t);
                    }
                    shared.cv_work.notify_all();
                    shared.cv_done.notify_all();
                }
            }
            Err(e) => {
                let mut core = shared.core.lock().unwrap();
                core.stats.bytes_deserialized += input_bytes;
                core.stats.deserialize_s += deser_end - deser_start;
                handle_failure(&shared, &mut core, &claim, wid, e);
            }
        }
    }
}

/// Failure path: resubmit within budget, else fail + cancel downstream.
fn handle_failure(
    shared: &Arc<Shared>,
    core: &mut crate::coordinator::runtime::Core,
    claim: &Claim,
    wid: WorkerId,
    err: anyhow::Error,
) {
    let attempts = core
        .graph
        .node(claim.id)
        .map(|n| n.attempts)
        .unwrap_or(u32::MAX);
    if shared.retry.may_retry(attempts) {
        // COMPSs-style resubmission: back to the ready queue; any worker
        // (possibly on another node) may pick it up.
        core.stats.resubmissions += 1;
        core.graph.resubmit(claim.id);
        core.enqueue_ready(claim.id);
        shared.cv_work.notify_one();
        eprintln!(
            "[rcompss] task {} '{}' failed on {wid} (attempt {attempts}): {err}; resubmitting",
            claim.id, claim.spec.name
        );
    } else {
        let cancelled = core.graph.fail(claim.id);
        core.stats.tasks_failed += 1;
        core.stats.tasks_cancelled += cancelled.len() as u64;
        debug_assert_eq!(core.graph.state(claim.id), Some(TaskState::Failed));
        eprintln!(
            "[rcompss] task {} '{}' failed permanently after {attempts} attempts: {err}; cancelled {} dependents",
            claim.id,
            claim.spec.name,
            cancelled.len()
        );
        shared.cv_done.notify_all();
        shared.cv_work.notify_all();
    }
}
