//! The persistent worker executor loop.
//!
//! RCOMPSs deploys one worker process per node with "as many executor
//! processes as available cores; each executor lives during the entire
//! application execution time" (§3.2). Here each executor is a thread,
//! pinned logically to a (node, slot) pair. The loop:
//!
//! 1. pops a ready task from its node's shard of the dispatch fabric
//!    (stealing from other shards before parking — no global lock),
//! 2. flips the task to Running and grabs its metadata `Arc` — the only
//!    touch of the control lock before execution; locality and paths are
//!    resolved afterwards against the sharded version table,
//! 3. gathers inputs: zero-copy `Arc` handles from the hot tier for
//!    node-local values, in-memory decodes of warm-tier blobs for demoted
//!    values, codec reads for file-plane and cold-spilled values, and
//!    cross-node transfers (which force the value through the codec, as on
//!    a real cluster),
//! 4. executes the task body (with failure injection if configured),
//! 5. publishes the outputs — into the store (memory plane, spilling under
//!    pressure) or through `Codec::write_file` (file plane, byte-identical
//!    to the original runtime) — and completes the task, which unblocks
//!    dependents and waiters; on failure it resubmits within the retry
//!    budget.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::coordinator::dag::{TaskId, TaskState};
use crate::coordinator::registry::{DataKey, NodeId};
use crate::coordinator::runtime::{
    collect_version, kill_node_now, reap_if_drained, recover_lost_versions, release_inputs,
    Core, Shared, TaskMeta,
};
use crate::coordinator::store::{self, cold};
use crate::trace::{EventKind, WorkerId};
use crate::value::RValue;

/// Assumed cold-tier write bandwidth (bytes/s) for the `--checkpoint cold`
/// cost bound. Deliberately conservative: checkpointing is skipped only
/// when the write would clearly cost more than re-deriving the value.
const CHECKPOINT_BW: f64 = 100e6;
/// A checkpoint is written when `re-execution cost × safety ≥ write cost`
/// — re-running a task also replays its upstream staging, so the measured
/// duration undercounts what a loss actually costs.
const CHECKPOINT_SAFETY: f64 = 8.0;

/// Fetch an available value for a node-local consumer, climbing the tier
/// ladder: a zero-copy handle when the hot tier holds it, an in-memory
/// decode of the warm blob (no disk) when it was demoted, a codec reload
/// of its spill file as the cold fallback (re-caching the result either
/// way). Returns `(value, decoded, serialized_bytes)`.
///
/// Only called for values already marked available, whose producer always
/// publishes a tier entry or the spill path first — the yield loop can
/// only spin across the instants of a concurrent demotion. A version the
/// GC reclaimed is an error, never a hang (the refcount protocol makes
/// this unreachable from a live claim path).
pub(crate) fn fetch_resident(
    shared: &Shared,
    key: DataKey,
) -> anyhow::Result<(Arc<RValue>, bool, u64)> {
    loop {
        if let Some(v) = shared.store.hot().get(key) {
            return Ok((v, false, 0));
        }
        if let Some(blob) = shared.store.warm().get(key) {
            // Warm promotion: decode the cached blob — zero file I/O. The
            // hot entry carries `has_file` only when a cold file actually
            // exists for this version (per-tier residency), so a later
            // demotion is free exactly when it can be.
            let v = Arc::new(shared.codec.decode(&blob)?);
            let has_file = shared.table.path_of(key).is_some();
            let victims = shared.store.hot().put(key, Arc::clone(&v), has_file);
            store::demote_victims(shared, victims);
            return Ok((v, true, blob.len() as u64));
        }
        if let Some(path) = shared.table.path_of(key) {
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            shared.store.cold().note_read();
            let v = Arc::new(shared.codec.read_file(&path)?);
            let victims = shared.store.hot().put(key, Arc::clone(&v), true);
            store::demote_victims(shared, victims);
            return Ok((v, true, bytes));
        }
        if shared.table.is_collected(key) {
            anyhow::bail!("datum {key} was reclaimed by the version GC");
        }
        if !shared.table.is_available(key) {
            // Lost with a dead node: fail fast instead of spinning across
            // the re-derivation window — the caller's failure path
            // resubmits and the retry finds the recovered bytes.
            anyhow::bail!("datum {key} is unavailable (lost with a dead node)");
        }
        std::thread::yield_now();
    }
}

/// Gather one input for a worker on `node`. Returns
/// `(value, decoded, file_bytes)` where `decoded` marks an actual codec
/// invocation on this (claim) path — it drives the Deserialize trace event
/// and byte stats.
///
/// Cross-node inputs are normally staged by a mover thread before the
/// claim (schedule-time prefetch); the claimant then takes the zero-copy
/// fast path. It parks on the transfer only when the bytes are not there
/// at the moment it actually needs them, and runs the codec itself only as
/// a last-resort fallback (service disabled or transfer failed) — the
/// counted seed behavior.
pub(crate) fn acquire_input(
    shared: &Shared,
    key: DataKey,
    node: NodeId,
    was_local: bool,
) -> anyhow::Result<(Arc<RValue>, bool, u64)> {
    if !shared.store.enabled() {
        // File plane: byte-identical to the seed runtime.
        let path = shared.path_for(key);
        shared.store.cold().note_read();
        let v = shared.codec.read_file(&path)?;
        let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        return Ok((Arc::new(v), true, bytes));
    }
    if was_local || shared.table.is_local(key, node) {
        // Node-local, or staged by a mover since routing: zero-copy handle
        // (or a pressure-spill reload).
        return fetch_resident(shared, key);
    }
    if shared.transfers.enabled() {
        // A stolen task can need bytes the router never prefetched here;
        // the size estimate keeps the in-flight gauge honest either way.
        let bytes = shared.table.info(key).map(|i| i.bytes).unwrap_or(0);
        match shared.transfers.await_staged(key, node, bytes) {
            Ok(()) => return fetch_resident(shared, key),
            Err(e) => eprintln!(
                "[rcompss] transfer of {key} to node {} failed ({e}); \
                 falling back to a synchronous reload",
                node.0
            ),
        }
    }
    // Synchronous fallback (the seed behavior): the claim path itself runs
    // the cross-node codec round-trip. Counted — the transfer tests assert
    // this stays zero while the service is on and healthy.
    shared.store.hot().note_sync_transfer_decode();
    let path = cold::ensure_file(shared, key)?;
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    shared.store.cold().note_read();
    let v = Arc::new(shared.codec.read_file(&path)?);
    let victims = shared.store.hot().put(key, Arc::clone(&v), true);
    store::demote_victims(shared, victims);
    shared.table.add_location(key, node);
    Ok((v, true, bytes))
}

/// One dispatch unit as a worker sees it: the claimed task plus the
/// window compiler's plan entries taken with the claim. `fused` names the
/// member to run inline after a successful completion, `alias` is the
/// ahead-of-time death list, and `handed` carries a fused intermediate
/// received worker-local from the head — never published to any tier.
struct Dispatch {
    id: TaskId,
    meta: Arc<TaskMeta>,
    fused: Option<(TaskId, DataKey)>,
    alias: Vec<DataKey>,
    handed: Option<(DataKey, Arc<RValue>)>,
}

/// Body of every persistent worker thread.
pub(crate) fn worker_loop(shared: Arc<Shared>, wid: WorkerId) {
    // `pop` parks the thread between tasks and returns None at shutdown.
    while let Some(id) = shared.ready.pop(wid.node) {
        // ---- claim: the control lock covers only the state flip, an Arc
        // clone of the metadata, and the take of the compiled plan
        // entries for this task (no per-input work under the lock).
        let claim: Option<Dispatch> = {
            let mut core = shared.core.lock().unwrap();
            if core.graph.state(id) != Some(TaskState::Ready) {
                // Stale queue entry: `reopen` re-gated this task (node-loss
                // recovery) and the fresh entry is elsewhere — or another
                // path already handled it. Discard.
                None
            } else if !shared.health.is_alive(wid.node) {
                // Popped in the race window of a kill: a dead node runs
                // nothing — hand the task back to the alive shards.
                let core = &mut *core;
                shared.enqueue_ready(core, id);
                None
            } else {
                core.graph.start(id);
                // The fusion link is consumed by the successful claim: a
                // later retry of this task dispatches unfused, so every
                // failure path degrades to the ordinary protocol.
                let fused = core.fused_next.remove(&id);
                let alias = core.alias.remove(&id).unwrap_or_default();
                Some(Dispatch {
                    id,
                    meta: Arc::clone(&core.meta[&id]),
                    fused,
                    alias,
                    handed: None,
                })
            }
        };
        // A fused chain runs to exhaustion on this worker: each member is
        // claimed under the lock inside `run_unit` and handed back here.
        let mut current = claim;
        while let Some(unit) = current {
            current = run_unit(&shared, wid, unit);
        }
    }
}

/// Gather, execute, publish, and complete one dispatch unit. Returns the
/// fused member to run inline next (already claimed, with the
/// intermediate in hand), or `None` when the chain ends here.
fn run_unit(shared: &Arc<Shared>, wid: WorkerId, unit: Dispatch) -> Option<Dispatch> {
    let Dispatch {
        id,
        meta,
        fused,
        alias,
        handed,
    } = unit;
    // Locality accounting against the sharded table, outside all locks.
    // On the memory plane the location of a cross-node input is
    // published by whoever actually stages the bytes (mover or
    // fallback); on the file plane the codec read below stages them
    // implicitly, so the claim records the location up front as the
    // seed runtime did. A handed intermediate lives in this worker's
    // hands, not in any tier — always "local", never recorded.
    let inputs: Vec<(DataKey, bool)> = meta
        .inputs
        .iter()
        .map(|k| {
            if handed.as_ref().is_some_and(|(hk, _)| hk == k) {
                return (*k, true);
            }
            let local = shared.table.is_local(*k, wid.node);
            if !local && !shared.store.enabled() {
                shared.table.add_location(*k, wid.node);
            }
            (*k, local)
        })
        .collect();

    // ---- gather inputs ------------------------------------------------
    let mut args: Vec<Arc<RValue>> = Vec::with_capacity(inputs.len());
    let mut input_bytes = 0u64;
    let mut decoded_any = false;
    let deser_start = shared.tracer.now();
    let mut io_error: Option<anyhow::Error> = None;
    for (key, was_local) in &inputs {
        if let Some((hk, hv)) = &handed {
            if key == hk {
                // The fused hand-off: zero-copy, zero-lookup.
                args.push(Arc::clone(hv));
                continue;
            }
        }
        // A read of a version not resident on this node counts as a
        // transfer (live mode shares one address space, so the
        // "transfer" cost is the codec round-trip; the event keeps
        // live traces comparable with simulated ones).
        if !*was_local {
            let t = shared.tracer.now();
            shared
                .tracer
                .record_at(wid, EventKind::Transfer, Some(id), t, t);
        }
        match acquire_input(shared, *key, wid.node, *was_local) {
            Ok((v, decoded, bytes)) => {
                args.push(v);
                input_bytes += bytes;
                decoded_any |= decoded;
            }
            Err(e) => {
                io_error = Some(e.context(format!("deserialize {key}")));
                break;
            }
        }
    }
    let deser_end = shared.tracer.now();
    if decoded_any {
        shared.tracer.record_at(
            wid,
            EventKind::Deserialize,
            Some(id),
            deser_start,
            deser_end,
        );
    }

    // ---- execute -------------------------------------------------------
    let exec_start = shared.tracer.now();
    let result: anyhow::Result<Vec<RValue>> = match io_error {
        Some(e) => Err(e),
        None => {
            if shared.injector.should_fail(&meta.spec.name) {
                Err(anyhow::anyhow!(
                    "injected failure in '{}' (attempt on {wid})",
                    meta.spec.name
                ))
            } else {
                (meta.spec.body)(&args)
            }
        }
    };
    drop(args);
    let exec_end = shared.tracer.now();
    shared.tracer.record_at(
        wid,
        EventKind::TaskExec(Arc::clone(&meta.spec.name)),
        Some(id),
        exec_start,
        exec_end,
    );
    // Feed the adaptive router's duration signal: one per-type EWMA
    // sample per successful execution (failures would poison the
    // estimate with injector/retry noise).
    if result.is_ok() {
        if let Some(fb) = &shared.feedback {
            fb.record_task(&meta.spec.name, exec_end - exec_start);
        }
    }

    match result {
        Ok(outputs) => {
            // The node died while this task was executing: its outputs
            // are gone with it — discard them and resubmit so an alive
            // node re-runs the attempt (inputs are consumed again by
            // the retry; no references are released here).
            if !shared.health.is_alive(wid.node) {
                let mut core = shared.core.lock().unwrap();
                if core.graph.state(id) == Some(TaskState::Running) {
                    core.stats.resubmissions += 1;
                    core.graph.resubmit(id);
                    let core = &mut *core;
                    if let Some((hk, _)) = &handed {
                        // The unpublished fused intermediate died with the
                        // node: lineage recovery reopens the head (whose
                        // fused entry the claim already consumed, so its
                        // retry publishes normally) and re-gates this
                        // member behind the fresh output.
                        recover_lost_versions(shared, core, &[*hk]);
                    }
                    if core.graph.state(id) == Some(TaskState::Ready) {
                        shared.enqueue_ready(core, id);
                    }
                }
                return None;
            }
            // ---- publish outputs (outside the control lock) -----------
            let ser_start = shared.tracer.now();
            let mut ser_error: Option<anyhow::Error> = None;
            let mut produced_bytes = 0u64;
            let mut encoded_any = false;
            let mut handoff: Option<(DataKey, Arc<RValue>)> = None;
            let mut early_released = false;
            if outputs.len() != meta.outputs.len() {
                ser_error = Some(anyhow::anyhow!(
                    "task '{}' returned {} values, declared {}",
                    meta.spec.name,
                    outputs.len(),
                    meta.outputs.len()
                ));
            } else if shared.store.enabled() {
                // Ahead-of-time death list: this task is the predicted
                // last reader of these versions — release them *before*
                // the outputs allocate, so a dying buffer's budget is
                // already free when its successor is put (refcount-gated:
                // a racing reader from an earlier window still holds a
                // reference and the release just decrements). No failure
                // can interpose between here and completion on this
                // plane, so the references release exactly once.
                let mut freed_pool = 0u64;
                for k in &alias {
                    if let Some(act) = shared.table.release_consumer(*k, shared.gc_enabled) {
                        shared.aot_frees.fetch_add(1, Ordering::Relaxed);
                        freed_pool += act.bytes;
                        collect_version(shared, &act);
                    }
                }
                early_released = !alias.is_empty();
                // Memory plane: the store takes ownership; the codec
                // runs only if memory pressure spills a victim. The
                // reap covers outputs whose consumers were all
                // cancelled while this task was still running.
                for (key, value) in meta.outputs.iter().zip(outputs.into_iter()) {
                    let value = Arc::new(value);
                    if fused.as_ref().is_some_and(|(_, fk)| fk == key) {
                        // The fused intermediate: handed to the member
                        // on this worker, never published.
                        handoff = Some((*key, value));
                        continue;
                    }
                    let nbytes = value.byte_size() as u64;
                    if nbytes > 0 && freed_pool >= nbytes {
                        // The death list covered this allocation: the
                        // hot tier reused the dying buffer's budget.
                        freed_pool -= nbytes;
                        shared.alias_reuses.fetch_add(1, Ordering::Relaxed);
                    }
                    let victims = shared.store.hot().put(*key, Arc::clone(&value), false);
                    shared.table.mark_available_memory(*key, wid.node, nbytes);
                    store::demote_victims(shared, victims);
                    reap_if_drained(shared, *key);
                }
            } else {
                // File plane: byte-identical to the seed runtime (a
                // fused intermediate skips its file and rides the
                // hand-off instead).
                let mut produced = Vec::with_capacity(meta.outputs.len());
                let mut values = outputs.into_iter();
                for key in meta.outputs.iter() {
                    let value = values.next().expect("arity checked above");
                    if fused.as_ref().is_some_and(|(_, fk)| fk == key) {
                        handoff = Some((*key, Arc::new(value)));
                        continue;
                    }
                    let path = shared.path_for(*key);
                    match shared.codec.write_file(&value, &path) {
                        Ok(()) => {
                            shared.store.cold().note_write();
                            let bytes =
                                std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                            produced.push((*key, bytes, path));
                        }
                        Err(e) => {
                            ser_error = Some(e.context(format!("serialize {key}")));
                            break;
                        }
                    }
                }
                if ser_error.is_none() {
                    encoded_any = !produced.is_empty();
                    for (key, bytes, path) in produced {
                        shared.table.mark_available(key, wid.node, bytes, path);
                        produced_bytes += bytes;
                        reap_if_drained(shared, key);
                    }
                }
            }
            let ser_end = shared.tracer.now();
            if encoded_any {
                shared.tracer.record_at(
                    wid,
                    EventKind::Serialize,
                    Some(id),
                    ser_start,
                    ser_end,
                );
            }

            let mut success = false;
            let mut done_count = 0u64;
            let mut inline: Option<Dispatch> = None;
            let to_release = {
                let mut core = shared.core.lock().unwrap();
                if let Some(e) = ser_error {
                    // A failed fused head publishes its intermediate
                    // normally before the failure is handled, so the
                    // unfused retry (or the member, if the head somehow
                    // half-published) finds consistent state. Only the
                    // file plane can reach this with a handoff pending.
                    if let Some((hk, hv)) = handoff.take() {
                        publish_fallback(shared, wid, hk, hv);
                    }
                    handle_failure(shared, &mut core, id, &meta, wid, e)
                } else {
                    core.stats.bytes_serialized += produced_bytes;
                    core.stats.bytes_deserialized += input_bytes;
                    core.stats.deserialize_s += deser_end - deser_start;
                    core.stats.serialize_s += ser_end - ser_start;
                    core.stats.exec_s += exec_end - exec_start;
                    // String-keyed public map, Arc<str>-interned name:
                    // allocate the key only on the first completion of
                    // each type. (The two-step lookup is deliberate —
                    // `match get_mut { None => insert }` is the
                    // get-or-insert shape stable borrowck rejects, and
                    // `entry()` would allocate a String per call.)
                    if !core.stats.per_type.contains_key(meta.spec.name.as_ref()) {
                        core.stats
                            .per_type
                            .insert(meta.spec.name.to_string(), (0, 0.0));
                    }
                    let per = core
                        .stats
                        .per_type
                        .get_mut(meta.spec.name.as_ref())
                        .expect("per-type entry just ensured");
                    per.0 += 1;
                    per.1 += exec_end - exec_start;
                    core.stats.tasks_done += 1;
                    done_count = core.stats.tasks_done;
                    let newly_ready = core.graph.complete(id);
                    let core = &mut *core;
                    // Fused hand-off: claim the member inline while the
                    // lock is held — one claim, zero queue traffic, the
                    // intermediate never published. Fallback (member
                    // re-gated by recovery, node dying): publish the
                    // intermediate *before* the enqueue below so no
                    // racing claimant can find its input missing.
                    let mut inline_member: Option<TaskId> = None;
                    if let Some((m, _)) = fused {
                        let (hk, hv) = handoff.take().expect("fused head has one output");
                        if core.graph.state(m) == Some(TaskState::Ready)
                            && shared.health.is_alive(wid.node)
                        {
                            core.graph.start(m);
                            core.placement.remove(&m);
                            let mfused = core.fused_next.remove(&m);
                            let malias = core.alias.remove(&m).unwrap_or_default();
                            inline = Some(Dispatch {
                                id: m,
                                meta: Arc::clone(&core.meta[&m]),
                                fused: mfused,
                                alias: malias,
                                handed: Some((hk, hv)),
                            });
                            inline_member = Some(m);
                        } else {
                            publish_fallback(shared, wid, hk, hv);
                        }
                    }
                    for t in newly_ready {
                        if inline_member == Some(t) {
                            continue;
                        }
                        shared.enqueue_ready(core, t);
                    }
                    // A completed member retires its hand-off: the sole
                    // consumer is done, nothing can name it again. (A
                    // waiter that pinned it mid-flight keeps the mark
                    // off and gets the compiler's wait_on error.)
                    if let Some((hk, _)) = &handed {
                        shared.table.collect_unproduced(*hk);
                        shared.transfers.purge_version(*hk);
                    }
                    shared.cv_done.notify_all();
                    success = true;
                    Vec::new()
                }
            };
            // Outside the control lock: drop this task's consumer
            // references. On success the inputs were consumed exactly
            // once; on permanent failure the references of the failed
            // task and its cancelled dependents are in `to_release`.
            // The version GC reclaims whatever drained to zero.
            if success {
                if early_released {
                    // The death-list keys released pre-publish; drop
                    // only the remaining references (multiplicity-aware).
                    let mut skip: HashMap<DataKey, usize> = HashMap::new();
                    for k in &alias {
                        *skip.entry(*k).or_insert(0) += 1;
                    }
                    let rest: Vec<DataKey> = meta
                        .inputs
                        .iter()
                        .filter(|k| {
                            if let Some(c) = skip.get_mut(k) {
                                if *c > 0 {
                                    *c -= 1;
                                    return false;
                                }
                            }
                            true
                        })
                        .copied()
                        .collect();
                    release_inputs(shared, &rest);
                } else {
                    release_inputs(shared, &meta.inputs);
                }
                if shared.checkpoint_cold
                    && shared.ready.nodes() > 1
                    && shared.store.enabled()
                {
                    maybe_checkpoint(shared, &meta, exec_end - exec_start);
                }
                // Armed chaos: the victim dies the instant the N-th
                // completion lands — a deterministic mid-run kill.
                if shared.injector.node_kill_due(done_count) {
                    if let Some(victim) = shared.chaos_victim {
                        kill_node_now(shared, victim);
                    }
                }
            } else {
                release_inputs(shared, &to_release);
            }
            inline
        }
        Err(e) => {
            // A failed fused member must not strand its unpublished
            // intermediate: publish it first (alive node) so the retry —
            // on any node — gathers it like a normal input, or hand it
            // to lineage recovery (dead node) so the head re-derives it.
            let alive = shared.health.is_alive(wid.node);
            if let Some((hk, hv)) = &handed {
                if alive {
                    publish_fallback(shared, wid, *hk, Arc::clone(hv));
                }
            }
            let to_release = {
                let mut core = shared.core.lock().unwrap();
                core.stats.bytes_deserialized += input_bytes;
                core.stats.deserialize_s += deser_end - deser_start;
                let to_release = handle_failure(shared, &mut core, id, &meta, wid, e);
                if !alive && core.graph.state(id) == Some(TaskState::Ready) {
                    if let Some((hk, _)) = &handed {
                        // Resubmitted on a dead node with the hand-off
                        // lost: reopen the head (it republishes) and
                        // re-gate this member behind it. The stale queue
                        // entry from the resubmission is discarded by
                        // the claim-time state check.
                        recover_lost_versions(shared, &mut core, &[*hk]);
                    }
                }
                to_release
            };
            release_inputs(shared, &to_release);
            None
        }
    }
}

/// Publish a fused intermediate through the normal produce path — the
/// fallback when the member cannot run inline (re-gated by recovery,
/// dying node, head or member failure). Touches only leaf domains, so it
/// is safe both under and off the control lock.
fn publish_fallback(shared: &Arc<Shared>, wid: WorkerId, key: DataKey, value: Arc<RValue>) {
    if shared.store.enabled() {
        let nbytes = value.byte_size() as u64;
        let victims = shared.store.hot().put(key, value, false);
        shared.table.mark_available_memory(key, wid.node, nbytes);
        store::demote_victims(shared, victims);
        reap_if_drained(shared, key);
    } else {
        let path = shared.path_for(key);
        match shared.codec.write_file(&value, &path) {
            Ok(()) => {
                shared.store.cold().note_write();
                let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                shared.table.mark_available(key, wid.node, bytes, path);
                reap_if_drained(shared, key);
            }
            Err(e) => eprintln!(
                "[rcompss] publish of fused intermediate {key} failed: {e:#}"
            ),
        }
    }
}

/// `--checkpoint cold`: after a successful publish, proactively write the
/// task's **sole-replica, file-less** outputs through the cold tier so a
/// node loss finds a surviving file instead of a lost version (the shared
/// filesystem outlives any node). Bounded by this execution's measured
/// cost: a value cheaper to re-derive than to write is left alone. Runs
/// off every lock; `ensure_file` is idempotent and collected-safe.
fn maybe_checkpoint(shared: &Shared, meta: &TaskMeta, exec_s: f64) {
    let reexec = exec_s.max(1e-3);
    for key in &meta.outputs {
        let Some(info) = shared.table.info(*key) else {
            continue;
        };
        if !info.available || info.locations.len() != 1 || !info.path.as_os_str().is_empty() {
            continue;
        }
        let write_s = info.bytes as f64 / CHECKPOINT_BW;
        if reexec * CHECKPOINT_SAFETY < write_s {
            continue;
        }
        if cold::ensure_file(shared, *key).is_ok() {
            let bytes = shared.table.info(*key).map(|i| i.bytes).unwrap_or(0);
            shared.checkpoints_written.fetch_add(1, Ordering::Relaxed);
            shared.checkpoint_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
    }
}

/// Failure path: resubmit within budget, else fail + cancel downstream.
/// Returns the consumer references to release once the control lock is
/// dropped: empty on resubmission (the retry consumes the inputs again),
/// the failed task's and every cancelled dependent's inputs on permanent
/// failure (none of them will ever consume).
fn handle_failure(
    shared: &Arc<Shared>,
    core: &mut Core,
    id: crate::coordinator::dag::TaskId,
    meta: &Arc<TaskMeta>,
    wid: WorkerId,
    err: anyhow::Error,
) -> Vec<DataKey> {
    let attempts = core.graph.node(id).map(|n| n.attempts).unwrap_or(u32::MAX);
    if shared.retry.may_retry(attempts) {
        // COMPSs-style resubmission: back to the ready queues; any worker
        // (possibly on another node) may pick it up.
        core.stats.resubmissions += 1;
        core.graph.resubmit(id);
        shared.enqueue_ready(core, id);
        eprintln!(
            "[rcompss] task {} '{}' failed on {wid} (attempt {attempts}): {err}; resubmitting",
            id, meta.spec.name
        );
        Vec::new()
    } else {
        let cancelled = core.graph.fail_with(id, Some(wid.node), &format!("{err:#}"));
        core.stats.tasks_failed += 1;
        core.stats.tasks_cancelled += cancelled.len() as u64;
        debug_assert_eq!(core.graph.state(id), Some(TaskState::Failed));
        eprintln!(
            "[rcompss] task {} '{}' failed permanently after {attempts} attempts: {err}; cancelled {} dependents",
            id,
            meta.spec.name,
            cancelled.len()
        );
        let mut keys: Vec<DataKey> = meta.inputs.clone();
        for t in &cancelled {
            if let Some(m) = core.meta.get(t) {
                keys.extend(m.inputs.iter().copied());
            }
        }
        shared.cv_done.notify_all();
        keys
    }
}
