//! Deterministic schedule-fuzzing yield points for the live concurrency
//! planes.
//!
//! The simulator's fuzz layer (`sim::engine`) permutes event order in
//! virtual time; this module is its live twin. The runtime's hazard
//! windows — the gaps where one thread's half-finished protocol step is
//! visible to another — are instrumented with `yield_point` calls:
//!
//! | site | window |
//! |------|--------|
//! | `ReadyPush` | `ShardedReady::push`: between routing and shard insert |
//! | `ReadySteal` | `ShardedReady::pop`: before each steal scan |
//! | `ReadyPark` | `ShardedReady::pop`: between empty scan and park |
//! | `TransferNext` | mover loop: between claim and transfer |
//! | `TransferComplete` | mover loop: between transfer and board update |
//! | `TransferPurge` | `purge_version`: before draining tombstones |
//! | `GcCollect` | `collect_version`: before discarding residency |
//! | `NodeKill` | `kill_node_now`: between health flip and board poison |
//! | `NodeJoin` | `rejoin_node`: between health flip and board revive |
//!
//! When fuzzing is off — no `RCOMPSS_SCHED_FUZZ`, no `with_sched_fuzz`,
//! no `schedfuzz` feature — every hook holds a `None` and compiles down to
//! one branch on an option discriminant: the plane costs nothing in
//! production. When armed, a seeded [`FuzzController`] decides, per visit,
//! whether to fall through, surrender the timeslice, or sleep for a few
//! hundred microseconds — widening exactly the windows the PR-4 class of
//! transfer-board/GC races needed hand-crafted timing to reach.
//!
//! # Reproducibility protocol
//!
//! The perturbation at visit `i` of site `s` under seed `k` is the pure
//! function [`decision`]`(k, s, i)` — no wall clock, no thread identity,
//! no global state. One seed therefore yields one byte-identical
//! perturbation schedule per site, run after run; what the OS scheduler
//! does inside a widened window still varies, so a seed defines a
//! reproducible *neighborhood* of interleavings rather than a single one,
//! and the invariant assertions (transfer-board accounting, zero dead
//! version bytes, correct results) must hold everywhere in it. Replay a
//! CI failure with `RCOMPSS_SCHED_FUZZ=<seed>` or
//! `CoordinatorConfig::with_sched_fuzz(seed)`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The instrumented hazard sites (see the module table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuzzSite {
    ReadyPush = 0,
    ReadySteal = 1,
    ReadyPark = 2,
    TransferNext = 3,
    TransferComplete = 4,
    TransferPurge = 5,
    GcCollect = 6,
    NodeKill = 7,
    NodeJoin = 8,
}

/// Number of [`FuzzSite`] variants (per-site visit counters).
pub const SITE_COUNT: usize = 9;

/// What one visit to a yield point does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Perturbation {
    /// Fall straight through.
    None,
    /// `thread::yield_now()` this many times: surrender the timeslice so
    /// a racing thread can take the window.
    Yield(u8),
    /// Deterministic sleep in microseconds: hold the window open long
    /// enough for a whole mover/GC/kill pipeline on another core to pass
    /// through it.
    Sleep(u16),
}

/// The pure decision function: the perturbation at visit `index` of
/// `site` under `seed`. splitmix64-style finalizer — cheap, branchless,
/// identical on every platform. Distribution: 1/2 fall through, 3/8
/// yield 1–3 times, 1/8 sleep 50–500 µs.
pub fn decision(seed: u64, site: FuzzSite, index: u64) -> Perturbation {
    let mut h = seed
        ^ (site as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ index.rotate_left(17);
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    match h % 8 {
        0..=3 => Perturbation::None,
        4..=6 => Perturbation::Yield(1 + ((h >> 8) % 3) as u8),
        _ => Perturbation::Sleep(50 + ((h >> 16) % 450) as u16),
    }
}

/// The first `n` decisions of one site's schedule — the replay protocol's
/// ground truth: two runs under one seed walk identical vectors.
pub fn schedule(seed: u64, site: FuzzSite, n: u64) -> Vec<Perturbation> {
    (0..n).map(|i| decision(seed, site, i)).collect()
}

/// Seeded perturbation controller, installed once per runtime instance —
/// never a process-global: parallel `cargo test` runtimes in one process
/// must not share visit counters, or seeds would stop replaying. Each
/// instrumented structure holds an `Option<Arc<FuzzController>>`; `None`
/// (the production configuration) short-circuits in `yield_point`.
pub struct FuzzController {
    seed: u64,
    visits: [AtomicU64; SITE_COUNT],
}

impl FuzzController {
    pub fn new(seed: u64) -> FuzzController {
        FuzzController {
            seed,
            visits: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Visits taken at `site` so far (diagnostics; summed into
    /// `RuntimeStats::sched_fuzz_perturbations` at stop).
    pub fn visits(&self, site: FuzzSite) -> u64 {
        self.visits[site as usize].load(Ordering::Relaxed)
    }

    /// Total visits across all sites.
    pub fn total_visits(&self) -> u64 {
        self.visits.iter().map(|v| v.load(Ordering::Relaxed)).sum()
    }

    /// Execute the seeded perturbation for this visit of `site`.
    pub fn perturb(&self, site: FuzzSite) {
        let index = self.visits[site as usize].fetch_add(1, Ordering::Relaxed);
        match decision(self.seed, site, index) {
            Perturbation::None => {}
            Perturbation::Yield(n) => {
                for _ in 0..n {
                    std::thread::yield_now();
                }
            }
            Perturbation::Sleep(us) => {
                std::thread::sleep(std::time::Duration::from_micros(us as u64))
            }
        }
    }

    /// The default seed from the environment: `RCOMPSS_SCHED_FUZZ=<seed>`
    /// arms the plane in any build; under `--features schedfuzz` the plane
    /// is armed with seed 0 even without the variable (so a fuzzing build
    /// can never silently run unperturbed). Unparsable values are ignored
    /// with a warning rather than failing startup.
    pub fn seed_from_env() -> Option<u64> {
        if let Ok(v) = std::env::var("RCOMPSS_SCHED_FUZZ") {
            if let Ok(seed) = v.trim().parse::<u64>() {
                return Some(seed);
            }
            eprintln!("rcompss: ignoring unparsable RCOMPSS_SCHED_FUZZ='{v}' (want a u64 seed)");
        }
        if cfg!(feature = "schedfuzz") {
            Some(0)
        } else {
            None
        }
    }
}

/// The hook the hazard sites call. `None` — every production run — is a
/// single branch; the whole plane optimizes out of the loops that matter.
#[inline(always)]
pub(crate) fn yield_point(fuzz: &Option<Arc<FuzzController>>, site: FuzzSite) {
    if let Some(c) = fuzz {
        c.perturb(site);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_streams_are_pure_and_deterministic() {
        // The replay contract: (seed, site, index) fully determines the
        // perturbation — two schedules from one seed are byte-identical.
        for site in [FuzzSite::ReadyPush, FuzzSite::TransferComplete, FuzzSite::GcCollect] {
            assert_eq!(schedule(42, site, 256), schedule(42, site, 256));
        }
        // Different seeds and different sites explore different orders.
        assert_ne!(
            schedule(1, FuzzSite::ReadyPush, 256),
            schedule(2, FuzzSite::ReadyPush, 256)
        );
        assert_ne!(
            schedule(1, FuzzSite::ReadyPush, 256),
            schedule(1, FuzzSite::ReadyPark, 256)
        );
    }

    #[test]
    fn decision_mix_covers_all_perturbation_kinds() {
        let s = schedule(7, FuzzSite::TransferNext, 512);
        assert!(s.iter().any(|p| *p == Perturbation::None));
        assert!(s.iter().any(|p| matches!(p, Perturbation::Yield(_))));
        assert!(s.iter().any(|p| matches!(p, Perturbation::Sleep(_))));
        // Sleeps stay inside the documented 50-500 µs envelope.
        for p in &s {
            if let Perturbation::Sleep(us) = p {
                assert!((50..500).contains(us), "sleep {us}µs out of envelope");
            }
        }
    }

    #[test]
    fn controller_counts_visits_per_site() {
        let c = FuzzController::new(3);
        assert_eq!(c.total_visits(), 0);
        for _ in 0..5 {
            c.perturb(FuzzSite::ReadyPush);
        }
        c.perturb(FuzzSite::NodeKill);
        assert_eq!(c.visits(FuzzSite::ReadyPush), 5);
        assert_eq!(c.visits(FuzzSite::NodeKill), 1);
        assert_eq!(c.visits(FuzzSite::GcCollect), 0);
        assert_eq!(c.total_visits(), 6);
        assert_eq!(c.seed(), 3);
    }

    #[test]
    fn disarmed_hook_is_a_no_op() {
        // The production path: a None controller does nothing (and in
        // particular never panics or allocates).
        yield_point(&None, FuzzSite::TransferPurge);
    }
}
