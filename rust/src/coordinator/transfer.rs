//! Asynchronous cross-node transfer service — the data-movement half of
//! the value-lifecycle engine.
//!
//! The seed runtime performed every cross-node consumption *synchronously
//! on the claiming worker*: the claim path serialized the value (if it was
//! memory-resident), read the file back, and decoded it — a full codec
//! round-trip inside the worker's critical path. The pbdR line of work the
//! paper builds on shows that overlapping data movement with compute, not
//! just parallelizing compute, is what preserves efficiency as node counts
//! grow (§4, Figure 8). [`TransferService`] makes that overlap real:
//!
//! * **requests** are issued at *schedule* time: when the dispatch fabric
//!   routes a ready task to a node, every input without a replica on that
//!   node is queued for transfer (`Shared::enqueue_ready`);
//! * **movers** — `transfer_threads` dedicated threads per emulated node —
//!   drain the per-node request queues (stealing from other nodes' queues
//!   when idle), run the codec boundary off the critical path, cache the
//!   decoded replica in the hot tier of the
//!   [`TieredStore`](super::store::TieredStore), and publish the new
//!   location in the [`VersionTable`](super::registry::VersionTable).
//!   With the warm tier on, movers ship the cached serialized blob
//!   directly (`super::store::stage_blob`): an N-node fan-out of a
//!   memory-resident version costs exactly one encode and zero file I/O —
//!   the `ensure_file` spill path survives only as the cold-tier fallback;
//! * **claimants** call [`TransferService::await_staged`] only when the
//!   bytes are not yet local at the moment they are actually needed —
//!   parking on a condvar until the mover finishes (futures-by-parking). A
//!   transfer that completes first costs the claimant nothing: the fast
//!   path is an ordinary zero-copy store lookup.
//!
//! The split is observable: `transfers_prefetched` counts transfers that
//! completed before any claimant had to wait, `transfers_waited` the ones a
//! claimant parked on, and the
//! [`DataStore`](super::store::hot::DataStore)'s `sync_transfer_decodes`
//! counter stays zero whenever the service is enabled (no codec on the
//! claim path). Requests are deduplicated per `(version, destination)`
//! pair; a failed pair is re-queued on the next `request`/`await_staged`
//! (bounded retry, `MAX_TRANSFER_ATTEMPTS` = 3) and only degrades to the
//! seed-style synchronous fallback once the budget is exhausted —
//! robustness, not correctness, is what the mover threads add.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::placement::InflightSource;
use crate::coordinator::registry::{DataKey, NodeId};
use crate::coordinator::runtime::Shared;
use crate::coordinator::schedfuzz::{yield_point, FuzzController, FuzzSite};

/// Total attempts allowed per `(version, node)` pair. A `Failed` entry
/// with fewer failures is a *retryable* tombstone: the next
/// `request`/`await_staged` clears it and re-queues. At the budget the
/// tombstone is permanent and claimants fall back to the synchronous path.
const MAX_TRANSFER_ATTEMPTS: u32 = 3;

/// Base delay of the retry backoff schedule (first retry).
const BACKOFF_BASE_MS: u64 = 5;

/// Cap on the backoff exponent: delays stop doubling past
/// `BACKOFF_BASE_MS << BACKOFF_MAX_SHIFT`.
const BACKOFF_MAX_SHIFT: u32 = 6;

/// Deterministic backoff for retry number `attempt` (1-based) of the
/// `(key, node)` pair: an exponential term (5 ms, 10 ms, 20 ms, ...,
/// capped) plus a jitter of at most half the exponential term, derived by
/// hashing the pair — not by a thread-local RNG — so two runs of the same
/// failure schedule re-queue at identical offsets and different pairs
/// failing together do not re-stampede the same link in lockstep.
pub(crate) fn retry_backoff(key: DataKey, node: NodeId, attempt: u32) -> std::time::Duration {
    let attempt = attempt.max(1);
    let exp_ms = BACKOFF_BASE_MS << (attempt - 1).min(BACKOFF_MAX_SHIFT);
    // splitmix64-style finalizer over the pair identity: cheap, stable
    // across platforms, and unrelated keys decorrelate immediately.
    let mut h = key.data.0
        ^ (u64::from(key.version) << 32)
        ^ (u64::from(node.0) << 17)
        ^ u64::from(attempt);
    h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 29;
    h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= h >> 32;
    let jitter_ms = h % (exp_ms / 2 + 1);
    std::time::Duration::from_millis(exp_ms + jitter_ms)
}

/// State of one `(version, destination-node)` transfer. Queued/Running
/// carry the requester's byte estimate so completion can settle the
/// per-node in-flight gauge the placement engine reads, plus the failure
/// count driving the bounded retry.
#[derive(Clone, Debug)]
enum TransferState {
    Queued { bytes: u64, attempts: u32 },
    Running { bytes: u64, attempts: u32 },
    /// Replica cached in the store and the location published.
    Done,
    Failed { error: String, attempts: u32 },
}

struct Inner {
    /// Per-destination-node request queues; a node's movers prefer their
    /// own queue and steal from the others when idle.
    queues: Vec<VecDeque<(DataKey, NodeId)>>,
    /// State per `(version, destination-node)` pair. Done/Failed entries
    /// are tombstones; [`TransferService::purge_version`] removes a
    /// version's entries when the GC collects it, so the map tracks *live*
    /// versions, not the full tasks x inputs history.
    states: HashMap<(DataKey, u32), TransferState>,
    /// Claimants currently parked per pair — drives the prefetched/waited
    /// accounting in [`TransferService::complete`].
    waiting: HashMap<(DataKey, u32), u32>,
    /// Retries parked until their backoff deadline; `next_request` promotes
    /// due entries into the queues and sizes its park timeout by the
    /// earliest remaining deadline.
    delayed: Vec<(std::time::Instant, DataKey, NodeId)>,
    /// Per-slot liveness: requests toward a dead node fast-fail with a
    /// permanent tombstone instead of grinding through the retry budget.
    dead: Vec<bool>,
}

/// The transfer request board shared by the master (prefetch requests),
/// the mover threads (work queue), and the claiming workers (completion
/// futures). All methods take `&self`; `movers_per_node == 0` disables the
/// service entirely and every cross-node consumption falls back to the
/// seed-style synchronous path.
pub struct TransferService {
    movers_per_node: u32,
    inner: Mutex<Inner>,
    /// Movers park here for work.
    cv_work: Condvar,
    /// Claimants park here for completions.
    cv_done: Condvar,
    shutdown: AtomicBool,
    /// Estimated serialized bytes queued or moving toward each node — the
    /// placement engine's transfer-pressure signal (`--router cost`). Kept
    /// as atomics beside the board mutex so routing never takes the lock.
    inflight: Vec<AtomicU64>,
    requested: AtomicU64,
    prefetched: AtomicU64,
    waited: AtomicU64,
    dropped: AtomicU64,
    failed: AtomicU64,
    retried: AtomicU64,
    bytes: AtomicU64,
    /// Schedule-fuzz controller; `None` (production) makes every yield
    /// point a single no-op branch.
    fuzz: Option<Arc<FuzzController>>,
}

impl TransferService {
    /// A service for `nodes` emulated nodes with `movers_per_node` mover
    /// threads each (0 disables asynchronous transfers).
    pub fn new(movers_per_node: u32, nodes: u32) -> TransferService {
        let nodes = nodes.max(1) as usize;
        TransferService {
            movers_per_node,
            inner: Mutex::new(Inner {
                queues: (0..nodes).map(|_| VecDeque::new()).collect(),
                states: HashMap::new(),
                waiting: HashMap::new(),
                delayed: Vec::new(),
                dead: vec![false; nodes],
            }),
            cv_work: Condvar::new(),
            cv_done: Condvar::new(),
            shutdown: AtomicBool::new(false),
            inflight: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            requested: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            waited: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            fuzz: None,
        }
    }

    /// Arm the schedule-fuzz yield points (`None` keeps them no-op).
    pub fn with_fuzz(mut self, fuzz: Option<Arc<FuzzController>>) -> TransferService {
        self.fuzz = fuzz;
        self
    }

    /// Node → queue/gauge slot. The one mapping shared by
    /// [`TransferService::enqueue_request`], [`TransferService::complete`],
    /// and [`TransferService::inflight_toward`]: an out-of-range `NodeId`
    /// (stale location, test stub) wraps to the same slot everywhere
    /// instead of inflating a gauge the reader never consults — the
    /// phantom-pressure leak that used to mislead the `cost`/`adaptive`
    /// routers. (`inflight` and the queue vector are always the same
    /// length.)
    fn slot(&self, node: NodeId) -> usize {
        (node.0 as usize) % self.inflight.len()
    }

    /// Is the asynchronous transfer path active?
    pub fn enabled(&self) -> bool {
        self.movers_per_node > 0
    }

    /// Mover threads per emulated node (the `--transfer-threads` knob).
    pub fn movers_per_node(&self) -> u32 {
        self.movers_per_node
    }

    /// Ask for `key` (an estimated `bytes` large) to be staged on `node`
    /// (the schedule-time prefetch). Duplicate requests for a pair already
    /// queued, running, or finished are no-ops.
    pub fn request(&self, key: DataKey, node: NodeId, bytes: u64) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        self.enqueue_request(&mut inner, key, node, bytes);
    }

    /// Shared enqueue (board lock held): dedup by pair, queue toward the
    /// destination node, count, raise the destination's in-flight gauge,
    /// and wake a mover. A `Failed` entry with attempts left is *not* an
    /// in-flight state: the tombstone is cleared and the pair re-queued
    /// (the old behavior kept it forever, so one failed transfer condemned
    /// every later consumer on that node to the synchronous-decode
    /// fallback). Notifying under the lock means a mover is either about
    /// to re-scan the queues (and will see this request) or provably
    /// parked.
    fn enqueue_request(&self, inner: &mut Inner, key: DataKey, node: NodeId, bytes: u64) {
        let pair = (key, node.0);
        let qi = self.slot(node);
        if inner.dead.get(qi).copied().unwrap_or(false) {
            // Dead destination: no queue entry, no gauge pressure, no retry
            // grind — a permanent tombstone so claimants error immediately
            // and fall back (or resubmit). `or_insert` keeps whatever
            // `fail_node` already settled.
            inner.states.entry(pair).or_insert(TransferState::Failed {
                error: format!("node {} is down", node.0),
                attempts: MAX_TRANSFER_ATTEMPTS,
            });
            self.cv_done.notify_all();
            return;
        }
        let attempts = match inner.states.get(&pair) {
            Some(TransferState::Failed { attempts, .. }) if *attempts < MAX_TRANSFER_ATTEMPTS => {
                self.retried.fetch_add(1, Ordering::Relaxed);
                *attempts
            }
            Some(_) => return,
            None => 0,
        };
        inner.states.insert(pair, TransferState::Queued { bytes, attempts });
        if attempts > 0 {
            // Retry: park behind the deterministic backoff instead of
            // re-stampeding the pair immediately.
            let due = std::time::Instant::now() + retry_backoff(key, node, attempts);
            inner.delayed.push((due, key, node));
        } else {
            inner.queues[qi].push_back((key, node));
        }
        self.inflight[qi].fetch_add(bytes, Ordering::Relaxed);
        self.requested.fetch_add(1, Ordering::Relaxed);
        self.cv_work.notify_one();
    }

    /// Mover side: block for the next request, preferring `home`'s queue
    /// and stealing from the other nodes' queues otherwise. Returns `None`
    /// only at shutdown. Queue entries whose state was purged (version GC
    /// collected the version mid-queue) are skipped, never handed out.
    pub(crate) fn next_request(&self, home: NodeId) -> Option<(DataKey, NodeId)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // Promote delayed retries whose backoff has elapsed.
            let now = std::time::Instant::now();
            let slots = self.inflight.len();
            let mut i = 0;
            while i < inner.delayed.len() {
                if inner.delayed[i].0 <= now {
                    let (_, key, node) = inner.delayed.swap_remove(i);
                    let qi = (node.0 as usize) % slots;
                    inner.queues[qi].push_back((key, node));
                } else {
                    i += 1;
                }
            }
            let n = inner.queues.len();
            let start = (home.0 as usize) % n;
            for i in 0..n {
                let qi = (start + i) % n;
                while let Some((key, node)) = inner.queues[qi].pop_front() {
                    let pair = (key, node.0);
                    let (bytes, attempts) = match inner.states.get(&pair) {
                        Some(TransferState::Queued { bytes, attempts }) => (*bytes, *attempts),
                        // Purged (collected mid-queue), poisoned (node
                        // died), or superseded: stale entry, nothing to
                        // move.
                        _ => continue,
                    };
                    inner.states.insert(pair, TransferState::Running { bytes, attempts });
                    return Some((key, node));
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            // Park for work — or only until the earliest backoff deadline
            // when retries are pending, so a delayed pair is picked up
            // promptly even if no new request ever lands.
            let until = inner
                .delayed
                .iter()
                .map(|(due, _, _)| due.saturating_duration_since(now))
                .min();
            match until {
                Some(d) => {
                    let timeout = d.max(std::time::Duration::from_millis(1));
                    let (guard, _) = self.cv_work.wait_timeout(inner, timeout).unwrap();
                    inner = guard;
                }
                None => inner = self.cv_work.wait(inner).unwrap(),
            }
        }
    }

    /// Mover side: publish the outcome of a transfer and wake claimants.
    /// A staged transfer (`Ok(Some(bytes))`) nobody was parked on counts
    /// as *prefetched* (it fully overlapped with compute); one with parked
    /// claimants as *waited*. `Ok(None)` is a *dropped* transfer — the
    /// bytes were already local or the version was reclaimed mid-flight —
    /// and inflates neither overlap metric.
    pub(crate) fn complete(&self, key: DataKey, node: NodeId, result: anyhow::Result<Option<u64>>) {
        let mut inner = self.inner.lock().unwrap();
        let pair = (key, node.0);
        let had_waiter = inner.waiting.get(&pair).copied().unwrap_or(0) > 0;
        // Settle the in-flight gauge with the bytes the request was
        // enqueued with (whatever the outcome — the pressure is gone). A
        // purged pair (version collected mid-flight) already settled its
        // gauge and must not grow a fresh tombstone.
        let state = inner.states.get(&pair).cloned();
        let (pending, attempts) = match &state {
            Some(TransferState::Queued { bytes, attempts })
            | Some(TransferState::Running { bytes, attempts }) => (*bytes, *attempts),
            _ => (0, 0),
        };
        let purged = state.is_none();
        // A pair `fail_node` poisoned while this transfer was in flight
        // must keep its permanent tombstone: overwriting it with Done would
        // advertise a replica on a dead node, and overwriting with a fresh
        // low-attempt Failed would re-open the retry grind the poison
        // exists to skip.
        let poisoned = matches!(
            &state,
            Some(TransferState::Failed { attempts, .. }) if *attempts >= MAX_TRANSFER_ATTEMPTS
        );
        let keep = !purged && !poisoned;
        self.inflight[self.slot(node)].fetch_sub(pending, Ordering::Relaxed);
        match result {
            Ok(Some(nbytes)) => {
                if keep {
                    inner.states.insert(pair, TransferState::Done);
                }
                self.bytes.fetch_add(nbytes, Ordering::Relaxed);
                if had_waiter {
                    self.waited.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.prefetched.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(None) => {
                if keep {
                    inner.states.insert(pair, TransferState::Done);
                }
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                if keep {
                    inner.states.insert(
                        pair,
                        TransferState::Failed {
                            error: format!("{e:#}"),
                            attempts: attempts + 1,
                        },
                    );
                }
                self.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.cv_done.notify_all();
    }

    /// Claimant side: block until `key` is staged on `node`, requesting
    /// the transfer first if nobody did (a stolen task can land on a node
    /// the router never prefetched for). A retryable `Failed` tombstone is
    /// cleared and re-queued rather than surfaced — `Err` is returned only
    /// once the pair's attempt budget is exhausted, and the caller falls
    /// back to the synchronous path. `Ok(())` means the replica's location
    /// is published — or the version was GC-collected mid-wait (its
    /// entries purged), in which case the caller's store fetch surfaces
    /// the precise reclamation error.
    pub fn await_staged(&self, key: DataKey, node: NodeId, bytes: u64) -> Result<(), String> {
        if !self.enabled() {
            return Err("transfer service disabled".into());
        }
        let pair = (key, node.0);
        let mut inner = self.inner.lock().unwrap();
        // A stolen task can land on a node the router never prefetched
        // for; the dedup inside makes this a no-op otherwise, and a
        // retryable failure is re-queued here.
        self.enqueue_request(&mut inner, key, node, bytes);
        loop {
            match inner.states.get(&pair).cloned() {
                Some(TransferState::Done) | None => return Ok(()),
                Some(TransferState::Failed { error, attempts }) => {
                    if attempts >= MAX_TRANSFER_ATTEMPTS {
                        return Err(error);
                    }
                    // A retryable failure landed while we were parked:
                    // clear the tombstone, re-queue, keep waiting.
                    self.enqueue_request(&mut inner, key, node, bytes);
                }
                Some(TransferState::Queued { .. }) | Some(TransferState::Running { .. }) => {}
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return Err("runtime stopping".into());
            }
            *inner.waiting.entry(pair).or_insert(0) += 1;
            inner = self.cv_done.wait(inner).unwrap();
            let drained = match inner.waiting.get_mut(&pair) {
                Some(w) => {
                    *w -= 1;
                    *w == 0
                }
                None => false,
            };
            if drained {
                inner.waiting.remove(&pair);
            }
        }
    }

    /// Wake every mover and claimant; subsequent `next_request`s return
    /// `None` and parked claimants error out.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.inner.lock().unwrap();
        self.cv_work.notify_all();
        self.cv_done.notify_all();
    }

    /// Estimated serialized bytes currently queued or moving toward
    /// `node` — the transfer-pressure input of the placement engine's
    /// `cost`/`adaptive` models (a replica already on its way counts as
    /// local). Reads the same wrapped slot the enqueue/complete paths
    /// write, so pressure always drains back to zero.
    pub fn inflight_toward(&self, node: NodeId) -> u64 {
        self.inflight[self.slot(node)].load(Ordering::Relaxed)
    }

    /// Drop every state entry of a version the GC just collected (any
    /// destination), settling the in-flight gauges of entries that never
    /// ran. Without this, Done/Failed tombstones accumulate for the
    /// lifetime of the service — "bounded by tasks x inputs" is a leak for
    /// a long-running runtime. A purged Queued request counts as *dropped*
    /// (its queue entry is skipped by `next_request`, so no completion
    /// will ever account for it); a purged Running request is accounted by
    /// the mover's own completion, which then settles nothing and
    /// re-creates no tombstone.
    pub(crate) fn purge_version(&self, key: DataKey) {
        if !self.enabled() {
            return;
        }
        // Hazard window: the GC has decided to collect but the board still
        // advertises the version — a mover completing the same pair races
        // the purge.
        yield_point(&self.fuzz, FuzzSite::TransferPurge);
        let mut inner = self.inner.lock().unwrap();
        let slots = self.inflight.len();
        let inflight = &self.inflight;
        let dropped = &self.dropped;
        let before = inner.states.len();
        inner.states.retain(|&(k, n), state| {
            if k != key {
                return true;
            }
            match state {
                TransferState::Queued { bytes, .. } => {
                    inflight[(n as usize) % slots].fetch_sub(*bytes, Ordering::Relaxed);
                    dropped.fetch_add(1, Ordering::Relaxed);
                }
                TransferState::Running { bytes, .. } => {
                    inflight[(n as usize) % slots].fetch_sub(*bytes, Ordering::Relaxed);
                }
                TransferState::Done | TransferState::Failed { .. } => {}
            }
            false
        });
        if inner.states.len() != before {
            // Nobody should be parked on a collected version (a parked
            // claimant holds a consumer reference, which keeps the version
            // uncollected), but waking claimants is cheap. A woken claimant
            // sees the entry gone, returns Ok, and its subsequent store
            // fetch surfaces the precise "reclaimed by the version GC"
            // error — never a hang.
            self.cv_done.notify_all();
        }
    }

    /// Node-loss fast path: mark `node`'s slot dead and poison every board
    /// entry toward it. Queued/Running pairs settle their gauges and become
    /// permanent `Failed` tombstones (no 3-attempt grind); `Done` entries
    /// are removed outright — the location they advertised just left the
    /// version table, so a post-rejoin consumer must restage, not trust a
    /// stale tombstone. Parked claimants wake and error out immediately.
    pub fn fail_node(&self, node: NodeId) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let qi = self.slot(node);
        if let Some(flag) = inner.dead.get_mut(qi) {
            *flag = true;
        }
        // Delayed retries toward the node would only promote into stale
        // queue entries; drop them now.
        inner
            .delayed
            .retain(|(_, _, n)| (n.0 as usize) % self.inflight.len() != qi);
        let slots = self.inflight.len();
        let inflight = &self.inflight;
        let failed = &self.failed;
        let error = format!("node {} is down", node.0);
        inner.states.retain(|&(_, n), state| {
            if n != node.0 {
                return true;
            }
            match state {
                TransferState::Queued { bytes, .. } | TransferState::Running { bytes, .. } => {
                    inflight[(n as usize) % slots].fetch_sub(*bytes, Ordering::Relaxed);
                    failed.fetch_add(1, Ordering::Relaxed);
                    *state = TransferState::Failed {
                        error: error.clone(),
                        attempts: MAX_TRANSFER_ATTEMPTS,
                    };
                    true
                }
                TransferState::Failed { attempts, .. } => {
                    *attempts = MAX_TRANSFER_ATTEMPTS;
                    true
                }
                TransferState::Done => false,
            }
        });
        self.cv_done.notify_all();
        self.cv_work.notify_all();
    }

    /// Node-join: re-open `node`'s slot for staging and clear the
    /// tombstones `fail_node` (or organic failures) left toward it, so the
    /// first post-rejoin consumer restages instead of inheriting a
    /// permanent error.
    pub fn revive_node(&self, node: NodeId) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let qi = self.slot(node);
        if let Some(flag) = inner.dead.get_mut(qi) {
            *flag = false;
        }
        inner
            .states
            .retain(|&(_, n), state| n != node.0 || !matches!(state, TransferState::Failed { .. }));
    }

    /// Entries alive in the state map: in-flight transfers plus
    /// Done/Failed tombstones. The GC purge keeps this bounded by live
    /// versions at quiescence, not by the tasks x inputs history.
    pub fn state_count(&self) -> usize {
        self.inner.lock().unwrap().states.len()
    }

    /// Transfer requests ever enqueued (deduplicated per in-flight pair; a
    /// bounded retry of a failed pair counts again).
    pub fn requested(&self) -> u64 {
        self.requested.load(Ordering::Relaxed)
    }

    /// Failed pairs re-queued by the bounded retry.
    pub fn retried(&self) -> u64 {
        self.retried.load(Ordering::Relaxed)
    }

    /// Transfers that completed before any claimant parked on them.
    pub fn prefetched(&self) -> u64 {
        self.prefetched.load(Ordering::Relaxed)
    }

    /// Transfers at least one claimant parked on.
    pub fn waited(&self) -> u64 {
        self.waited.load(Ordering::Relaxed)
    }

    /// Transfers dropped without moving bytes (destination already had a
    /// replica, or the version was reclaimed mid-flight).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Failed transfer attempts. Each is retried on the next
    /// `request`/`await_staged` until the pair's attempt budget runs out;
    /// only then do claimants fall back to the synchronous path.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Serialized bytes moved by the movers.
    pub fn transfer_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    #[cfg(test)]
    fn waiting_count(&self, key: DataKey, node: NodeId) -> u32 {
        self.inner
            .lock()
            .unwrap()
            .waiting
            .get(&(key, node.0))
            .copied()
            .unwrap_or(0)
    }
}

impl InflightSource for TransferService {
    fn inflight_toward(&self, node: NodeId) -> u64 {
        TransferService::inflight_toward(self, node)
    }
}

/// Body of a mover thread: drain transfer requests (preferring `home`'s
/// queue) until shutdown, feeding the `adaptive` router's observation
/// sink with per-destination throughput as transfers complete. Spawned by
/// `Coordinator::start`, joined by `Coordinator::stop`.
pub(crate) fn mover_loop(shared: Arc<Shared>, home: NodeId) {
    while let Some((key, node)) = shared.transfers.next_request(home) {
        // Hazard window: the pair is claimed (Running) but no bytes have
        // moved — GC purges, node kills, and duplicate requests race here.
        yield_point(&shared.transfers.fuzz, FuzzSite::TransferNext);
        let t0 = std::time::Instant::now();
        let result = perform_transfer(&shared, key, node);
        // Hazard window: the replica is staged and its location published,
        // but the board still says Running — the PR-4 class of
        // tombstone/GC races lives exactly in this gap.
        yield_point(&shared.transfers.fuzz, FuzzSite::TransferComplete);
        // Per-destination staging throughput, observed coordinator-side.
        // The *per-pair* link samples (`record_transfer_pair`) are fed by
        // the TCP transport itself from its `ShipDone` acks — the source
        // worker measures the direct src→dst stream, which this wall
        // clock cannot see.
        if let (Some(fb), Ok(Some(nbytes))) = (&shared.feedback, &result) {
            fb.record_transfer(node, *nbytes, t0.elapsed().as_secs_f64());
        }
        // A request can race the GC: the version may have been collected
        // after the purge ran (a late prefetch). Re-purging after the
        // completion keeps the board free of tombstones for dead versions.
        let collected = shared.table.is_collected(key);
        shared.transfers.complete(key, node, result);
        if collected {
            shared.transfers.purge_version(key);
        }
    }
}

/// Move one version to `node`: cross the serialization boundary on the
/// mover — not the claimant — decode, cache the replica zero-copy for the
/// destination's consumers, and publish the location. Returns the
/// serialized byte count. The actual byte movement is delegated to the
/// configured [`Transport`](super::transport::Transport) — in-process
/// staging or a socket hop; every guard here is transport-agnostic.
///
/// A version the GC reclaimed mid-transfer is *dropped* (`Ok(None)`), not
/// failed: the refcount protocol keeps any version with a live (or
/// parked) consumer uncollected, so a collected version means the
/// prefetch went to a node whose claimant was stolen away — nobody needs
/// the bytes anymore. Already-local destinations are dropped the same
/// way.
fn perform_transfer(
    shared: &Shared,
    key: DataKey,
    node: NodeId,
) -> anyhow::Result<Option<u64>> {
    if shared.table.is_local(key, node) {
        // Raced with a synchronous fallback or duplicate: already staged.
        return Ok(None);
    }
    if shared.table.is_collected(key) {
        return Ok(None);
    }
    if !shared.health.is_alive(node) {
        // Destination died after this request was claimed: drop it rather
        // than stage toward a machine that is gone. `fail_node` has (or
        // will have) poisoned the pair; the completion keeps the tombstone.
        return Ok(None);
    }
    // Deterministic fault injection for the retry tests. The pseudo-type
    // only matches injectors that name it (or catch-all empty filters —
    // for those, transfer failures are legitimate chaos: bounded retry
    // degrades to the counted synchronous fallback, never to wrong data).
    if shared.injector.should_fail("__transfer__") {
        anyhow::bail!("injected transfer failure for {key} -> node {}", node.0);
    }
    // Source hint for socket transports: the first live replica holder
    // other than the destination. The in-process transport ignores it
    // (every node shares one address space).
    let from = shared.table.info(key).and_then(|info| {
        info.locations
            .iter()
            .copied()
            .find(|n| *n != node && shared.health.is_alive(*n))
    });
    match shared.transport.fetch(shared, key, from, node) {
        Ok(staged) => Ok(staged),
        // Collected while we were encoding/decoding it: benign.
        Err(_) if shared.table.is_collected(key) => Ok(None),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::DataId;
    use std::time::{Duration, Instant};

    fn key(d: u64) -> DataKey {
        DataKey {
            data: DataId(d),
            version: 1,
        }
    }

    #[test]
    fn request_dedups_and_mover_drains() {
        let s = TransferService::new(1, 2);
        s.request(key(1), NodeId(1), 128);
        s.request(key(1), NodeId(1), 128); // duplicate: no second queue entry
        assert_eq!(s.requested(), 1);
        // The pending request registers as pressure toward node 1 only.
        assert_eq!(s.inflight_toward(NodeId(1)), 128);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        assert_eq!((k, n), (key(1), NodeId(1)));
        assert_eq!(s.inflight_toward(NodeId(1)), 128, "running still counts");
        s.complete(k, n, Ok(Some(128)));
        // Completed with nobody parked: a prefetch that fully overlapped.
        assert_eq!(s.prefetched(), 1);
        assert_eq!(s.waited(), 0);
        assert_eq!(s.transfer_bytes(), 128);
        assert_eq!(s.inflight_toward(NodeId(1)), 0, "completion settles the gauge");
        // Done tombstone: claimants return immediately.
        assert_eq!(s.await_staged(key(1), NodeId(1), 128), Ok(()));
        assert_eq!(s.waited(), 0);
        // A dropped transfer (already local / reclaimed) is Done for
        // claimants but inflates neither overlap counter.
        s.request(key(2), NodeId(0), 64);
        let (k2, n2) = s.next_request(NodeId(0)).unwrap();
        s.complete(k2, n2, Ok(None));
        assert_eq!(s.dropped(), 1);
        assert_eq!(s.prefetched(), 1);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
        assert_eq!(s.await_staged(key(2), NodeId(0), 64), Ok(()));
    }

    #[test]
    fn claimant_parks_until_completion_and_counts_waited() {
        let s = Arc::new(TransferService::new(1, 2));
        s.request(key(7), NodeId(1), 64);
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s2.await_staged(key(7), NodeId(1), 64));
        // Deterministic: wait until the claimant is provably parked.
        let t0 = Instant::now();
        while s.waiting_count(key(7), NodeId(1)) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "claimant never parked");
            std::thread::yield_now();
        }
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        s.complete(k, n, Ok(Some(64)));
        assert_eq!(waiter.join().unwrap(), Ok(()));
        assert_eq!(s.waited(), 1);
        assert_eq!(s.prefetched(), 0);
    }

    #[test]
    fn failed_transfer_retries_then_reports_to_claimant() {
        let s = Arc::new(TransferService::new(1, 1));
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s2.await_staged(key(3), NodeId(0), 32));
        // await_staged enqueues; every failure is re-queued by the parked
        // claimant until the attempt budget runs out (next_request blocks
        // until each re-queue lands).
        for _ in 0..MAX_TRANSFER_ATTEMPTS {
            let (k, n) = s.next_request(NodeId(0)).unwrap();
            assert_eq!((k, n), (key(3), NodeId(0)));
            s.complete(k, n, Err(anyhow::anyhow!("boom")));
        }
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.contains("boom"), "{err}");
        assert_eq!(s.failed(), u64::from(MAX_TRANSFER_ATTEMPTS));
        assert_eq!(s.retried(), u64::from(MAX_TRANSFER_ATTEMPTS) - 1);
        assert_eq!(s.inflight_toward(NodeId(0)), 0, "failures settle the gauge");
        // The exhausted tombstone is permanent: immediate error, no park.
        assert!(s.await_staged(key(3), NodeId(0), 32).is_err());
        assert_eq!(s.retried(), u64::from(MAX_TRANSFER_ATTEMPTS) - 1);
    }

    #[test]
    fn failed_pair_is_restageable_on_next_request() {
        // Regression: a Failed tombstone used to be treated like an
        // in-flight state, so one failure made the pair permanently
        // un-stageable. The next await_staged must clear it, re-queue, and
        // succeed via the retried mover transfer.
        let s = Arc::new(TransferService::new(1, 2));
        s.request(key(4), NodeId(1), 64);
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        s.complete(k, n, Err(anyhow::anyhow!("flaky link")));
        assert_eq!(s.failed(), 1);
        assert_eq!(s.inflight_toward(NodeId(1)), 0);
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s2.await_staged(key(4), NodeId(1), 64));
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        assert_eq!((k, n), (key(4), NodeId(1)));
        assert_eq!(s.inflight_toward(NodeId(1)), 64, "retry re-raises the gauge");
        s.complete(k, n, Ok(Some(64)));
        assert_eq!(waiter.join().unwrap(), Ok(()));
        assert_eq!(s.retried(), 1);
        // A later prefetch of the now-Done pair is a no-op again.
        s.request(key(4), NodeId(1), 64);
        assert_eq!(s.requested(), 2);
    }

    #[test]
    fn out_of_range_node_maps_to_one_slot_consistently() {
        // Regression: enqueue/complete wrapped the node index while the
        // gauge read did not, so a stale out-of-range NodeId inflated a
        // wrapped node's gauge that `inflight_toward` never read back — a
        // permanent phantom-pressure leak. All three now share one slot
        // mapping.
        let s = TransferService::new(1, 2);
        s.request(key(1), NodeId(5), 128);
        assert_eq!(s.inflight_toward(NodeId(5)), 128);
        assert_eq!(s.inflight_toward(NodeId(1)), 128, "5 % 2 == 1");
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
        let (k, n) = s.next_request(NodeId(0)).unwrap();
        s.complete(k, n, Ok(Some(128)));
        assert_eq!(s.inflight_toward(NodeId(1)), 0, "completion settles the slot");
        assert_eq!(s.inflight_toward(NodeId(5)), 0);
    }

    #[test]
    fn purge_version_drains_tombstones_and_settles_gauges() {
        let s = TransferService::new(1, 2);
        // One Done tombstone and one still-queued request, same version.
        s.request(key(1), NodeId(0), 32);
        let (k, n) = s.next_request(NodeId(0)).unwrap();
        s.complete(k, n, Ok(Some(32)));
        s.request(key(1), NodeId(1), 32);
        assert_eq!(s.state_count(), 2);
        assert_eq!(s.inflight_toward(NodeId(1)), 32);
        s.purge_version(key(1));
        assert_eq!(s.state_count(), 0, "collected version leaves no entries");
        assert_eq!(s.inflight_toward(NodeId(1)), 0, "purged request settles its gauge");
        // The never-run request is accounted as dropped, keeping
        // staged + dropped + failed == requested.
        assert_eq!(s.dropped(), 1);
        assert_eq!(s.prefetched() + s.dropped(), s.requested());
        // The stale queue entry is skipped, never handed to a mover: after
        // stop() the scan drains it and exits.
        s.stop();
        assert!(s.next_request(NodeId(1)).is_none());
    }

    #[test]
    fn disabled_service_rejects_claims() {
        let s = TransferService::new(0, 4);
        assert!(!s.enabled());
        assert!(s.await_staged(key(1), NodeId(0), 8).is_err());
        s.request(key(1), NodeId(0), 8); // no-op
        assert_eq!(s.requested(), 0);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
    }

    #[test]
    fn stop_releases_movers_and_waiters() {
        let s = Arc::new(TransferService::new(1, 1));
        let s_mover = Arc::clone(&s);
        let mover = std::thread::spawn(move || s_mover.next_request(NodeId(0)));
        s.request(key(9), NodeId(0), 16);
        // The mover takes the request but never completes it; a claimant
        // parks on it.
        let s_waiter = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s_waiter.await_staged(key(9), NodeId(0), 16));
        let t0 = Instant::now();
        while s.waiting_count(key(9), NodeId(0)) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "claimant never parked");
            std::thread::yield_now();
        }
        s.stop();
        assert!(waiter.join().unwrap().is_err(), "shutdown must release claimants");
        // The mover got the request before stop, or None after it.
        let _ = mover.join().unwrap();
        // Post-stop, movers drain whatever is still queued, then exit.
        while s.next_request(NodeId(0)).is_some() {}
        assert!(s.next_request(NodeId(0)).is_none(), "post-stop movers exit");
    }

    #[test]
    fn backoff_schedule_is_deterministic_with_bounded_jitter() {
        // Same (pair, attempt) → identical delay, run after run.
        assert_eq!(
            retry_backoff(key(3), NodeId(1), 1),
            retry_backoff(key(3), NodeId(1), 1)
        );
        // Exponential envelope: base << (attempt-1) plus at most half that
        // again of jitter.
        for attempt in 1..=4u32 {
            let exp_ms = BACKOFF_BASE_MS << (attempt - 1);
            let d = retry_backoff(key(3), NodeId(1), attempt).as_millis() as u64;
            assert!(d >= exp_ms, "attempt {attempt}: {d} < {exp_ms}");
            assert!(d <= exp_ms + exp_ms / 2, "attempt {attempt}: {d} > 1.5x{exp_ms}");
        }
        // The exponent caps instead of overflowing.
        let capped = BACKOFF_BASE_MS << BACKOFF_MAX_SHIFT;
        let d = retry_backoff(key(3), NodeId(1), 40).as_millis() as u64;
        assert!((capped..=capped + capped / 2).contains(&d));
        // Jitter decorrelates pairs: across a handful of keys at the same
        // attempt, at least two distinct delays appear.
        let mut seen = std::collections::HashSet::new();
        for d in 0..8u64 {
            seen.insert(retry_backoff(key(d), NodeId(1), 2).as_millis());
        }
        assert!(seen.len() > 1, "jitter never varied: {seen:?}");
    }

    #[test]
    fn retries_wait_out_their_backoff_before_redelivery() {
        let s = TransferService::new(1, 1);
        s.request(key(6), NodeId(0), 16);
        let (k, n) = s.next_request(NodeId(0)).unwrap();
        s.complete(k, n, Err(anyhow::anyhow!("flaky")));
        // Re-queue (attempt 1): the pair must not be redelivered before its
        // deterministic delay elapses.
        let t0 = Instant::now();
        s.request(key(6), NodeId(0), 16);
        let expect = retry_backoff(key(6), NodeId(0), 1);
        let (k, _) = s.next_request(NodeId(0)).unwrap();
        let waited = t0.elapsed();
        assert_eq!(k, key(6));
        // Allow a little scheduler slop below the nominal deadline.
        assert!(
            waited + Duration::from_millis(1) >= expect,
            "redelivered after {waited:?}, backoff was {expect:?}"
        );
    }

    #[test]
    fn fail_node_poisons_pairs_and_fast_fails_claimants() {
        let s = TransferService::new(1, 2);
        // One Running and one Queued pair toward node 1, one Done toward
        // node 0 that must survive.
        s.request(key(1), NodeId(1), 64);
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        assert_eq!((k, n), (key(1), NodeId(1)));
        s.request(key(2), NodeId(1), 32);
        s.request(key(3), NodeId(0), 8);
        let (k0, n0) = s.next_request(NodeId(0)).unwrap();
        s.complete(k0, n0, Ok(Some(8)));
        s.fail_node(NodeId(1));
        // Gauges toward the dead node settle immediately.
        assert_eq!(s.inflight_toward(NodeId(1)), 0);
        // Claimants error out without the 3-attempt grind...
        let err = s.await_staged(key(2), NodeId(1), 32).unwrap_err();
        assert!(err.contains("down"), "{err}");
        // ...and brand-new requests toward the dead node fast-fail too.
        let err = s.await_staged(key(5), NodeId(1), 16).unwrap_err();
        assert!(err.contains("down"), "{err}");
        // The in-flight mover completing cannot resurrect the pair.
        s.complete(key(1), NodeId(1), Ok(Some(64)));
        assert!(s.await_staged(key(1), NodeId(1), 64).is_err());
        // Node 0 is untouched.
        assert_eq!(s.await_staged(key(3), NodeId(0), 8), Ok(()));
    }

    #[test]
    fn revive_node_reopens_staging() {
        let s = TransferService::new(1, 2);
        s.fail_node(NodeId(1));
        assert!(s.await_staged(key(4), NodeId(1), 64).is_err());
        s.revive_node(NodeId(1));
        // Tombstones are gone: the next request queues and stages normally.
        let before = s.requested();
        s.request(key(4), NodeId(1), 64);
        assert_eq!(s.requested(), before + 1);
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        assert_eq!((k, n), (key(4), NodeId(1)));
        s.complete(k, n, Ok(Some(64)));
        assert_eq!(s.await_staged(key(4), NodeId(1), 64), Ok(()));
    }

    #[test]
    fn per_node_queues_prefer_home_but_steal() {
        let s = TransferService::new(1, 2);
        s.request(key(1), NodeId(0), 8);
        s.request(key(2), NodeId(1), 8);
        // Node-1 mover prefers its own queue...
        let (k, _) = s.next_request(NodeId(1)).unwrap();
        assert_eq!(k, key(2));
        // ...and steals node-0 work when its own queue is empty.
        let (k, _) = s.next_request(NodeId(1)).unwrap();
        assert_eq!(k, key(1));
    }
}
