//! Asynchronous cross-node transfer service — the data-movement half of
//! the value-lifecycle engine.
//!
//! The seed runtime performed every cross-node consumption *synchronously
//! on the claiming worker*: the claim path serialized the value (if it was
//! memory-resident), read the file back, and decoded it — a full codec
//! round-trip inside the worker's critical path. The pbdR line of work the
//! paper builds on shows that overlapping data movement with compute, not
//! just parallelizing compute, is what preserves efficiency as node counts
//! grow (§4, Figure 8). [`TransferService`] makes that overlap real:
//!
//! * **requests** are issued at *schedule* time: when the dispatch fabric
//!   routes a ready task to a node, every input without a replica on that
//!   node is queued for transfer (`Shared::enqueue_ready`);
//! * **movers** — `transfer_threads` dedicated threads per emulated node —
//!   drain the per-node request queues (stealing from other nodes' queues
//!   when idle), run the codec boundary off the critical path, cache the
//!   decoded replica in the [`DataStore`](super::datastore::DataStore), and
//!   publish the new location in the
//!   [`VersionTable`](super::registry::VersionTable);
//! * **claimants** call [`TransferService::await_staged`] only when the
//!   bytes are not yet local at the moment they are actually needed —
//!   parking on a condvar until the mover finishes (futures-by-parking). A
//!   transfer that completes first costs the claimant nothing: the fast
//!   path is an ordinary zero-copy store lookup.
//!
//! The split is observable: `transfers_prefetched` counts transfers that
//! completed before any claimant had to wait, `transfers_waited` the ones a
//! claimant parked on, and the
//! [`DataStore`](super::datastore::DataStore)'s `sync_transfer_decodes`
//! counter stays zero whenever the service is enabled (no codec on the
//! claim path). Requests are deduplicated per `(version, destination)`
//! pair, and a failed transfer degrades to the seed-style synchronous
//! fallback on the claimant — robustness, not correctness, is what the
//! mover threads add.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::placement::InflightSource;
use crate::coordinator::registry::{DataKey, NodeId};
use crate::coordinator::runtime::{spill_victims, Shared};

/// State of one `(version, destination-node)` transfer. Queued/Running
/// carry the requester's byte estimate so completion can settle the
/// per-node in-flight gauge the placement engine reads.
#[derive(Clone, Debug)]
enum TransferState {
    Queued(u64),
    Running(u64),
    /// Replica cached in the store and the location published.
    Done,
    Failed(String),
}

struct Inner {
    /// Per-destination-node request queues; a node's movers prefer their
    /// own queue and steal from the others when idle.
    queues: Vec<VecDeque<(DataKey, NodeId)>>,
    /// State per `(version, destination-node)` pair. Done/Failed entries
    /// are kept as tombstones (bounded by the number of distinct
    /// transfers, i.e. by tasks x inputs).
    states: HashMap<(DataKey, u32), TransferState>,
    /// Claimants currently parked per pair — drives the prefetched/waited
    /// accounting in [`TransferService::complete`].
    waiting: HashMap<(DataKey, u32), u32>,
}

/// The transfer request board shared by the master (prefetch requests),
/// the mover threads (work queue), and the claiming workers (completion
/// futures). All methods take `&self`; `movers_per_node == 0` disables the
/// service entirely and every cross-node consumption falls back to the
/// seed-style synchronous path.
pub struct TransferService {
    movers_per_node: u32,
    inner: Mutex<Inner>,
    /// Movers park here for work.
    cv_work: Condvar,
    /// Claimants park here for completions.
    cv_done: Condvar,
    shutdown: AtomicBool,
    /// Estimated serialized bytes queued or moving toward each node — the
    /// placement engine's transfer-pressure signal (`--router cost`). Kept
    /// as atomics beside the board mutex so routing never takes the lock.
    inflight: Vec<AtomicU64>,
    requested: AtomicU64,
    prefetched: AtomicU64,
    waited: AtomicU64,
    dropped: AtomicU64,
    failed: AtomicU64,
    bytes: AtomicU64,
}

impl TransferService {
    /// A service for `nodes` emulated nodes with `movers_per_node` mover
    /// threads each (0 disables asynchronous transfers).
    pub fn new(movers_per_node: u32, nodes: u32) -> TransferService {
        let nodes = nodes.max(1) as usize;
        TransferService {
            movers_per_node,
            inner: Mutex::new(Inner {
                queues: (0..nodes).map(|_| VecDeque::new()).collect(),
                states: HashMap::new(),
                waiting: HashMap::new(),
            }),
            cv_work: Condvar::new(),
            cv_done: Condvar::new(),
            shutdown: AtomicBool::new(false),
            inflight: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            requested: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            waited: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// Is the asynchronous transfer path active?
    pub fn enabled(&self) -> bool {
        self.movers_per_node > 0
    }

    /// Mover threads per emulated node (the `--transfer-threads` knob).
    pub fn movers_per_node(&self) -> u32 {
        self.movers_per_node
    }

    /// Ask for `key` (an estimated `bytes` large) to be staged on `node`
    /// (the schedule-time prefetch). Duplicate requests for a pair already
    /// queued, running, or finished are no-ops.
    pub fn request(&self, key: DataKey, node: NodeId, bytes: u64) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        self.enqueue_request(&mut inner, key, node, bytes);
    }

    /// Shared enqueue (board lock held): dedup by pair, queue toward the
    /// destination node, count, raise the destination's in-flight gauge,
    /// and wake a mover. Notifying under the lock means a mover is either
    /// about to re-scan the queues (and will see this request) or provably
    /// parked.
    fn enqueue_request(&self, inner: &mut Inner, key: DataKey, node: NodeId, bytes: u64) {
        let pair = (key, node.0);
        if inner.states.contains_key(&pair) {
            return;
        }
        inner.states.insert(pair, TransferState::Queued(bytes));
        let qi = (node.0 as usize) % inner.queues.len();
        inner.queues[qi].push_back((key, node));
        self.inflight[qi].fetch_add(bytes, Ordering::Relaxed);
        self.requested.fetch_add(1, Ordering::Relaxed);
        self.cv_work.notify_one();
    }

    /// Mover side: block for the next request, preferring `home`'s queue
    /// and stealing from the other nodes' queues otherwise. Returns `None`
    /// only at shutdown.
    pub(crate) fn next_request(&self, home: NodeId) -> Option<(DataKey, NodeId)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let n = inner.queues.len();
            let start = (home.0 as usize) % n;
            for i in 0..n {
                let qi = (start + i) % n;
                if let Some((key, node)) = inner.queues[qi].pop_front() {
                    let pair = (key, node.0);
                    let bytes = match inner.states.get(&pair) {
                        Some(TransferState::Queued(b)) => *b,
                        _ => 0,
                    };
                    inner.states.insert(pair, TransferState::Running(bytes));
                    return Some((key, node));
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            inner = self.cv_work.wait(inner).unwrap();
        }
    }

    /// Mover side: publish the outcome of a transfer and wake claimants.
    /// A staged transfer (`Ok(Some(bytes))`) nobody was parked on counts
    /// as *prefetched* (it fully overlapped with compute); one with parked
    /// claimants as *waited*. `Ok(None)` is a *dropped* transfer — the
    /// bytes were already local or the version was reclaimed mid-flight —
    /// and inflates neither overlap metric.
    pub(crate) fn complete(&self, key: DataKey, node: NodeId, result: anyhow::Result<Option<u64>>) {
        let mut inner = self.inner.lock().unwrap();
        let pair = (key, node.0);
        let had_waiter = inner.waiting.get(&pair).copied().unwrap_or(0) > 0;
        // Settle the in-flight gauge with the bytes the request was
        // enqueued with (whatever the outcome — the pressure is gone).
        let pending = match inner.states.get(&pair) {
            Some(TransferState::Queued(b)) | Some(TransferState::Running(b)) => *b,
            _ => 0,
        };
        self.inflight[(node.0 as usize) % inner.queues.len()]
            .fetch_sub(pending, Ordering::Relaxed);
        match result {
            Ok(Some(nbytes)) => {
                inner.states.insert(pair, TransferState::Done);
                self.bytes.fetch_add(nbytes, Ordering::Relaxed);
                if had_waiter {
                    self.waited.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.prefetched.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(None) => {
                inner.states.insert(pair, TransferState::Done);
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                inner.states.insert(pair, TransferState::Failed(format!("{e:#}")));
                self.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.cv_done.notify_all();
    }

    /// Claimant side: block until `key` is staged on `node`, requesting
    /// the transfer first if nobody did (a stolen task can land on a node
    /// the router never prefetched for). `Ok(())` means the replica's
    /// location is published; `Err` carries the transfer failure and the
    /// caller falls back to the synchronous path.
    pub fn await_staged(&self, key: DataKey, node: NodeId, bytes: u64) -> Result<(), String> {
        if !self.enabled() {
            return Err("transfer service disabled".into());
        }
        let pair = (key, node.0);
        let mut inner = self.inner.lock().unwrap();
        // A stolen task can land on a node the router never prefetched
        // for; the dedup inside makes this a no-op otherwise.
        self.enqueue_request(&mut inner, key, node, bytes);
        loop {
            match inner.states.get(&pair) {
                Some(TransferState::Done) | None => return Ok(()),
                Some(TransferState::Failed(e)) => return Err(e.clone()),
                Some(TransferState::Queued(_)) | Some(TransferState::Running(_)) => {}
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return Err("runtime stopping".into());
            }
            *inner.waiting.entry(pair).or_insert(0) += 1;
            inner = self.cv_done.wait(inner).unwrap();
            let drained = match inner.waiting.get_mut(&pair) {
                Some(w) => {
                    *w -= 1;
                    *w == 0
                }
                None => false,
            };
            if drained {
                inner.waiting.remove(&pair);
            }
        }
    }

    /// Wake every mover and claimant; subsequent `next_request`s return
    /// `None` and parked claimants error out.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.inner.lock().unwrap();
        self.cv_work.notify_all();
        self.cv_done.notify_all();
    }

    /// Estimated serialized bytes currently queued or moving toward
    /// `node` — the transfer-pressure input of the placement engine's
    /// `cost` model (a replica already on its way counts as local).
    pub fn inflight_toward(&self, node: NodeId) -> u64 {
        self.inflight
            .get(node.0 as usize)
            .map(|b| b.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Transfers ever requested (deduplicated pairs).
    pub fn requested(&self) -> u64 {
        self.requested.load(Ordering::Relaxed)
    }

    /// Transfers that completed before any claimant parked on them.
    pub fn prefetched(&self) -> u64 {
        self.prefetched.load(Ordering::Relaxed)
    }

    /// Transfers at least one claimant parked on.
    pub fn waited(&self) -> u64 {
        self.waited.load(Ordering::Relaxed)
    }

    /// Transfers dropped without moving bytes (destination already had a
    /// replica, or the version was reclaimed mid-flight).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Transfers that failed (their claimants fell back to the
    /// synchronous path).
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Serialized bytes moved by the movers.
    pub fn transfer_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    #[cfg(test)]
    fn waiting_count(&self, key: DataKey, node: NodeId) -> u32 {
        self.inner
            .lock()
            .unwrap()
            .waiting
            .get(&(key, node.0))
            .copied()
            .unwrap_or(0)
    }
}

impl InflightSource for TransferService {
    fn inflight_toward(&self, node: NodeId) -> u64 {
        TransferService::inflight_toward(self, node)
    }
}

/// Body of a mover thread: drain transfer requests (preferring `home`'s
/// queue) until shutdown. Spawned by `Coordinator::start`, joined by
/// `Coordinator::stop`.
pub(crate) fn mover_loop(shared: Arc<Shared>, home: NodeId) {
    while let Some((key, node)) = shared.transfers.next_request(home) {
        let result = perform_transfer(&shared, key, node);
        shared.transfers.complete(key, node, result);
    }
}

/// Move one version to `node`: make sure a serialized file exists (the
/// cross-node codec boundary, run on the mover — not the claimant), decode
/// it, cache the replica zero-copy for the destination's consumers, and
/// publish the location. Returns the serialized byte count.
///
/// A version the GC reclaimed mid-transfer is *dropped* (`Ok(None)`), not
/// failed: the refcount protocol keeps any version with a live (or
/// parked) consumer uncollected, so a collected version means the
/// prefetch went to a node whose claimant was stolen away — nobody needs
/// the bytes anymore. Already-local destinations are dropped the same
/// way.
fn perform_transfer(
    shared: &Shared,
    key: DataKey,
    node: NodeId,
) -> anyhow::Result<Option<u64>> {
    if shared.table.is_local(key, node) {
        // Raced with a synchronous fallback or duplicate: already staged.
        return Ok(None);
    }
    if shared.table.is_collected(key) {
        return Ok(None);
    }
    match stage_replica(shared, key, node) {
        Ok(staged) => Ok(staged),
        // Collected while we were encoding/decoding it: benign.
        Err(_) if shared.table.is_collected(key) => Ok(None),
        Err(e) => Err(e),
    }
}

fn stage_replica(shared: &Shared, key: DataKey, node: NodeId) -> anyhow::Result<Option<u64>> {
    let path = crate::coordinator::executor::ensure_file(shared, key)?;
    let nbytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let value = Arc::new(shared.codec.read_file(&path)?);
    let victims = shared.store.put(key, value, true);
    spill_victims(shared, victims);
    if shared.table.is_collected(key) {
        // The GC ran between our decode and this publish: whichever of the
        // two `store.remove`s runs last clears the replica; never publish
        // the location of a reclaimed version.
        shared.store.remove(key);
        return Ok(None);
    }
    shared.table.add_location(key, node);
    Ok(Some(nbytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::DataId;
    use std::time::{Duration, Instant};

    fn key(d: u64) -> DataKey {
        DataKey {
            data: DataId(d),
            version: 1,
        }
    }

    #[test]
    fn request_dedups_and_mover_drains() {
        let s = TransferService::new(1, 2);
        s.request(key(1), NodeId(1), 128);
        s.request(key(1), NodeId(1), 128); // duplicate: no second queue entry
        assert_eq!(s.requested(), 1);
        // The pending request registers as pressure toward node 1 only.
        assert_eq!(s.inflight_toward(NodeId(1)), 128);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        assert_eq!((k, n), (key(1), NodeId(1)));
        assert_eq!(s.inflight_toward(NodeId(1)), 128, "running still counts");
        s.complete(k, n, Ok(Some(128)));
        // Completed with nobody parked: a prefetch that fully overlapped.
        assert_eq!(s.prefetched(), 1);
        assert_eq!(s.waited(), 0);
        assert_eq!(s.transfer_bytes(), 128);
        assert_eq!(s.inflight_toward(NodeId(1)), 0, "completion settles the gauge");
        // Done tombstone: claimants return immediately.
        assert_eq!(s.await_staged(key(1), NodeId(1), 128), Ok(()));
        assert_eq!(s.waited(), 0);
        // A dropped transfer (already local / reclaimed) is Done for
        // claimants but inflates neither overlap counter.
        s.request(key(2), NodeId(0), 64);
        let (k2, n2) = s.next_request(NodeId(0)).unwrap();
        s.complete(k2, n2, Ok(None));
        assert_eq!(s.dropped(), 1);
        assert_eq!(s.prefetched(), 1);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
        assert_eq!(s.await_staged(key(2), NodeId(0), 64), Ok(()));
    }

    #[test]
    fn claimant_parks_until_completion_and_counts_waited() {
        let s = Arc::new(TransferService::new(1, 2));
        s.request(key(7), NodeId(1), 64);
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s2.await_staged(key(7), NodeId(1), 64));
        // Deterministic: wait until the claimant is provably parked.
        let t0 = Instant::now();
        while s.waiting_count(key(7), NodeId(1)) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "claimant never parked");
            std::thread::yield_now();
        }
        let (k, n) = s.next_request(NodeId(1)).unwrap();
        s.complete(k, n, Ok(Some(64)));
        assert_eq!(waiter.join().unwrap(), Ok(()));
        assert_eq!(s.waited(), 1);
        assert_eq!(s.prefetched(), 0);
    }

    #[test]
    fn failed_transfer_reports_to_claimant() {
        let s = Arc::new(TransferService::new(1, 1));
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s2.await_staged(key(3), NodeId(0), 32));
        let (k, n) = loop {
            // await_staged itself enqueues the request.
            if let Some(req) = s.next_request(NodeId(0)) {
                break req;
            }
        };
        s.complete(k, n, Err(anyhow::anyhow!("boom")));
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.contains("boom"), "{err}");
        assert_eq!(s.failed(), 1);
        assert_eq!(s.inflight_toward(NodeId(0)), 0, "failure settles the gauge");
    }

    #[test]
    fn disabled_service_rejects_claims() {
        let s = TransferService::new(0, 4);
        assert!(!s.enabled());
        assert!(s.await_staged(key(1), NodeId(0), 8).is_err());
        s.request(key(1), NodeId(0), 8); // no-op
        assert_eq!(s.requested(), 0);
        assert_eq!(s.inflight_toward(NodeId(0)), 0);
    }

    #[test]
    fn stop_releases_movers_and_waiters() {
        let s = Arc::new(TransferService::new(1, 1));
        let s_mover = Arc::clone(&s);
        let mover = std::thread::spawn(move || s_mover.next_request(NodeId(0)));
        s.request(key(9), NodeId(0), 16);
        // The mover takes the request but never completes it; a claimant
        // parks on it.
        let s_waiter = Arc::clone(&s);
        let waiter = std::thread::spawn(move || s_waiter.await_staged(key(9), NodeId(0), 16));
        let t0 = Instant::now();
        while s.waiting_count(key(9), NodeId(0)) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "claimant never parked");
            std::thread::yield_now();
        }
        s.stop();
        assert!(waiter.join().unwrap().is_err(), "shutdown must release claimants");
        // The mover got the request before stop, or None after it.
        let _ = mover.join().unwrap();
        // Post-stop, movers drain whatever is still queued, then exit.
        while s.next_request(NodeId(0)).is_some() {}
        assert!(s.next_request(NodeId(0)).is_none(), "post-stop movers exit");
    }

    #[test]
    fn per_node_queues_prefer_home_but_steal() {
        let s = TransferService::new(1, 2);
        s.request(key(1), NodeId(0), 8);
        s.request(key(2), NodeId(1), 8);
        // Node-1 mover prefers its own queue...
        let (k, _) = s.next_request(NodeId(1)).unwrap();
        assert_eq!(k, key(2));
        // ...and steals node-0 work when its own queue is empty.
        let (k, _) = s.next_request(NodeId(1)).unwrap();
        assert_eq!(k, key(1));
    }
}
