//! The versioned data registry — COMPSs' object tracker.
//!
//! Every value that crosses a task boundary becomes a *datum* with an id and
//! a version: the `dXvY` labels on the paper's DAG figures (§3.4, Figures
//! 2-5). Writing a datum creates version `Y+1` while readers of version `Y`
//! keep a consistent snapshot — this renaming is what lets the superscalar
//! dependency analysis avoid false WAR/WAW serialization.
//!
//! The registry is split along its two access patterns:
//!
//! * [`DataRegistry`] — the *dependency half* (latest-version map and
//!   read/write access history). It is consulted only during submission, on
//!   the master's dependency-analysis path, and stays behind the
//!   coordinator's control lock.
//! * [`VersionTable`] — the *location half* (where each version's bytes
//!   live, how big they are, whether they are memory-resident). Workers hit
//!   it on every claim and completion, so it is sharded behind fine-grained
//!   `RwLock`s and shared via `Arc`: claim-path lookups never touch the
//!   control lock.
//!
//! The data-locality scheduler and the simulator's transfer model both read
//! the location half.

use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::coordinator::dag::TaskId;
use crate::value::RValue;

/// A cluster node index. Node 0 also hosts the master, as in COMPSs
/// deployments where the leader process shares the first allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Identity of a logical datum (stable across versions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataId(pub u64);

/// A specific version of a datum: the `dXvY` in the paper's DAGs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataKey {
    pub data: DataId,
    pub version: u32,
}

impl fmt::Display for DataKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}v{}", self.data.0, self.version)
    }
}

/// Per-version bookkeeping.
#[derive(Clone, Debug)]
pub struct VersionInfo {
    /// Task that produces this version; `None` for values materialized by
    /// the master at submission time (literal arguments).
    pub producer: Option<TaskId>,
    /// Whether the value exists yet (producer finished / literal written) —
    /// either as a serialized file or as a memory-resident object.
    pub available: bool,
    /// The value is held by the in-memory
    /// [`DataStore`](super::store::hot::DataStore); `path` may be empty
    /// until it spills.
    pub in_memory: bool,
    /// Nodes that currently hold a replica.
    pub locations: Vec<NodeId>,
    /// Size in bytes (serialized size when a file exists, payload estimate
    /// for memory-resident values; 0 until known).
    pub bytes: u64,
    /// Backing file (file plane or spilled); empty for memory-resident
    /// values and in pure simulation.
    pub path: PathBuf,
    /// Consumer tasks registered by the dependency analysis that have not
    /// yet finished consuming this version (one count per reading argument;
    /// see `DataRegistry::record_read`).
    pub consumers_left: u32,
    /// Consumer references ever registered. Distinguishes a *drained*
    /// intermediate (`consumers_total > 0 && consumers_left == 0`, dead)
    /// from a terminal output nothing ever read (`consumers_total == 0`,
    /// live until the application fetches it).
    pub consumers_total: u32,
    /// Pinned by `wait_on`: the master may fetch this version again, so
    /// the version GC must never reclaim it.
    pub pinned: bool,
    /// Reclaimed by the version GC: the store entry was dropped and any
    /// spill file deleted. A collected version can never be fetched again.
    pub collected: bool,
}

/// What the version GC must free once the last consumer reference of a
/// version is released. Computed atomically under the shard lock by
/// [`VersionTable::release_consumer`]; the caller performs the actual
/// freeing (store removal, file deletion) outside the lock.
#[derive(Debug)]
pub struct CollectAction {
    pub key: DataKey,
    /// Published spill/parameter file to delete, when one exists.
    pub path: Option<PathBuf>,
    /// Recorded size of the version (serialized size or payload estimate).
    pub bytes: u64,
}

/// Outcome of [`VersionTable::drop_node`] — the location-half of losing a
/// node.
#[derive(Debug, Default)]
pub struct NodeDropReport {
    /// Versions whose *only* replica lived on the dead node and that have
    /// no published cold-tier file: their bytes are gone and must be
    /// re-derived from lineage (or re-materialized, for literals).
    pub lost: Vec<DataKey>,
    /// Versions that lost their last node replica but keep a cold-tier
    /// file on the shared filesystem: recoverable without re-execution
    /// (this is what `--checkpoint cold` buys).
    pub survivable: usize,
}

/// Sharded version/location table. Every method takes `&self`; shard locks
/// are leaf locks (no other lock is ever taken while one is held), so the
/// table can be consulted from any context.
#[derive(Debug)]
pub struct VersionTable {
    shards: Vec<RwLock<HashMap<DataKey, VersionInfo>>>,
}

const VERSION_SHARDS: usize = 16;

impl Default for VersionTable {
    fn default() -> Self {
        VersionTable {
            shards: (0..VERSION_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }
}

impl VersionTable {
    pub fn new() -> VersionTable {
        VersionTable::default()
    }

    fn shard(&self, key: DataKey) -> &RwLock<HashMap<DataKey, VersionInfo>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    pub fn insert(&self, key: DataKey, info: VersionInfo) {
        self.shard(key).write().unwrap().insert(key, info);
    }

    /// Snapshot of a version's info (cloned out of the shard lock).
    pub fn info(&self, key: DataKey) -> Option<VersionInfo> {
        self.shard(key).read().unwrap().get(&key).cloned()
    }

    pub fn is_available(&self, key: DataKey) -> bool {
        self.shard(key)
            .read()
            .unwrap()
            .get(&key)
            .map(|i| i.available)
            .unwrap_or(false)
    }

    /// Does `node` hold a replica of this version?
    pub fn is_local(&self, key: DataKey, node: NodeId) -> bool {
        self.shard(key)
            .read()
            .unwrap()
            .get(&key)
            .map(|i| i.locations.contains(&node))
            .unwrap_or(false)
    }

    /// Atomically take a version's published file path *out* of the table
    /// (clearing it under the shard lock, so no reader can reach the file
    /// once the caller deletes it). Returns the path and the recorded
    /// serialized size. Used by the cold tier's [`discard`] — the GC's own
    /// collect path takes the path through `CollectAction` instead.
    ///
    /// [`discard`]: crate::coordinator::store::ValueStore::discard
    pub fn take_path(&self, key: DataKey) -> Option<(PathBuf, u64)> {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key)?;
        if info.path.as_os_str().is_empty() {
            return None;
        }
        Some((std::mem::take(&mut info.path), info.bytes))
    }

    /// The spill/parameter file path, when one has been published.
    pub fn path_of(&self, key: DataKey) -> Option<PathBuf> {
        self.shard(key)
            .read()
            .unwrap()
            .get(&key)
            .filter(|i| !i.path.as_os_str().is_empty())
            .map(|i| i.path.clone())
    }

    /// Mark a version as produced on disk, with its file and size.
    pub fn mark_available(&self, key: DataKey, node: NodeId, bytes: u64, path: PathBuf) {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key).expect("mark of unknown version");
        info.available = true;
        info.in_memory = false;
        info.bytes = bytes;
        info.path = path;
        if !info.locations.contains(&node) {
            info.locations.push(node);
        }
    }

    /// Mark a version as produced into the in-memory store (no file yet).
    pub fn mark_available_memory(&self, key: DataKey, node: NodeId, bytes: u64) {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key).expect("mark of unknown version");
        info.available = true;
        info.in_memory = true;
        info.bytes = bytes;
        if !info.locations.contains(&node) {
            info.locations.push(node);
        }
    }

    /// Publish the spill file of a memory-resident version. The value may
    /// stay cached (spill-for-transfer), so `in_memory` is left as-is.
    /// Returns `false` — without publishing — when the GC collected the
    /// version in the meantime (the caller must delete the orphan file).
    pub fn mark_spilled(&self, key: DataKey, bytes: u64, path: PathBuf) -> bool {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key).expect("spill of unknown version");
        if info.collected {
            return false;
        }
        info.bytes = bytes;
        info.path = path;
        true
    }

    /// Record that `node` now also holds a replica (after a transfer).
    pub fn add_location(&self, key: DataKey, node: NodeId) {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key).expect("unknown version");
        if !info.locations.contains(&node) {
            info.locations.push(node);
        }
    }

    /// Record the exact serialized size of a version once its warm-tier
    /// blob is built (the path stays untouched). Placement-engine byte
    /// estimates and transfer-request gauges read `bytes`, so the first
    /// encode upgrades them from payload estimates to real wire sizes —
    /// which also sharpens the `cost`/`adaptive` feedback signal. A no-op
    /// for unknown or collected versions.
    pub fn update_bytes(&self, key: DataKey, bytes: u64) {
        let mut shard = self.shard(key).write().unwrap();
        if let Some(info) = shard.get_mut(&key) {
            if !info.collected {
                info.bytes = bytes;
            }
        }
    }

    /// Register one consumer reference (a task argument that reads this
    /// version). Called by the dependency analysis at submission time.
    pub fn add_consumer(&self, key: DataKey) {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key).expect("consumer on unknown version");
        info.consumers_left += 1;
        info.consumers_total += 1;
    }

    /// Pin a version so the GC never reclaims it (`wait_on` does this
    /// before checking availability, closing the race against the last
    /// consumer's release). Returns `false` for an unknown version.
    pub fn pin(&self, key: DataKey) -> bool {
        let mut shard = self.shard(key).write().unwrap();
        match shard.get_mut(&key) {
            Some(info) => {
                info.pinned = true;
                true
            }
            None => false,
        }
    }

    /// Has the version GC reclaimed this version?
    pub fn is_collected(&self, key: DataKey) -> bool {
        self.shard(key)
            .read()
            .unwrap()
            .get(&key)
            .map(|i| i.collected)
            .unwrap_or(false)
    }

    /// Release one consumer reference. With `collect` set (the runtime's
    /// GC knob), the version is atomically marked collected when this was
    /// the last reference on an unpinned, produced, at-least-once-consumed
    /// version; the returned action tells the caller what to free. The
    /// shard lock makes the mark exclusive: two racing releasers can never
    /// both receive an action for the same version.
    pub fn release_consumer(&self, key: DataKey, collect: bool) -> Option<CollectAction> {
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key)?;
        info.consumers_left = info.consumers_left.saturating_sub(1);
        if collect {
            try_mark_collected(key, info)
        } else {
            None
        }
    }

    /// Publish-side half of the GC: collect a version whose consumers all
    /// disappeared (cancelled) *before* it became available — its final
    /// `release_consumer` found `available == false` and could not act.
    /// The runtime calls this right after `mark_available*` on the worker
    /// publish paths.
    pub fn reap_if_drained(&self, key: DataKey, collect: bool) -> Option<CollectAction> {
        if !collect {
            return None;
        }
        let mut shard = self.shard(key).write().unwrap();
        let info = shard.get_mut(&key)?;
        try_mark_collected(key, info)
    }

    /// Bytes held by *dead* versions: fully consumed, unpinned, produced,
    /// and not yet reclaimed. With the version GC enabled this is zero at
    /// quiescence — the acceptance metric for the value-lifecycle engine.
    pub fn dead_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.read()
                    .unwrap()
                    .values()
                    .filter(|i| {
                        i.available
                            && !i.collected
                            && !i.pinned
                            && i.consumers_total > 0
                            && i.consumers_left == 0
                    })
                    .map(|i| i.bytes)
                    .sum::<u64>()
            })
            .sum()
    }

    /// Number of live versions (for stats).
    pub fn version_count(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// Bytes of uncollected versions with a published file — the cold
    /// tier's resident footprint (the table is the cold tier's index).
    pub fn file_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.read()
                    .unwrap()
                    .values()
                    .filter(|i| !i.collected && !i.path.as_os_str().is_empty())
                    .map(|i| i.bytes)
                    .sum::<u64>()
            })
            .sum()
    }

    /// Uncollected versions with a published file (cold-tier entry count).
    pub fn file_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.read()
                    .unwrap()
                    .values()
                    .filter(|i| !i.collected && !i.path.as_os_str().is_empty())
                    .count()
            })
            .sum()
    }

    /// Total bytes across all versions.
    pub fn total_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().values().map(|v| v.bytes).sum::<u64>())
            .sum()
    }

    /// Drop a dead node from every version's location set (node-loss
    /// recovery, step one). A version whose only replica lived there
    /// becomes *lost* — unavailable, to be re-derived from lineage —
    /// unless a cold-tier file was published for it (the shared
    /// filesystem survives the node), in which case it stays available
    /// and future consumers stage it from the file.
    pub fn drop_node(&self, node: NodeId) -> NodeDropReport {
        let mut report = NodeDropReport::default();
        for s in &self.shards {
            let mut shard = s.write().unwrap();
            for (key, info) in shard.iter_mut() {
                if !info.locations.contains(&node) {
                    continue;
                }
                info.locations.retain(|n| *n != node);
                if info.collected || !info.available || !info.locations.is_empty() {
                    continue;
                }
                info.in_memory = false;
                if info.path.as_os_str().is_empty() {
                    info.available = false;
                    report.lost.push(*key);
                } else {
                    report.survivable += 1;
                }
            }
        }
        report
    }

    /// Window-compiler settlement of a version that will never be
    /// produced — its producer was culled, or it was elided as a fused
    /// intermediate (handed producer-to-consumer without a publish). The
    /// version is marked collected so a late `wait_on` errors
    /// deterministically instead of parking forever and the transfer
    /// plane never stages it. Unlike the GC's collection gate this does
    /// not require availability: there are no bytes to free, so no
    /// [`CollectAction`] is returned. Produced, pinned, or
    /// already-collected versions are left alone (`false`): a `wait_on`
    /// pin that raced the compile pass wins, and the caller must keep the
    /// producer runnable.
    pub fn collect_unproduced(&self, key: DataKey) -> bool {
        let mut shard = self.shard(key).write().unwrap();
        let Some(info) = shard.get_mut(&key) else {
            return false;
        };
        if info.available || info.pinned || info.collected {
            return false;
        }
        info.collected = true;
        info.in_memory = false;
        info.path = PathBuf::new();
        true
    }

    /// Revert a [`VersionTable::collect_unproduced`] mark: the compile
    /// pass settles a task's outputs one at a time, and when a later
    /// output refuses (a racing pin), the earlier marks must be undone so
    /// the task can execute normally. Only flips versions that are still
    /// unproduced-and-collected — the exact state `collect_unproduced`
    /// left them in.
    pub fn uncollect_unproduced(&self, key: DataKey) {
        let mut shard = self.shard(key).write().unwrap();
        if let Some(info) = shard.get_mut(&key) {
            if info.collected && !info.available {
                info.collected = false;
            }
        }
    }

    /// Reset a version so its producer can re-derive it (lineage
    /// recovery): availability, residency, locations, and — for a version
    /// the GC already collected — the `collected` mark are cleared, so the
    /// re-execution's publish and the re-registered consumers drive the
    /// normal lifecycle again. The path is cleared too (a collected
    /// version's file is already deleted; a lost one never had a file).
    pub fn reset_for_recovery(&self, key: DataKey) {
        let mut shard = self.shard(key).write().unwrap();
        if let Some(info) = shard.get_mut(&key) {
            info.available = false;
            info.in_memory = false;
            info.collected = false;
            info.locations.clear();
            info.path = PathBuf::new();
        }
    }
}

/// Shared collection gate (called under the owning shard's write lock):
/// mark a drained, unpinned, produced, at-least-once-consumed version as
/// collected and describe what to free. At most one caller ever receives
/// the action for a given version.
fn try_mark_collected(key: DataKey, info: &mut VersionInfo) -> Option<CollectAction> {
    if info.consumers_left == 0
        && info.consumers_total > 0
        && !info.pinned
        && !info.collected
        && info.available
    {
        info.collected = true;
        info.in_memory = false;
        let path = if info.path.as_os_str().is_empty() {
            None
        } else {
            Some(std::mem::take(&mut info.path))
        };
        Some(CollectAction {
            key,
            path,
            bytes: info.bytes,
        })
    } else {
        None
    }
}

/// Per-datum access history used by the dependency analysis.
#[derive(Clone, Debug, Default)]
struct AccessHistory {
    /// Task that wrote the latest version (None if literal).
    last_writer: Option<TaskId>,
    /// Tasks that have read the latest version since it was written.
    readers_since_write: Vec<TaskId>,
}

/// The dependency half of the registry. Owns (an `Arc` to) the version
/// table it creates entries in; location updates go through
/// [`DataRegistry::table`] directly on hot paths.
#[derive(Debug)]
pub struct DataRegistry {
    next_data: u64,
    /// Latest version number per datum.
    latest: HashMap<DataId, u32>,
    history: HashMap<DataId, AccessHistory>,
    table: Arc<VersionTable>,
    /// Lineage retention for master-materialized values: a memory-plane
    /// literal has no producer task to re-run, so node-loss recovery
    /// re-publishes it from this map instead. (File-plane literals live on
    /// the shared filesystem and never need it.) Retained for the whole
    /// run — literals are the leaves of every lineage chain.
    literals: HashMap<DataKey, Arc<RValue>>,
}

impl Default for DataRegistry {
    fn default() -> Self {
        DataRegistry::with_table(Arc::new(VersionTable::new()))
    }
}

impl DataRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a registry whose version entries land in a shared table.
    pub fn with_table(table: Arc<VersionTable>) -> Self {
        DataRegistry {
            next_data: 0,
            latest: HashMap::new(),
            history: HashMap::new(),
            table,
            literals: HashMap::new(),
        }
    }

    /// Retain a memory-plane literal's value for node-loss recovery (see
    /// the `literals` field). The runtime calls this right after
    /// materializing a literal into the hot tier.
    pub fn retain_literal(&mut self, key: DataKey, value: Arc<RValue>) {
        self.literals.insert(key, value);
    }

    /// The retained value of a master-materialized literal, if any.
    pub fn literal_value(&self, key: DataKey) -> Option<Arc<RValue>> {
        self.literals.get(&key).cloned()
    }

    /// The shared location half.
    pub fn table(&self) -> &Arc<VersionTable> {
        &self.table
    }

    /// Register a brand-new datum whose first version is materialized by
    /// the master (a literal argument). Returns its key (version 1).
    pub fn new_literal(&mut self, bytes: u64, node: NodeId) -> DataKey {
        self.next_data += 1;
        let key = DataKey {
            data: DataId(self.next_data),
            version: 1,
        };
        self.latest.insert(key.data, 1);
        self.table.insert(
            key,
            VersionInfo {
                producer: None,
                available: true,
                in_memory: false,
                locations: vec![node],
                bytes,
                path: PathBuf::new(),
                consumers_left: 0,
                consumers_total: 0,
                pinned: false,
                collected: false,
            },
        );
        self.history.insert(key.data, AccessHistory::default());
        key
    }

    /// Register a brand-new datum to be produced by `producer` (a task
    /// return value). Returns its key (version 1, unavailable).
    pub fn new_future(&mut self, producer: TaskId) -> DataKey {
        self.next_data += 1;
        let key = DataKey {
            data: DataId(self.next_data),
            version: 1,
        };
        self.latest.insert(key.data, 1);
        self.table.insert(
            key,
            VersionInfo {
                producer: Some(producer),
                available: false,
                in_memory: false,
                locations: Vec::new(),
                bytes: 0,
                path: PathBuf::new(),
                consumers_left: 0,
                consumers_total: 0,
                pinned: false,
                collected: false,
            },
        );
        self.history.insert(
            key.data,
            AccessHistory {
                last_writer: Some(producer),
                readers_since_write: Vec::new(),
            },
        );
        key
    }

    /// Latest version key of a datum.
    pub fn latest_key(&self, data: DataId) -> Option<DataKey> {
        self.latest.get(&data).map(|v| DataKey { data, version: *v })
    }

    /// Record a read of the datum's latest version by `reader`.
    /// Returns the key read and the task to depend on (RAW), if any.
    /// Also registers one consumer reference in the version table — the
    /// count the version GC drains as readers finish.
    pub fn record_read(&mut self, data: DataId, reader: TaskId) -> (DataKey, Option<TaskId>) {
        let key = self.latest_key(data).expect("read of unknown datum");
        let hist = self.history.get_mut(&data).expect("history missing");
        hist.readers_since_write.push(reader);
        self.table.add_consumer(key);
        (key, hist.last_writer)
    }

    /// Record a write (OUT or the write half of INOUT) by `writer`:
    /// bumps the version and returns `(new_key, waw_dep, war_deps)`.
    pub fn record_write(
        &mut self,
        data: DataId,
        writer: TaskId,
    ) -> (DataKey, Option<TaskId>, Vec<TaskId>) {
        let v = self.latest.get_mut(&data).expect("write of unknown datum");
        *v += 1;
        let new_key = DataKey { data, version: *v };
        self.table.insert(
            new_key,
            VersionInfo {
                producer: Some(writer),
                available: false,
                in_memory: false,
                locations: Vec::new(),
                bytes: 0,
                path: PathBuf::new(),
                consumers_left: 0,
                consumers_total: 0,
                pinned: false,
                collected: false,
            },
        );
        let hist = self.history.get_mut(&data).expect("history missing");
        let waw = hist.last_writer;
        let war = std::mem::take(&mut hist.readers_since_write);
        hist.last_writer = Some(writer);
        (new_key, waw, war)
    }

    // ---- delegating accessors (compat with the pre-split API; the live
    // runtime's hot paths go through `table()` directly) ------------------

    /// Mark a version as produced, with its physical location and size.
    pub fn mark_available(&mut self, key: DataKey, node: NodeId, bytes: u64, path: PathBuf) {
        self.table.mark_available(key, node, bytes, path);
    }

    /// Record that `node` now also holds a replica (after a transfer).
    pub fn add_location(&mut self, key: DataKey, node: NodeId) {
        self.table.add_location(key, node);
    }

    /// Snapshot of a version's info.
    pub fn info(&self, key: DataKey) -> Option<VersionInfo> {
        self.table.info(key)
    }

    pub fn is_available(&self, key: DataKey) -> bool {
        self.table.is_available(key)
    }

    /// Does `node` hold this version locally?
    pub fn is_local(&self, key: DataKey, node: NodeId) -> bool {
        self.table.is_local(key, node)
    }

    /// Number of registered data (for stats).
    pub fn datum_count(&self) -> usize {
        self.latest.len()
    }

    /// Number of live versions (for stats).
    pub fn version_count(&self) -> usize {
        self.table.version_count()
    }

    /// Total serialized bytes across all available versions.
    pub fn total_bytes(&self) -> u64 {
        self.table.total_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T1: TaskId = TaskId(1);
    const T2: TaskId = TaskId(2);
    const T3: TaskId = TaskId(3);

    #[test]
    fn literal_is_immediately_available() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(128, NodeId(0));
        assert!(reg.is_available(key));
        assert!(reg.is_local(key, NodeId(0)));
        assert!(!reg.is_local(key, NodeId(1)));
        assert_eq!(key.to_string(), "d1v1");
    }

    #[test]
    fn future_becomes_available_when_marked() {
        let mut reg = DataRegistry::new();
        let key = reg.new_future(T1);
        assert!(!reg.is_available(key));
        reg.mark_available(key, NodeId(2), 64, PathBuf::from("/tmp/d1v1"));
        assert!(reg.is_available(key));
        assert_eq!(reg.info(key).unwrap().bytes, 64);
        assert!(reg.is_local(key, NodeId(2)));
    }

    #[test]
    fn raw_dependency_on_last_writer() {
        let mut reg = DataRegistry::new();
        let key = reg.new_future(T1);
        let (read_key, dep) = reg.record_read(key.data, T2);
        assert_eq!(read_key, key);
        assert_eq!(dep, Some(T1));
    }

    #[test]
    fn write_bumps_version_and_reports_war_waw() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        // Two readers of v1.
        reg.record_read(key.data, T1);
        reg.record_read(key.data, T2);
        // T3 writes: WAR on T1,T2; no WAW (literal had no writer).
        let (new_key, waw, war) = reg.record_write(key.data, T3);
        assert_eq!(new_key.version, 2);
        assert_eq!(waw, None);
        assert_eq!(war, vec![T1, T2]);
        // Subsequent read depends on T3 (RAW) and sees v2.
        let (k, dep) = reg.record_read(key.data, T1);
        assert_eq!(k.version, 2);
        assert_eq!(dep, Some(T3));
    }

    #[test]
    fn waw_between_successive_writers() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        let (_, waw1, _) = reg.record_write(key.data, T1);
        assert_eq!(waw1, None);
        let (k2, waw2, war2) = reg.record_write(key.data, T2);
        assert_eq!(k2.version, 3);
        assert_eq!(waw2, Some(T1));
        assert!(war2.is_empty());
    }

    #[test]
    fn old_versions_remain_after_write() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        reg.record_write(key.data, T1);
        // v1 still readable (snapshot isolation for in-flight readers).
        assert!(reg.is_available(key));
        assert_eq!(reg.version_count(), 2);
        assert_eq!(reg.datum_count(), 1);
    }

    #[test]
    fn version_table_memory_lifecycle() {
        // memory-resident -> spilled -> file: availability never flickers
        // and the path appears exactly when the spill publishes it.
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        assert!(table.path_of(key).is_none());

        table.mark_available_memory(key, NodeId(1), 256);
        let info = table.info(key).unwrap();
        assert!(info.available && info.in_memory);
        assert_eq!(info.bytes, 256);
        assert!(table.is_local(key, NodeId(1)));
        assert!(table.path_of(key).is_none(), "no file before the spill");

        table.mark_spilled(key, 300, PathBuf::from("/tmp/d1v1.par"));
        assert!(table.is_available(key));
        assert_eq!(table.path_of(key).unwrap(), PathBuf::from("/tmp/d1v1.par"));
        assert_eq!(table.info(key).unwrap().bytes, 300);
    }

    #[test]
    fn consumer_refcount_collects_on_last_release() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 256);
        // Two readers registered by the dependency analysis.
        reg.record_read(key.data, T2);
        reg.record_read(key.data, T3);
        assert_eq!(table.info(key).unwrap().consumers_left, 2);
        // A pending consumer (e.g. one whose bytes are still being
        // transferred cross-node) keeps the version alive.
        assert!(table.release_consumer(key, true).is_none());
        assert!(!table.is_collected(key));
        // Last release collects.
        let act = table.release_consumer(key, true).expect("collect on last release");
        assert_eq!(act.key, key);
        assert_eq!(act.bytes, 256);
        assert!(act.path.is_none(), "memory-resident version has no file");
        assert!(table.is_collected(key));
        // Idempotent: further releases never double-collect.
        assert!(table.release_consumer(key, true).is_none());
    }

    #[test]
    fn pinned_versions_survive_release() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 64);
        reg.record_read(key.data, T2);
        assert!(table.pin(key), "pin of a known version succeeds");
        assert!(table.release_consumer(key, true).is_none());
        assert!(!table.is_collected(key));
        assert!(!table.pin(DataKey { data: DataId(999), version: 1 }));
    }

    #[test]
    fn publish_side_reap_collects_pre_drained_versions() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        reg.record_read(key.data, T2);
        // T2 is cancelled while the producer still runs: its release finds
        // the version unavailable and must not collect.
        assert!(table.release_consumer(key, true).is_none());
        assert!(!table.is_collected(key));
        // The producer finally publishes; the publish-side sweep reclaims
        // the drained version instead of leaking it.
        table.mark_available_memory(key, NodeId(0), 64);
        let act = table.reap_if_drained(key, true).expect("drained at publish");
        assert_eq!(act.bytes, 64);
        assert!(table.is_collected(key));
        assert_eq!(table.dead_bytes(), 0);
        // Never-consumed terminal outputs are not reaped...
        let key2 = reg.new_future(T3);
        table.mark_available_memory(key2, NodeId(0), 8);
        assert!(table.reap_if_drained(key2, true).is_none());
        // ...and with the GC off the sweep is inert.
        let key3 = reg.new_future(T1);
        reg.record_read(key3.data, T2);
        table.mark_available_memory(key3, NodeId(0), 8);
        assert!(table.release_consumer(key3, false).is_none());
        assert!(table.reap_if_drained(key3, false).is_none());
    }

    #[test]
    fn gc_disabled_releases_never_collect() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 128);
        reg.record_read(key.data, T2);
        assert!(table.release_consumer(key, false).is_none());
        assert!(!table.is_collected(key));
        // Fully consumed, unpinned, unreclaimed: counted as dead bytes.
        assert_eq!(table.dead_bytes(), 128);
    }

    #[test]
    fn terminal_outputs_are_not_dead() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 512);
        // No consumer was ever registered: the version is a live result,
        // not a dead intermediate.
        assert_eq!(table.dead_bytes(), 0);
        assert!(table.release_consumer(key, true).is_none());
        assert!(!table.is_collected(key));
    }

    #[test]
    fn collect_action_carries_spill_path() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 64);
        table.mark_spilled(key, 80, PathBuf::from("/tmp/d1v1.par"));
        reg.record_read(key.data, T2);
        let act = table.release_consumer(key, true).expect("collect");
        assert_eq!(act.path.as_deref(), Some(std::path::Path::new("/tmp/d1v1.par")));
        // The path is cleared so no reader can reach the deleted file.
        assert!(table.path_of(key).is_none());
    }

    #[test]
    fn drop_node_distinguishes_lost_replicated_and_survivable() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        // Sole memory replica on the dead node: lost.
        let lost = reg.new_future(T1);
        table.mark_available_memory(lost, NodeId(2), 64);
        // Replicated on another node: survives with the other replica.
        let replicated = reg.new_future(T1);
        table.mark_available_memory(replicated, NodeId(2), 32);
        table.add_location(replicated, NodeId(0));
        // Sole replica but a cold file was published: survivable.
        let spilled = reg.new_future(T1);
        table.mark_available_memory(spilled, NodeId(2), 16);
        table.mark_spilled(spilled, 20, PathBuf::from("/tmp/d3v1.par"));
        // Not on the dead node at all: untouched.
        let elsewhere = reg.new_future(T1);
        table.mark_available_memory(elsewhere, NodeId(0), 8);

        let report = table.drop_node(NodeId(2));
        assert_eq!(report.lost, vec![lost]);
        assert_eq!(report.survivable, 1);
        assert!(!table.is_available(lost), "lost version is unavailable");
        assert!(table.is_available(replicated));
        assert!(table.is_local(replicated, NodeId(0)));
        assert!(!table.is_local(replicated, NodeId(2)));
        assert!(table.is_available(spilled), "cold file keeps it available");
        assert!(table.info(spilled).unwrap().locations.is_empty());
        assert!(table.is_available(elsewhere));
        // Idempotent: a second drop finds nothing.
        let again = table.drop_node(NodeId(2));
        assert!(again.lost.is_empty());
        assert_eq!(again.survivable, 0);
    }

    #[test]
    fn reset_for_recovery_revives_collected_versions() {
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T1);
        table.mark_available_memory(key, NodeId(0), 64);
        reg.record_read(key.data, T2);
        table.release_consumer(key, true).expect("collected");
        assert!(table.is_collected(key));
        // A reopened consumer re-registers, then recovery resets the
        // version; the re-executed producer's publish restarts the cycle.
        table.add_consumer(key);
        table.reset_for_recovery(key);
        let info = table.info(key).unwrap();
        assert!(!info.collected && !info.available && !info.in_memory);
        assert!(info.locations.is_empty());
        assert_eq!(info.consumers_left, 1);
        table.mark_available_memory(key, NodeId(1), 64);
        let act = table.release_consumer(key, true).expect("collects again");
        assert_eq!(act.bytes, 64);
    }

    #[test]
    fn literal_retention_round_trips() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(16, NodeId(0));
        assert!(reg.literal_value(key).is_none());
        let v = Arc::new(RValue::scalar(7.0));
        reg.retain_literal(key, Arc::clone(&v));
        let got = reg.literal_value(key).expect("retained");
        assert!(Arc::ptr_eq(&v, &got));
    }

    #[test]
    fn version_table_is_shared_between_registry_and_workers() {
        // A worker-side mark through the table is visible through the
        // registry's delegating accessors, and vice versa.
        let table = Arc::new(VersionTable::new());
        let mut reg = DataRegistry::with_table(Arc::clone(&table));
        let key = reg.new_future(T2);
        table.mark_available(key, NodeId(3), 99, PathBuf::from("/x"));
        assert!(reg.is_available(key));
        assert_eq!(reg.info(key).unwrap().bytes, 99);
        reg.add_location(key, NodeId(4));
        assert!(table.is_local(key, NodeId(4)));
    }
}
