//! The versioned data registry — COMPSs' object tracker.
//!
//! Every value that crosses a task boundary becomes a *datum* with an id and
//! a version: the `dXvY` labels on the paper's DAG figures (§3.4, Figures
//! 2-5). Writing a datum creates version `Y+1` while readers of version `Y`
//! keep a consistent snapshot — this renaming is what lets the superscalar
//! dependency analysis avoid false WAR/WAW serialization.
//!
//! The registry also tracks *where* each version lives (which cluster nodes
//! hold its serialized file) and how big it is; the data-locality scheduler
//! and the simulator's transfer model both read that.

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;

use crate::coordinator::dag::TaskId;

/// A cluster node index. Node 0 also hosts the master, as in COMPSs
/// deployments where the leader process shares the first allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Identity of a logical datum (stable across versions).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataId(pub u64);

/// A specific version of a datum: the `dXvY` in the paper's DAGs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataKey {
    pub data: DataId,
    pub version: u32,
}

impl fmt::Display for DataKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "d{}v{}", self.data.0, self.version)
    }
}

/// Per-version bookkeeping.
#[derive(Clone, Debug)]
pub struct VersionInfo {
    /// Task that produces this version; `None` for values materialized by
    /// the master at submission time (literal arguments).
    pub producer: Option<TaskId>,
    /// Whether the bytes exist yet (producer finished / literal written).
    pub available: bool,
    /// Nodes that currently hold the serialized file.
    pub locations: Vec<NodeId>,
    /// Serialized size in bytes (0 until known).
    pub bytes: u64,
    /// Backing file (local mode); empty in pure simulation.
    pub path: PathBuf,
}

/// Per-datum access history used by the dependency analysis.
#[derive(Clone, Debug, Default)]
struct AccessHistory {
    /// Task that wrote the latest version (None if literal).
    last_writer: Option<TaskId>,
    /// Tasks that have read the latest version since it was written.
    readers_since_write: Vec<TaskId>,
}

/// The registry proper.
#[derive(Debug, Default)]
pub struct DataRegistry {
    next_data: u64,
    /// Latest version number per datum.
    latest: HashMap<DataId, u32>,
    /// Version table.
    versions: HashMap<DataKey, VersionInfo>,
    history: HashMap<DataId, AccessHistory>,
}

impl DataRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a brand-new datum whose first version is materialized by
    /// the master (a literal argument). Returns its key (version 1).
    pub fn new_literal(&mut self, bytes: u64, node: NodeId) -> DataKey {
        self.next_data += 1;
        let key = DataKey {
            data: DataId(self.next_data),
            version: 1,
        };
        self.latest.insert(key.data, 1);
        self.versions.insert(
            key,
            VersionInfo {
                producer: None,
                available: true,
                locations: vec![node],
                bytes,
                path: PathBuf::new(),
            },
        );
        self.history.insert(key.data, AccessHistory::default());
        key
    }

    /// Register a brand-new datum to be produced by `producer` (a task
    /// return value). Returns its key (version 1, unavailable).
    pub fn new_future(&mut self, producer: TaskId) -> DataKey {
        self.next_data += 1;
        let key = DataKey {
            data: DataId(self.next_data),
            version: 1,
        };
        self.latest.insert(key.data, 1);
        self.versions.insert(
            key,
            VersionInfo {
                producer: Some(producer),
                available: false,
                locations: Vec::new(),
                bytes: 0,
                path: PathBuf::new(),
            },
        );
        self.history.insert(
            key.data,
            AccessHistory {
                last_writer: Some(producer),
                readers_since_write: Vec::new(),
            },
        );
        key
    }

    /// Latest version key of a datum.
    pub fn latest_key(&self, data: DataId) -> Option<DataKey> {
        self.latest.get(&data).map(|v| DataKey { data, version: *v })
    }

    /// Record a read of the datum's latest version by `reader`.
    /// Returns the key read and the task to depend on (RAW), if any.
    pub fn record_read(&mut self, data: DataId, reader: TaskId) -> (DataKey, Option<TaskId>) {
        let key = self.latest_key(data).expect("read of unknown datum");
        let hist = self.history.get_mut(&data).expect("history missing");
        hist.readers_since_write.push(reader);
        (key, hist.last_writer)
    }

    /// Record a write (OUT or the write half of INOUT) by `writer`:
    /// bumps the version and returns `(new_key, waw_dep, war_deps)`.
    pub fn record_write(
        &mut self,
        data: DataId,
        writer: TaskId,
    ) -> (DataKey, Option<TaskId>, Vec<TaskId>) {
        let v = self.latest.get_mut(&data).expect("write of unknown datum");
        *v += 1;
        let new_key = DataKey { data, version: *v };
        self.versions.insert(
            new_key,
            VersionInfo {
                producer: Some(writer),
                available: false,
                locations: Vec::new(),
                bytes: 0,
                path: PathBuf::new(),
            },
        );
        let hist = self.history.get_mut(&data).expect("history missing");
        let waw = hist.last_writer;
        let war = std::mem::take(&mut hist.readers_since_write);
        hist.last_writer = Some(writer);
        (new_key, waw, war)
    }

    /// Mark a version as produced, with its physical location and size.
    pub fn mark_available(&mut self, key: DataKey, node: NodeId, bytes: u64, path: PathBuf) {
        let info = self.versions.get_mut(&key).expect("mark of unknown version");
        info.available = true;
        info.bytes = bytes;
        info.path = path;
        if !info.locations.contains(&node) {
            info.locations.push(node);
        }
    }

    /// Record that `node` now also holds a replica (after a transfer).
    pub fn add_location(&mut self, key: DataKey, node: NodeId) {
        let info = self.versions.get_mut(&key).expect("unknown version");
        if !info.locations.contains(&node) {
            info.locations.push(node);
        }
    }

    pub fn info(&self, key: DataKey) -> Option<&VersionInfo> {
        self.versions.get(&key)
    }

    pub fn is_available(&self, key: DataKey) -> bool {
        self.versions.get(&key).map(|i| i.available).unwrap_or(false)
    }

    /// Does `node` hold this version locally?
    pub fn is_local(&self, key: DataKey, node: NodeId) -> bool {
        self.versions
            .get(&key)
            .map(|i| i.locations.contains(&node))
            .unwrap_or(false)
    }

    /// Number of registered data (for stats).
    pub fn datum_count(&self) -> usize {
        self.latest.len()
    }

    /// Number of live versions (for stats).
    pub fn version_count(&self) -> usize {
        self.versions.len()
    }

    /// Total serialized bytes across all available versions.
    pub fn total_bytes(&self) -> u64 {
        self.versions.values().map(|v| v.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T1: TaskId = TaskId(1);
    const T2: TaskId = TaskId(2);
    const T3: TaskId = TaskId(3);

    #[test]
    fn literal_is_immediately_available() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(128, NodeId(0));
        assert!(reg.is_available(key));
        assert!(reg.is_local(key, NodeId(0)));
        assert!(!reg.is_local(key, NodeId(1)));
        assert_eq!(key.to_string(), "d1v1");
    }

    #[test]
    fn future_becomes_available_when_marked() {
        let mut reg = DataRegistry::new();
        let key = reg.new_future(T1);
        assert!(!reg.is_available(key));
        reg.mark_available(key, NodeId(2), 64, PathBuf::from("/tmp/d1v1"));
        assert!(reg.is_available(key));
        assert_eq!(reg.info(key).unwrap().bytes, 64);
        assert!(reg.is_local(key, NodeId(2)));
    }

    #[test]
    fn raw_dependency_on_last_writer() {
        let mut reg = DataRegistry::new();
        let key = reg.new_future(T1);
        let (read_key, dep) = reg.record_read(key.data, T2);
        assert_eq!(read_key, key);
        assert_eq!(dep, Some(T1));
    }

    #[test]
    fn write_bumps_version_and_reports_war_waw() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        // Two readers of v1.
        reg.record_read(key.data, T1);
        reg.record_read(key.data, T2);
        // T3 writes: WAR on T1,T2; no WAW (literal had no writer).
        let (new_key, waw, war) = reg.record_write(key.data, T3);
        assert_eq!(new_key.version, 2);
        assert_eq!(waw, None);
        assert_eq!(war, vec![T1, T2]);
        // Subsequent read depends on T3 (RAW) and sees v2.
        let (k, dep) = reg.record_read(key.data, T1);
        assert_eq!(k.version, 2);
        assert_eq!(dep, Some(T3));
    }

    #[test]
    fn waw_between_successive_writers() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        let (_, waw1, _) = reg.record_write(key.data, T1);
        assert_eq!(waw1, None);
        let (k2, waw2, war2) = reg.record_write(key.data, T2);
        assert_eq!(k2.version, 3);
        assert_eq!(waw2, Some(T1));
        assert!(war2.is_empty());
    }

    #[test]
    fn old_versions_remain_after_write() {
        let mut reg = DataRegistry::new();
        let key = reg.new_literal(8, NodeId(0));
        reg.record_write(key.data, T1);
        // v1 still readable (snapshot isolation for in-flight readers).
        assert!(reg.is_available(key));
        assert_eq!(reg.version_count(), 2);
        assert_eq!(reg.datum_count(), 1);
    }
}
