//! Runtime-observation feedback — the learned half of the placement
//! engine.
//!
//! The `cost` model routes on *byte counts*: bytes still to move, bytes
//! already in flight, queue depth. That is the right heuristic when every
//! link and every task behave identically — and exactly the assumption the
//! pbdR line of work (Ostrouchov et al.) shows breaking down on real
//! machines, where the win comes from adapting data movement to *observed*
//! behavior. This module closes the loop:
//!
//! * **observe** — mover threads record per-destination transfer
//!   throughput (serialized bytes ÷ wall time) into [`FeedbackStats`] as
//!   each transfer completes, and the executor records per-task-type
//!   execution durations; the simulator feeds the identical sink from its
//!   simulated transfer timings, so a simulated `adaptive` run learns the
//!   way a live one does;
//! * **decay** — every signal is a decay-weighted EWMA
//!   ([`EWMA_ALPHA`] = 0.25): new observations dominate quickly, stale
//!   ones fade, and a mid-run bandwidth shift re-routes within a few
//!   transfers;
//! * **score** — [`AdaptivePlacement`] ranks nodes in estimated *time*:
//!   bytes still to move ÷ observed bandwidth toward the node, plus queue
//!   depth × the observed duration of this task's type. Until enough
//!   transfers have been observed ([`WARM_TRANSFER_OBS`]) it degrades
//!   gracefully to the `cost` model's byte heuristic, verdict-for-verdict.
//!
//! The per-node signals the push hot path reads (bandwidth EWMAs, the
//! global duration EWMA) are plain atomics — no lock is ever taken while
//! routing. The per-task-type duration map sits behind an `RwLock` that is
//! written once per task completion and read at most once per placement
//! decision.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::placement::{
    resident_per_node, with_scores, CostPlacement, PlacementModel, PlacementSignals,
};
use super::registry::NodeId;
use super::scheduler::ReadyTask;

/// Weight of the newest observation in every EWMA. At 0.25 an
/// observation's influence halves in ~2.4 samples — fast enough to track a
/// mid-run bandwidth shift, slow enough to ride out a single outlier.
pub const EWMA_ALPHA: f64 = 0.25;

/// Destination slots tracked. Nodes map as `node.0 % FEEDBACK_SLOTS`, so
/// this sink reads and writes one consistent slot per node for any
/// cluster up to 64 nodes (larger clusters alias slots — an approximation,
/// never an out-of-bounds access). Placement only ever queries real node
/// indices in `0..nodes`.
const FEEDBACK_SLOTS: usize = 64;

/// Completed-transfer observations required before [`AdaptivePlacement`]
/// trusts its time estimates; below this it delegates to the `cost` byte
/// heuristic (cold start).
pub const WARM_TRANSFER_OBS: u64 = 3;

/// Seconds charged per queued task until any duration has been observed.
const DEFAULT_TASK_SECONDS: f64 = 1e-3;

/// Lock-free (on the read/route path) runtime-observation sink shared by
/// the mover threads, the executor, the simulator, and the `adaptive`
/// placement model.
pub struct FeedbackStats {
    /// Per-destination-slot bandwidth EWMA (bytes/second), stored as f64
    /// bits so movers on different nodes can fold observations in without
    /// a lock.
    bw: Vec<AtomicU64>,
    /// Observations per slot; 0 means the slot has no signal yet.
    bw_obs: Vec<AtomicU64>,
    /// Cross-destination bandwidth EWMA — the estimate for nodes without
    /// observations of their own.
    bw_all: AtomicU64,
    /// Completed-transfer observations (drives the warm gate).
    transfer_obs: AtomicU64,
    /// Global task-duration EWMA (seconds, f64 bits).
    task_all: AtomicU64,
    task_obs: AtomicU64,
    /// Per-task-type duration EWMAs. Written once per completion, read at
    /// most once per placement decision — every per-node hot signal above
    /// stays a plain atomic.
    per_type: RwLock<HashMap<String, f64>>,
    /// Per-*pair* bandwidth EWMAs (bytes/second, f64 bits), a flat
    /// `FEEDBACK_SLOTS × FEEDBACK_SLOTS` matrix indexed
    /// `(src % S) * S + dst % S`. Fed by the TCP transport's direct
    /// worker-to-worker ships, whose `ShipDone` acks carry bytes/wall-time
    /// measured *at the source* — the real src→dst link, not a
    /// coordinator-relative average.
    pair_bw: Vec<AtomicU64>,
    pair_obs: Vec<AtomicU64>,
    /// Total pair observations; 0 keeps [`AdaptivePlacement`] on its
    /// original per-destination scoring, bit-for-bit.
    pair_obs_total: AtomicU64,
}

impl FeedbackStats {
    pub fn new() -> FeedbackStats {
        FeedbackStats {
            bw: (0..FEEDBACK_SLOTS).map(|_| AtomicU64::new(0)).collect(),
            bw_obs: (0..FEEDBACK_SLOTS).map(|_| AtomicU64::new(0)).collect(),
            bw_all: AtomicU64::new(0),
            transfer_obs: AtomicU64::new(0),
            task_all: AtomicU64::new(0),
            task_obs: AtomicU64::new(0),
            per_type: RwLock::new(HashMap::new()),
            pair_bw: (0..FEEDBACK_SLOTS * FEEDBACK_SLOTS)
                .map(|_| AtomicU64::new(0))
                .collect(),
            pair_obs: (0..FEEDBACK_SLOTS * FEEDBACK_SLOTS)
                .map(|_| AtomicU64::new(0))
                .collect(),
            pair_obs_total: AtomicU64::new(0),
        }
    }

    /// Fold `sample` into the EWMA cell. `first` seeds the cell instead of
    /// decaying toward the zero-initialized bits. Two racing first
    /// observations can at worst under-weight one sample — benign, and the
    /// price of keeping the fold lock-free.
    fn fold(cell: &AtomicU64, first: bool, sample: f64) {
        let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            let next = if first {
                sample
            } else {
                EWMA_ALPHA * sample + (1.0 - EWMA_ALPHA) * f64::from_bits(bits)
            };
            Some(next.to_bits())
        });
    }

    fn slot(&self, node: NodeId) -> usize {
        (node.0 as usize) % self.bw.len()
    }

    /// Record one completed transfer of `bytes` serialized bytes toward
    /// `node` that took `seconds` of wall (live) or virtual (sim) time.
    pub fn record_transfer(&self, node: NodeId, bytes: u64, seconds: f64) {
        if bytes == 0 || !seconds.is_finite() {
            return;
        }
        let sample = bytes as f64 / seconds.max(1e-9);
        let slot = self.slot(node);
        let first = self.bw_obs[slot].fetch_add(1, Ordering::Relaxed) == 0;
        Self::fold(&self.bw[slot], first, sample);
        let first_all = self.transfer_obs.fetch_add(1, Ordering::Relaxed) == 0;
        Self::fold(&self.bw_all, first_all, sample);
    }

    /// Record one completed *direct* transfer over the `src → dst` link:
    /// `bytes` serialized bytes in `seconds` of wall time, measured at the
    /// source worker. Folds into the pair matrix only — the per-
    /// destination and global EWMAs keep their original meaning (the
    /// coordinator-observed staging throughput recorded by the movers), so
    /// a run without direct ships scores exactly as before.
    pub fn record_transfer_pair(&self, src: NodeId, dst: NodeId, bytes: u64, seconds: f64) {
        if bytes == 0 || !seconds.is_finite() {
            return;
        }
        let sample = bytes as f64 / seconds.max(1e-9);
        let slot = self.slot(src) * FEEDBACK_SLOTS + self.slot(dst);
        let first = self.pair_obs[slot].fetch_add(1, Ordering::Relaxed) == 0;
        Self::fold(&self.pair_bw[slot], first, sample);
        self.pair_obs_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Observed bandwidth over the `src → dst` link, if any direct ship
    /// has been measured on it.
    pub fn bandwidth_between(&self, src: NodeId, dst: NodeId) -> Option<f64> {
        let slot = self.slot(src) * FEEDBACK_SLOTS + self.slot(dst);
        if self.pair_obs[slot].load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some(f64::from_bits(self.pair_bw[slot].load(Ordering::Relaxed)))
    }

    /// Has any per-pair signal landed? Gates the pair-aware scoring
    /// branch in [`AdaptivePlacement`].
    pub fn has_pair_observations(&self) -> bool {
        self.pair_obs_total.load(Ordering::Relaxed) > 0
    }

    /// Record one execution of task type `ty` taking `seconds`.
    pub fn record_task(&self, ty: &str, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        let first = self.task_obs.fetch_add(1, Ordering::Relaxed) == 0;
        Self::fold(&self.task_all, first, seconds);
        let mut map = self.per_type.write().unwrap();
        match map.get_mut(ty) {
            Some(e) => *e = EWMA_ALPHA * seconds + (1.0 - EWMA_ALPHA) * *e,
            None => {
                map.insert(ty.to_string(), seconds);
            }
        }
    }

    /// Observed bandwidth toward `node` (bytes/s), if any observation has
    /// landed on its slot.
    pub fn bandwidth_toward(&self, node: NodeId) -> Option<f64> {
        let slot = self.slot(node);
        if self.bw_obs[slot].load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some(f64::from_bits(self.bw[slot].load(Ordering::Relaxed)))
    }

    /// Cross-destination bandwidth EWMA — the fallback estimate for nodes
    /// the movers have not reached yet.
    pub fn mean_bandwidth(&self) -> Option<f64> {
        if self.transfer_obs.load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some(f64::from_bits(self.bw_all.load(Ordering::Relaxed)))
    }

    /// Duration estimate for task type `ty`: the per-type EWMA when one
    /// exists, else the global EWMA, else a 1 ms default.
    pub fn task_seconds(&self, ty: &str) -> f64 {
        if let Some(d) = self.per_type.read().unwrap().get(ty) {
            return *d;
        }
        if self.task_obs.load(Ordering::Relaxed) > 0 {
            return f64::from_bits(self.task_all.load(Ordering::Relaxed));
        }
        DEFAULT_TASK_SECONDS
    }

    /// Completed-transfer observations folded in so far.
    pub fn transfer_observations(&self) -> u64 {
        self.transfer_obs.load(Ordering::Relaxed)
    }

    /// Has enough signal accumulated for time-based scoring?
    pub fn warm(&self) -> bool {
        self.transfer_observations() >= WARM_TRANSFER_OBS
    }
}

impl Default for FeedbackStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Feedback-driven placement: rank nodes by estimated *time to start
/// computing* instead of byte counts.
///
/// time(N) = (missing(N) − credit(N)) ÷ bandwidth(N) + depth(N) × dur(task)
///
/// where `missing(N)` is the task's input bytes without a replica on N,
/// `credit(N)` caps N's in-flight bytes at `missing(N)` (a replica already
/// moving does not need to move again), `bandwidth(N)` is the observed
/// EWMA toward N (falling back to the cross-node mean), and `dur(task)` is
/// the observed duration EWMA of this task's type (falling back to the
/// global mean, then to 1 ms). Ties break toward the shallower queue, then
/// the lower index — the model keeps no cursor, so two instances fed the
/// same observations produce identical verdict sequences (the live-vs-sim
/// equivalence property).
///
/// Once the TCP transport's direct ships have measured at least one real
/// `src → dst` link ([`FeedbackStats::record_transfer_pair`]), the move
/// term upgrades to *per-pair* pricing: each absent input is charged over
/// the best observed link from any node holding it, so the model sees the
/// actual topology (a slow cross-rack pair, a fast intra-node loopback)
/// instead of a per-destination average. Runs without direct ships never
/// enter that branch and score exactly as before.
///
/// Cold start: until [`WARM_TRANSFER_OBS`] transfers have been observed,
/// `place` delegates to an inner [`CostPlacement`], so `--router adaptive`
/// begins exactly as `--router cost` and only diverges once it has
/// evidence.
pub struct AdaptivePlacement {
    stats: Arc<FeedbackStats>,
    fallback: CostPlacement,
}

impl AdaptivePlacement {
    /// A model with a fresh, cold observation sink.
    pub fn new() -> AdaptivePlacement {
        Self::with_stats(Arc::new(FeedbackStats::new()))
    }

    /// Build around an existing sink. Tests share one sink between the
    /// live fabric's model and the sim router's model to pin warm-path
    /// placement equivalence.
    pub fn with_stats(stats: Arc<FeedbackStats>) -> AdaptivePlacement {
        AdaptivePlacement {
            stats,
            fallback: CostPlacement::new(),
        }
    }

    /// The model's observation sink.
    pub fn stats(&self) -> &Arc<FeedbackStats> {
        &self.stats
    }
}

impl Default for AdaptivePlacement {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementModel for AdaptivePlacement {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn feedback(&self) -> Option<Arc<FeedbackStats>> {
        Some(Arc::clone(&self.stats))
    }

    fn place(&self, task: &ReadyTask, nodes: usize, signals: &dyn PlacementSignals) -> usize {
        if !self.stats.warm() {
            return self.fallback.place(task, nodes, signals);
        }
        let total = task.total_bytes();
        let dur = self.stats.task_seconds(&task.type_name);
        // Pair-aware pricing only once a direct ship has actually been
        // measured: without pair signal the scoring below reduces to the
        // original per-destination math, verdict-for-verdict.
        let pair_aware = self.stats.has_pair_observations();
        with_scores(nodes, |resident| {
            resident_per_node(task, resident);
            let mut best: Option<(f64, usize, usize)> = None;
            for (i, res) in resident.iter().enumerate() {
                let node = NodeId(i as u32);
                if !signals.alive(node) {
                    continue;
                }
                let missing = total.saturating_sub(*res);
                let credit = signals.inflight_toward(node).min(missing);
                let move_s = if pair_aware {
                    // Price each absent input over the best observed link
                    // from any node already holding it, falling back to
                    // the destination's coordinator-observed EWMA. The
                    // in-flight credit scales the total proportionally —
                    // bytes already moving cost nothing more to move.
                    let mut secs = 0.0;
                    for (bytes, holders) in &task.inputs {
                        if holders.contains(&node) {
                            continue;
                        }
                        let bw = holders
                            .iter()
                            .filter_map(|h| self.stats.bandwidth_between(*h, node))
                            .fold(None::<f64>, |acc, b| Some(acc.map_or(b, |a| a.max(b))))
                            .or_else(|| self.stats.bandwidth_toward(node))
                            .or_else(|| self.stats.mean_bandwidth())
                            .unwrap_or(1.0)
                            .max(1.0);
                        secs += *bytes as f64 / bw;
                    }
                    if missing > 0 {
                        secs * ((missing - credit) as f64 / missing as f64)
                    } else {
                        0.0
                    }
                } else {
                    let bw = self
                        .stats
                        .bandwidth_toward(node)
                        .or_else(|| self.stats.mean_bandwidth())
                        .unwrap_or(1.0)
                        .max(1.0);
                    (missing - credit) as f64 / bw
                };
                let depth = signals.queue_depth(node);
                let score = move_s + depth as f64 * dur;
                let better = match &best {
                    None => true,
                    Some((bs, bd, _)) => score < *bs || (score == *bs && depth < *bd),
                };
                if better {
                    best = Some((score, depth, i));
                }
            }
            best.map(|(_, _, i)| i).unwrap_or(0)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dag::TaskId;
    use crate::coordinator::placement::{placement_by_name, NoSignals};

    fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs,
            type_name: "t".into(),
        }
    }

    /// Scriptable signals: fixed inflight/depth vectors.
    struct Stub {
        inflight: Vec<u64>,
        depth: Vec<usize>,
    }

    impl PlacementSignals for Stub {
        fn inflight_toward(&self, node: NodeId) -> u64 {
            self.inflight.get(node.0 as usize).copied().unwrap_or(0)
        }

        fn queue_depth(&self, node: NodeId) -> usize {
            self.depth.get(node.0 as usize).copied().unwrap_or(0)
        }
    }

    #[test]
    fn ewma_decays_deterministically() {
        let s = FeedbackStats::new();
        s.record_transfer(NodeId(1), 1000, 1.0); // 1000 B/s seed
        assert_eq!(s.bandwidth_toward(NodeId(1)), Some(1000.0));
        s.record_transfer(NodeId(1), 2000, 1.0); // 0.25*2000 + 0.75*1000
        assert_eq!(s.bandwidth_toward(NodeId(1)), Some(1250.0));
        s.record_transfer(NodeId(1), 1250, 1.0); // fixed point
        assert_eq!(s.bandwidth_toward(NodeId(1)), Some(1250.0));
        assert_eq!(s.bandwidth_toward(NodeId(0)), None, "no observation, no signal");
        assert_eq!(s.transfer_observations(), 3);
        // Task durations: the per-type EWMA decays the same way, and an
        // unseen type falls back to the global EWMA.
        s.record_task("gemm", 4.0);
        s.record_task("gemm", 8.0); // 0.25*8 + 0.75*4 = 5
        assert_eq!(s.task_seconds("gemm"), 5.0);
        s.record_task("tiny", 1.0); // global: 4 -> 5 -> 0.25*1 + 0.75*5 = 4
        assert_eq!(s.task_seconds("unseen"), 4.0);
        // Degenerate observations are discarded, not folded.
        s.record_transfer(NodeId(1), 0, 1.0);
        s.record_transfer(NodeId(1), 10, f64::NAN);
        assert_eq!(s.transfer_observations(), 3);
    }

    #[test]
    fn pair_ewma_records_and_queries_per_link() {
        let s = FeedbackStats::new();
        assert!(!s.has_pair_observations());
        assert_eq!(s.bandwidth_between(NodeId(1), NodeId(2)), None);
        s.record_transfer_pair(NodeId(1), NodeId(2), 1000, 1.0);
        s.record_transfer_pair(NodeId(1), NodeId(2), 2000, 1.0);
        assert_eq!(s.bandwidth_between(NodeId(1), NodeId(2)), Some(1250.0));
        // Directional: the reverse link has its own slot.
        assert_eq!(s.bandwidth_between(NodeId(2), NodeId(1)), None);
        assert!(s.has_pair_observations());
        // Pair samples never leak into the coordinator-staging EWMAs:
        // the warm gate and per-destination signals are untouched.
        assert_eq!(s.transfer_observations(), 0);
        assert_eq!(s.bandwidth_toward(NodeId(2)), None);
        // Degenerate samples are discarded.
        s.record_transfer_pair(NodeId(1), NodeId(2), 0, 1.0);
        s.record_transfer_pair(NodeId(1), NodeId(2), 10, f64::NAN);
        assert_eq!(s.bandwidth_between(NodeId(1), NodeId(2)), Some(1250.0));
    }

    #[test]
    fn pair_observations_price_the_real_link() {
        // Input lives on node 1; candidates are nodes 2 and 3. The
        // per-destination EWMAs see both the same, but measured direct
        // ships say the 1→3 link flies while 1→2 crawls: the pair-aware
        // branch must route to 3. A twin model without pair samples ties
        // the two and takes the lower index — the original behavior.
        struct DeadOneAliveRest;
        impl PlacementSignals for DeadOneAliveRest {
            fn inflight_toward(&self, _node: NodeId) -> u64 {
                0
            }
            fn queue_depth(&self, _node: NodeId) -> usize {
                0
            }
            fn alive(&self, node: NodeId) -> bool {
                node.0 >= 2
            }
        }
        let t = rt(1, vec![(1_000_000, vec![NodeId(1)])]);
        let plain = AdaptivePlacement::new();
        for _ in 0..3 {
            plain.stats().record_transfer(NodeId(2), 1_000, 1.0);
            plain.stats().record_transfer(NodeId(3), 1_000, 1.0);
        }
        assert_eq!(plain.place(&t, 4, &DeadOneAliveRest), 2);
        let paired = AdaptivePlacement::new();
        for _ in 0..3 {
            paired.stats().record_transfer(NodeId(2), 1_000, 1.0);
            paired.stats().record_transfer(NodeId(3), 1_000, 1.0);
        }
        paired.stats().record_transfer_pair(NodeId(1), NodeId(2), 1_000, 1.0); // 1 KB/s
        paired.stats().record_transfer_pair(NodeId(1), NodeId(3), 1 << 30, 1.0); // 1 GB/s
        assert_eq!(paired.place(&t, 4, &DeadOneAliveRest), 3);
    }

    #[test]
    fn out_of_range_nodes_wrap_to_one_slot() {
        let s = FeedbackStats::new();
        s.record_transfer(NodeId(FEEDBACK_SLOTS as u32 + 1), 500, 1.0);
        assert_eq!(s.bandwidth_toward(NodeId(1)), Some(500.0));
    }

    #[test]
    fn cold_start_falls_back_to_cost_verdicts() {
        let adaptive = AdaptivePlacement::new();
        let cost = placement_by_name("cost").unwrap();
        assert!(!adaptive.stats().warm());
        let tasks = [
            rt(1, vec![(100, vec![NodeId(0)]), (300, vec![NodeId(2)])]),
            rt(2, vec![]),
            rt(3, vec![(125, vec![NodeId(0)]), (875, vec![])]),
        ];
        let signals = Stub {
            inflight: vec![0, 400, 0],
            depth: vec![2, 0, 1],
        };
        for t in &tasks {
            assert_eq!(
                adaptive.place(t, 3, &signals),
                cost.place(t, 3, &signals),
                "cold adaptive must be verdict-identical to cost"
            );
        }
    }

    #[test]
    fn bandwidth_skew_flips_the_byte_verdict() {
        // `cost` chases the fewest missing bytes (node 0); observed
        // bandwidth says node 0's link crawls while node 1's flies, so the
        // adaptive model routes where the *time* is lower — node 1. This is
        // the mid-run regression: stub observations flip the verdict away
        // from the byte heuristic.
        let adaptive = AdaptivePlacement::new();
        adaptive.stats().record_transfer(NodeId(0), 10_000, 10.0); // 1 KB/s
        adaptive.stats().record_transfer(NodeId(1), 1 << 30, 1.0); // 1 GB/s
        adaptive.stats().record_transfer(NodeId(1), 1 << 30, 1.0);
        assert!(adaptive.stats().warm());
        let t = rt(1, vec![(800, vec![NodeId(0)]), (200, vec![NodeId(1)])]);
        assert_eq!(placement_by_name("cost").unwrap().place(&t, 2, &NoSignals), 0);
        assert_eq!(adaptive.place(&t, 2, &NoSignals), 1);
    }

    #[test]
    fn observed_durations_price_queue_depth() {
        // A locality edge worth 0.1 s of movement loses to an idle node
        // once two queued ~1 s tasks are priced in; with an idle home the
        // resident bytes win outright.
        let adaptive = AdaptivePlacement::new();
        for _ in 0..3 {
            adaptive.stats().record_transfer(NodeId(0), 1_000, 1.0); // 1 KB/s
        }
        adaptive.stats().record_task("t", 1.0);
        let t = rt(1, vec![(100, vec![NodeId(0)])]);
        let busy = Stub {
            inflight: vec![0, 0],
            depth: vec![2, 0],
        };
        assert_eq!(adaptive.place(&t, 2, &busy), 1);
        let idle = Stub {
            inflight: vec![0, 0],
            depth: vec![0, 0],
        };
        assert_eq!(adaptive.place(&t, 2, &idle), 0);
    }

    #[test]
    fn inflight_credit_erases_move_time() {
        // Bytes already moving toward node 1 cost nothing more to move:
        // the adaptive model rides the prefetcher exactly as `cost` does.
        let adaptive = AdaptivePlacement::new();
        for _ in 0..3 {
            adaptive.stats().record_transfer(NodeId(0), 1_000, 1.0);
        }
        let t = rt(1, vec![(1000, vec![NodeId(0)])]);
        let signals = Stub {
            inflight: vec![0, 1000],
            depth: vec![1, 0],
        };
        assert_eq!(adaptive.place(&t, 2, &signals), 1);
    }

    #[test]
    fn warm_adaptive_skips_dead_nodes() {
        // Node 0 has the fastest observed link and all the resident bytes,
        // but is dead: the warm scorer must not pick it.
        struct DeadZero;
        impl PlacementSignals for DeadZero {
            fn inflight_toward(&self, _node: NodeId) -> u64 {
                0
            }
            fn queue_depth(&self, _node: NodeId) -> usize {
                0
            }
            fn alive(&self, node: NodeId) -> bool {
                node.0 != 0
            }
        }
        let adaptive = AdaptivePlacement::new();
        for _ in 0..3 {
            adaptive.stats().record_transfer(NodeId(0), 1 << 30, 1.0);
        }
        assert!(adaptive.stats().warm());
        let t = rt(1, vec![(1000, vec![NodeId(0)])]);
        assert_eq!(adaptive.place(&t, 2, &DeadZero), 1);
    }

    #[test]
    fn by_name_constructs_adaptive_with_its_own_sink() {
        let m = placement_by_name("adaptive").unwrap();
        assert_eq!(m.name(), "adaptive");
        let fb = m.feedback().expect("adaptive exposes its sink");
        assert!(!fb.warm());
        assert!(placement_by_name("cost").unwrap().feedback().is_none());
        assert!(placement_by_name("bytes").unwrap().feedback().is_none());
    }
}
