//! The dynamic task dependency graph.
//!
//! Tasks enter the graph at submission time with the dependency edges the
//! registry reported (RAW from producers, WAR from readers, WAW from prior
//! writers). The graph maintains the ready frontier as tasks complete, and
//! exports Graphviz DOT with `dXvY` edge labels reproducing the paper's
//! Figures 2-5.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::coordinator::registry::{DataKey, NodeId};

/// Task identity, in submission order (node "1", "2", ... in Figure 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// Why an edge exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Read-after-write: true dataflow.
    Raw,
    /// Write-after-read: version renaming makes this ordering-only.
    War,
    /// Write-after-write.
    Waw,
}

impl fmt::Display for EdgeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            EdgeKind::Raw => "RAW",
            EdgeKind::War => "WAR",
            EdgeKind::Waw => "WAW",
        };
        write!(f, "{s}")
    }
}

/// Lifecycle of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting on dependencies.
    Pending,
    /// All dependencies satisfied; queued at the scheduler.
    Ready,
    /// Claimed by a worker.
    Running,
    /// Finished successfully.
    Done,
    /// Failed after exhausting resubmissions.
    Failed,
    /// A transitive dependency failed; will never run.
    Cancelled,
}

/// A directed dependency edge.
#[derive(Clone, Debug, PartialEq)]
pub struct Edge {
    pub from: TaskId,
    pub to: TaskId,
    pub kind: EdgeKind,
    /// The datum version that carries the dependency (for DOT labels).
    pub key: DataKey,
}

/// Graph node.
#[derive(Clone, Debug)]
pub struct TaskNode {
    pub id: TaskId,
    /// Task type name ("KNN_frag", "partial_sum", ...). Drives trace colors
    /// and DOT shapes.
    pub type_name: String,
    pub state: TaskState,
    /// Input versions this task reads (for locality decisions).
    pub reads: Vec<DataKey>,
    /// Output versions this task produces.
    pub writes: Vec<DataKey>,
    /// Remaining unfinished dependencies.
    pub pending_deps: usize,
    /// Tasks waiting on this one.
    pub dependents: Vec<TaskId>,
    /// Execution attempts so far (fault tolerance).
    pub attempts: u32,
    /// Node the final failed attempt ran on (root-cause reporting).
    pub failed_on: Option<NodeId>,
    /// Error message of the final failed attempt.
    pub fail_error: Option<String>,
    /// For cancelled tasks: the permanently-failed ancestor that doomed
    /// them (root-cause reporting for `wait_on`/`barrier`).
    pub cancelled_by: Option<TaskId>,
}

/// The task graph.
#[derive(Debug, Default)]
pub struct TaskGraph {
    next_id: u64,
    nodes: HashMap<TaskId, TaskNode>,
    edges: Vec<Edge>,
    /// Insertion order, for deterministic DOT output and iteration.
    order: Vec<TaskId>,
    done_count: usize,
    failed_count: usize,
    cancelled_count: usize,
    /// First task to fail permanently — the root cause reported by
    /// `wait_on`/`barrier` errors.
    first_failed: Option<TaskId>,
}

impl TaskGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate the next task id (submission order).
    pub fn next_task_id(&mut self) -> TaskId {
        self.next_id += 1;
        TaskId(self.next_id)
    }

    /// Insert a task with its dependency edges. `deps` pairs each
    /// predecessor with the edge kind and carrying datum. Duplicate
    /// predecessors are collapsed (a task depending on the same producer
    /// through three arguments still has one pending-dep).
    ///
    /// Returns `true` if the task is immediately ready.
    pub fn insert_task(
        &mut self,
        id: TaskId,
        type_name: &str,
        reads: Vec<DataKey>,
        writes: Vec<DataKey>,
        deps: Vec<(TaskId, EdgeKind, DataKey)>,
    ) -> bool {
        let mut uniq: HashSet<TaskId> = HashSet::new();
        let mut pending = 0usize;
        for (from, kind, key) in deps {
            debug_assert!(from != id, "self-dependency on {id}");
            // Edges to finished predecessors don't gate readiness but are
            // kept for the DOT view. `uniq` collapses multi-edge
            // predecessors so `pending_deps` and the dependent list agree:
            // one unfinished predecessor == one pending count == one
            // dependent entry (complete() decrements exactly once).
            let from_state = self.nodes.get(&from).map(|n| n.state);
            self.edges.push(Edge { from, to: id, kind, key });
            if uniq.insert(from) {
                match from_state {
                    Some(TaskState::Done) => {}
                    Some(TaskState::Failed) | Some(TaskState::Cancelled) => {
                        // Dependency already failed: this task can never run
                        // (the `dead` sweep below cancels it). Keep pending
                        // >0 so it is never scheduled; do not register a
                        // dependent (failed tasks never complete()).
                        pending += 1;
                    }
                    _ => {
                        pending += 1;
                        if let Some(n) = self.nodes.get_mut(&from) {
                            n.dependents.push(id);
                        }
                    }
                }
            }
        }
        let ready = pending == 0;
        self.nodes.insert(
            id,
            TaskNode {
                id,
                type_name: type_name.to_string(),
                state: if ready { TaskState::Ready } else { TaskState::Pending },
                reads,
                writes,
                pending_deps: pending,
                dependents: Vec::new(),
                attempts: 0,
                failed_on: None,
                fail_error: None,
                cancelled_by: None,
            },
        );
        self.order.push(id);
        // If any predecessor already failed, cancel immediately (naming
        // the failed ancestor as the root cause).
        let dead_root = self.edges.iter().find_map(|e| {
            if e.to != id {
                return None;
            }
            match self.nodes.get(&e.from) {
                Some(n) if n.state == TaskState::Failed => Some(n.id),
                Some(n) if n.state == TaskState::Cancelled => {
                    Some(n.cancelled_by.unwrap_or(n.id))
                }
                _ => None,
            }
        });
        if let Some(root) = dead_root {
            self.cancel(id, Some(root));
            return false;
        }
        ready
    }

    /// Mark a ready task as claimed by a worker.
    pub fn start(&mut self, id: TaskId) {
        let n = self.nodes.get_mut(&id).expect("start of unknown task");
        debug_assert_eq!(n.state, TaskState::Ready, "start on non-ready {id}");
        n.state = TaskState::Running;
        n.attempts += 1;
    }

    /// Put a running task back in the ready state (resubmission).
    pub fn resubmit(&mut self, id: TaskId) {
        let n = self.nodes.get_mut(&id).expect("resubmit of unknown task");
        debug_assert_eq!(n.state, TaskState::Running);
        n.state = TaskState::Ready;
    }

    /// Complete a running task; returns the dependents that became ready.
    pub fn complete(&mut self, id: TaskId) -> Vec<TaskId> {
        let dependents = {
            let n = self.nodes.get_mut(&id).expect("complete of unknown task");
            debug_assert_eq!(n.state, TaskState::Running, "complete on non-running {id}");
            n.state = TaskState::Done;
            std::mem::take(&mut n.dependents)
        };
        self.done_count += 1;
        let mut newly_ready = Vec::new();
        for dep in dependents {
            let n = self.nodes.get_mut(&dep).expect("dependent missing");
            n.pending_deps -= 1;
            if n.pending_deps == 0 && n.state == TaskState::Pending {
                n.state = TaskState::Ready;
                newly_ready.push(dep);
            }
        }
        newly_ready
    }

    /// Retire a task the window compiler culled — its outputs are provably
    /// never consumed — without executing it. The task counts as Done, so
    /// quiescence accounting and ordering-only (WAR/WAW) dependents behave
    /// exactly as if it had run; returns the dependents that became ready.
    /// Only undispatched tasks may be culled: the compiler decides at
    /// window flush, before the window's first enqueue, so the claim-path
    /// Running assertion of [`TaskGraph::complete`] is replaced by a
    /// Pending/Ready one. A culled task that still has unfinished
    /// predecessors is safe: later `complete`/`cull` calls decrement its
    /// `pending_deps` but skip the Done state.
    pub fn cull(&mut self, id: TaskId) -> Vec<TaskId> {
        let dependents = {
            let n = self.nodes.get_mut(&id).expect("cull of unknown task");
            debug_assert!(
                matches!(n.state, TaskState::Pending | TaskState::Ready),
                "cull on dispatched {id}"
            );
            n.state = TaskState::Done;
            std::mem::take(&mut n.dependents)
        };
        self.done_count += 1;
        let mut newly_ready = Vec::new();
        for dep in dependents {
            let n = self.nodes.get_mut(&dep).expect("dependent missing");
            n.pending_deps -= 1;
            if n.pending_deps == 0 && n.state == TaskState::Pending {
                n.state = TaskState::Ready;
                newly_ready.push(dep);
            }
        }
        newly_ready
    }

    /// Mark a running task as permanently failed; transitively cancels
    /// everything downstream. Returns the cancelled set.
    pub fn fail(&mut self, id: TaskId) -> Vec<TaskId> {
        self.fail_with(id, None, "")
    }

    /// [`TaskGraph::fail`], recording the node the final attempt ran on
    /// and its error so `wait_on`/`barrier` can report the root cause.
    /// Every cancelled dependent names `id` as its failed ancestor.
    pub fn fail_with(&mut self, id: TaskId, node: Option<NodeId>, error: &str) -> Vec<TaskId> {
        {
            let n = self.nodes.get_mut(&id).expect("fail of unknown task");
            n.state = TaskState::Failed;
            n.failed_on = node;
            if !error.is_empty() {
                n.fail_error = Some(error.to_string());
            }
        }
        self.failed_count += 1;
        if self.first_failed.is_none() {
            self.first_failed = Some(id);
        }
        let mut cancelled = Vec::new();
        let mut stack: Vec<TaskId> = self
            .nodes
            .get(&id)
            .map(|n| n.dependents.clone())
            .unwrap_or_default();
        while let Some(t) = stack.pop() {
            let n = self.nodes.get_mut(&t).expect("dependent missing");
            if matches!(n.state, TaskState::Pending | TaskState::Ready) {
                n.state = TaskState::Cancelled;
                n.cancelled_by = Some(id);
                self.cancelled_count += 1;
                cancelled.push(t);
                stack.extend(n.dependents.clone());
            }
        }
        cancelled
    }

    fn cancel(&mut self, id: TaskId, root: Option<TaskId>) {
        if let Some(n) = self.nodes.get_mut(&id) {
            if n.state != TaskState::Cancelled {
                n.state = TaskState::Cancelled;
                n.cancelled_by = root;
                self.cancelled_count += 1;
            }
        }
    }

    /// Reopen a set of completed tasks for lineage re-execution after
    /// node loss. States flip Done → Pending, intra-set dependency counts
    /// and dependent lists are rebuilt (`complete` drained them), and
    /// downstream tasks outside the set that have not started yet are
    /// re-gated so they wait for the fresh outputs (a re-gated Ready task
    /// leaves a stale queue entry behind; the executor's claim-time state
    /// check discards it). Returns the reopened tasks that are
    /// immediately ready.
    pub fn reopen(&mut self, ids: &HashSet<TaskId>) -> Vec<TaskId> {
        for id in ids {
            let n = self.nodes.get_mut(id).expect("reopen of unknown task");
            debug_assert_eq!(n.state, TaskState::Done, "reopen of non-done {id}");
            n.state = TaskState::Pending;
            n.pending_deps = 0;
            self.done_count -= 1;
        }
        // One gate per distinct (producer-in-set → consumer) pair:
        // consumers inside the set re-run after their producers; Pending/
        // Ready consumers outside it must wait for the fresh output too.
        let mut pairs: Vec<(TaskId, TaskId)> = Vec::new();
        let mut seen: HashSet<(TaskId, TaskId)> = HashSet::new();
        for e in &self.edges {
            if !ids.contains(&e.from) || e.from == e.to {
                continue;
            }
            let gates = ids.contains(&e.to)
                || matches!(
                    self.nodes.get(&e.to).map(|n| n.state),
                    Some(TaskState::Pending) | Some(TaskState::Ready)
                );
            if gates && seen.insert((e.from, e.to)) {
                pairs.push((e.from, e.to));
            }
        }
        for (from, to) in pairs {
            self.nodes
                .get_mut(&from)
                .expect("reopened producer")
                .dependents
                .push(to);
            let n = self.nodes.get_mut(&to).expect("re-gated consumer");
            n.pending_deps += 1;
            if n.state == TaskState::Ready {
                n.state = TaskState::Pending;
            }
        }
        let mut ready = Vec::new();
        for id in ids {
            let n = self.nodes.get_mut(id).expect("reopened task");
            if n.state == TaskState::Pending && n.pending_deps == 0 {
                n.state = TaskState::Ready;
                ready.push(*id);
            }
        }
        ready.sort_unstable();
        ready
    }

    /// The first permanently-failed task, for root-cause error reporting.
    pub fn root_failure(&self) -> Option<&TaskNode> {
        self.first_failed.and_then(|id| self.nodes.get(&id))
    }

    /// Human-readable root-cause blurb for a failed task:
    /// `t7 (knn_partial, 3 attempts, node 1): <error>`.
    pub fn failure_blurb(&self, id: TaskId) -> String {
        match self.nodes.get(&id) {
            Some(n) => {
                let node = n
                    .failed_on
                    .map(|nd| format!("node {}", nd.0))
                    .unwrap_or_else(|| "unknown node".to_string());
                let mut s =
                    format!("{} ({}, {} attempt(s), {})", n.id, n.type_name, n.attempts, node);
                if let Some(e) = &n.fail_error {
                    s.push_str(": ");
                    s.push_str(e);
                }
                s
            }
            None => id.to_string(),
        }
    }

    // ---- queries -----------------------------------------------------------

    pub fn node(&self, id: TaskId) -> Option<&TaskNode> {
        self.nodes.get(&id)
    }

    pub fn state(&self, id: TaskId) -> Option<TaskState> {
        self.nodes.get(&id).map(|n| n.state)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn done_count(&self) -> usize {
        self.done_count
    }

    pub fn failed_count(&self) -> usize {
        self.failed_count
    }

    pub fn cancelled_count(&self) -> usize {
        self.cancelled_count
    }

    /// All tasks have reached a terminal state.
    pub fn quiescent(&self) -> bool {
        self.done_count + self.failed_count + self.cancelled_count == self.nodes.len()
    }

    pub fn tasks_in_order(&self) -> impl Iterator<Item = &TaskNode> {
        self.order.iter().map(move |id| &self.nodes[id])
    }

    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Length of the critical path (in tasks) — the depth bound on
    /// parallel speedup the paper invokes to explain linear regression's
    /// weaker scaling ("deeper task dependencies").
    pub fn critical_path_len(&self) -> usize {
        let mut depth: HashMap<TaskId, usize> = HashMap::new();
        let mut best = 0usize;
        // `order` is a topological order: dependencies are always submitted
        // before dependents in a superscalar runtime.
        let mut preds: HashMap<TaskId, Vec<TaskId>> = HashMap::new();
        for e in &self.edges {
            preds.entry(e.to).or_default().push(e.from);
        }
        for id in &self.order {
            let d = preds
                .get(id)
                .map(|ps| ps.iter().filter_map(|p| depth.get(p)).max().copied().unwrap_or(0))
                .unwrap_or(0)
                + 1;
            depth.insert(*id, d);
            best = best.max(d);
        }
        best
    }

    // ---- DOT export (Figures 2-5) -------------------------------------------

    /// Graphviz DOT with the paper's visual vocabulary: one node per task
    /// (colored by task type), `main` and `sync` pseudo-nodes, and edges
    /// labeled with the carrying `dXvY`.
    pub fn to_dot(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str("digraph RCOMPSs {\n");
        out.push_str(&format!("  label=\"{title}\";\n"));
        out.push_str("  rankdir=TB;\n  node [style=filled, fontname=\"Helvetica\"];\n");
        out.push_str("  main [shape=box, fillcolor=lightgray];\n");
        out.push_str("  sync [shape=octagon, fillcolor=red, fontcolor=white];\n");

        // Stable color per task type, matching the paper's palette where
        // the type names match (fill=blue, frag/partial=white, merge=red,
        // classify/pred=pink/yellow...).
        let palette = [
            ("fill", "steelblue"),
            ("frag", "white"),
            ("partial_sum", "white"),
            ("partial_ztz", "indianred"),
            ("partial_zty", "lightpink"),
            ("merge", "firebrick"),
            ("classify", "pink"),
            ("compute_model_parameters", "green3"),
            ("genpred", "white"),
            ("compute_prediction", "gold"),
        ];
        let color_of = |ty: &str| -> &'static str {
            for (pat, color) in palette {
                if ty.contains(pat) {
                    return color;
                }
            }
            "lightyellow"
        };

        let has_preds: HashSet<TaskId> = self.edges.iter().map(|e| e.to).collect();
        let has_succs: HashSet<TaskId> = self.edges.iter().map(|e| e.from).collect();

        for n in self.tasks_in_order() {
            out.push_str(&format!(
                "  {} [label=\"{}\\n{}\", fillcolor=\"{}\"];\n",
                n.id.0,
                n.id.0,
                n.type_name,
                color_of(&n.type_name)
            ));
            if !has_preds.contains(&n.id) {
                out.push_str(&format!("  main -> {};\n", n.id.0));
            }
            if !has_succs.contains(&n.id) {
                out.push_str(&format!("  {} -> sync;\n", n.id.0));
            }
        }
        for e in &self.edges {
            let style = match e.kind {
                EdgeKind::Raw => "solid",
                EdgeKind::War => "dashed",
                EdgeKind::Waw => "dotted",
            };
            out.push_str(&format!(
                "  {} -> {} [label=\"{}\", style={}];\n",
                e.from.0, e.to.0, e.key, style
            ));
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::{DataId, DataKey};

    fn key(d: u64, v: u32) -> DataKey {
        DataKey {
            data: DataId(d),
            version: v,
        }
    }

    /// Build the Figure-2 diamond: t1, t2 independent; t3 reads both.
    fn diamond() -> (TaskGraph, TaskId, TaskId, TaskId) {
        let mut g = TaskGraph::new();
        let t1 = g.next_task_id();
        assert!(g.insert_task(t1, "add", vec![], vec![key(1, 1)], vec![]));
        let t2 = g.next_task_id();
        assert!(g.insert_task(t2, "add", vec![], vec![key(2, 1)], vec![]));
        let t3 = g.next_task_id();
        let ready = g.insert_task(
            t3,
            "add",
            vec![key(1, 1), key(2, 1)],
            vec![key(3, 1)],
            vec![(t1, EdgeKind::Raw, key(1, 1)), (t2, EdgeKind::Raw, key(2, 1))],
        );
        assert!(!ready);
        (g, t1, t2, t3)
    }

    #[test]
    fn readiness_propagates_on_completion() {
        let (mut g, t1, t2, t3) = diamond();
        g.start(t1);
        assert!(g.complete(t1).is_empty());
        g.start(t2);
        assert_eq!(g.complete(t2), vec![t3]);
        assert_eq!(g.state(t3), Some(TaskState::Ready));
        g.start(t3);
        g.complete(t3);
        assert!(g.quiescent());
        assert_eq!(g.done_count(), 3);
    }

    #[test]
    fn duplicate_predecessor_counts_once() {
        let mut g = TaskGraph::new();
        let t1 = g.next_task_id();
        g.insert_task(t1, "p", vec![], vec![key(1, 1), key(2, 1)], vec![]);
        let t2 = g.next_task_id();
        g.insert_task(
            t2,
            "c",
            vec![key(1, 1), key(2, 1)],
            vec![],
            vec![(t1, EdgeKind::Raw, key(1, 1)), (t1, EdgeKind::Raw, key(2, 1))],
        );
        g.start(t1);
        assert_eq!(g.complete(t1), vec![t2]);
    }

    #[test]
    fn dep_on_done_task_is_satisfied() {
        let mut g = TaskGraph::new();
        let t1 = g.next_task_id();
        g.insert_task(t1, "p", vec![], vec![key(1, 1)], vec![]);
        g.start(t1);
        g.complete(t1);
        let t2 = g.next_task_id();
        let ready = g.insert_task(t2, "c", vec![key(1, 1)], vec![], vec![(
            t1,
            EdgeKind::Raw,
            key(1, 1),
        )]);
        assert!(ready, "dependency on finished task must not block");
    }

    #[test]
    fn failure_cancels_downstream_transitively() {
        let (mut g, t1, t2, t3) = diamond();
        let t4 = g.next_task_id();
        g.insert_task(t4, "sink", vec![key(3, 1)], vec![], vec![(
            t3,
            EdgeKind::Raw,
            key(3, 1),
        )]);
        g.start(t1);
        let cancelled = g.fail(t1);
        assert!(cancelled.contains(&t3));
        assert!(cancelled.contains(&t4));
        assert_eq!(g.state(t3), Some(TaskState::Cancelled));
        // t2 is unaffected.
        assert_eq!(g.state(t2), Some(TaskState::Ready));
        g.start(t2);
        g.complete(t2);
        assert!(g.quiescent());
    }

    #[test]
    fn resubmit_returns_to_ready() {
        let (mut g, t1, _, _) = diamond();
        g.start(t1);
        g.resubmit(t1);
        assert_eq!(g.state(t1), Some(TaskState::Ready));
        g.start(t1);
        assert_eq!(g.node(t1).unwrap().attempts, 2);
        g.complete(t1);
    }

    #[test]
    fn critical_path_of_diamond_is_two() {
        let (g, ..) = diamond();
        assert_eq!(g.critical_path_len(), 2);
    }

    #[test]
    fn chain_critical_path_equals_length() {
        let mut g = TaskGraph::new();
        let mut prev: Option<TaskId> = None;
        for i in 0..10 {
            let t = g.next_task_id();
            let deps = prev
                .map(|p| vec![(p, EdgeKind::Raw, key(i, 1))])
                .unwrap_or_default();
            g.insert_task(t, "link", vec![], vec![], deps);
            prev = Some(t);
        }
        assert_eq!(g.critical_path_len(), 10);
    }

    #[test]
    fn dot_contains_paper_vocabulary() {
        let (g, ..) = diamond();
        let dot = g.to_dot("add four numbers");
        assert!(dot.contains("main ->"));
        assert!(dot.contains("-> sync"));
        assert!(dot.contains("d1v1"));
        assert!(dot.contains("digraph RCOMPSs"));
    }

    #[test]
    fn fail_with_records_root_cause_and_cancelled_name_ancestor() {
        let (mut g, t1, _t2, t3) = diamond();
        let t4 = g.next_task_id();
        g.insert_task(t4, "sink", vec![key(3, 1)], vec![], vec![(
            t3,
            EdgeKind::Raw,
            key(3, 1),
        )]);
        g.start(t1);
        let cancelled = g.fail_with(t1, Some(NodeId(2)), "boom");
        assert!(cancelled.contains(&t3) && cancelled.contains(&t4));
        let root = g.root_failure().expect("root failure recorded");
        assert_eq!(root.id, t1);
        assert_eq!(root.failed_on, Some(NodeId(2)));
        assert_eq!(root.fail_error.as_deref(), Some("boom"));
        assert_eq!(g.node(t3).unwrap().cancelled_by, Some(t1));
        assert_eq!(g.node(t4).unwrap().cancelled_by, Some(t1));
        let blurb = g.failure_blurb(t1);
        assert!(blurb.contains("t1") && blurb.contains("add"));
        assert!(blurb.contains("node 2") && blurb.contains("boom"));
        // A task submitted under the cancelled t3 names t1 too.
        let t5 = g.next_task_id();
        g.insert_task(t5, "late", vec![key(3, 1)], vec![], vec![(
            t3,
            EdgeKind::Raw,
            key(3, 1),
        )]);
        assert_eq!(g.node(t5).unwrap().cancelled_by, Some(t1));
    }

    #[test]
    fn reopen_replays_a_done_subgraph_in_dependency_order() {
        // t1 -> t3 <- t2, plus t4 reading t3: run everything, then reopen
        // {t1, t3} (t1's output was lost, t3 consumed it).
        let (mut g, t1, t2, t3) = diamond();
        let t4 = g.next_task_id();
        g.insert_task(t4, "sink", vec![key(3, 1)], vec![], vec![(
            t3,
            EdgeKind::Raw,
            key(3, 1),
        )]);
        for t in [t1, t2, t3, t4] {
            g.start(t);
            g.complete(t);
        }
        assert_eq!(g.done_count(), 4);
        let ids: HashSet<TaskId> = [t1, t3].into_iter().collect();
        let ready = g.reopen(&ids);
        assert_eq!(ready, vec![t1], "only the root of the lost subgraph");
        assert_eq!(g.state(t1), Some(TaskState::Ready));
        assert_eq!(g.state(t3), Some(TaskState::Pending));
        assert_eq!(g.state(t2), Some(TaskState::Done), "t2 untouched");
        assert_eq!(g.state(t4), Some(TaskState::Done), "done consumers untouched");
        assert_eq!(g.done_count(), 2);
        // Replay drives the normal readiness propagation.
        g.start(t1);
        assert_eq!(g.complete(t1), vec![t3]);
        g.start(t3);
        assert!(g.complete(t3).is_empty(), "t4 is already done");
        assert!(g.quiescent());
        assert_eq!(g.done_count(), 4);
    }

    #[test]
    fn reopen_regates_unstarted_downstream_consumers() {
        // t1 done, t2 (reads t1's output) still Ready and queued: reopening
        // t1 must pull t2 back to Pending until the fresh output lands.
        let mut g = TaskGraph::new();
        let t1 = g.next_task_id();
        g.insert_task(t1, "p", vec![], vec![key(1, 1)], vec![]);
        g.start(t1);
        g.complete(t1);
        let t2 = g.next_task_id();
        assert!(g.insert_task(t2, "c", vec![key(1, 1)], vec![], vec![(
            t1,
            EdgeKind::Raw,
            key(1, 1),
        )]));
        assert_eq!(g.state(t2), Some(TaskState::Ready));
        let ids: HashSet<TaskId> = [t1].into_iter().collect();
        let ready = g.reopen(&ids);
        assert_eq!(ready, vec![t1]);
        assert_eq!(g.state(t2), Some(TaskState::Pending), "re-gated");
        g.start(t1);
        assert_eq!(g.complete(t1), vec![t2], "t2 becomes ready again");
        g.start(t2);
        g.complete(t2);
        assert!(g.quiescent());
    }

    #[test]
    fn cull_counts_as_done_and_unblocks_ordering_dependents() {
        // t1 (Ready) is culled before dispatch; t3, gated on t1 and t2,
        // must become ready once t2 completes — exactly as if t1 ran.
        let (mut g, t1, t2, t3) = diamond();
        assert!(g.cull(t1).is_empty());
        assert_eq!(g.state(t1), Some(TaskState::Done));
        g.start(t2);
        assert_eq!(g.complete(t2), vec![t3]);
        g.start(t3);
        g.complete(t3);
        assert!(g.quiescent());
        assert_eq!(g.done_count(), 3);
        // A Pending task whose predecessor already vanished via cull: cull
        // cascades — culling the consumer first, then the producer, must
        // not underflow the consumer's pending count.
        let mut g2 = TaskGraph::new();
        let p = g2.next_task_id();
        g2.insert_task(p, "p", vec![], vec![key(9, 1)], vec![]);
        let c = g2.next_task_id();
        g2.insert_task(c, "c", vec![key(9, 1)], vec![], vec![(p, EdgeKind::Raw, key(9, 1))]);
        assert!(g2.cull(c).is_empty(), "Pending consumer culled first");
        assert!(g2.cull(p).is_empty(), "Done consumer is not re-readied");
        assert!(g2.quiescent());
    }

    #[test]
    fn submitting_under_failed_dependency_cancels_immediately() {
        let mut g = TaskGraph::new();
        let t1 = g.next_task_id();
        g.insert_task(t1, "p", vec![], vec![key(1, 1)], vec![]);
        g.start(t1);
        g.fail(t1);
        let t2 = g.next_task_id();
        let ready = g.insert_task(t2, "c", vec![key(1, 1)], vec![], vec![(
            t1,
            EdgeKind::Raw,
            key(1, 1),
        )]);
        assert!(!ready);
        assert_eq!(g.state(t2), Some(TaskState::Cancelled));
    }
}
