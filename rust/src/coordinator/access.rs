//! Parameter access directions.
//!
//! COMPSs' dependency detection is driven by how each task parameter is
//! accessed: inputs create read-after-write dependencies on the last
//! producer, outputs create write-after-read/write-after-write dependencies
//! and bump the datum's version. RCOMPSs derives directions from the R
//! function signature (arguments are IN, return values are OUT); the
//! binding-commons API also supports INOUT, which we keep for generality.

use std::fmt;

/// How a task accesses one parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    /// Read-only: the task consumes the current version.
    In,
    /// Write-only: the task produces a fresh version; prior content unread.
    Out,
    /// Read-modify-write: consumes the current version, produces the next.
    InOut,
}

impl Direction {
    pub fn reads(self) -> bool {
        matches!(self, Direction::In | Direction::InOut)
    }

    pub fn writes(self) -> bool {
        matches!(self, Direction::Out | Direction::InOut)
    }
}

impl fmt::Display for Direction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Direction::In => "IN",
            Direction::Out => "OUT",
            Direction::InOut => "INOUT",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_predicates() {
        assert!(Direction::In.reads() && !Direction::In.writes());
        assert!(!Direction::Out.reads() && Direction::Out.writes());
        assert!(Direction::InOut.reads() && Direction::InOut.writes());
    }

    #[test]
    fn display_matches_compss_vocabulary() {
        assert_eq!(Direction::In.to_string(), "IN");
        assert_eq!(Direction::Out.to_string(), "OUT");
        assert_eq!(Direction::InOut.to_string(), "INOUT");
    }
}
