//! The COMPSs-style coordination core — the paper's contribution.
//!
//! RCOMPSs lets users write sequential code; the runtime detects data
//! dependencies between annotated tasks, builds a DAG at submission time,
//! and schedules ready tasks asynchronously over persistent workers
//! (§3.1-3.2). This module is that machinery:
//!
//! * [`access`] — parameter directions (IN / OUT / INOUT) and access records;
//! * [`registry`] — the versioned data registry: every task parameter is a
//!   `dXvY` datum (data X, version Y), exactly the labels on the paper's
//!   DAG figures;
//! * [`dag`] — superscalar dependency analysis (RAW/WAR/WAW) and the task
//!   graph, with DOT export reproducing Figures 2-5;
//! * [`scheduler`] — pluggable policies: FIFO, LIFO, data-locality
//!   (the paper cites these as COMPSs' pluggable scheduling policies);
//! * [`executor`] — the persistent worker pool (threads) for real local
//!   execution, with file-based parameter passing through the codecs;
//! * [`fault`] — task resubmission on failure and failure injection;
//! * [`runtime`] — the orchestrator gluing the above behind the API.
//!
//! The DAG, registry, and scheduler are *pure* (no threads, no I/O); both
//! the live executor and the discrete-event simulator (`crate::sim`) drive
//! the same code, which is what makes the simulated scale-out runs of
//! Figures 6-9 a faithful extrapolation of the real runtime.

pub mod access;
pub mod dag;
pub mod executor;
pub mod fault;
pub mod registry;
pub mod runtime;
pub mod scheduler;

pub use access::Direction;
pub use dag::{EdgeKind, TaskGraph, TaskId, TaskState};
pub use registry::{DataKey, DataRegistry, NodeId};
pub use runtime::{Coordinator, CoordinatorConfig, SubmitOutcome};
