//! The COMPSs-style coordination core — the paper's contribution.
//!
//! RCOMPSs lets users write sequential code; the runtime detects data
//! dependencies between annotated tasks, builds a DAG at submission time,
//! and schedules ready tasks asynchronously over persistent workers
//! (§3.1-3.2). This module is that machinery:
//!
//! * [`access`] — parameter directions (IN / OUT / INOUT) and access records;
//! * [`registry`] — the versioned data registry: every task parameter is a
//!   `dXvY` datum (data X, version Y), exactly the labels on the paper's
//!   DAG figures, split into a master-side dependency half and a sharded
//!   worker-side [`registry::VersionTable`];
//! * [`dag`] — superscalar dependency analysis (RAW/WAR/WAW) and the task
//!   graph, with DOT export reproducing Figures 2-5;
//! * [`store`] — the tiered value store behind one [`store::ValueStore`]
//!   facade: **hot** (decoded `Arc<RValue>`s with a byte budget and
//!   LRU/largest demotion), **warm** (encoded `Arc<[u8]>` blobs under
//!   `--warm-budget`, filled lazily by the first encode), **cold** (the
//!   spill-file plane);
//! * [`scheduler`] — pluggable policies: FIFO, LIFO, data-locality, plus
//!   [`scheduler::ShardedReady`], the per-node dispatch fabric with work
//!   stealing that the live executor drives;
//! * [`placement`] — the unified placement engine: one
//!   [`placement::PlacementModel`] (`bytes` | `cost` | `roundrobin` |
//!   `adaptive`) routes ready tasks for the dispatch fabric, the
//!   schedule-time prefetcher, *and* the simulator, so all three agree on
//!   where a task belongs;
//! * [`feedback`] — the runtime-observation loop behind the `adaptive`
//!   model: movers record per-node transfer bandwidth, workers per-type
//!   task durations (decay-weighted EWMAs), and placement scores nodes in
//!   estimated *time* once the signal is warm;
//! * [`executor`] — the persistent worker pool (threads) for real local
//!   execution, with memory- or file-based parameter passing;
//! * [`compile`] — the window compiler: an ahead-of-time DAG compilation
//!   pass (render-graph style) over bounded submission windows — dead-task
//!   culling, ahead-of-time lifetime/death lists with hot-tier buffer
//!   aliasing, short-chain fusion into single dispatch units, and
//!   whole-window placement replacing per-task greedy verdicts (armed by
//!   `--compile window` / `RCOMPSS_COMPILE=window`; off by default);
//! * [`fault`] — task resubmission on failure and failure injection;
//! * [`schedfuzz`] — deterministic schedule-fuzzing yield points at the
//!   concurrency planes' hazard windows (armed by `RCOMPSS_SCHED_FUZZ` or
//!   `with_sched_fuzz`; a no-op branch otherwise);
//! * `transport` (crate-internal) — the pluggable replica-shipping
//!   plane: the mover loop's staging requests resolve to
//!   `Transport::fetch`, implemented by the in-process emulation
//!   (default) or by real `rcompss worker` processes over TCP
//!   (`--transport tcp`), with the warm tier's encoded blobs going on
//!   the wire verbatim;
//! * [`runtime`] — the orchestrator gluing the above behind the API.
//!
//! The DAG, registry, and scheduler policies are *pure* (no threads, no
//! I/O); both the live executor and the discrete-event simulator
//! (`crate::sim`) drive the same code, which is what makes the simulated
//! scale-out runs of Figures 6-9 a faithful extrapolation of the real
//! runtime.
//!
//! # Data plane & locking
//!
//! The seed runtime funneled every operation — dependency analysis, ready
//! queues, location tracking, claim resolution — through one global
//! `Mutex<Core>`, and moved every parameter through a serialized file. Both
//! were per-task overhead on the dispatch hot path, precisely what the
//! paper says must stay small relative to task granularity for 70%+
//! parallel efficiency at 128 cores (§4). The runtime now separates four
//! concerns with four synchronization domains:
//!
//! | Domain | Structure | Who touches it |
//! |--------|-----------|----------------|
//! | control (DAG, dependency analysis, metadata, stats) | `Mutex<Core>` + `cv_done` | master on submit/wait; workers only to flip task states |
//! | dispatch (ready tasks) | [`scheduler::ShardedReady`]: per-node policy shards + park lot | workers pop/steal; submit & completions push |
//! | location (where each `dXvY` lives) | [`registry::VersionTable`]: 16 `RwLock` shards | workers on every claim/publish, lock-free of control |
//! | values (the bytes themselves) | [`store::TieredStore`]: hot `Arc<RValue>` cache + warm `Arc<[u8]>` blob cache + cold spill files | producers put hot, consumers get zero-copy handles, demotion walks the tiers |
//! | movement (cross-node staging) | [`transfer::TransferService`]: per-node request queues + mover threads | routing prefetches, movers stage, claimants park |
//! | shipping (how staged bytes move) | `transport::Transport`: in-process staging or TCP worker sockets | movers call `fetch`; kill/rejoin close/reopen peers |
//!
//! Lock ordering: the control lock may be held while touching the leaf
//! domains (dispatch shards, table shards, store, transfer board); leaf
//! locks never nest into each other or back into control. `cv_done`
//! waiters recheck state guarded by leaves only after a completion has
//! re-acquired the control lock, which rules out missed wakeups.
//!
//! # Value lifecycle
//!
//! Every `dXvY` version moves through: **produce** (task output or
//! literal) → **cache** (zero-copy `Arc` in the store) → **transfer /
//! prefetch** (movers stage replicas on consumer nodes at schedule time) →
//! **consume** (zero-copy claim) → **GC / spill** (last registered
//! consumer done ⇒ reclaimed; memory pressure ⇒ spilled through the
//! codec). See `ARCHITECTURE.md` at the repository root for the full
//! narrative, the lifecycle diagram, and the locking rules.
//!
//! **Data-plane knobs** (`runtime::CoordinatorConfig`): `memory_budget`
//! (bytes; default [`runtime::DEFAULT_MEMORY_BUDGET`] = 256 MiB; 0 = file
//! plane, byte-identical to the seed runtime), `warm_budget` (bytes of
//! encoded warm-tier blobs; default [`runtime::DEFAULT_WARM_BUDGET`] =
//! 64 MiB; 0 = pre-tier hot→file demotion and file-backed transfer
//! staging), `store` (tier preset for A/B runs: `"tiered"` | `"hot"` |
//! `"file"`), `spill` (`"lru"` | `"largest"`), `transfer_threads` (movers
//! per emulated node; 0 = synchronous seed-style cross-node reloads),
//! `gc` (reference-counted version GC, default on), and `router`
//! (placement model: `"bytes"` | `"cost"` | `"roundrobin"` |
//! `"adaptive"`). With the memory plane on, the configured codec runs
//! only at tier boundaries: memory pressure, cross-node transfer, and
//! reloads of demoted values — and with `transfer_threads > 0` the
//! cross-node boundary runs on mover threads, never on a claiming
//! worker's critical path. A node-local RAW chain therefore executes with
//! zero file I/O and zero serialization, and with the warm tier on a
//! memory-resident version fanned out to N nodes costs exactly one encode
//! and zero file I/O.

pub mod access;
pub mod compile;
pub mod dag;
pub mod executor;
pub mod fault;
pub mod feedback;
pub mod placement;
pub mod registry;
pub mod runtime;
pub mod schedfuzz;
pub mod scheduler;
pub mod store;
pub mod transfer;
// Crate-internal: the Transport trait's `fetch` signature names the
// crate-private `Shared` handle. The CLI reaches the worker entry point
// through the `api::run_tcp_worker` facade re-export.
pub(crate) mod transport;

pub use access::Direction;
pub use compile::{compile_window, WindowCtx, WindowPlan, WindowTask};
pub use dag::{EdgeKind, TaskGraph, TaskId, TaskState};
pub use feedback::{AdaptivePlacement, FeedbackStats};
pub use placement::{placement_by_name, PlacementModel, RoutedReady};
pub use registry::{DataKey, DataRegistry, NodeId, VersionTable};
pub use runtime::{Coordinator, CoordinatorConfig, SubmitOutcome};
pub use schedfuzz::{FuzzController, FuzzSite};
pub use store::{DataStore, SpillPolicy, Tier, TieredStore, ValueStore, WarmStore};
pub use transfer::TransferService;
