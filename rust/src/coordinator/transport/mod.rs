//! Pluggable replica-shipping plane — *how* bytes reach a destination
//! node, factored out of *when* they move.
//!
//! The [`TransferService`](super::transfer::TransferService) decides which
//! `(version, node)` pairs to stage and in what order; its mover threads
//! then call [`Transport::fetch`] to actually move the bytes. Everything
//! above that call — placement verdicts, feedback EWMAs, the version GC,
//! chaos kill/join recovery, the sched-fuzz yield points, the window
//! compiler — is transport-agnostic and must behave identically no matter
//! which implementation is underneath:
//!
//! * [`InProcTransport`] — the emulated cluster: nodes share one address
//!   space, so staging a replica is a warm-blob (or cold-file) round-trip
//!   into the shared hot tier. The test-harness default.
//! * [`TcpTransport`](tcp::TcpTransport) — real `rcompss worker` processes
//!   registered over sockets: the same warm blob additionally ships to the
//!   destination worker, verbatim — zero re-encode, zero coordinator-side
//!   file I/O for memory-resident values. Two wire paths: a direct
//!   worker-to-worker stream of chunked [`BlobChunk`](
//!   crate::serialization::wire::FrameKind) frames triggered by a tiny
//!   `ShipTo` control frame (the default), and the coordinator-relayed
//!   `Put` frame (the `--p2p off` mode and the universal fallback).
//!
//! The invariance is pinned by running the unmodified integration and
//! property suites against a loopback-TCP cluster
//! (`RCOMPSS_TRANSPORT=tcp`, CI's `distributed-matrix` job).

pub mod tcp;

use std::sync::Arc;

use crate::coordinator::registry::{DataKey, NodeId};
use crate::coordinator::runtime::Shared;
use crate::coordinator::store::{self, cold};
use crate::value::RValue;

/// Shipping-plane counters a transport may expose (all zero for
/// transports without a wire, like [`InProcTransport`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShipStats {
    /// Blobs streamed worker-to-worker (`ShipTo` → `BlobChunk`×k).
    pub direct_ships: u64,
    /// Blobs relayed through the coordinator (`Put`).
    pub relay_ships: u64,
    /// Relay `Put`s issued only to seed a fresh version's producer-side
    /// cache so the rest of its fan-out can go direct.
    pub seed_ships: u64,
    /// Direct ships that reused a pooled peer connection.
    pub pool_hits: u64,
    /// Coordinator→worker request bytes (frame headers + payloads):
    /// relay `Put`s count their blob, `ShipTo` only the control frame.
    pub egress_bytes: u64,
}

/// One way of moving a replica of `key` onto `to`.
///
/// `fetch` runs on a mover thread with **no locks held**; it may block on
/// I/O, sleep for backoff, and call back into the store/table/health
/// planes. The return contract matches the old `stage_replica`:
/// `Ok(Some(nbytes))` — replica staged and location published;
/// `Ok(None)` — transfer *dropped* without moving bytes (version
/// collected, destination dead, or destination unreachable after the
/// bounded reconnect budget); `Err` — a retryable failure, counted and
/// re-queued by the transfer board's attempt budget.
pub trait Transport: Send + Sync {
    /// Short name for banners and stats (`"inproc"` | `"tcp"`).
    fn name(&self) -> &'static str;

    /// Move one replica. `from` is a hint — the first live node already
    /// holding the version — which socket transports use to source the
    /// bytes when the coordinator's own tiers no longer hold them.
    fn fetch(
        &self,
        shared: &Shared,
        key: DataKey,
        from: Option<NodeId>,
        to: NodeId,
    ) -> anyhow::Result<Option<u64>>;

    /// A node was declared dead (`kill_node` or a transport-detected
    /// drop). Close/poison any per-node resources.
    fn on_node_down(&self, _node: NodeId) {}

    /// A node rejoined (`add_node`). Re-open per-node resources.
    fn on_node_up(&self, _node: NodeId) {}

    /// The version GC reclaimed `key`: drop any cached belief about where
    /// its bytes live (the TCP transport's worker-cache location map).
    fn on_version_purged(&self, _key: DataKey) {}

    /// Shipping-plane counters for the stats surface; the default is all
    /// zeros (no wire, nothing shipped).
    fn ship_stats(&self) -> ShipStats {
        ShipStats::default()
    }

    /// Orderly teardown at `Coordinator::stop` (movers already joined).
    fn shutdown(&self) {}
}

/// Publish a decoded replica into the hot tier and advertise the location
/// — the tail every transport shares. Returns `false` when the publish
/// was abandoned (version collected mid-stage, or destination died):
/// the transfer is then *dropped*, not failed.
pub(crate) fn publish_replica(
    shared: &Shared,
    key: DataKey,
    node: NodeId,
    value: Arc<RValue>,
    has_file: bool,
) -> bool {
    let victims = shared.store.hot().put(key, value, has_file);
    store::demote_victims(shared, victims);
    if shared.table.is_collected(key) {
        // The GC ran between the decode and this publish: whichever
        // removal runs last clears the replica; never publish the
        // location of a reclaimed version.
        shared.store.discard_resident(key);
        return false;
    }
    if !shared.health.is_alive(node) {
        // The destination died mid-stage: never advertise a replica on
        // a dead node. The hot entry itself stays — in the emulated
        // single-address-space store it still serves other nodes.
        return false;
    }
    shared.table.add_location(key, node);
    true
}

/// The emulated cluster's transport: nodes are threads sharing one
/// address space, so "shipping" a replica is staging it in the shared
/// tiered store. This is the pre-refactor `stage_replica` verbatim — the
/// extraction is behavior-identical by construction and stays the
/// default so every existing suite keeps exercising it.
pub struct InProcTransport;

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    /// Stage one replica of `key` on `to`, warm-first: ship the warm
    /// tier's serialized blob — built lazily by the first transfer, so an
    /// N-node fan-out of a memory-resident version runs `codec.encode`
    /// exactly once and touches no file — and decode it into the
    /// destination's hot tier. Only when the warm tier is off (or the
    /// bytes were transiently unreachable) does the old file-staging path
    /// run: publish a spill file, read it back, decode (`ensure_file` is
    /// the cold-tier fallback).
    fn fetch(
        &self,
        shared: &Shared,
        key: DataKey,
        _from: Option<NodeId>,
        to: NodeId,
    ) -> anyhow::Result<Option<u64>> {
        if let Some(blob) = store::stage_blob(shared, key)? {
            let nbytes = blob.len() as u64;
            let value = Arc::new(shared.codec.decode(&blob)?);
            // Per-tier residency: the replica entry claims a cold file
            // only when one was actually published for this version — the
            // GC must only ever delete files that exist.
            let has_file = shared.table.path_of(key).is_some();
            if !publish_replica(shared, key, to, value, has_file) {
                return Ok(None);
            }
            return Ok(Some(nbytes));
        }
        let path = cold::ensure_file(shared, key)?;
        let nbytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        shared.store.cold().note_read();
        let value = Arc::new(shared.codec.read_file(&path)?);
        if !publish_replica(shared, key, to, value, true) {
            return Ok(None);
        }
        Ok(Some(nbytes))
    }
}
