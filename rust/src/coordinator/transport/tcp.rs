//! TCP transport — real worker processes behind the [`Transport`] trait.
//!
//! Topology: one coordinator owns the DAG, the scheduler, and the tiered
//! store; `rcompss worker --connect <addr>` processes register over TCP
//! and serve as **replica stores** — each holds a budget-bounded cache of
//! the serialized blobs shipped to it, exactly the bytes a real
//! distributed claim would read. Node 0 is coordinator-resident (no
//! socket); nodes `1..n` map to registered workers.
//!
//! A staging request becomes, on the wire (framing:
//! [`crate::serialization::wire`], fixed little-endian header, payload =
//! the warm tier's already-encoded `Arc<[u8]>` blob **verbatim** — zero
//! re-encode):
//!
//! ```text
//! coordinator                                worker (node n)
//!     | Put  { key, blob }  ────────────────────▶ |  cache.insert
//!     | ◀────────────────────────────── PutOk { } |
//! ```
//!
//! with `Get`/`Blob`/`NotFound` as the reverse path (the coordinator
//! pulling a blob back from a worker's cache — the last-resort source
//! when its own tiers lost the bytes), and `Hello`/`Assign` as the
//! registration handshake.
//!
//! Failure mapping: a dead socket is retried with the transfer board's
//! own deterministic `retry_backoff` schedule; once the attempt budget is
//! exhausted the node is routed through [`kill_node_now`] — the same
//! poisoning path as `kill_node` — so a dropped worker looks exactly
//! like a chaos node-kill to placement, GC, and lineage recovery.
//!
//! Two bootstrap modes:
//! * **self-hosted** (`RCOMPSS_TRANSPORT=tcp`, no `--listen`): the
//!   coordinator binds a loopback listener and spawns one in-process
//!   worker *thread* per emulated node over real sockets — the whole
//!   unmodified test suite runs over TCP in one process. This is the
//!   invariance pin.
//! * **external** (`--listen <addr>`): the coordinator waits for
//!   `rcompss worker --connect` processes to register before starting.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{publish_replica, Transport};
use crate::coordinator::registry::{DataId, DataKey, NodeId};
use crate::coordinator::runtime::{kill_node_now, Shared};
use crate::coordinator::store::{self, cold};
use crate::coordinator::transfer::retry_backoff;
use crate::serialization::wire::{read_frame, write_frame, Frame, FrameKind};

/// Wire size of a `DataKey`: `data:u64(le) version:u32(le)`.
const KEY_BYTES: usize = 12;

/// `Hello` payload meaning "any free slot".
const ANY_NODE: u32 = u32::MAX;

/// Per-request reply timeout on coordinator-side sockets: a worker that
/// stops answering is indistinguishable from a dead one and is treated as
/// such (retry → poison).
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Attempts per shipped replica before the destination is declared dead —
/// mirrors the transfer board's own `MAX_TRANSFER_ATTEMPTS`.
const SHIP_ATTEMPTS: u32 = 3;

/// How long one ship attempt waits for an empty peer slot to (re)register
/// before the attempt counts as failed — covers the self-host rejoin race
/// (worker thread spawned but not yet through the handshake).
const SLOT_WAIT: Duration = Duration::from_millis(500);

fn encode_key(key: DataKey) -> [u8; KEY_BYTES] {
    let mut out = [0u8; KEY_BYTES];
    out[..8].copy_from_slice(&key.data.0.to_le_bytes());
    out[8..].copy_from_slice(&key.version.to_le_bytes());
    out
}

fn decode_key(payload: &[u8]) -> Result<DataKey> {
    if payload.len() < KEY_BYTES {
        bail!("key payload too short: {} bytes", payload.len());
    }
    Ok(DataKey {
        data: DataId(u64::from_le_bytes(payload[..8].try_into().unwrap())),
        version: u32::from_le_bytes(payload[8..KEY_BYTES].try_into().unwrap()),
    })
}

/// The worker-side replica store: byte-budgeted FIFO of serialized blobs.
/// Eviction is silent — the coordinator treats `NotFound` as a cache miss
/// and falls back to its own tiers (which still hold every live version's
/// bytes or lineage).
struct BlobCache {
    budget: u64,
    used: u64,
    order: VecDeque<DataKey>,
    blobs: HashMap<DataKey, Vec<u8>>,
}

impl BlobCache {
    fn new(budget: u64) -> BlobCache {
        BlobCache {
            budget: budget.max(1),
            used: 0,
            order: VecDeque::new(),
            blobs: HashMap::new(),
        }
    }

    fn insert(&mut self, key: DataKey, blob: Vec<u8>) {
        if let Some(old) = self.blobs.remove(&key) {
            self.used -= old.len() as u64;
            self.order.retain(|k| *k != key);
        }
        self.used += blob.len() as u64;
        self.order.push_back(key);
        self.blobs.insert(key, blob);
        while self.used > self.budget && self.order.len() > 1 {
            if let Some(victim) = self.order.pop_front() {
                if let Some(b) = self.blobs.remove(&victim) {
                    self.used -= b.len() as u64;
                }
            }
        }
    }

    fn get(&self, key: DataKey) -> Option<&Vec<u8>> {
        self.blobs.get(&key)
    }
}

/// See the module docs. Constructed by `Coordinator::start` via
/// [`TcpTransport::bind`] + [`TcpTransport::wait_registered`].
pub struct TcpTransport {
    nodes: u32,
    /// Slot per node id; slot 0 (coordinator-resident) stays `None`. The
    /// mutex is held across one request/reply exchange, serializing the
    /// movers' use of each worker's socket.
    peers: Vec<Mutex<Option<TcpStream>>>,
    listen_addr: SocketAddr,
    /// Self-hosted loopback workers (threads) vs. external processes.
    self_host: bool,
    worker_budget: u64,
    shutting_down: AtomicBool,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl TcpTransport {
    /// Bind the registration listener and start the acceptor (plus the
    /// loopback worker threads in self-host mode). Non-blocking: pair
    /// with [`TcpTransport::wait_registered`] before serving traffic.
    pub fn bind(
        nodes: u32,
        listen: Option<&str>,
        self_host: bool,
        worker_budget: u64,
    ) -> Result<Arc<TcpTransport>> {
        let addr = listen.unwrap_or("127.0.0.1:0");
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("tcp transport: cannot listen on {addr}"))?;
        let listen_addr = listener.local_addr()?;
        let t = Arc::new(TcpTransport {
            nodes: nodes.max(1),
            peers: (0..nodes.max(1)).map(|_| Mutex::new(None)).collect(),
            listen_addr,
            self_host,
            worker_budget,
            shutting_down: AtomicBool::new(false),
            threads: Mutex::new(Vec::new()),
        });
        let mut threads = t.threads.lock().unwrap();
        let acceptor = Arc::clone(&t);
        threads.push(
            std::thread::Builder::new()
                .name("rcompss-accept".into())
                .spawn(move || acceptor.accept_loop(listener))
                .expect("spawn acceptor"),
        );
        if self_host {
            for n in 1..nodes.max(1) {
                threads.push(spawn_loopback_worker(listen_addr, n, worker_budget));
            }
        }
        drop(threads);
        Ok(t)
    }

    /// The address workers connect to (the ephemeral port in self-host
    /// mode, the `--listen` address otherwise).
    pub fn listen_addr(&self) -> SocketAddr {
        self.listen_addr
    }

    /// Block until every slot `1..nodes` holds a registered worker, or
    /// fail after `timeout` naming the missing slots.
    pub fn wait_registered(&self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let missing: Vec<u32> = (1..self.nodes)
                .filter(|n| self.peers[*n as usize].lock().unwrap().is_none())
                .collect();
            if missing.is_empty() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!(
                    "tcp transport: nodes {missing:?} never registered on {} \
                     (start them with: rcompss worker --connect {})",
                    self.listen_addr,
                    self.listen_addr
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Registration loop: accept, handshake (`Hello` → `Assign`), park
    /// the stream in its node slot. One bad handshake never kills the
    /// acceptor; shutdown is signalled by the flag plus a dummy connect.
    fn accept_loop(self: Arc<Self>, listener: TcpListener) {
        for stream in listener.incoming() {
            if self.shutting_down.load(Ordering::SeqCst) {
                return;
            }
            let Ok(mut stream) = stream else { continue };
            let _ = stream.set_nodelay(true);
            // The handshake is bounded so a connect-and-stall client
            // cannot wedge registration forever.
            let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
            let hello = match read_frame(&mut stream) {
                Ok(Frame {
                    kind: FrameKind::Hello,
                    payload,
                }) if payload.len() >= 4 => {
                    u32::from_le_bytes(payload[..4].try_into().unwrap())
                }
                _ => continue,
            };
            let assigned = self.assign_slot(hello, &stream);
            match assigned {
                Some(node) => {
                    let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
                    if write_frame(&mut stream, FrameKind::Assign, &node.to_le_bytes()).is_err() {
                        *self.peers[node as usize].lock().unwrap() = None;
                    }
                }
                None => {
                    let _ = write_frame(
                        &mut stream,
                        FrameKind::Error,
                        b"no free node slot (cluster full)",
                    );
                }
            }
        }
    }

    /// Pick the slot for a registering worker: its preferred node if that
    /// slot is free, else the lowest free slot. Stores the stream.
    fn assign_slot(&self, preferred: u32, stream: &TcpStream) -> Option<u32> {
        let candidates: Vec<u32> = if preferred != ANY_NODE {
            std::iter::once(preferred)
                .chain((1..self.nodes).filter(|n| *n != preferred))
                .collect()
        } else {
            (1..self.nodes).collect()
        };
        for n in candidates {
            if n == 0 || n >= self.nodes {
                continue;
            }
            let mut slot = self.peers[n as usize].lock().unwrap();
            if slot.is_none() {
                *slot = stream.try_clone().ok();
                if slot.is_some() {
                    return Some(n);
                }
            }
        }
        None
    }

    /// One request/reply exchange on `node`'s socket. Any error poisons
    /// the slot (socket closed and cleared) so the caller's retry path
    /// sees a clean "not registered" state.
    fn exchange(&self, node: NodeId, kind: FrameKind, payload: &[u8]) -> Result<Frame> {
        let mut slot = self.peers[node.0 as usize].lock().unwrap();
        let Some(stream) = slot.as_mut() else {
            bail!("node {} has no registered worker", node.0);
        };
        let run = (|| -> Result<Frame> {
            write_frame(stream, kind, payload)?;
            read_frame(stream)
        })();
        if run.is_err() {
            if let Some(s) = slot.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        run
    }

    /// Ship a blob to `node`'s replica cache, retrying with the transfer
    /// board's deterministic backoff. `false` means the destination is
    /// unreachable after the budget — the caller maps that to a node
    /// death.
    fn ship(&self, key: DataKey, node: NodeId, blob: &[u8]) -> bool {
        let mut payload = Vec::with_capacity(KEY_BYTES + blob.len());
        payload.extend_from_slice(&encode_key(key));
        payload.extend_from_slice(blob);
        for attempt in 1..=SHIP_ATTEMPTS {
            // Cover the (re)registration race: a rejoining worker may be
            // mid-handshake when the first post-revive transfer lands.
            let wait_deadline = Instant::now() + SLOT_WAIT;
            while self.peers[node.0 as usize].lock().unwrap().is_none()
                && Instant::now() < wait_deadline
                && !self.shutting_down.load(Ordering::SeqCst)
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            match self.exchange(node, FrameKind::Put, &payload) {
                Ok(Frame {
                    kind: FrameKind::PutOk,
                    ..
                }) => return true,
                Ok(f) => {
                    eprintln!(
                        "tcp transport: node {} answered Put with {:?}",
                        node.0, f.kind
                    );
                }
                Err(_) => {}
            }
            if self.shutting_down.load(Ordering::SeqCst) {
                return false;
            }
            if attempt < SHIP_ATTEMPTS {
                std::thread::sleep(retry_backoff(key, node, attempt));
            }
        }
        false
    }

    /// Pull a blob back from `node`'s replica cache (`Get` → `Blob` |
    /// `NotFound`) — the last-resort source when the coordinator's own
    /// tiers lost the bytes.
    fn get_remote(&self, node: NodeId, key: DataKey) -> Result<Option<Arc<[u8]>>> {
        match self.exchange(node, FrameKind::Get, &encode_key(key))? {
            Frame {
                kind: FrameKind::Blob,
                payload,
            } => Ok(Some(Arc::from(payload.into_boxed_slice()))),
            Frame {
                kind: FrameKind::NotFound,
                ..
            } => Ok(None),
            f => bail!("node {} answered Get with {:?}", node.0, f.kind),
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    /// Same staging contract as the in-process transport — warm blob
    /// first (one encode per fan-out), cold spill file as fallback, both
    /// on the owning side — plus the socket hop: the destination worker
    /// receives the blob verbatim before the coordinator publishes the
    /// replica. A destination that stays unreachable through the retry
    /// budget is declared dead via the `kill_node` path and the transfer
    /// is dropped, never failed.
    fn fetch(
        &self,
        shared: &Shared,
        key: DataKey,
        from: Option<NodeId>,
        to: NodeId,
    ) -> Result<Option<u64>> {
        let (blob, has_file): (Arc<[u8]>, bool) = match store::stage_blob(shared, key)? {
            Some(blob) => {
                let has_file = shared.table.path_of(key).is_some();
                (blob, has_file)
            }
            None => match cold::ensure_file(shared, key) {
                Ok(path) => {
                    // Cold fallback, owning side only: the spill file
                    // already holds the encoded bytes — read them
                    // verbatim, never re-encode.
                    shared.store.cold().note_read();
                    let bytes = std::fs::read(&path)?;
                    (Arc::from(bytes.into_boxed_slice()), true)
                }
                Err(e) => {
                    // Last resort: the version's bytes are gone from
                    // every coordinator tier, but a worker's replica
                    // cache may still hold the blob.
                    let Some(src) = from.filter(|s| s.0 != 0 && *s != to) else {
                        return Err(e);
                    };
                    match self.get_remote(src, key) {
                        Ok(Some(blob)) => (blob, false),
                        _ => return Err(e),
                    }
                }
            },
        };
        let nbytes = blob.len() as u64;
        if to.0 != 0 && !self.ship(key, to, &blob) {
            if self.shutting_down.load(Ordering::SeqCst) {
                return Ok(None);
            }
            // Unreachable after the attempt budget: fold the loss into
            // the existing recovery plane. `kill_node_now` poisons the
            // node's transfer pairs (`fail_node`), drops its locations,
            // and re-executes lost versions from lineage — a dropped
            // worker is indistinguishable from a chaos `kill_node`.
            if shared.health.is_alive(to) {
                eprintln!(
                    "tcp transport: node {} unreachable after {SHIP_ATTEMPTS} attempts; \
                     declaring it dead",
                    to.0
                );
                kill_node_now(shared, to);
            }
            return Ok(None);
        }
        let value = Arc::new(shared.codec.decode(&blob)?);
        if !publish_replica(shared, key, to, value, has_file) {
            return Ok(None);
        }
        Ok(Some(nbytes))
    }

    /// `kill_node` / transport-detected death: close and clear the slot
    /// so in-flight exchanges fail fast and a future rejoin re-registers
    /// from scratch.
    fn on_node_down(&self, node: NodeId) {
        if (node.0 as usize) < self.peers.len() {
            if let Some(s) = self.peers[node.0 as usize].lock().unwrap().take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    /// `add_node` rejoin: in self-host mode spawn a fresh loopback worker
    /// for the slot (the killed one's thread exited with its socket). In
    /// external mode the operator restarts `rcompss worker` — the
    /// acceptor fills the free slot whenever it arrives.
    fn on_node_up(&self, node: NodeId) {
        if self.self_host && node.0 != 0 && node.0 < self.nodes {
            let handle = spawn_loopback_worker(self.listen_addr, node.0, self.worker_budget);
            self.threads.lock().unwrap().push(handle);
        }
    }

    /// Orderly teardown: flag, `Shutdown` frame + close per peer, dummy
    /// connect to unblock the acceptor, join every thread.
    fn shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        for slot in &self.peers {
            if let Some(mut s) = slot.lock().unwrap().take() {
                let _ = write_frame(&mut s, FrameKind::Shutdown, &[]);
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        let _ = TcpStream::connect(self.listen_addr);
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

fn spawn_loopback_worker(addr: SocketAddr, node: u32, budget: u64) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("rcompss-worker-{node}"))
        .spawn(move || {
            let _ = run_worker(&addr.to_string(), Some(node), budget, true);
        })
        .expect("spawn loopback worker")
}

/// Body of `rcompss worker --connect <addr>` (and of the self-hosted
/// loopback worker threads): register, then serve the replica cache until
/// the coordinator says `Shutdown` or the socket dies. Connection is
/// retried for ~10 s so workers may start before (or racing) the
/// coordinator.
pub fn run_worker(addr: &str, preferred: Option<u32>, budget: u64, quiet: bool) -> Result<()> {
    let mut stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    let hello = preferred.unwrap_or(ANY_NODE).to_le_bytes();
    write_frame(&mut stream, FrameKind::Hello, &hello)?;
    let node = match read_frame(&mut stream)? {
        Frame {
            kind: FrameKind::Assign,
            payload,
        } if payload.len() >= 4 => u32::from_le_bytes(payload[..4].try_into().unwrap()),
        Frame {
            kind: FrameKind::Error,
            payload,
        } => bail!(
            "registration refused: {}",
            String::from_utf8_lossy(&payload)
        ),
        f => bail!("unexpected registration reply: {:?}", f.kind),
    };
    if !quiet {
        println!("rcompss worker: registered as node {node} on {addr} (budget {budget} B)");
    }
    let mut cache = BlobCache::new(budget);
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            // Coordinator gone (EOF/reset): a worker has no state worth
            // saving — exit quietly.
            Err(_) => return Ok(()),
        };
        match frame.kind {
            FrameKind::Put => {
                let key = decode_key(&frame.payload)?;
                cache.insert(key, frame.payload[KEY_BYTES..].to_vec());
                write_frame(&mut stream, FrameKind::PutOk, &[])?;
            }
            FrameKind::Get => {
                let key = decode_key(&frame.payload)?;
                match cache.get(key) {
                    Some(blob) => write_frame(&mut stream, FrameKind::Blob, blob)?,
                    None => write_frame(&mut stream, FrameKind::NotFound, &[])?,
                }
            }
            FrameKind::Shutdown => return Ok(()),
            other => {
                let msg = format!("unexpected frame {other:?}");
                write_frame(&mut stream, FrameKind::Error, msg.as_bytes())?;
            }
        }
        stream.flush()?;
    }
}

fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                bail!("cannot connect to coordinator at {addr}: {e}");
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(d: u64, v: u32) -> DataKey {
        DataKey {
            data: DataId(d),
            version: v,
        }
    }

    #[test]
    fn blob_cache_evicts_fifo_within_budget() {
        let mut c = BlobCache::new(100);
        c.insert(key(1, 1), vec![0u8; 40]);
        c.insert(key(2, 1), vec![0u8; 40]);
        assert!(c.get(key(1, 1)).is_some());
        c.insert(key(3, 1), vec![0u8; 40]);
        // Oldest out first; the two newest fit the budget.
        assert!(c.get(key(1, 1)).is_none());
        assert!(c.get(key(2, 1)).is_some());
        assert!(c.get(key(3, 1)).is_some());
        // Re-inserting an existing key replaces, never double-counts.
        c.insert(key(3, 1), vec![1u8; 60]);
        assert_eq!(c.get(key(3, 1)).unwrap().len(), 60);
        // A single over-budget blob is still held (the floor keeps one).
        c.insert(key(4, 1), vec![0u8; 400]);
        assert!(c.get(key(4, 1)).is_some());
    }

    #[test]
    fn key_codec_roundtrips() {
        let k = key(0xDEAD_BEEF_1234, 77);
        assert_eq!(decode_key(&encode_key(k)).unwrap(), k);
        assert!(decode_key(&[0u8; 4]).is_err());
    }

    #[test]
    fn external_registration_ship_and_get_roundtrip() {
        // 3 nodes: coordinator-resident 0 plus two external workers that
        // connect like `rcompss worker` processes would.
        let t = TcpTransport::bind(3, Some("127.0.0.1:0"), false, 1 << 20).unwrap();
        let addr = t.listen_addr().to_string();
        let (a1, a2) = (addr.clone(), addr.clone());
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 20, true));
        let w2 = std::thread::spawn(move || run_worker(&a2, Some(2), 1 << 20, true));
        t.wait_registered(Duration::from_secs(5)).unwrap();

        let k = key(42, 7);
        let blob: Vec<u8> = (0..1024u32).map(|b| b as u8).collect();
        assert!(t.ship(k, NodeId(1), &blob));
        assert!(t.ship(k, NodeId(2), &blob));
        // The blob comes back verbatim from the worker's replica cache.
        let back = t.get_remote(NodeId(1), k).unwrap().unwrap();
        assert_eq!(&back[..], &blob[..]);
        // A key never shipped is a clean miss, not an error.
        assert!(t.get_remote(NodeId(2), key(9, 9)).unwrap().is_none());

        t.shutdown();
        w1.join().unwrap().unwrap();
        w2.join().unwrap().unwrap();
    }

    #[test]
    fn preferred_slot_collision_falls_to_lowest_free() {
        let t = TcpTransport::bind(3, Some("127.0.0.1:0"), false, 1 << 20).unwrap();
        let addr = t.listen_addr().to_string();
        let (a1, a2) = (addr.clone(), addr.clone());
        // Both prefer node 1: one gets it, the other falls to slot 2.
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 20, true));
        let w2 = std::thread::spawn(move || run_worker(&a2, Some(1), 1 << 20, true));
        t.wait_registered(Duration::from_secs(5)).unwrap();
        t.shutdown();
        w1.join().unwrap().unwrap();
        w2.join().unwrap().unwrap();
    }

    #[test]
    fn unregistered_cluster_times_out_with_join_hint() {
        let t = TcpTransport::bind(2, Some("127.0.0.1:0"), false, 1 << 20).unwrap();
        let err = t
            .wait_registered(Duration::from_millis(50))
            .unwrap_err()
            .to_string();
        assert!(err.contains("rcompss worker --connect"), "{err}");
        // Shipping toward the empty slot fails cleanly (no panic, no hang
        // beyond the bounded slot wait + backoff).
        assert!(!t.ship(key(1, 1), NodeId(1), b"bytes"));
        t.shutdown();
    }
}
