//! TCP transport — real worker processes behind the [`Transport`] trait.
//!
//! Topology: one coordinator owns the DAG, the scheduler, and the tiered
//! store; `rcompss worker --connect <addr>` processes register over TCP
//! and serve as **replica stores** — each holds a budget-bounded LRU
//! cache of the serialized blobs shipped to it, exactly the bytes a real
//! distributed claim would read. Node 0 is coordinator-resident (no
//! socket); nodes `1..n` map to registered workers.
//!
//! A staging request becomes, on the wire (framing:
//! [`crate::serialization::wire`], fixed little-endian header, payload =
//! the warm tier's already-encoded `Arc<[u8]>` blob **verbatim** — zero
//! re-encode), one of two paths. The relay path (the original, still the
//! universal fallback and the whole story with `--p2p off`):
//!
//! ```text
//! coordinator                                worker (node n)
//!     | Put  { key, blob }  ────────────────────▶ |  cache.insert
//!     | ◀────────────────────────────── PutOk { } |
//! ```
//!
//! and the **direct path** (default under `--transport tcp`): when a live
//! worker's cache already holds the blob, the coordinator sends only a
//! tiny `ShipTo` control frame and the bytes move worker-to-worker over a
//! pooled peer socket, streamed in bounded CRC32-checked `BlobChunk`
//! frames (1 MiB each) so a huge replica never materializes twice on
//! either side of the link:
//!
//! ```text
//! coordinator                    worker src                worker dst
//!     | ShipTo {key,dst,addr} ─────▶ |                          |
//!     |                              | BlobChunk ×k ───────────▶|  (pooled peer
//!     |                              | ◀─────────────── PutOk {}|   socket)
//!     | ◀── ShipDone {key, status,   |                          |
//!     |     bytes, nanos}            |                          |
//! ```
//!
//! The first transfer of a fresh version has no worker-side copy yet (the
//! producer ran in a coordinator thread), so the coordinator **seeds** the
//! producer's worker cache with one relay `Put` and direct-ships from
//! there — coordinator egress per version is O(1), not O(fan-out). The
//! `ShipDone` ack carries measured bytes/wall-time, which feeds the
//! `adaptive` router's *per-pair* bandwidth EWMAs: the model prices the
//! real src→dst link, not a coordinator-relative average.
//!
//! `Get`/`Blob`/`NotFound` is the reverse path (the coordinator pulling a
//! blob back from a worker's cache — the last-resort source when its own
//! tiers lost the bytes), and `Hello`/`Assign` the registration
//! handshake. `Hello` carries the worker's peer-listener port plus the
//! shared secret (`--token` / `RCOMPSS_TOKEN`) when one is configured;
//! a token mismatch is rejected with a clean `Error` frame on both the
//! registration and the peer socket.
//!
//! Failure mapping: any direct-path failure (dead source, mid-stream peer
//! death, stale cache, bad chunk) falls back to relay in the same fetch —
//! the caller never sees it. A dead *relay* socket is retried with the
//! transfer board's own deterministic `retry_backoff` schedule; once the
//! attempt budget is exhausted the node is routed through
//! [`kill_node_now`] — the same poisoning path as `kill_node` — so a
//! dropped worker looks exactly like a chaos node-kill to placement, GC,
//! and lineage recovery.
//!
//! Two bootstrap modes:
//! * **self-hosted** (`RCOMPSS_TRANSPORT=tcp`, no `--listen`): the
//!   coordinator binds a loopback listener and spawns one in-process
//!   worker *thread* per emulated node over real sockets — the whole
//!   unmodified test suite runs over TCP in one process. This is the
//!   invariance pin.
//! * **external** (`--listen <addr>`): the coordinator waits for
//!   `rcompss worker --connect` processes to register before starting.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{publish_replica, ShipStats, Transport};
use crate::coordinator::feedback::FeedbackStats;
use crate::coordinator::registry::{DataId, DataKey, NodeId};
use crate::coordinator::runtime::{kill_node_now, Shared};
use crate::coordinator::store::{self, cold};
use crate::coordinator::transfer::retry_backoff;
use crate::serialization::wire::{
    decode_chunk, read_frame, write_blob_chunks, write_frame, Frame, FrameKind,
};

/// Wire size of a `DataKey`: `data:u64(le) version:u32(le)`.
const KEY_BYTES: usize = 12;

/// Frame header bytes on the wire (`magic:u32 kind:u8 len:u64`), counted
/// into the coordinator egress gauge alongside each payload.
const FRAME_HEADER_BYTES: u64 = 13;

/// `Hello` payload meaning "any free slot".
const ANY_NODE: u32 = u32::MAX;

/// Per-request reply timeout on coordinator-side sockets: a worker that
/// stops answering is indistinguishable from a dead one and is treated as
/// such (retry → poison).
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// Attempts per shipped replica before the destination is declared dead —
/// mirrors the transfer board's own `MAX_TRANSFER_ATTEMPTS`.
const SHIP_ATTEMPTS: u32 = 3;

/// How long one ship attempt waits for an empty peer slot to (re)register
/// before the attempt counts as failed — covers the self-host rejoin race
/// (worker thread spawned but not yet through the handshake).
const SLOT_WAIT: Duration = Duration::from_millis(500);

/// Connect budget for a fresh worker→worker peer socket; a peer that
/// cannot even accept within this is reported failed and the coordinator
/// relays instead.
const PEER_CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Idle lifetime of a pooled peer connection; reaped lazily on the next
/// `ShipTo` through the pool.
const POOL_IDLE: Duration = Duration::from_secs(30);

/// `ShipDone` status byte: the source could not deliver (connect/stream
/// failure, malformed request) — coordinator falls back to relay.
const SHIP_STATUS_FAILED: u8 = 0;
/// Delivered over a freshly opened peer connection.
const SHIP_STATUS_FRESH: u8 = 1;
/// Delivered over a pooled (reused) peer connection.
const SHIP_STATUS_POOLED: u8 = 2;
/// The source's cache no longer holds the blob (evicted) — coordinator
/// forgets the stale location and relays.
const SHIP_STATUS_MISS: u8 = 3;

/// `ShipDone` payload: `key(12) status(1) bytes:u64(le) nanos:u64(le)`.
const SHIP_DONE_BYTES: usize = KEY_BYTES + 1 + 8 + 8;

fn encode_key(key: DataKey) -> [u8; KEY_BYTES] {
    let mut out = [0u8; KEY_BYTES];
    out[..8].copy_from_slice(&key.data.0.to_le_bytes());
    out[8..].copy_from_slice(&key.version.to_le_bytes());
    out
}

fn decode_key(payload: &[u8]) -> Result<DataKey> {
    if payload.len() < KEY_BYTES {
        bail!("key payload too short: {} bytes", payload.len());
    }
    Ok(DataKey {
        data: DataId(u64::from_le_bytes(payload[..8].try_into().unwrap())),
        version: u32::from_le_bytes(payload[8..KEY_BYTES].try_into().unwrap()),
    })
}

/// The worker-side replica store: byte-budgeted **LRU** of serialized
/// blobs. A `get` (local claim read *or* an outbound direct ship) renews
/// the entry, so a hot replica fanning out to many peers is never evicted
/// mid-fan-out by colder traffic. Eviction is silent — the coordinator
/// treats a miss as exactly that and falls back to its own tiers (which
/// still hold every live version's bytes or lineage). Blobs are
/// `Arc<[u8]>` so an outbound peer stream borrows the bytes without
/// copying them.
struct BlobCache {
    budget: u64,
    used: u64,
    /// Recency order, least-recent at the front.
    order: VecDeque<DataKey>,
    blobs: HashMap<DataKey, Arc<[u8]>>,
}

impl BlobCache {
    fn new(budget: u64) -> BlobCache {
        BlobCache {
            budget: budget.max(1),
            used: 0,
            order: VecDeque::new(),
            blobs: HashMap::new(),
        }
    }

    fn insert(&mut self, key: DataKey, blob: Arc<[u8]>) {
        if let Some(old) = self.blobs.remove(&key) {
            self.used -= old.len() as u64;
            self.order.retain(|k| *k != key);
        }
        self.used += blob.len() as u64;
        self.order.push_back(key);
        self.blobs.insert(key, blob);
        while self.used > self.budget && self.order.len() > 1 {
            if let Some(victim) = self.order.pop_front() {
                if let Some(b) = self.blobs.remove(&victim) {
                    self.used -= b.len() as u64;
                }
            }
        }
    }

    fn get(&mut self, key: DataKey) -> Option<Arc<[u8]>> {
        let blob = self.blobs.get(&key).cloned()?;
        // LRU touch: move the key to the most-recent end.
        self.order.retain(|k| *k != key);
        self.order.push_back(key);
        Some(blob)
    }
}

/// See the module docs. Constructed by `Coordinator::start` via
/// [`TcpTransport::bind`] + [`TcpTransport::wait_registered`].
pub struct TcpTransport {
    nodes: u32,
    /// Slot per node id; slot 0 (coordinator-resident) stays `None`. The
    /// mutex is held across one request/reply exchange, serializing the
    /// movers' use of each worker's socket.
    peers: Vec<Mutex<Option<TcpStream>>>,
    /// Per-node peer-listener address (registration-socket IP + the port
    /// the worker announced in `Hello`); `None` until registered or for a
    /// worker too old to announce one — such a node is relay-only.
    ship_addrs: Vec<Mutex<Option<SocketAddr>>>,
    listen_addr: SocketAddr,
    /// Self-hosted loopback workers (threads) vs. external processes.
    self_host: bool,
    worker_budget: u64,
    /// Shared registration secret; `None` disables auth.
    token: Option<String>,
    /// Direct worker-to-worker shipping (on by default; `--p2p off` /
    /// `RCOMPSS_P2P=off` forces every blob through the relay path).
    p2p: bool,
    /// Which worker caches are *believed* to hold each key — noted on
    /// every successful relay, seed, or direct ship; pruned on node death,
    /// version GC, and `ShipDone` miss reports. Stale entries are safe:
    /// the source answers "miss" and the fetch relays.
    /// Lock order: `cache_locs` before any `peers` slot, never reverse.
    cache_locs: Mutex<HashMap<DataKey, Vec<u32>>>,
    direct_ships: AtomicU64,
    relay_ships: AtomicU64,
    seed_ships: AtomicU64,
    pool_hits: AtomicU64,
    /// Coordinator→worker request bytes (frame header + payload) — relay
    /// `Put`s count their blob here, `ShipTo` counts only the tiny
    /// control frame. The p2p win is this gauge staying O(1) per version
    /// on fan-out instead of O(nodes).
    egress_bytes: AtomicU64,
    shutting_down: AtomicBool,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl TcpTransport {
    /// Bind the registration listener and start the acceptor (plus the
    /// loopback worker threads in self-host mode). Non-blocking: pair
    /// with [`TcpTransport::wait_registered`] before serving traffic.
    pub fn bind(
        nodes: u32,
        listen: Option<&str>,
        self_host: bool,
        worker_budget: u64,
        token: Option<String>,
        p2p: bool,
    ) -> Result<Arc<TcpTransport>> {
        let addr = listen.unwrap_or("127.0.0.1:0");
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("tcp transport: cannot listen on {addr}"))?;
        let listen_addr = listener.local_addr()?;
        let t = Arc::new(TcpTransport {
            nodes: nodes.max(1),
            peers: (0..nodes.max(1)).map(|_| Mutex::new(None)).collect(),
            ship_addrs: (0..nodes.max(1)).map(|_| Mutex::new(None)).collect(),
            listen_addr,
            self_host,
            worker_budget,
            token,
            p2p,
            cache_locs: Mutex::new(HashMap::new()),
            direct_ships: AtomicU64::new(0),
            relay_ships: AtomicU64::new(0),
            seed_ships: AtomicU64::new(0),
            pool_hits: AtomicU64::new(0),
            egress_bytes: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            threads: Mutex::new(Vec::new()),
        });
        let mut threads = t.threads.lock().unwrap();
        let acceptor = Arc::clone(&t);
        threads.push(
            std::thread::Builder::new()
                .name("rcompss-accept".into())
                .spawn(move || acceptor.accept_loop(listener))
                .expect("spawn acceptor"),
        );
        if self_host {
            for n in 1..nodes.max(1) {
                threads.push(spawn_loopback_worker(
                    listen_addr,
                    n,
                    worker_budget,
                    t.token.clone(),
                ));
            }
        }
        drop(threads);
        Ok(t)
    }

    /// The address workers connect to (the ephemeral port in self-host
    /// mode, the `--listen` address otherwise).
    pub fn listen_addr(&self) -> SocketAddr {
        self.listen_addr
    }

    /// Block until every slot `1..nodes` holds a registered worker, or
    /// fail after `timeout` naming the missing slots.
    pub fn wait_registered(&self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let missing: Vec<u32> = (1..self.nodes)
                .filter(|n| self.peers[*n as usize].lock().unwrap().is_none())
                .collect();
            if missing.is_empty() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                bail!(
                    "tcp transport: nodes {missing:?} never registered on {} \
                     (start them with: rcompss worker --connect {})",
                    self.listen_addr,
                    self.listen_addr
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Registration loop: accept, handshake (`Hello` → `Assign`), park
    /// the stream in its node slot. One bad handshake never kills the
    /// acceptor; shutdown is signalled by the flag plus a dummy connect.
    ///
    /// `Hello` payload: `preferred:u32(le)` followed (since the p2p
    /// fabric) by `peer_port:u16(le)` and the raw token bytes. The old
    /// 4-byte shape still parses — such a worker is relay-only and, when
    /// a token is configured, rejected like any other mismatch.
    fn accept_loop(self: Arc<Self>, listener: TcpListener) {
        for stream in listener.incoming() {
            if self.shutting_down.load(Ordering::SeqCst) {
                return;
            }
            let Ok(mut stream) = stream else { continue };
            let _ = stream.set_nodelay(true);
            // The handshake is bounded so a connect-and-stall client
            // cannot wedge registration forever.
            let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
            let (hello, peer_port, supplied) = match read_frame(&mut stream) {
                Ok(Frame {
                    kind: FrameKind::Hello,
                    payload,
                }) if payload.len() >= 4 => {
                    let preferred = u32::from_le_bytes(payload[..4].try_into().unwrap());
                    let peer_port = if payload.len() >= 6 {
                        u16::from_le_bytes(payload[4..6].try_into().unwrap())
                    } else {
                        0
                    };
                    let supplied = payload.get(6..).unwrap_or(&[]).to_vec();
                    (preferred, peer_port, supplied)
                }
                _ => continue,
            };
            if let Some(expected) = &self.token {
                if supplied != expected.as_bytes() {
                    let _ = write_frame(
                        &mut stream,
                        FrameKind::Error,
                        b"bad token: registration rejected \
                          (pass the cluster secret via --token / RCOMPSS_TOKEN)",
                    );
                    continue;
                }
            }
            let assigned = self.assign_slot(hello, &stream);
            match assigned {
                Some(node) => {
                    let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
                    if write_frame(&mut stream, FrameKind::Assign, &node.to_le_bytes()).is_err() {
                        *self.peers[node as usize].lock().unwrap() = None;
                    } else if peer_port != 0 {
                        // Peer listener = the worker's announced port at
                        // the IP its registration socket came from.
                        if let Ok(remote) = stream.peer_addr() {
                            *self.ship_addrs[node as usize].lock().unwrap() =
                                Some(SocketAddr::new(remote.ip(), peer_port));
                        }
                    }
                }
                None => {
                    let _ = write_frame(
                        &mut stream,
                        FrameKind::Error,
                        b"no free node slot (cluster full)",
                    );
                }
            }
        }
    }

    /// Pick the slot for a registering worker: its preferred node if that
    /// slot is free, else the lowest free slot. Stores the stream.
    fn assign_slot(&self, preferred: u32, stream: &TcpStream) -> Option<u32> {
        let candidates: Vec<u32> = if preferred != ANY_NODE {
            std::iter::once(preferred)
                .chain((1..self.nodes).filter(|n| *n != preferred))
                .collect()
        } else {
            (1..self.nodes).collect()
        };
        for n in candidates {
            if n == 0 || n >= self.nodes {
                continue;
            }
            let mut slot = self.peers[n as usize].lock().unwrap();
            if slot.is_none() {
                *slot = stream.try_clone().ok();
                if slot.is_some() {
                    return Some(n);
                }
            }
        }
        None
    }

    /// One request/reply exchange on `node`'s socket. Any error poisons
    /// the slot (socket closed and cleared) so the caller's retry path
    /// sees a clean "not registered" state. Every request is counted into
    /// the coordinator egress gauge.
    fn exchange(&self, node: NodeId, kind: FrameKind, payload: &[u8]) -> Result<Frame> {
        self.egress_bytes
            .fetch_add(FRAME_HEADER_BYTES + payload.len() as u64, Ordering::Relaxed);
        let mut slot = self.peers[node.0 as usize].lock().unwrap();
        let Some(stream) = slot.as_mut() else {
            bail!("node {} has no registered worker", node.0);
        };
        let run = (|| -> Result<Frame> {
            write_frame(stream, kind, payload)?;
            read_frame(stream)
        })();
        if run.is_err() {
            if let Some(s) = slot.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        run
    }

    /// Relay-ship a blob to `node`'s replica cache, retrying with the
    /// transfer board's deterministic backoff. `false` means the
    /// destination is unreachable after the budget — the caller maps that
    /// to a node death.
    fn ship(&self, key: DataKey, node: NodeId, blob: &[u8]) -> bool {
        let mut payload = Vec::with_capacity(KEY_BYTES + blob.len());
        payload.extend_from_slice(&encode_key(key));
        payload.extend_from_slice(blob);
        for attempt in 1..=SHIP_ATTEMPTS {
            // Cover the (re)registration race: a rejoining worker may be
            // mid-handshake when the first post-revive transfer lands.
            let wait_deadline = Instant::now() + SLOT_WAIT;
            while self.peers[node.0 as usize].lock().unwrap().is_none()
                && Instant::now() < wait_deadline
                && !self.shutting_down.load(Ordering::SeqCst)
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            match self.exchange(node, FrameKind::Put, &payload) {
                Ok(Frame {
                    kind: FrameKind::PutOk,
                    ..
                }) => return true,
                Ok(f) => {
                    eprintln!(
                        "tcp transport: node {} answered Put with {:?}",
                        node.0, f.kind
                    );
                }
                Err(_) => {}
            }
            if self.shutting_down.load(Ordering::SeqCst) {
                return false;
            }
            if attempt < SHIP_ATTEMPTS {
                std::thread::sleep(retry_backoff(key, node, attempt));
            }
        }
        false
    }

    /// Pull a blob back from `node`'s replica cache (`Get` → `Blob` |
    /// `NotFound`) — the last-resort source when the coordinator's own
    /// tiers lost the bytes.
    fn get_remote(&self, node: NodeId, key: DataKey) -> Result<Option<Arc<[u8]>>> {
        match self.exchange(node, FrameKind::Get, &encode_key(key))? {
            Frame {
                kind: FrameKind::Blob,
                payload,
            } => Ok(Some(Arc::from(payload.into_boxed_slice()))),
            Frame {
                kind: FrameKind::NotFound,
                ..
            } => Ok(None),
            f => bail!("node {} answered Get with {:?}", node.0, f.kind),
        }
    }

    /// Note that `node`'s cache (should) hold `key`.
    fn cache_note(&self, key: DataKey, node: u32) {
        if node == 0 {
            return;
        }
        let mut locs = self.cache_locs.lock().unwrap();
        let v = locs.entry(key).or_default();
        if !v.contains(&node) {
            v.push(node);
        }
    }

    /// Drop a stale location claim (the source answered "miss").
    fn cache_forget(&self, key: DataKey, node: u32) {
        let mut locs = self.cache_locs.lock().unwrap();
        if let Some(v) = locs.get_mut(&key) {
            v.retain(|n| *n != node);
            if v.is_empty() {
                locs.remove(&key);
            }
        }
    }

    /// Pick (or create) a worker-side source for a direct ship of `key`
    /// toward `to`: a live, peer-capable worker whose cache is believed
    /// to hold the blob. When none exists — the version is fresh, its
    /// bytes live only coordinator-side — **seed** the transfer-hint
    /// worker (`from`, the producer's node) with one relay `Put` and use
    /// it. Holding `cache_locs` across the seed makes seeding
    /// single-flight: a concurrent fan-out mover blocks here and then
    /// finds the seeded location instead of seeding again.
    fn direct_source(
        &self,
        shared: &Shared,
        key: DataKey,
        from: Option<NodeId>,
        to: NodeId,
        blob: &[u8],
    ) -> Option<u32> {
        let mut locs = self.cache_locs.lock().unwrap();
        if let Some(nodes) = locs.get(&key) {
            for &n in nodes {
                if n != 0
                    && n != to.0
                    && shared.health.is_alive(NodeId(n))
                    && self.ship_addrs[n as usize].lock().unwrap().is_some()
                {
                    return Some(n);
                }
            }
        }
        let seed = from.filter(|s| {
            s.0 != 0
                && *s != to
                && shared.health.is_alive(*s)
                && self.ship_addrs[s.0 as usize].lock().unwrap().is_some()
        })?;
        if !self.ship(key, seed, blob) {
            return None;
        }
        self.seed_ships.fetch_add(1, Ordering::Relaxed);
        // Note inline — `cache_note` would re-lock the mutex we hold.
        let v = locs.entry(key).or_default();
        if !v.contains(&seed.0) {
            v.push(seed.0);
        }
        Some(seed.0)
    }

    /// Ask worker `src` to stream `key` directly to `to`'s peer listener.
    /// `true` means the destination's cache holds the blob and the pair
    /// bandwidth sample (measured at the source) has been recorded; any
    /// `false` means the caller should fall back to relay.
    fn ship_direct(&self, fb: Option<&FeedbackStats>, key: DataKey, src: u32, to: NodeId) -> bool {
        let dest = match *self.ship_addrs[to.0 as usize].lock().unwrap() {
            Some(a) => a.to_string(),
            None => return false,
        };
        let mut payload = Vec::with_capacity(KEY_BYTES + 4 + dest.len());
        payload.extend_from_slice(&encode_key(key));
        payload.extend_from_slice(&to.0.to_le_bytes());
        payload.extend_from_slice(dest.as_bytes());
        let reply = match self.exchange(NodeId(src), FrameKind::ShipTo, &payload) {
            Ok(f) => f,
            Err(_) => return false,
        };
        if reply.kind != FrameKind::ShipDone || reply.payload.len() < SHIP_DONE_BYTES {
            return false;
        }
        let status = reply.payload[KEY_BYTES];
        match status {
            SHIP_STATUS_FRESH | SHIP_STATUS_POOLED => {
                let bytes =
                    u64::from_le_bytes(reply.payload[KEY_BYTES + 1..KEY_BYTES + 9].try_into().unwrap());
                let nanos = u64::from_le_bytes(
                    reply.payload[KEY_BYTES + 9..SHIP_DONE_BYTES].try_into().unwrap(),
                );
                self.direct_ships.fetch_add(1, Ordering::Relaxed);
                if status == SHIP_STATUS_POOLED {
                    self.pool_hits.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(fb) = fb {
                    fb.record_transfer_pair(NodeId(src), to, bytes, nanos as f64 / 1e9);
                }
                self.cache_note(key, to.0);
                true
            }
            SHIP_STATUS_MISS => {
                self.cache_forget(key, src);
                false
            }
            _ => false,
        }
    }
}

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    /// Same staging contract as the in-process transport — warm blob
    /// first (one encode per fan-out), cold spill file as fallback, both
    /// on the owning side — plus the wire hop: the destination worker
    /// receives the blob verbatim before the coordinator publishes the
    /// replica. With p2p on the hop is attempted worker-to-worker first
    /// (seeding the producer's cache once per version); **any** direct
    /// failure — dead source, mid-stream peer death, stale location —
    /// falls back to the relay path right here, so recovery semantics are
    /// exactly the relay ones. A destination that stays unreachable
    /// through the relay retry budget is declared dead via the
    /// `kill_node` path and the transfer is dropped, never failed.
    fn fetch(
        &self,
        shared: &Shared,
        key: DataKey,
        from: Option<NodeId>,
        to: NodeId,
    ) -> Result<Option<u64>> {
        let (blob, has_file): (Arc<[u8]>, bool) = match store::stage_blob(shared, key)? {
            Some(blob) => {
                let has_file = shared.table.path_of(key).is_some();
                (blob, has_file)
            }
            None => match cold::ensure_file(shared, key) {
                Ok(path) => {
                    // Cold fallback, owning side only: the spill file
                    // already holds the encoded bytes — read them
                    // verbatim, never re-encode.
                    shared.store.cold().note_read();
                    let bytes = std::fs::read(&path)?;
                    (Arc::from(bytes.into_boxed_slice()), true)
                }
                Err(e) => {
                    // Last resort: the version's bytes are gone from
                    // every coordinator tier, but a worker's replica
                    // cache may still hold the blob.
                    let Some(src) = from.filter(|s| s.0 != 0 && *s != to) else {
                        return Err(e);
                    };
                    match self.get_remote(src, key) {
                        Ok(Some(blob)) => (blob, false),
                        _ => return Err(e),
                    }
                }
            },
        };
        let nbytes = blob.len() as u64;
        if to.0 != 0 {
            let mut shipped = false;
            if self.p2p && !self.shutting_down.load(Ordering::SeqCst) {
                if let Some(src) = self.direct_source(shared, key, from, to, &blob) {
                    shipped = self.ship_direct(shared.feedback.as_deref(), key, src, to);
                }
            }
            if !shipped {
                if !self.ship(key, to, &blob) {
                    if self.shutting_down.load(Ordering::SeqCst) {
                        return Ok(None);
                    }
                    // Unreachable after the attempt budget: fold the loss
                    // into the existing recovery plane. `kill_node_now`
                    // poisons the node's transfer pairs (`fail_node`),
                    // drops its locations, and re-executes lost versions
                    // from lineage — a dropped worker is indistinguishable
                    // from a chaos `kill_node`.
                    if shared.health.is_alive(to) {
                        eprintln!(
                            "tcp transport: node {} unreachable after {SHIP_ATTEMPTS} attempts; \
                             declaring it dead",
                            to.0
                        );
                        kill_node_now(shared, to);
                    }
                    return Ok(None);
                }
                self.relay_ships.fetch_add(1, Ordering::Relaxed);
                self.cache_note(key, to.0);
            }
        }
        let value = Arc::new(shared.codec.decode(&blob)?);
        if !publish_replica(shared, key, to, value, has_file) {
            return Ok(None);
        }
        Ok(Some(nbytes))
    }

    /// `kill_node` / transport-detected death: close and clear the slot
    /// (plus the peer-listener address and every cache-location claim) so
    /// in-flight exchanges fail fast and a future rejoin re-registers
    /// from scratch.
    fn on_node_down(&self, node: NodeId) {
        if (node.0 as usize) < self.peers.len() {
            let taken = self.peers[node.0 as usize].lock().unwrap().take();
            if let Some(s) = taken {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            *self.ship_addrs[node.0 as usize].lock().unwrap() = None;
            let mut locs = self.cache_locs.lock().unwrap();
            locs.retain(|_, v| {
                v.retain(|n| *n != node.0);
                !v.is_empty()
            });
        }
    }

    /// `add_node` rejoin: in self-host mode spawn a fresh loopback worker
    /// for the slot (the killed one's thread exited with its socket). In
    /// external mode the operator restarts `rcompss worker` — the
    /// acceptor fills the free slot whenever it arrives.
    fn on_node_up(&self, node: NodeId) {
        if self.self_host && node.0 != 0 && node.0 < self.nodes {
            let handle = spawn_loopback_worker(
                self.listen_addr,
                node.0,
                self.worker_budget,
                self.token.clone(),
            );
            self.threads.lock().unwrap().push(handle);
        }
    }

    /// Version GC: its blob is gone everywhere that matters — stop
    /// believing any worker cache still holds it.
    fn on_version_purged(&self, key: DataKey) {
        self.cache_locs.lock().unwrap().remove(&key);
    }

    fn ship_stats(&self) -> ShipStats {
        ShipStats {
            direct_ships: self.direct_ships.load(Ordering::Relaxed),
            relay_ships: self.relay_ships.load(Ordering::Relaxed),
            seed_ships: self.seed_ships.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            egress_bytes: self.egress_bytes.load(Ordering::Relaxed),
        }
    }

    /// Orderly teardown: flag, `Shutdown` frame + close per peer, dummy
    /// connect to unblock the acceptor, join every thread.
    fn shutdown(&self) {
        self.shutting_down.store(true, Ordering::SeqCst);
        for slot in &self.peers {
            if let Some(mut s) = slot.lock().unwrap().take() {
                let _ = write_frame(&mut s, FrameKind::Shutdown, &[]);
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        let _ = TcpStream::connect(self.listen_addr);
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

fn spawn_loopback_worker(
    addr: SocketAddr,
    node: u32,
    budget: u64,
    token: Option<String>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("rcompss-worker-{node}"))
        .spawn(move || {
            let _ = run_worker(&addr.to_string(), Some(node), budget, true, token.as_deref());
        })
        .expect("spawn loopback worker")
}

/// Per-destination pool of outbound peer sockets on the source worker.
/// Keyed by the destination's peer-listener address; idle entries are
/// reaped lazily on the next ship. A pooled socket that turns out stale
/// (destination restarted, idle-closed underneath us) costs one failed
/// attempt — the ship retries once on a fresh connection.
struct PeerPool {
    conns: HashMap<String, (TcpStream, Instant)>,
}

impl PeerPool {
    fn new() -> PeerPool {
        PeerPool {
            conns: HashMap::new(),
        }
    }

    /// Stream one blob to `dest`, pooling the connection afterwards.
    /// `Ok(true)` = delivered over a reused connection (a pool hit).
    fn ship(
        &mut self,
        dest: &str,
        my_node: u32,
        token: Option<&str>,
        id: [u8; 12],
        blob: &[u8],
    ) -> Result<bool> {
        self.reap();
        if let Some((mut s, _)) = self.conns.remove(dest) {
            if stream_blob(&mut s, id, blob).is_ok() {
                self.conns.insert(dest.to_owned(), (s, Instant::now()));
                return Ok(true);
            }
        }
        let mut s = peer_connect(dest, my_node, token)?;
        stream_blob(&mut s, id, blob)?;
        self.conns.insert(dest.to_owned(), (s, Instant::now()));
        Ok(false)
    }

    fn reap(&mut self) {
        self.conns.retain(|_, (s, last)| {
            if last.elapsed() <= POOL_IDLE {
                true
            } else {
                let _ = s.shutdown(std::net::Shutdown::Both);
                false
            }
        });
    }

    fn close_all(&mut self) {
        for (_, (s, _)) in self.conns.drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Open and authenticate a fresh peer connection: `Hello { my_node,
/// token }` → `Assign` (accepted) | `Error` (bad token).
fn peer_connect(dest: &str, my_node: u32, token: Option<&str>) -> Result<TcpStream> {
    let addr: SocketAddr = dest
        .parse()
        .with_context(|| format!("bad peer address {dest:?}"))?;
    let mut s = TcpStream::connect_timeout(&addr, PEER_CONNECT_TIMEOUT)
        .with_context(|| format!("cannot reach peer {dest}"))?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(REPLY_TIMEOUT));
    let mut hello = Vec::with_capacity(4 + token.map_or(0, str::len));
    hello.extend_from_slice(&my_node.to_le_bytes());
    if let Some(tok) = token {
        hello.extend_from_slice(tok.as_bytes());
    }
    write_frame(&mut s, FrameKind::Hello, &hello)?;
    match read_frame(&mut s)? {
        Frame {
            kind: FrameKind::Assign,
            ..
        } => Ok(s),
        Frame {
            kind: FrameKind::Error,
            payload,
        } => bail!(
            "peer {dest} refused connection: {}",
            String::from_utf8_lossy(&payload)
        ),
        f => bail!("unexpected peer handshake reply: {:?}", f.kind),
    }
}

/// Stream one blob as bounded `BlobChunk` frames and wait for the
/// receiver's `PutOk` — the single ack covers the whole blob.
fn stream_blob(s: &mut TcpStream, id: [u8; 12], blob: &[u8]) -> Result<()> {
    write_blob_chunks(s, id, blob)?;
    s.flush()?;
    match read_frame(s)? {
        Frame {
            kind: FrameKind::PutOk,
            ..
        } => Ok(()),
        Frame {
            kind: FrameKind::Error,
            payload,
        } => bail!("peer rejected blob: {}", String::from_utf8_lossy(&payload)),
        f => bail!("unexpected blob ack: {:?}", f.kind),
    }
}

/// Source-side handling of one `ShipTo { key, dest_node, dest_addr }`:
/// look the blob up in the local cache (an LRU touch — fan-out keeps it
/// hot) and stream it to the destination peer. Always returns a
/// `ShipDone` payload; failures are reported as a status byte, never as
/// a dead coordinator socket.
fn handle_ship_to(
    payload: &[u8],
    my_node: u32,
    cache: &Arc<Mutex<BlobCache>>,
    pool: &mut PeerPool,
    token: Option<&str>,
) -> Vec<u8> {
    let mut done = vec![0u8; SHIP_DONE_BYTES];
    if payload.len() < KEY_BYTES + 4 {
        return done; // SHIP_STATUS_FAILED with a zero key
    }
    done[..KEY_BYTES].copy_from_slice(&payload[..KEY_BYTES]);
    let Ok(key) = decode_key(payload) else {
        return done;
    };
    let Ok(dest) = std::str::from_utf8(&payload[KEY_BYTES + 4..]) else {
        return done;
    };
    if dest.is_empty() {
        return done;
    }
    let Some(blob) = cache.lock().unwrap().get(key) else {
        done[KEY_BYTES] = SHIP_STATUS_MISS;
        return done;
    };
    let id: [u8; 12] = payload[..KEY_BYTES].try_into().unwrap();
    let t0 = Instant::now();
    match pool.ship(dest, my_node, token, id, &blob) {
        Ok(pooled) => {
            done[KEY_BYTES] = if pooled {
                SHIP_STATUS_POOLED
            } else {
                SHIP_STATUS_FRESH
            };
            done[KEY_BYTES + 1..KEY_BYTES + 9]
                .copy_from_slice(&(blob.len() as u64).to_le_bytes());
            done[KEY_BYTES + 9..SHIP_DONE_BYTES]
                .copy_from_slice(&(t0.elapsed().as_nanos() as u64).to_le_bytes());
        }
        Err(_) => {} // status stays SHIP_STATUS_FAILED → coordinator relays
    }
    done
}

/// Destination-side peer server: accept inbound peer connections and
/// hand each to its own handler thread. Inbound streams are tracked so
/// worker teardown can unblock the (blocking) handler reads.
fn peer_accept_loop(
    listener: TcpListener,
    cache: Arc<Mutex<BlobCache>>,
    token: Option<String>,
    stop: Arc<AtomicBool>,
    inbound: Arc<Mutex<Vec<TcpStream>>>,
) {
    let mut handlers = Vec::new();
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let _ = stream.set_nodelay(true);
        if let Ok(clone) = stream.try_clone() {
            inbound.lock().unwrap().push(clone);
        }
        let cache = Arc::clone(&cache);
        let token = token.clone();
        if let Ok(h) = std::thread::Builder::new()
            .name("rcompss-peer".into())
            .spawn(move || serve_peer(stream, cache, token))
        {
            handlers.push(h);
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// One inbound peer connection: authenticate, then assemble in-order
/// `BlobChunk` streams into the local cache, acking each completed blob
/// with a single `PutOk`. Any protocol violation (out-of-order offset,
/// CRC mismatch, inconsistent totals) earns an `Error` frame and a
/// closed connection — the source maps that to a failed ship and the
/// coordinator relays.
fn serve_peer(mut stream: TcpStream, cache: Arc<Mutex<BlobCache>>, token: Option<String>) {
    let hello = match read_frame(&mut stream) {
        Ok(Frame {
            kind: FrameKind::Hello,
            payload,
        }) if payload.len() >= 4 => payload,
        _ => return,
    };
    if let Some(expected) = &token {
        if hello.get(4..).unwrap_or(&[]) != expected.as_bytes() {
            let _ = write_frame(
                &mut stream,
                FrameKind::Error,
                b"bad token: peer connection rejected",
            );
            return;
        }
    }
    if write_frame(&mut stream, FrameKind::Assign, &[]).is_err() {
        return;
    }
    let _ = stream.flush();
    // One blob in flight per connection: (key, bytes so far, total).
    let mut pending: Option<(DataKey, Vec<u8>, u64)> = None;
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        match frame.kind {
            FrameKind::BlobChunk => {
                let chunk = match decode_chunk(&frame.payload) {
                    Ok(c) => c,
                    Err(e) => {
                        let _ = write_frame(
                            &mut stream,
                            FrameKind::Error,
                            format!("bad chunk: {e}").as_bytes(),
                        );
                        return;
                    }
                };
                let Ok(key) = decode_key(&chunk.id) else {
                    let _ = write_frame(&mut stream, FrameKind::Error, b"bad chunk key");
                    return;
                };
                if chunk.offset == 0 {
                    // Bounded prealloc: trust `total` only up to a cap so
                    // a lying header cannot balloon memory up front.
                    let cap = (chunk.total as usize).min(8 << 20);
                    pending = Some((key, Vec::with_capacity(cap), chunk.total));
                }
                let Some((pkey, buf, total)) = pending.as_mut() else {
                    let _ = write_frame(&mut stream, FrameKind::Error, b"chunk with no open blob");
                    return;
                };
                if *pkey != key || chunk.offset != buf.len() as u64 || chunk.total != *total {
                    let _ = write_frame(&mut stream, FrameKind::Error, b"out-of-order chunk");
                    return;
                }
                buf.extend_from_slice(&chunk.data);
                if buf.len() as u64 == *total {
                    let (key, buf, _) = pending.take().unwrap();
                    cache
                        .lock()
                        .unwrap()
                        .insert(key, Arc::from(buf.into_boxed_slice()));
                    if write_frame(&mut stream, FrameKind::PutOk, &[]).is_err() {
                        return;
                    }
                    let _ = stream.flush();
                }
            }
            FrameKind::Shutdown => return,
            other => {
                let _ = write_frame(
                    &mut stream,
                    FrameKind::Error,
                    format!("unexpected peer frame {other:?}").as_bytes(),
                );
                return;
            }
        }
    }
}

/// Body of `rcompss worker --connect <addr>` (and of the self-hosted
/// loopback worker threads): register (announcing the peer-listener port
/// and the shared token), then serve the replica cache — coordinator
/// `Put`/`Get`/`ShipTo` on the registration socket, inbound peer streams
/// on the peer listener — until the coordinator says `Shutdown` or the
/// socket dies. Connection is retried for ~10 s so workers may start
/// before (or racing) the coordinator.
pub fn run_worker(
    addr: &str,
    preferred: Option<u32>,
    budget: u64,
    quiet: bool,
    token: Option<&str>,
) -> Result<()> {
    let mut stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    // Direct worker-to-worker streams land on this listener; its port
    // rides in the Hello so the coordinator can hand out our address.
    let peer_listener = TcpListener::bind(SocketAddr::new(stream.local_addr()?.ip(), 0))?;
    let peer_addr = peer_listener.local_addr()?;
    let mut hello = Vec::with_capacity(6 + token.map_or(0, str::len));
    hello.extend_from_slice(&preferred.unwrap_or(ANY_NODE).to_le_bytes());
    hello.extend_from_slice(&peer_addr.port().to_le_bytes());
    if let Some(tok) = token {
        hello.extend_from_slice(tok.as_bytes());
    }
    write_frame(&mut stream, FrameKind::Hello, &hello)?;
    let node = match read_frame(&mut stream)? {
        Frame {
            kind: FrameKind::Assign,
            payload,
        } if payload.len() >= 4 => u32::from_le_bytes(payload[..4].try_into().unwrap()),
        Frame {
            kind: FrameKind::Error,
            payload,
        } => bail!(
            "registration refused: {}",
            String::from_utf8_lossy(&payload)
        ),
        f => bail!("unexpected registration reply: {:?}", f.kind),
    };
    if !quiet {
        println!(
            "rcompss worker: registered as node {node} on {addr} \
             (budget {budget} B, peer {peer_addr})"
        );
    }
    let cache = Arc::new(Mutex::new(BlobCache::new(budget)));
    let stop = Arc::new(AtomicBool::new(false));
    let inbound: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let acceptor = {
        let cache = Arc::clone(&cache);
        let token = token.map(str::to_owned);
        let stop = Arc::clone(&stop);
        let inbound = Arc::clone(&inbound);
        std::thread::Builder::new()
            .name(format!("rcompss-peer-accept-{node}"))
            .spawn(move || peer_accept_loop(peer_listener, cache, token, stop, inbound))
            .expect("spawn peer acceptor")
    };
    let mut pool = PeerPool::new();
    let result = (|| -> Result<()> {
        loop {
            let frame = match read_frame(&mut stream) {
                Ok(f) => f,
                // Coordinator gone (EOF/reset): a worker has no state
                // worth saving — exit quietly.
                Err(_) => return Ok(()),
            };
            match frame.kind {
                FrameKind::Put => {
                    let key = decode_key(&frame.payload)?;
                    let blob: Arc<[u8]> = Arc::from(&frame.payload[KEY_BYTES..]);
                    cache.lock().unwrap().insert(key, blob);
                    write_frame(&mut stream, FrameKind::PutOk, &[])?;
                }
                FrameKind::Get => {
                    let key = decode_key(&frame.payload)?;
                    let blob = cache.lock().unwrap().get(key);
                    match blob {
                        Some(blob) => write_frame(&mut stream, FrameKind::Blob, &blob)?,
                        None => write_frame(&mut stream, FrameKind::NotFound, &[])?,
                    }
                }
                FrameKind::ShipTo => {
                    let done = handle_ship_to(&frame.payload, node, &cache, &mut pool, token);
                    write_frame(&mut stream, FrameKind::ShipDone, &done)?;
                }
                FrameKind::Shutdown => return Ok(()),
                other => {
                    let msg = format!("unexpected frame {other:?}");
                    write_frame(&mut stream, FrameKind::Error, msg.as_bytes())?;
                }
            }
            stream.flush()?;
        }
    })();
    // Teardown: stop the peer plane — flag, close outbound pool and
    // tracked inbound streams (unblocks handler reads), dummy connect to
    // unblock the acceptor, join (the acceptor joins its handlers).
    stop.store(true, Ordering::SeqCst);
    pool.close_all();
    for s in inbound.lock().unwrap().drain(..) {
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
    let _ = TcpStream::connect(peer_addr);
    let _ = acceptor.join();
    result
}

fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                bail!("cannot connect to coordinator at {addr}: {e}");
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(d: u64, v: u32) -> DataKey {
        DataKey {
            data: DataId(d),
            version: v,
        }
    }

    fn blob(len: usize, fill: u8) -> Arc<[u8]> {
        Arc::from(vec![fill; len].into_boxed_slice())
    }

    #[test]
    fn blob_cache_evicts_lru_within_budget() {
        let mut c = BlobCache::new(100);
        c.insert(key(1, 1), blob(40, 0));
        c.insert(key(2, 1), blob(40, 0));
        // Touch (1,1): it becomes most-recent, so the next eviction takes
        // (2,1) — the least recently used — not the oldest-inserted.
        assert!(c.get(key(1, 1)).is_some());
        c.insert(key(3, 1), blob(40, 0));
        assert!(c.get(key(2, 1)).is_none());
        assert!(c.get(key(1, 1)).is_some());
        assert!(c.get(key(3, 1)).is_some());
        // Re-inserting an existing key replaces, never double-counts.
        c.insert(key(3, 1), blob(60, 1));
        assert_eq!(c.get(key(3, 1)).unwrap().len(), 60);
        // A single over-budget blob is still held (the floor keeps one).
        c.insert(key(4, 1), blob(400, 0));
        assert!(c.get(key(4, 1)).is_some());
    }

    #[test]
    fn blob_cache_get_renews_against_fanout_eviction() {
        // The fan-out pattern that motivated LRU: one hot replica being
        // shipped to many peers (a get per ship) while colder inserts
        // stream through. FIFO would evict the hot blob; LRU never does.
        let mut c = BlobCache::new(100);
        c.insert(key(7, 1), blob(40, 7));
        for d in 0..8u64 {
            assert!(c.get(key(7, 1)).is_some(), "hot blob evicted at step {d}");
            c.insert(key(100 + d, 1), blob(40, 0));
        }
        assert!(c.get(key(7, 1)).is_some());
    }

    #[test]
    fn key_codec_roundtrips() {
        let k = key(0xDEAD_BEEF_1234, 77);
        assert_eq!(decode_key(&encode_key(k)).unwrap(), k);
        assert!(decode_key(&[0u8; 4]).is_err());
    }

    #[test]
    fn external_registration_ship_and_get_roundtrip() {
        // 3 nodes: coordinator-resident 0 plus two external workers that
        // connect like `rcompss worker` processes would.
        let t = TcpTransport::bind(3, Some("127.0.0.1:0"), false, 1 << 20, None, true).unwrap();
        let addr = t.listen_addr().to_string();
        let (a1, a2) = (addr.clone(), addr.clone());
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 20, true, None));
        let w2 = std::thread::spawn(move || run_worker(&a2, Some(2), 1 << 20, true, None));
        t.wait_registered(Duration::from_secs(5)).unwrap();

        let k = key(42, 7);
        let blob: Vec<u8> = (0..1024u32).map(|b| b as u8).collect();
        assert!(t.ship(k, NodeId(1), &blob));
        assert!(t.ship(k, NodeId(2), &blob));
        // The blob comes back verbatim from the worker's replica cache.
        let back = t.get_remote(NodeId(1), k).unwrap().unwrap();
        assert_eq!(&back[..], &blob[..]);
        // A key never shipped is a clean miss, not an error.
        assert!(t.get_remote(NodeId(2), key(9, 9)).unwrap().is_none());

        t.shutdown();
        w1.join().unwrap().unwrap();
        w2.join().unwrap().unwrap();
    }

    #[test]
    fn direct_ship_streams_worker_to_worker_and_pools_the_link() {
        let t = TcpTransport::bind(3, Some("127.0.0.1:0"), false, 1 << 24, None, true).unwrap();
        let addr = t.listen_addr().to_string();
        let (a1, a2) = (addr.clone(), addr.clone());
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 24, true, None));
        let w2 = std::thread::spawn(move || run_worker(&a2, Some(2), 1 << 24, true, None));
        t.wait_registered(Duration::from_secs(5)).unwrap();

        // Two blobs on worker 1 — the second spans multiple chunks so the
        // streamed reassembly is exercised end to end.
        let k1 = key(1, 1);
        let k2 = key(2, 1);
        let b1: Vec<u8> = (0..4096u32).map(|b| (b % 251) as u8).collect();
        let b2: Vec<u8> = (0..(crate::serialization::wire::CHUNK_BYTES + 777))
            .map(|b| (b % 253) as u8)
            .collect();
        assert!(t.ship(k1, NodeId(1), &b1));
        assert!(t.ship(k2, NodeId(1), &b2));

        // Direct-ship both 1 → 2; the second ship reuses the pooled peer
        // connection (a pool hit, reported by the source in ShipDone).
        assert!(t.ship_direct(None, k1, 1, NodeId(2)));
        assert!(t.ship_direct(None, k2, 1, NodeId(2)));
        let s = t.ship_stats();
        assert_eq!(s.direct_ships, 2);
        assert_eq!(s.pool_hits, 1);

        // The bytes landed verbatim in the destination's cache.
        assert_eq!(&t.get_remote(NodeId(2), k1).unwrap().unwrap()[..], &b1[..]);
        assert_eq!(&t.get_remote(NodeId(2), k2).unwrap().unwrap()[..], &b2[..]);

        // A stale location claim is a reported miss, not a hang: the
        // source answers SHIP_STATUS_MISS and the claim is forgotten.
        let k3 = key(3, 1);
        t.cache_note(k3, 1);
        assert!(!t.ship_direct(None, k3, 1, NodeId(2)));
        assert_eq!(t.ship_stats().direct_ships, 2);
        assert!(t.cache_locs.lock().unwrap().get(&k3).is_none());

        t.shutdown();
        w1.join().unwrap().unwrap();
        w2.join().unwrap().unwrap();
    }

    #[test]
    fn token_mismatch_is_rejected_cleanly() {
        let t = TcpTransport::bind(
            2,
            Some("127.0.0.1:0"),
            false,
            1 << 20,
            Some("sesame".into()),
            true,
        )
        .unwrap();
        let addr = t.listen_addr().to_string();
        // Wrong token: refused with a message naming the knob.
        let err = run_worker(&addr, Some(1), 1 << 20, true, Some("guess"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("bad token"), "{err}");
        // No token at all: same refusal.
        let err = run_worker(&addr, Some(1), 1 << 20, true, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bad token"), "{err}");
        // Right token: registers normally.
        let a1 = addr.clone();
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 20, true, Some("sesame")));
        t.wait_registered(Duration::from_secs(5)).unwrap();
        t.shutdown();
        w1.join().unwrap().unwrap();
    }

    #[test]
    fn preferred_slot_collision_falls_to_lowest_free() {
        let t = TcpTransport::bind(3, Some("127.0.0.1:0"), false, 1 << 20, None, true).unwrap();
        let addr = t.listen_addr().to_string();
        let (a1, a2) = (addr.clone(), addr.clone());
        // Both prefer node 1: one gets it, the other falls to slot 2.
        let w1 = std::thread::spawn(move || run_worker(&a1, Some(1), 1 << 20, true, None));
        let w2 = std::thread::spawn(move || run_worker(&a2, Some(1), 1 << 20, true, None));
        t.wait_registered(Duration::from_secs(5)).unwrap();
        t.shutdown();
        w1.join().unwrap().unwrap();
        w2.join().unwrap().unwrap();
    }

    #[test]
    fn unregistered_cluster_times_out_with_join_hint() {
        let t = TcpTransport::bind(2, Some("127.0.0.1:0"), false, 1 << 20, None, true).unwrap();
        let err = t
            .wait_registered(Duration::from_millis(50))
            .unwrap_err()
            .to_string();
        assert!(err.contains("rcompss worker --connect"), "{err}");
        // Shipping toward the empty slot fails cleanly (no panic, no hang
        // beyond the bounded slot wait + backoff).
        assert!(!t.ship(key(1, 1), NodeId(1), b"bytes"));
        // So does a direct ship toward it (no peer address registered).
        assert!(!t.ship_direct(None, key(1, 1), 1, NodeId(1)));
        t.shutdown();
    }
}
