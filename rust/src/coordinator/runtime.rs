//! The orchestrator: glue between the API, the dependency machinery, the
//! scheduler, and the persistent worker pool.
//!
//! This is the RCOMPSs `Core` module of Figure 1b: it performs "all
//! necessary actions for task preparation (parameter serialization, task
//! registry, and object tracking) and COMPSs requests for execution or data
//! retrieval". The master thread runs the user's sequential program;
//! [`Coordinator::submit`] analyzes each call's data accesses against the
//! versioned registry, inserts the task into the DAG, and hands ready tasks
//! to the sharded dispatch fabric, while persistent workers (see
//! [`super::executor`]) pull, gather inputs, execute, and publish outputs
//! asynchronously.
//!
//! Locking layout (see `coordinator/mod.rs` § *Data plane & locking* and
//! `ARCHITECTURE.md` at the repository root): the control lock (`Core`)
//! now guards only the DAG, the dependency half of the registry, task
//! metadata, and stats. Ready-task dispatch lives in [`ShardedReady`],
//! version locations in the sharded
//! [`VersionTable`](crate::coordinator::registry::VersionTable), produced
//! values in the tiered [`TieredStore`] (hot `Arc<RValue>`s, warm encoded
//! blobs, cold spill files), and cross-node staging in the
//! [`TransferService`] — workers touch the control lock only to flip task
//! states.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::access::Direction;
use crate::coordinator::compile::{self, WindowCtx, WindowTask};
use crate::coordinator::dag::{EdgeKind, TaskGraph, TaskId, TaskState};
use crate::coordinator::executor;
use crate::coordinator::fault::{ChaosSpec, FailureInjector, NodeHealth, RetryPolicy};
use crate::coordinator::feedback::FeedbackStats;
use crate::coordinator::placement::{placement_by_name, InflightSource};
use crate::coordinator::registry::{CollectAction, DataKey, DataRegistry, NodeId, VersionTable};
use crate::coordinator::schedfuzz::{yield_point, FuzzController, FuzzSite};
use crate::coordinator::scheduler::{ReadyTask, ShardedReady};
use crate::coordinator::store::{self, SpillPolicy, TieredStore};
use crate::coordinator::transfer::{self, TransferService};
use crate::coordinator::transport::{tcp::TcpTransport, InProcTransport, Transport};
use crate::serialization::{codec_by_name, Codec};
use crate::trace::{EventKind, Tracer, WorkerId};
use crate::value::RValue;

/// A task body: pure function from input values to output values. Inputs
/// arrive as shared handles so the in-memory data plane can feed the same
/// allocation to every node-local consumer (zero-copy); `Arc<RValue>`
/// derefs to [`RValue`], so bodies read arguments exactly as before.
pub type TaskBody = Arc<dyn Fn(&[Arc<RValue>]) -> Result<Vec<RValue>> + Send + Sync>;

/// Registered task metadata (the product of the R-level `task()` call).
pub struct TaskSpec {
    /// Task type name, interned: every `ReadyTask`, trace event, and sim
    /// meta shares this allocation instead of cloning a `String` per
    /// push/steal.
    pub name: Arc<str>,
    pub arity: usize,
    pub n_outputs: usize,
    /// Per-argument directions; length == arity.
    pub directions: Vec<Direction>,
    pub body: TaskBody,
}

/// An argument at a call site: either a literal value (materialized by the
/// master at submission, like COMPSs does) or a reference to runtime data.
#[derive(Clone)]
pub enum Arg {
    Value(RValue),
    Ref(DataKey),
}

/// What `submit` returns: the OUT data produced by the call.
#[derive(Clone, Debug)]
pub struct SubmitOutcome {
    /// One key per declared output (function return values).
    pub returns: Vec<DataKey>,
    /// New versions of INOUT arguments, in argument order.
    pub updated: Vec<DataKey>,
}

/// Coordinator configuration.
///
/// Re-exported as `rcompss::api::RuntimeConfig`. The data-plane knobs
/// compose; the example below runs the memory plane with asynchronous
/// cross-node transfers and the version GC, and checks the GC left no
/// dead bytes behind:
///
/// ```
/// use rcompss::api::{CompssRuntime, RuntimeConfig, TaskDef};
/// use rcompss::value::RValue;
///
/// let config = RuntimeConfig::local(2)
///     .with_memory_budget(64 << 20) // hot tier: zero-copy Arc<RValue>s
///     .with_warm_budget(16 << 20)   // warm tier: encoded blobs (no disk)
///     .with_transfer_threads(1)     // movers stage cross-node inputs
///     .with_gc(true);               // reclaim dead dXvY versions
/// let rt = CompssRuntime::start(config).unwrap();
/// let add = rt.register_task(TaskDef::new("add", 2, |a| {
///     Ok(vec![RValue::scalar(
///         a[0].as_f64().unwrap() + a[1].as_f64().unwrap(),
///     )])
/// }));
/// let r1 = rt.submit(&add, &[1.0.into(), 2.0.into()]).unwrap();
/// let r2 = rt.submit(&add, &[r1.into(), 3.0.into()]).unwrap();
/// assert_eq!(rt.wait_on(&r2).unwrap().as_f64(), Some(6.0));
/// let stats = rt.stop().unwrap();
/// assert_eq!(stats.dead_version_bytes, 0, "GC reclaimed every drained version");
/// ```
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Cluster nodes to emulate in live mode (workers are threads; node
    /// membership affects locality accounting and tracing).
    pub nodes: u32,
    pub workers_per_node: u32,
    /// Scheduling policy: "fifo" | "lifo" | "locality".
    pub scheduler: String,
    /// Placement model routing ready tasks to node shards (and prefetches
    /// with them): "bytes" (default) | "cost" | "roundrobin" | "adaptive"
    /// (feedback-driven: observed transfer bandwidth + task durations).
    /// See `coordinator::placement` and `coordinator::feedback`.
    pub router: String,
    /// Parameter codec (Table 1): "rmvl" (default) | "qs" | ...
    pub codec: String,
    /// Directory for serialized parameter files.
    pub workdir: PathBuf,
    pub retry: RetryPolicy,
    /// Collect trace events.
    pub trace: bool,
    /// Failure injection (tests/chaos benches).
    pub injector: Arc<FailureInjector>,
    /// Byte budget of the in-memory data plane's **hot tier** (default
    /// [`DEFAULT_MEMORY_BUDGET`], 256 MiB). 0 disables the store entirely
    /// (the warm tier follows): every parameter goes through the codec and
    /// the workdir, byte-identical to the original file-based runtime.
    pub memory_budget: u64,
    /// Byte budget of the **warm tier** — encoded `Arc<[u8]>` blobs kept
    /// after the first encode (default [`DEFAULT_WARM_BUDGET`], 64 MiB).
    /// Hot-tier victims demote here instead of to disk, reloads decode in
    /// memory, and cross-node transfers ship the blob directly (one encode
    /// per N-node fan-out, zero file I/O). 0 disables the tier and
    /// restores the pre-tier hot→file behavior byte for byte.
    pub warm_budget: u64,
    /// Tier preset for A/B runs: `"tiered"` (hot+warm+cold, the default),
    /// `"hot"` (warm tier off), `"file"` (seed-identical file plane).
    /// Presets override the budgets above at startup.
    pub store: String,
    /// Spill victim selection when over budget: "lru" | "largest".
    pub spill: String,
    /// Mover threads per emulated node for asynchronous cross-node
    /// transfers (default 1). 0 restores the seed behavior: the claiming
    /// worker runs the codec round-trip synchronously. Only meaningful on
    /// the memory plane (`memory_budget > 0`).
    pub transfer_threads: u32,
    /// Reference-counted version GC (default on). When on, a `dXvY`
    /// version whose last registered consumer finishes is reclaimed
    /// immediately — the store frees its bytes and any spill file is
    /// deleted — instead of lingering until pressure eviction. Versions
    /// fetched with `wait_on` (or pinned with `Coordinator::pin`) are
    /// never reclaimed; fetching a *different* handle after its last
    /// consumer already finished is an error under GC (pin or fetch
    /// before the last consumer, or disable GC).
    pub gc: bool,
    /// Chaos plan (`--chaos` / `RCOMPSS_CHAOS`): probabilistic task
    /// failures and/or a seeded one-shot node kill mid-run. Default: no
    /// chaos. `with_chaos` with a positive task-fail probability also
    /// raises the retry budget floor to 6 so chaos exercises recovery, not
    /// spurious permanent failures.
    pub chaos: ChaosSpec,
    /// Checkpoint policy (`--checkpoint`): `"none"` (default) or `"cold"`
    /// — proactively publish sole-replica hot/warm versions through the
    /// cold tier (bounded by measured re-execution cost) so a node loss
    /// replays *tasks, not runs*.
    pub checkpoint: String,
    /// Schedule-fuzz seed (`RCOMPSS_SCHED_FUZZ` / `with_sched_fuzz`):
    /// arms deterministic yield points at the concurrency planes' hazard
    /// windows (see [`crate::coordinator::schedfuzz`]). `None` (default)
    /// leaves every hook a single no-op branch.
    pub sched_fuzz: Option<u64>,
    /// Window-compiler mode (`--compile` / `RCOMPSS_COMPILE`): `"off"`
    /// (default — greedy per-task dispatch) or `"window"` — buffer
    /// submissions into bounded windows and run the DAG compilation
    /// passes (dead-task culling, ahead-of-time lifetimes with hot-tier
    /// buffer aliasing, short-chain fusion, whole-window placement)
    /// before any task reaches the ready queues. See
    /// [`crate::coordinator::compile`].
    pub compile: String,
    /// Replica-shipping transport (`--transport` / `RCOMPSS_TRANSPORT`):
    /// `"inproc"` (default — emulated nodes share one address space) or
    /// `"tcp"` — worker processes serve replicas over sockets. Without
    /// [`CoordinatorConfig::listen`] the TCP transport self-hosts a
    /// loopback cluster (worker threads over real sockets), which is how
    /// the unmodified test suites pin transport invariance. See the
    /// crate-internal `coordinator::transport` module and
    /// `ARCHITECTURE.md` § Transport.
    pub transport: String,
    /// TCP-only (`--listen <addr>`): accept external
    /// `rcompss worker --connect` registrations on this address instead
    /// of self-hosting loopback workers.
    pub listen: Option<String>,
    /// TCP-only shared registration secret (`--token` / `RCOMPSS_TOKEN`):
    /// workers (and worker-to-worker peer connections) must present it in
    /// their `Hello` frame; a mismatch is rejected with a clean error.
    /// `None` (default) disables auth.
    pub token: Option<String>,
    /// TCP-only direct worker-to-worker shipping (`--p2p` /
    /// `RCOMPSS_P2P`): on by default; off forces every replica through
    /// the coordinator relay path.
    pub p2p: bool,
}

/// Default byte budget of the in-memory data plane — the single source of
/// truth shared by [`CoordinatorConfig::local`], the CLI's
/// `--memory-budget` default, and the docs.
pub const DEFAULT_MEMORY_BUDGET: u64 = 256 << 20;

/// Default byte budget of the warm (serialized-blob) tier — the single
/// source of truth shared by [`CoordinatorConfig::local`], the CLI's
/// `--warm-budget` default, and the docs.
pub const DEFAULT_WARM_BUDGET: u64 = 64 << 20;

impl CoordinatorConfig {
    /// Sensible local defaults: one node, `workers` executors, RMVL codec,
    /// FIFO policy, workdir under the system temp dir, the in-memory data
    /// plane ([`DEFAULT_MEMORY_BUDGET`]) with the version GC on.
    /// `with_memory_budget(0).with_gc(false)` restores the seed-identical
    /// file plane.
    ///
    /// The `RCOMPSS_SCHEDULER`, `RCOMPSS_ROUTER`, `RCOMPSS_WARM_BUDGET`,
    /// and `RCOMPSS_COMPILE` environment variables override the
    /// scheduler/router/warm-budget/compile *defaults* (explicit
    /// `with_*` calls still win) — this is how CI sweeps the placement ×
    /// policy × warm × compile matrix over the unmodified test suite.
    pub fn local(workers: u32) -> CoordinatorConfig {
        CoordinatorConfig {
            nodes: 1,
            workers_per_node: workers.max(1),
            scheduler: std::env::var("RCOMPSS_SCHEDULER").unwrap_or_else(|_| "fifo".into()),
            router: std::env::var("RCOMPSS_ROUTER").unwrap_or_else(|_| "bytes".into()),
            codec: "rmvl".into(),
            workdir: std::env::temp_dir().join(format!(
                "rcompss_{}_{}",
                std::process::id(),
                unique_run_id()
            )),
            retry: RetryPolicy::default(),
            trace: false,
            injector: Arc::new(FailureInjector::none()),
            memory_budget: DEFAULT_MEMORY_BUDGET,
            warm_budget: std::env::var("RCOMPSS_WARM_BUDGET")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(DEFAULT_WARM_BUDGET),
            store: "tiered".into(),
            spill: "lru".into(),
            transfer_threads: 1,
            gc: true,
            chaos: std::env::var("RCOMPSS_CHAOS")
                .ok()
                .and_then(|v| ChaosSpec::parse(&v).ok())
                .unwrap_or_default(),
            checkpoint: std::env::var("RCOMPSS_CHECKPOINT").unwrap_or_else(|_| "none".into()),
            sched_fuzz: FuzzController::seed_from_env(),
            compile: std::env::var("RCOMPSS_COMPILE").unwrap_or_else(|_| "off".into()),
            transport: std::env::var("RCOMPSS_TRANSPORT").unwrap_or_else(|_| "inproc".into()),
            listen: None,
            token: std::env::var("RCOMPSS_TOKEN").ok().filter(|t| !t.is_empty()),
            p2p: std::env::var("RCOMPSS_P2P")
                .map(|v| !matches!(v.to_ascii_lowercase().as_str(), "off" | "0" | "false"))
                .unwrap_or(true),
        }
    }

    /// Alias of [`CoordinatorConfig::local`], kept for source
    /// compatibility from when the memory plane was opt-in (its 256 MiB
    /// budget is now the `local` default).
    pub fn local_in_memory(workers: u32) -> CoordinatorConfig {
        CoordinatorConfig::local(workers)
    }

    pub fn with_scheduler(mut self, name: &str) -> Self {
        self.scheduler = name.into();
        self
    }

    /// Placement model: "bytes" | "cost" | "roundrobin" | "adaptive".
    pub fn with_router(mut self, name: &str) -> Self {
        self.router = name.into();
        self
    }

    pub fn with_codec(mut self, name: &str) -> Self {
        self.codec = name.into();
        self
    }

    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    pub fn with_nodes(mut self, nodes: u32, workers_per_node: u32) -> Self {
        self.nodes = nodes.max(1);
        self.workers_per_node = workers_per_node.max(1);
        self
    }

    /// Enable the in-memory data plane with the given byte budget
    /// (0 disables it again).
    pub fn with_memory_budget(mut self, bytes: u64) -> Self {
        self.memory_budget = bytes;
        self
    }

    /// Byte budget of the warm (serialized-blob) tier; 0 disables it and
    /// restores the pre-tier hot→file demotion and file-backed transfer
    /// staging byte for byte.
    pub fn with_warm_budget(mut self, bytes: u64) -> Self {
        self.warm_budget = bytes;
        self
    }

    /// Tier preset for A/B runs: `"tiered"` | `"hot"` | `"file"`.
    /// Validated at [`Coordinator::start`]; overrides the budgets.
    pub fn with_store(mut self, preset: &str) -> Self {
        self.store = preset.into();
        self
    }

    /// Spill policy of the in-memory plane: "lru" | "largest".
    pub fn with_spill(mut self, policy: &str) -> Self {
        self.spill = policy.into();
        self
    }

    /// Mover threads per emulated node for asynchronous cross-node
    /// transfers (0 = synchronous seed behavior).
    pub fn with_transfer_threads(mut self, threads: u32) -> Self {
        self.transfer_threads = threads;
        self
    }

    /// Enable the reference-counted version GC.
    pub fn with_gc(mut self, on: bool) -> Self {
        self.gc = on;
        self
    }

    /// Install a chaos plan (see [`ChaosSpec::parse`] for the `--chaos`
    /// grammar). A positive task-fail probability raises the retry budget
    /// floor to 6 so injected failures exercise resubmission rather than
    /// instantly exhausting the default budget.
    pub fn with_chaos(mut self, chaos: ChaosSpec) -> Self {
        if chaos.task_fail_p > 0.0 {
            self.retry.max_retries = self.retry.max_retries.max(6);
        }
        self.chaos = chaos;
        self
    }

    /// Checkpoint policy: `"none"` | `"cold"`. Validated at
    /// [`Coordinator::start`].
    pub fn with_checkpoint(mut self, policy: &str) -> Self {
        self.checkpoint = policy.into();
        self
    }

    /// Per-task retry budget (`--max-retries`): how many times a failed
    /// execution is resubmitted before the task fails permanently.
    pub fn with_max_retries(mut self, retries: u32) -> Self {
        self.retry.max_retries = retries;
        self
    }

    /// Arm the schedule-fuzz plane with `seed`: every yield point executes
    /// the deterministic perturbation stream `decision(seed, site, visit)`
    /// — the replay knob for CI-found interleaving failures.
    pub fn with_sched_fuzz(mut self, seed: u64) -> Self {
        self.sched_fuzz = Some(seed);
        self
    }

    /// Window-compiler mode: `"off"` | `"window"`. Validated at
    /// [`Coordinator::start`].
    pub fn with_compile(mut self, mode: &str) -> Self {
        self.compile = mode.into();
        self
    }

    /// Replica-shipping transport: `"inproc"` | `"tcp"`. Validated at
    /// [`Coordinator::start`].
    pub fn with_transport(mut self, name: &str) -> Self {
        self.transport = name.into();
        self
    }

    /// TCP transport only: accept external worker registrations on
    /// `addr` instead of self-hosting a loopback cluster.
    pub fn with_listen(mut self, addr: &str) -> Self {
        self.listen = Some(addr.into());
        self
    }

    /// TCP transport only: require this shared secret in every `Hello`
    /// (worker registration and worker-to-worker peer connections).
    pub fn with_token(mut self, token: &str) -> Self {
        self.token = Some(token.into());
        self
    }

    /// TCP transport only: enable/disable direct worker-to-worker
    /// shipping (on by default; off relays every replica through the
    /// coordinator).
    pub fn with_p2p(mut self, on: bool) -> Self {
        self.p2p = on;
        self
    }
}

pub(crate) fn unique_run_id() -> u64 {
    use std::sync::atomic::AtomicU64;
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Aggregate runtime statistics, printed at `stop()` and used by benches.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub tasks_submitted: u64,
    pub tasks_done: u64,
    pub tasks_failed: u64,
    pub tasks_cancelled: u64,
    pub resubmissions: u64,
    pub bytes_serialized: u64,
    pub bytes_deserialized: u64,
    pub serialize_s: f64,
    pub deserialize_s: f64,
    pub exec_s: f64,
    /// Per task type: (count, total execution seconds).
    pub per_type: HashMap<String, (u64, f64)>,
    /// Hot tier: zero-copy consumptions served by the store.
    pub store_hits: u64,
    /// Hot tier: consumptions that fell back to a lower tier.
    pub store_misses: u64,
    /// Values pushed through the codec by memory pressure (hot-tier
    /// demotions — into the warm tier when it is on, to a spill file
    /// otherwise).
    pub spills: u64,
    /// Serialized bytes produced by those demotions.
    pub spill_bytes: u64,
    /// Warm tier: reloads/transfer stagings served from a cached blob
    /// (each one is a decode with zero file I/O).
    pub warm_hits: u64,
    /// Warm tier: lookups that found no blob.
    pub warm_misses: u64,
    /// Warm tier: blobs created (pressure demotions + lazy first-encode
    /// transfer fills).
    pub warm_fills: u64,
    /// Warm tier: blobs flushed to cold spill files by warm-budget
    /// pressure.
    pub warm_evictions: u64,
    /// Warm tier: blob bytes resident at snapshot time. With the GC on
    /// this drains to ~0 at quiescence alongside `transfer_states` — a
    /// collected version's blob is reclaimed with its other tiers.
    pub warm_resident_bytes: u64,
    /// Codec `encode` invocations by the data plane (demotions, transfer
    /// fills, spill writes). A memory-resident N-node fan-out transfer
    /// performs exactly one with the warm tier on.
    pub store_encodes: u64,
    /// Cold tier: parameter/spill files read.
    pub store_file_reads: u64,
    /// Cold tier: parameter/spill files written.
    pub store_file_writes: u64,
    /// Version GC: dead `dXvY` versions reclaimed.
    pub gc_collected: u64,
    /// Version GC: recorded bytes of the reclaimed versions.
    pub gc_bytes: u64,
    /// Version GC: spill/parameter files deleted.
    pub gc_files_deleted: u64,
    /// Async transfers: `(version, node)` pairs ever requested.
    pub transfers_requested: u64,
    /// Async transfers staged before any claimant had to wait (the
    /// transfer fully overlapped with compute).
    pub transfers_prefetched: u64,
    /// Async transfers at least one claimant parked on.
    pub transfers_waited: u64,
    /// Async transfers dropped without moving bytes (destination already
    /// held a replica, or the version was reclaimed mid-flight).
    pub transfers_dropped: u64,
    /// Async transfer attempts that failed (retried within the bounded
    /// per-pair budget; claimants fall back to the synchronous path only
    /// once it is exhausted).
    pub transfers_failed: u64,
    /// Failed transfers re-queued by the bounded retry.
    pub transfers_retried: u64,
    /// Transfer-board state entries at snapshot time (in-flight +
    /// Done/Failed tombstones). The version GC purges a version's entries
    /// when it collects it, so at quiescence this tracks live versions —
    /// not the tasks x inputs history.
    pub transfer_states: u64,
    /// Serialized bytes moved by the mover threads.
    pub transfer_bytes: u64,
    /// Cross-node consumptions that ran the codec synchronously on the
    /// claim path (the seed behavior; zero with the transfer service on).
    pub sync_transfer_decodes: u64,
    /// Store bytes resident at snapshot time.
    pub store_resident_bytes: u64,
    /// Bytes of dead versions (fully consumed, unpinned, unreclaimed) at
    /// snapshot time — zero at quiescence when the GC is on.
    pub dead_version_bytes: u64,
    /// Node-loss recovery: tasks whose Done state was reopened and
    /// re-executed to re-derive versions lost with a node. Strictly less
    /// than `tasks_submitted` when recovery replays only the lost subgraph.
    pub lineage_resubmissions: u64,
    /// Checkpoint policy: sole-replica versions proactively published
    /// through the cold tier.
    pub checkpoints_written: u64,
    /// Serialized bytes those checkpoints wrote.
    pub checkpoint_bytes: u64,
    /// Nodes lost (`kill_node` / `--chaos node-kill`).
    pub nodes_killed: u64,
    /// Nodes rejoined (`add_node`).
    pub nodes_joined: u64,
    /// Schedule-fuzz plane: yield-point visits taken across all sites
    /// (0 when the plane is disarmed — proof the hooks cost nothing).
    pub sched_fuzz_perturbations: u64,
    /// Window compiler: windows flushed (size cap + sync points). Zero
    /// with `--compile off`.
    pub windows_flushed: u64,
    /// Window compiler: tasks retired without executing because every
    /// output was superseded, unpinned, and read only by culled tasks.
    pub window_culled: u64,
    /// Window compiler: fusion links — member tasks that ran inline on
    /// their head's worker with the intermediate handed off unpublished.
    pub window_fused: u64,
    /// Window compiler: ahead-of-time death-list releases that really
    /// collected the version at its predicted last read (pre-publish).
    pub aot_frees: u64,
    /// Window compiler: predicted frees whose reclaimed bytes covered an
    /// output the same task then produced — the hot tier reused the
    /// dying buffer's budget for the successor allocation.
    pub alias_reuses: u64,
    /// Placement verdicts issued: one per greedy ready-queue push, one
    /// per compiled window (all its dispatch units share it).
    pub placement_verdicts: u64,
    /// Hot tier: peak resident bytes over the run. Aliasing keeps this
    /// flat where the greedy path stacks dying value + successor.
    pub hot_peak_bytes: u64,
    /// TCP transport: blobs streamed directly worker-to-worker (`ShipTo`
    /// → chunked peer stream). Zero on the in-process transport.
    pub direct_ships: u64,
    /// TCP transport: blobs relayed through the coordinator (`Put`).
    pub relay_ships: u64,
    /// TCP transport: relay `Put`s issued solely to seed a fresh
    /// version's producer-side worker cache for direct fan-out.
    pub seed_ships: u64,
    /// TCP transport: direct ships that reused a pooled peer connection.
    pub pool_hits: u64,
    /// TCP transport: coordinator→worker request bytes (frame headers +
    /// payloads). Direct shipping keeps this O(1) per version on fan-out.
    pub coord_egress_bytes: u64,
}

/// Per-task metadata kept by the coordinator; shared with claimants as an
/// `Arc` so the claim path never deep-copies input lists under the lock.
pub(crate) struct TaskMeta {
    pub spec: Arc<TaskSpec>,
    pub inputs: Vec<DataKey>,
    pub outputs: Vec<DataKey>,
}

/// Mutable coordinator control state (behind the control lock): the DAG,
/// the dependency half of the registry, task metadata, and stats. The
/// dispatch queues, version locations, and produced values live outside.
pub(crate) struct Core {
    pub graph: TaskGraph,
    pub registry: DataRegistry,
    pub meta: HashMap<TaskId, Arc<TaskMeta>>,
    pub stats: RuntimeStats,
    /// Window compiler: submitted-but-undispatched tasks buffered for
    /// the next flush (empty and untouched with `--compile off`).
    pub window: Vec<TaskId>,
    /// Compiled fusion links, `head → (member, intermediate)`. The
    /// executor claims (removes) an entry when it starts the head; a
    /// retry after a failed start therefore degrades to unfused
    /// dispatch automatically.
    pub fused_next: HashMap<TaskId, (TaskId, DataKey)>,
    /// Compiled ahead-of-time death lists: input versions a task
    /// releases *before* publishing, as their predicted last reader.
    pub alias: HashMap<TaskId, Vec<DataKey>>,
    /// Compiled whole-window placement, task → node shard. Consumed by
    /// [`Shared::enqueue_ready`]; a task with no entry gets a greedy
    /// verdict as before.
    pub placement: HashMap<TaskId, usize>,
}

/// Shared coordinator handle (master + workers).
pub(crate) struct Shared {
    pub core: Mutex<Core>,
    /// Waiters (`wait_on`, `barrier`) wait here for completions.
    pub cv_done: Condvar,
    /// Sharded version/location table — the claim path reads it lock-free
    /// of the control lock.
    pub table: Arc<VersionTable>,
    /// Per-node ready queues with stealing and parking.
    pub ready: ShardedReady,
    /// The tiered value store: hot `Arc<RValue>` cache (disabled at
    /// budget 0), warm encoded-blob cache, cold spill-file accounting.
    pub store: TieredStore,
    /// Asynchronous cross-node transfer board (movers disabled at
    /// `transfer_threads` 0 or on the file plane). Shared (`Arc`) with the
    /// dispatch fabric, whose placement model reads the per-node in-flight
    /// gauge on every routing decision.
    pub transfers: Arc<TransferService>,
    /// Observation sink of an `adaptive` router (`None` for the static
    /// models): movers feed per-node transfer throughput, workers feed
    /// per-task-type durations, the model reads both on every verdict.
    pub feedback: Option<Arc<FeedbackStats>>,
    /// Reference-counted version GC knob.
    pub gc_enabled: bool,
    /// GC accounting: versions reclaimed / recorded bytes / files deleted.
    pub gc_collected: AtomicU64,
    pub gc_bytes: AtomicU64,
    pub gc_files: AtomicU64,
    pub codec: Box<dyn Codec>,
    pub tracer: Tracer,
    pub workdir: PathBuf,
    pub retry: RetryPolicy,
    pub injector: Arc<FailureInjector>,
    pub stopping: AtomicBool,
    /// Node liveness plane: one flag per emulated node, read by the
    /// dispatch fabric, the placement models, the movers, and the claim
    /// path. `kill_node` flips a flag dead; `add_node` flips it back.
    pub health: Arc<NodeHealth>,
    /// `--checkpoint cold`: proactively publish sole-replica versions
    /// through the cold tier after execution (bounded by measured
    /// re-execution cost).
    pub checkpoint_cold: bool,
    /// `--chaos node-kill` victim (highest-numbered node), killed once the
    /// armed completion count is reached.
    pub chaos_victim: Option<NodeId>,
    /// Checkpoint accounting: versions written / serialized bytes.
    pub checkpoints_written: AtomicU64,
    pub checkpoint_bytes: AtomicU64,
    /// Schedule-fuzz controller (shared with the dispatch fabric and the
    /// transfer board); `None` in production.
    pub fuzz: Option<Arc<FuzzController>>,
    /// Replica-shipping transport the movers fetch through — in-process
    /// staging (the emulated cluster) or TCP worker processes. Everything
    /// above [`Transport::fetch`] is transport-agnostic.
    pub transport: Arc<dyn Transport>,
    /// Window-compiler arm flag (`--compile window`).
    pub compile_window: bool,
    /// Window-compiler accounting (the `RuntimeStats` twins).
    pub windows_flushed: AtomicU64,
    pub window_culled: AtomicU64,
    pub window_fused: AtomicU64,
    pub aot_frees: AtomicU64,
    pub alias_reuses: AtomicU64,
    pub placement_verdicts: AtomicU64,
}

/// One flush's (or batch's) version-table snapshot cache: each input
/// version is read once per flush/batch instead of once per task. The
/// placement model and the prefetcher both route on the cached view;
/// staleness is harmless — prefetch requests are idempotent and the
/// claim path re-resolves locations at gather time.
pub(crate) type LocSnapshot = HashMap<DataKey, (u64, Vec<NodeId>)>;

impl Shared {
    /// File path for a datum version: `workdir/dXvY.par` — the on-disk
    /// sibling of the paper's `dXvY` labels.
    pub fn path_for(&self, key: DataKey) -> PathBuf {
        self.workdir.join(format!("{key}.par"))
    }

    /// Push a newly-ready task to the dispatch fabric and prefetch its
    /// remote inputs — one placement verdict drives both. The version
    /// table is read *once* per input into a locality snapshot; the
    /// placement model routes on that snapshot, and every input the
    /// snapshot shows missing from the routed node is handed to the
    /// transfer service at *schedule* time (so by the time a worker claims
    /// the task the bytes are usually staged already). Routing and
    /// prefetch can therefore never disagree about where a replica lives —
    /// the split-brain the old two-read path allowed.
    pub(crate) fn enqueue_ready(&self, core: &mut Core, id: TaskId) {
        let mut cache = LocSnapshot::new();
        self.enqueue_ready_cached(core, id, &mut cache);
    }

    /// [`Shared::enqueue_ready`] with a caller-held snapshot cache, so a
    /// batch submission or a window flush reads each shared input
    /// version once — not once per consuming task.
    pub(crate) fn enqueue_ready_cached(
        &self,
        core: &mut Core,
        id: TaskId,
        cache: &mut LocSnapshot,
    ) {
        // A buffered window task never dispatches early: a completion
        // that turns it ready leaves it for its flush to place.
        if self.compile_window && core.window.contains(&id) {
            return;
        }
        let meta = Arc::clone(&core.meta[&id]);
        let snapshot: Vec<(DataKey, u64, Vec<NodeId>)> = meta
            .inputs
            .iter()
            .map(|k| {
                let (bytes, locs) = cache.entry(*k).or_insert_with(|| {
                    let info = self.table.info(*k).expect("input version missing");
                    (info.bytes, info.locations)
                });
                (*k, *bytes, locs.clone())
            })
            .collect();
        let inputs = snapshot
            .iter()
            .map(|(_, bytes, locs)| (*bytes, locs.clone()))
            .collect();
        let task = ReadyTask {
            id,
            inputs,
            type_name: Arc::clone(&meta.spec.name),
        };
        // A compiled window placed this task already — honor the plan
        // (its whole window shared one verdict). No entry → a greedy
        // per-task verdict, the pre-compiler behavior.
        let node = match core.placement.remove(&id) {
            Some(shard) => self.ready.push_routed(shard, task),
            None => {
                self.placement_verdicts.fetch_add(1, Ordering::Relaxed);
                self.ready.push(task)
            }
        };
        if self.ready.nodes() > 1 && self.store.enabled() && self.transfers.enabled() {
            let dst = NodeId(node as u32);
            for (k, bytes, locs) in &snapshot {
                if !locs.contains(&dst) {
                    self.transfers.request(*k, dst, *bytes);
                }
            }
        }
    }

    /// Flush the submission window: compile the buffered tasks (cull /
    /// lifetime / fusion passes — see [`compile`]), settle the culled
    /// tasks' registry state, record the fusion and death-list plans for
    /// the executor, issue **one** whole-window placement verdict, and
    /// release the ready frontier to the dispatch fabric. Runs under the
    /// held control lock; touching the leaf domains (table, store,
    /// transfer board, ready shards) from here is legal per the lock
    /// ordering.
    pub(crate) fn flush_window(&self, core: &mut Core) {
        if core.window.is_empty() {
            return;
        }
        let window = std::mem::take(&mut core.window);
        self.windows_flushed.fetch_add(1, Ordering::Relaxed);

        // The compiler's pure snapshot. Tasks cancelled while buffered
        // (failed upstream) drop out here — the failure path settled them.
        let mut tasks: Vec<WindowTask> = Vec::with_capacity(window.len());
        let mut ctx = WindowCtx::default();
        for id in &window {
            if !matches!(
                core.graph.state(*id),
                Some(TaskState::Pending) | Some(TaskState::Ready)
            ) {
                continue;
            }
            let meta = &core.meta[id];
            tasks.push(WindowTask {
                id: *id,
                type_name: Arc::clone(&meta.spec.name),
                inputs: meta.inputs.clone(),
                outputs: meta.outputs.clone(),
            });
            for k in meta.inputs.iter().chain(meta.outputs.iter()) {
                if ctx.consumers.contains_key(k) {
                    continue;
                }
                let Some(info) = self.table.info(*k) else { continue };
                ctx.consumers.insert(*k, info.consumers_total);
                if info.bytes > 0 {
                    ctx.bytes.insert(*k, info.bytes);
                }
                if info.pinned {
                    ctx.pinned.insert(*k);
                }
                if core.registry.latest_key(k.data) != Some(*k) {
                    ctx.superseded.insert(*k);
                }
            }
        }
        for t in &tasks {
            let Some(node) = core.graph.node(t.id) else { continue };
            for dep in &node.dependents {
                if let Some(d) = core.graph.node(*dep) {
                    if d.pending_deps == 1 {
                        ctx.sole_gate.insert((*dep, t.id));
                    }
                }
            }
        }
        let plan = compile::compile_window(&tasks, &ctx);

        // Apply the culls, consumers-first (reverse submission order,
        // mirroring the compile fixpoint). `collect_unproduced` is the
        // per-output commit point: it refuses when a waiter pinned the
        // version after the compile snapshot, in which case this cull —
        // and, via the committed-reads recheck, any producer cull that
        // depended on its reads — aborts and the task dispatches
        // normally.
        let in_plan: HashSet<TaskId> = plan.culled.iter().copied().collect();
        let mut committed: HashSet<TaskId> = HashSet::new();
        let mut committed_reads: HashMap<DataKey, u32> = HashMap::new();
        for t in tasks.iter().rev().filter(|t| in_plan.contains(&t.id)) {
            let refs_settled = t.outputs.iter().all(|k| {
                let total = self
                    .table
                    .info(*k)
                    .map(|i| i.consumers_total)
                    .unwrap_or(0);
                total <= committed_reads.get(k).copied().unwrap_or(0)
            });
            let mut collected: Vec<DataKey> = Vec::new();
            let commit = refs_settled
                && t.outputs.iter().all(|k| {
                    if self.table.collect_unproduced(*k) {
                        collected.push(*k);
                        true
                    } else {
                        false
                    }
                });
            if !commit {
                for k in collected {
                    self.table.uncollect_unproduced(k);
                }
                continue;
            }
            committed.insert(t.id);
            for k in &t.inputs {
                *committed_reads.entry(*k).or_insert(0) += 1;
            }
            // Retire in the graph (counts as done for quiescence and
            // ordering; dependents un-gate), settle the reads so the GC
            // sees the same drain a real execution would have produced,
            // and drop any transfer-board entries naming the dead
            // outputs (none should exist — the task never enqueued).
            core.graph.cull(t.id);
            for k in &t.inputs {
                if let Some(act) = self.table.release_consumer(*k, self.gc_enabled) {
                    collect_version(self, &act);
                }
            }
            for k in &t.outputs {
                self.transfers.purge_version(*k);
            }
            self.window_culled.fetch_add(1, Ordering::Relaxed);
        }

        // Record the fusion links and death lists for the executor.
        for l in &plan.fused {
            core.fused_next.insert(l.head, (l.member, l.key));
        }
        self.window_fused
            .fetch_add(plan.fused.len() as u64, Ordering::Relaxed);
        for (id, list) in &plan.alias {
            core.alias.insert(*id, list.clone());
        }

        // Dispatch units: everything that still executes and is not a
        // fused member — the plan's units plus any aborted cull.
        let members: HashSet<TaskId> = plan.fused.iter().map(|l| l.member).collect();
        let dispatch: Vec<TaskId> = tasks
            .iter()
            .filter(|t| !members.contains(&t.id) && !committed.contains(&t.id))
            .map(|t| t.id)
            .collect();

        // One placement verdict for the whole window: score the
        // aggregate input set once, then round-robin the dispatch units
        // over the alive nodes from that anchor. Fused members inherit
        // their head's shard so a chain never crosses a node boundary.
        let mut cache = LocSnapshot::new();
        if !dispatch.is_empty() {
            let mut agg_inputs: Vec<(u64, Vec<NodeId>)> = Vec::new();
            for id in &dispatch {
                for k in &core.meta[id].inputs {
                    let (bytes, locs) = cache.entry(*k).or_insert_with(|| {
                        let info = self.table.info(*k).expect("input version missing");
                        (info.bytes, info.locations)
                    });
                    agg_inputs.push((*bytes, locs.clone()));
                }
            }
            let anchor = self.ready.place_window(&ReadyTask {
                id: dispatch[0],
                inputs: agg_inputs,
                type_name: Arc::clone(&core.meta[&dispatch[0]].spec.name),
            });
            self.placement_verdicts.fetch_add(1, Ordering::Relaxed);
            let nodes = self.ready.nodes() as usize;
            let mut shard = anchor;
            for id in &dispatch {
                for _ in 0..nodes {
                    if self.health.is_alive(NodeId(shard as u32)) {
                        break;
                    }
                    shard = (shard + 1) % nodes;
                }
                core.placement.insert(*id, shard);
                let mut h = *id;
                while let Some((m, _)) = core.fused_next.get(&h) {
                    core.placement.insert(*m, shard);
                    h = *m;
                }
                shard = (shard + 1) % nodes;
            }
        }

        // Release the ready frontier (the snapshot cache carries over —
        // the aggregate pass already resolved most inputs).
        for id in &dispatch {
            if core.graph.state(*id) == Some(TaskState::Ready) {
                self.enqueue_ready_cached(core, *id, &mut cache);
            }
        }
        // Culls may have drained a waited-on datum's last consumer.
        self.cv_done.notify_all();
    }
}

/// Release one consumer reference per key (a finished, failed, or
/// cancelled reader); with the GC knob on, a version whose last reference
/// this was is reclaimed on the spot — store entry dropped, spill file
/// deleted. Runs outside every lock; the shard-atomic mark in
/// [`VersionTable::release_consumer`] guarantees single collection.
pub(crate) fn release_inputs(shared: &Shared, keys: &[DataKey]) {
    for k in keys {
        if let Some(act) = shared.table.release_consumer(*k, shared.gc_enabled) {
            collect_version(shared, &act);
        }
    }
}

/// Publish-side GC sweep: reclaim a just-published version whose
/// consumers all vanished (cancelled) before it became available — their
/// releases found `available == false` and could not collect, so the
/// producer's publish is the last event that can. Called by the worker
/// publish paths right after `mark_available*`.
pub(crate) fn reap_if_drained(shared: &Shared, key: DataKey) {
    if let Some(act) = shared.table.reap_if_drained(key, shared.gc_enabled) {
        collect_version(shared, &act);
    }
}

/// Free what a collected version held across **all three tiers**: the hot
/// entry, the warm blob, and the published spill file (deleted loudly —
/// per-tier residency tracking means the path is only present when a file
/// was actually published, so a failed delete is a reported leak, never a
/// silently swallowed error). The version table entry stays (marked
/// collected) so diagnostics and late `wait_on`s get a precise error
/// instead of a hang.
pub(crate) fn collect_version(shared: &Shared, act: &CollectAction) {
    // Hazard window: the version is marked collected but its residency,
    // file, and board entries are still being torn down — a mover staging
    // the same version races every step below.
    yield_point(&shared.fuzz, FuzzSite::GcCollect);
    shared.store.discard_resident(act.key);
    if let Some(path) = &act.path {
        if shared.store.cold().delete_file(path) {
            shared.gc_files.fetch_add(1, Ordering::Relaxed);
        }
    }
    // Drop the collected version's transfer-board entries (tombstones and
    // never-run requests) so the board tracks live versions only, and the
    // transport's belief about which worker caches still hold the blob.
    shared.transfers.purge_version(act.key);
    shared.transport.on_version_purged(act.key);
    shared.gc_collected.fetch_add(1, Ordering::Relaxed);
    shared.gc_bytes.fetch_add(act.bytes, Ordering::Relaxed);
}

/// Kill a node: mark it dead in the health plane (dispatch, placement, and
/// the movers all stop routing toward it), fast-fail its in-flight
/// transfers, drop it from every version's location set, and re-derive the
/// versions it was the sole holder of by reopening their producing tasks
/// (transitively — a producer whose own inputs died with the node reopens
/// too). Refuses to kill the last alive node. Returns whether the node was
/// alive (idempotent).
pub(crate) fn kill_node_now(shared: &Shared, node: NodeId) -> bool {
    if shared.health.alive_count() <= 1 {
        return false;
    }
    if !shared.health.mark_dead(node) {
        return false;
    }
    // Hazard window: the health plane says dead but the transfer board
    // still accepts requests toward the node — routing verdicts and mover
    // completions race the poison below.
    yield_point(&shared.fuzz, FuzzSite::NodeKill);
    // Fail in-flight and queued transfers toward/from the dead node fast —
    // claimants get an immediate error instead of a 3-attempt grind.
    shared.transfers.fail_node(node);
    // Close the transport's per-node resources (a TCP peer socket) so
    // in-flight exchanges fail fast instead of timing out.
    shared.transport.on_node_down(node);
    let report = shared.table.drop_node(node);
    {
        let mut core = shared.core.lock().unwrap();
        core.stats.nodes_killed += 1;
        recover_lost_versions(shared, &mut core, &report.lost);
    }
    // Dead workers park; waiters may be blocked on a version that just got
    // rewired to a reopened producer.
    shared.ready.wake_all();
    shared.cv_done.notify_all();
    true
}

/// Re-admit a node: mark it alive (its shard re-opens for placement and
/// stealing, its parked workers resume) and clear the transfer board's
/// dead-node tombstones. Returns whether the node was dead (idempotent).
pub(crate) fn rejoin_node(shared: &Shared, node: NodeId) -> bool {
    if !shared.health.mark_alive(node) {
        return false;
    }
    // Hazard window: the node is alive for routing but its dead-node
    // tombstones are still on the board — a first post-rejoin prefetch
    // races the revive below.
    yield_point(&shared.fuzz, FuzzSite::NodeJoin);
    shared.transfers.revive_node(node);
    // Re-open the transport's per-node resources (self-hosted TCP spawns
    // a fresh loopback worker; external mode waits for an operator to
    // restart `rcompss worker`).
    shared.transport.on_node_up(node);
    {
        let mut core = shared.core.lock().unwrap();
        core.stats.nodes_joined += 1;
    }
    shared.ready.wake_all();
    true
}

/// Lineage re-execution: given the versions that became unavailable with a
/// dead node, walk producers transitively, reopen every completed task
/// whose output was lost, re-seed lost literal arguments from the
/// registry's retained copies, and resubmit the ready frontier. Runs under
/// the held control lock so no claim can interleave between the consumer
/// re-registration, the version resets, and the reopen.
pub(crate) fn recover_lost_versions(shared: &Shared, core: &mut Core, lost: &[DataKey]) {
    let mut stack: Vec<DataKey> = lost.to_vec();
    let mut seen: HashSet<DataKey> = lost.iter().copied().collect();
    let mut reopen: HashSet<TaskId> = HashSet::new();
    let mut lost_literals: Vec<DataKey> = Vec::new();
    while let Some(key) = stack.pop() {
        // The store may still hold a stale hot/warm entry for the lost
        // replica (residency is emulated per node); drop it and the
        // version's transfer-board entries so nothing serves stale bytes.
        shared.store.discard_resident(key);
        shared.transfers.purge_version(key);
        let Some(info) = shared.table.info(key) else {
            continue;
        };
        match info.producer {
            None => lost_literals.push(key),
            Some(tid) => {
                if core.graph.state(tid) == Some(TaskState::Done) && reopen.insert(tid) {
                    // The producer must re-run: every input it consumed is
                    // needed again. Inputs that are themselves gone
                    // (collected by the GC, or lost with the node and not
                    // replicated anywhere) recurse.
                    let meta = Arc::clone(&core.meta[&tid]);
                    for input in &meta.inputs {
                        if seen.contains(input) {
                            continue;
                        }
                        let gone = match shared.table.info(*input) {
                            Some(i) => {
                                i.collected
                                    || !i.available
                                    || (i.locations.is_empty() && i.path.as_os_str().is_empty())
                            }
                            None => true,
                        };
                        if gone {
                            seen.insert(*input);
                            stack.push(*input);
                        }
                    }
                }
            }
        }
    }
    if reopen.is_empty() && lost_literals.is_empty() {
        return;
    }
    // Order matters, all under the one lock hold:
    // (a) re-register a consumer count on every input of every reopened
    //     task — before any reset, so the GC can never reclaim an input
    //     between its reset and the re-execution that reads it;
    for tid in &reopen {
        let meta = Arc::clone(&core.meta[tid]);
        for input in &meta.inputs {
            shared.table.add_consumer(*input);
        }
    }
    // (b) re-seed lost literals from the registry's retained values (the
    //     master materialized them; no task can re-derive them);
    for key in lost_literals {
        let Some(value) = core.registry.literal_value(key) else {
            eprintln!("rcompss: literal {key} lost with node and not retained; dependents will fail");
            continue;
        };
        let home = shared.health.first_alive().unwrap_or(NodeId(0));
        let nbytes = value.byte_size() as u64;
        shared.table.reset_for_recovery(key);
        let victims = shared.store.hot().put(key, value, false);
        shared.table.mark_available_memory(key, home, nbytes);
        store::demote_victims(shared, victims);
    }
    // (c) reset the reopened tasks' lost outputs to unavailable (never
    //     clobbering a version that still has a live replica or file);
    for tid in &reopen {
        let meta = Arc::clone(&core.meta[tid]);
        for output in &meta.outputs {
            if let Some(i) = shared.table.info(*output) {
                let still_there = i.available
                    && (!i.locations.is_empty() || !i.path.as_os_str().is_empty());
                if i.collected || !still_there {
                    shared.table.reset_for_recovery(*output);
                }
            }
        }
    }
    // (d) flip the DAG states and resubmit the ready frontier.
    let ready = core.graph.reopen(&reopen);
    core.stats.lineage_resubmissions += reopen.len() as u64;
    for id in ready {
        shared.enqueue_ready(core, id);
    }
}

/// The coordinator: one per application run (`compss_start` .. `compss_stop`).
pub struct Coordinator {
    pub(crate) shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    movers: Vec<std::thread::JoinHandle<()>>,
    pub config: CoordinatorConfig,
}

impl Coordinator {
    /// Start the runtime: create the workdir, spawn the persistent worker
    /// pool, and return the handle (the `compss_start()` of the paper).
    pub fn start(mut config: CoordinatorConfig) -> Result<Coordinator> {
        std::fs::create_dir_all(&config.workdir)
            .with_context(|| format!("create workdir {}", config.workdir.display()))?;
        let checkpoint_requested = match config.checkpoint.as_str() {
            "none" => false,
            "cold" => true,
            other => bail!(
                "unknown checkpoint policy '{other}' (none|cold; set via --checkpoint or \
                 with_checkpoint)"
            ),
        };
        let compile_window = match config.compile.as_str() {
            "off" => false,
            "window" => true,
            other => bail!(
                "unknown compile mode '{other}' (off|window; set via --compile, \
                 with_compile, or the RCOMPSS_COMPILE default override)"
            ),
        };
        let model = placement_by_name(&config.router).ok_or_else(|| {
            anyhow!(
                "unknown router '{}' (bytes|cost|roundrobin|adaptive; set via --router, \
                 with_router, or the RCOMPSS_ROUTER default override)",
                config.router
            )
        })?;
        // An adaptive model shares its observation sink with the runtime:
        // movers and workers feed it, the model reads it on every verdict.
        let feedback = model.feedback();
        let codec = codec_by_name(&config.codec)
            .ok_or_else(|| anyhow!("unknown codec '{}'", config.codec))?;
        let spill = SpillPolicy::by_name(&config.spill)
            .ok_or_else(|| anyhow!("unknown spill policy '{}' (lru|largest)", config.spill))?;
        // The `--store` preset resolves the effective tier budgets for A/B
        // runs: "tiered" keeps the configured budgets, "hot" switches the
        // warm tier off, "file" restores the seed-identical file plane.
        let (memory_budget, warm_budget) = match config.store.as_str() {
            "tiered" => (config.memory_budget, config.warm_budget),
            "hot" => (config.memory_budget, 0),
            "file" => (0, 0),
            other => bail!(
                "unknown store preset '{other}' (tiered|hot|file; set via --store or with_store)"
            ),
        };
        let table = Arc::new(VersionTable::new());
        // Async transfers exist only on the memory plane: the file plane
        // reads every parameter from its file anyway.
        let movers_per_node = if memory_budget > 0 {
            config.transfer_threads
        } else {
            0
        };
        // One schedule-fuzz controller per runtime instance (never a
        // process global: parallel test runtimes must not share visit
        // counters or seeds would stop replaying), shared by every
        // instrumented plane.
        let fuzz = config.sched_fuzz.map(|seed| Arc::new(FuzzController::new(seed)));
        let transfers = Arc::new(
            TransferService::new(movers_per_node, config.nodes).with_fuzz(fuzz.clone()),
        );
        let health = Arc::new(NodeHealth::new(config.nodes as usize));
        // Chaos plan: a positive task-fail probability installs a
        // catch-all injector (and `with_chaos` already raised the retry
        // floor); `node-kill` arms a one-shot seeded kill of the
        // highest-numbered node after a few completions. An explicitly
        // configured injector wins over the env/`--chaos` plan so tests
        // that pin their own injection stay deterministic under a
        // chaos-matrix environment.
        if config.chaos.task_fail_p > 0.0 && config.injector.is_noop() {
            config.retry.max_retries = config.retry.max_retries.max(6);
            config.injector = Arc::new(FailureInjector::new(
                config.chaos.task_fail_p,
                "",
                u32::MAX,
                config.chaos.seed,
            ));
        }
        // The replica-shipping transport. TCP without `--listen`
        // self-hosts a loopback cluster (worker threads over real
        // sockets) so unmodified suites run over TCP; with `--listen` it
        // blocks here until every external worker registers.
        let transport: Arc<dyn Transport> = match config.transport.as_str() {
            "inproc" => {
                if config.listen.is_some() {
                    bail!("--listen requires the tcp transport (got transport 'inproc')");
                }
                Arc::new(InProcTransport)
            }
            "tcp" => {
                let self_host = config.listen.is_none();
                let budget = if config.warm_budget > 0 {
                    config.warm_budget
                } else {
                    DEFAULT_WARM_BUDGET
                };
                let t = TcpTransport::bind(
                    config.nodes,
                    config.listen.as_deref(),
                    self_host,
                    budget,
                    config.token.clone(),
                    config.p2p,
                )?;
                if config.nodes > 1 {
                    if !self_host {
                        println!(
                            "rcompss: waiting for {} worker(s) on {} — join with: \
                             rcompss worker --connect {}",
                            config.nodes - 1,
                            t.listen_addr(),
                            t.listen_addr()
                        );
                    }
                    let deadline = if self_host {
                        std::time::Duration::from_secs(30)
                    } else {
                        std::time::Duration::from_secs(300)
                    };
                    t.wait_registered(deadline)?;
                }
                t
            }
            other => bail!(
                "unknown transport '{other}' (inproc|tcp; set via --transport, \
                 with_transport, or the RCOMPSS_TRANSPORT default override)"
            ),
        };
        let chaos_victim = if config.chaos.node_kill && config.nodes > 1 {
            let mut rng = crate::util::prng::Pcg64::new(config.chaos.seed, 0xD1E);
            config.injector.arm_node_kill(3 + rng.below(20));
            Some(NodeId(config.nodes - 1))
        } else {
            None
        };
        // The fabric routes with the configured placement model and reads
        // the transfer board's in-flight gauge — the same verdict the
        // prefetcher and the simulator consult.
        let ready = ShardedReady::new(
            &config.scheduler,
            config.nodes,
            model,
            Some(Arc::clone(&transfers) as Arc<dyn InflightSource>),
        )
        .ok_or_else(|| {
            anyhow!(
                "unknown scheduler '{}' (fifo|lifo|locality; set via --scheduler, \
                 with_scheduler, or the RCOMPSS_SCHEDULER default override)",
                config.scheduler
            )
        })?
        .with_health(Arc::clone(&health))
        .with_fuzz(fuzz.clone());
        let shared = Arc::new(Shared {
            core: Mutex::new(Core {
                graph: TaskGraph::new(),
                registry: DataRegistry::with_table(Arc::clone(&table)),
                meta: HashMap::new(),
                stats: RuntimeStats::default(),
                window: Vec::new(),
                fused_next: HashMap::new(),
                alias: HashMap::new(),
                placement: HashMap::new(),
            }),
            cv_done: Condvar::new(),
            table: Arc::clone(&table),
            ready,
            store: TieredStore::new(memory_budget, spill, warm_budget, table),
            transfers,
            feedback,
            gc_enabled: config.gc,
            gc_collected: AtomicU64::new(0),
            gc_bytes: AtomicU64::new(0),
            gc_files: AtomicU64::new(0),
            codec,
            tracer: Tracer::new(config.trace),
            workdir: config.workdir.clone(),
            retry: config.retry,
            injector: config.injector.clone(),
            stopping: AtomicBool::new(false),
            health,
            // Checkpointing needs a cold tier to write through, which only
            // exists on the memory plane.
            checkpoint_cold: checkpoint_requested && memory_budget > 0,
            chaos_victim,
            checkpoints_written: AtomicU64::new(0),
            checkpoint_bytes: AtomicU64::new(0),
            fuzz,
            transport,
            compile_window,
            windows_flushed: AtomicU64::new(0),
            window_culled: AtomicU64::new(0),
            window_fused: AtomicU64::new(0),
            aot_frees: AtomicU64::new(0),
            alias_reuses: AtomicU64::new(0),
            placement_verdicts: AtomicU64::new(0),
        });

        // Persistent worker pool: `nodes * workers_per_node` executors that
        // live for the whole application (the PyCOMPSs-inherited model,
        // §3.3.2).
        let mut workers = Vec::new();
        for node in 0..config.nodes {
            for slot in 0..config.workers_per_node {
                let wid = WorkerId {
                    node: NodeId(node),
                    slot,
                };
                let sh = Arc::clone(&shared);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("rcompss-{wid}"))
                        .spawn(move || executor::worker_loop(sh, wid))
                        .context("spawn worker")?,
                );
            }
        }
        // Dedicated mover threads per emulated node: they run the codec
        // boundary of cross-node transfers off the workers' claim paths.
        let mut movers = Vec::new();
        for node in 0..config.nodes {
            for slot in 0..movers_per_node {
                let sh = Arc::clone(&shared);
                let home = NodeId(node);
                movers.push(
                    std::thread::Builder::new()
                        .name(format!("rcompss-mover-{node}.{slot}"))
                        .spawn(move || transfer::mover_loop(sh, home))
                        .context("spawn mover")?,
                );
            }
        }
        Ok(Coordinator {
            shared,
            workers,
            movers,
            config,
        })
    }

    /// Master pseudo-worker id used for submission-side serialization
    /// events in traces.
    fn master_wid(&self) -> WorkerId {
        WorkerId {
            node: NodeId(0),
            slot: u32::MAX,
        }
    }

    /// Submit a task call: analyze accesses, build edges, enqueue if ready.
    /// Returns the OUT data handles. This is asynchronous — it returns as
    /// soon as the task is in the DAG.
    pub fn submit(&self, spec: &Arc<TaskSpec>, args: &[Arg]) -> Result<SubmitOutcome> {
        if args.len() != spec.arity {
            bail!(
                "task '{}' expects {} arguments, got {}",
                spec.name,
                spec.arity,
                args.len()
            );
        }
        if self.shared.stopping.load(Ordering::SeqCst) {
            bail!("runtime is stopping");
        }
        let literal_keys = self.materialize_literals(args)?;
        let (outcome, cancelled) = {
            let mut core = self.shared.core.lock().unwrap();
            let mut cache = LocSnapshot::new();
            self.analyze_and_insert(&mut core, spec, args, &literal_keys, &mut cache)
        };
        if let Some(meta) = cancelled {
            release_inputs(&self.shared, &meta.inputs);
        }
        Ok(outcome)
    }

    /// Submit a batch of task calls, amortizing the control lock: every
    /// literal is materialized first (off the lock), then the whole batch
    /// runs dependency analysis and DAG insertion under a *single* lock
    /// acquisition. Semantically identical to calling
    /// [`Coordinator::submit`] once per element, in order — the apps'
    /// partition loops use this to shrink per-task dispatch overhead.
    pub fn submit_batch(&self, calls: &[(Arc<TaskSpec>, Vec<Arg>)]) -> Result<Vec<SubmitOutcome>> {
        for (spec, args) in calls {
            if args.len() != spec.arity {
                bail!(
                    "task '{}' expects {} arguments, got {}",
                    spec.name,
                    spec.arity,
                    args.len()
                );
            }
        }
        if self.shared.stopping.load(Ordering::SeqCst) {
            bail!("runtime is stopping");
        }
        let mut literal_keys = Vec::with_capacity(calls.len());
        for (_, args) in calls {
            literal_keys.push(self.materialize_literals(args)?);
        }
        let mut cancelled: Vec<Arc<TaskMeta>> = Vec::new();
        let outcomes: Vec<SubmitOutcome> = {
            let mut core = self.shared.core.lock().unwrap();
            // One snapshot cache per lock hold: a shared input read by
            // every element of the batch costs one table read, not N.
            let mut cache = LocSnapshot::new();
            calls
                .iter()
                .zip(literal_keys.iter())
                .map(|((spec, args), lits)| {
                    let (out, c) =
                        self.analyze_and_insert(&mut core, spec, args, lits, &mut cache);
                    if let Some(meta) = c {
                        cancelled.push(meta);
                    }
                    out
                })
                .collect()
        };
        for meta in cancelled {
            release_inputs(&self.shared, &meta.inputs);
        }
        Ok(outcomes)
    }

    /// Phase 1 of submission: materialize literal arguments. On the file
    /// plane this is master-side serialization (traced, like COMPSs); on
    /// the memory plane the value goes straight into the store — the codec
    /// runs only if it later spills.
    fn materialize_literals(&self, args: &[Arg]) -> Result<Vec<Option<DataKey>>> {
        let mut literal_keys: Vec<Option<DataKey>> = vec![None; args.len()];
        for (i, arg) in args.iter().enumerate() {
            if let Arg::Value(v) = arg {
                if self.shared.store.enabled() {
                    let value = Arc::new(v.clone());
                    let nbytes = value.byte_size() as u64;
                    let key = {
                        let mut core = self.shared.core.lock().unwrap();
                        let key = core.registry.new_literal(nbytes, NodeId(0));
                        // Retained so node-loss recovery can re-seed the
                        // literal — no task can re-derive it.
                        core.registry.retain_literal(key, Arc::clone(&value));
                        key
                    };
                    let victims = self.shared.store.hot().put(key, value, false);
                    self.shared.table.mark_available_memory(key, NodeId(0), nbytes);
                    store::demote_victims(&self.shared, victims);
                    literal_keys[i] = Some(key);
                } else {
                    let start = self.shared.tracer.now();
                    let bytes = self.shared.codec.encode(v)?;
                    let nbytes = bytes.len() as u64;
                    let key = {
                        let mut core = self.shared.core.lock().unwrap();
                        let key = core.registry.new_literal(nbytes, NodeId(0));
                        core.stats.bytes_serialized += nbytes;
                        key
                    };
                    let path = self.shared.path_for(key);
                    std::fs::write(&path, &bytes)
                        .with_context(|| format!("write literal {}", path.display()))?;
                    self.shared.store.cold().note_write();
                    self.shared.table.mark_available(key, NodeId(0), nbytes, path);
                    {
                        let mut core = self.shared.core.lock().unwrap();
                        core.stats.serialize_s += self.shared.tracer.now() - start;
                    }
                    self.shared.tracer.record_at(
                        self.master_wid(),
                        EventKind::Serialize,
                        None,
                        start,
                        self.shared.tracer.now(),
                    );
                    literal_keys[i] = Some(key);
                }
            }
        }
        Ok(literal_keys)
    }

    /// Phase 2 of submission: dependency analysis + DAG insertion, under
    /// the control lock (kept atomic so a dependent can never be inserted
    /// before its producer). Returns the outcome plus, when the task was
    /// cancelled on insert (failed upstream), its metadata so the caller
    /// can release the never-to-be-consumed input references off the lock.
    fn analyze_and_insert(
        &self,
        core: &mut Core,
        spec: &Arc<TaskSpec>,
        args: &[Arg],
        literal_keys: &[Option<DataKey>],
        cache: &mut LocSnapshot,
    ) -> (SubmitOutcome, Option<Arc<TaskMeta>>) {
        let id = core.graph.next_task_id();
        let mut deps: Vec<(TaskId, EdgeKind, DataKey)> = Vec::new();
        let mut reads: Vec<DataKey> = Vec::new();
        let mut input_keys: Vec<DataKey> = Vec::with_capacity(args.len());
        let mut writes: Vec<DataKey> = Vec::new();
        let mut updated: Vec<DataKey> = Vec::new();

        for (i, arg) in args.iter().enumerate() {
            let dir = spec.directions[i];
            let data_id = match (arg, literal_keys[i]) {
                (_, Some(k)) => k.data,
                (Arg::Ref(k), _) => k.data,
                (Arg::Value(_), None) => unreachable!("literal not materialized"),
            };
            if dir.reads() {
                let (key, raw) = core.registry.record_read(data_id, id);
                if !core.registry.is_available(key) || raw.is_some() {
                    if let Some(p) = raw {
                        deps.push((p, EdgeKind::Raw, key));
                    }
                }
                reads.push(key);
                input_keys.push(key);
            }
            if dir.writes() {
                let (new_key, waw, war) = core.registry.record_write(data_id, id);
                if let Some(p) = waw {
                    deps.push((p, EdgeKind::Waw, new_key));
                }
                for r in war {
                    if r != id {
                        deps.push((r, EdgeKind::War, new_key));
                    }
                }
                writes.push(new_key);
                updated.push(new_key);
            }
        }

        // Return values: fresh data produced by this task.
        let mut returns = Vec::with_capacity(spec.n_outputs);
        for _ in 0..spec.n_outputs {
            let key = core.registry.new_future(id);
            writes.push(key);
            returns.push(key);
        }

        let meta = Arc::new(TaskMeta {
            spec: Arc::clone(spec),
            inputs: input_keys,
            outputs: writes.clone(),
        });
        core.meta.insert(id, Arc::clone(&meta));
        core.stats.tasks_submitted += 1;

        let ready = core.graph.insert_task(id, &spec.name, reads, writes, deps);
        if self.shared.compile_window {
            // Buffer instead of dispatching; the whole window compiles
            // and flushes together at the size cap or the next sync.
            if core.graph.state(id) != Some(TaskState::Cancelled) {
                core.window.push(id);
                if core.window.len() >= compile::WINDOW_CAP {
                    self.shared.flush_window(core);
                }
            }
        } else if ready {
            self.shared.enqueue_ready_cached(core, id, cache);
        }
        // A task may have been cancelled on insert (failed upstream); its
        // input references are handed back for release off the lock.
        let mut cancelled = None;
        if core.graph.state(id) == Some(TaskState::Cancelled) {
            core.stats.tasks_cancelled += 1;
            cancelled = Some(meta);
            self.shared.cv_done.notify_all();
        }
        (SubmitOutcome { returns, updated }, cancelled)
    }

    /// Kill an emulated node mid-run: its workers park, its shard closes
    /// for placement and stealing, in-flight transfers toward/from it fail
    /// fast, and every version it was the sole holder of is re-derived by
    /// lineage re-execution (the producing tasks — transitively — reopen
    /// and re-enter the ready queue). Refuses to kill the last alive node.
    /// Returns `true` if the node was alive.
    pub fn kill_node(&self, node: NodeId) -> bool {
        kill_node_now(&self.shared, node)
    }

    /// Re-admit a previously-killed node: its shard re-opens for placement
    /// and stealing and its parked workers resume. Returns `true` if the
    /// node was dead.
    pub fn add_node(&self, node: NodeId) -> bool {
        rejoin_node(&self.shared, node)
    }

    /// Pin a version so the GC never reclaims it, without waiting for it.
    /// Call this before the value's last task consumer may finish when the
    /// application plans to fetch the handle later — `wait_on` pins
    /// implicitly, but only at fetch time, which is too late for a value
    /// whose consumers were submitted (and may drain) first.
    pub fn pin(&self, key: DataKey) -> Result<()> {
        if !self.shared.table.pin(key) {
            bail!("unknown datum {key}");
        }
        Ok(())
    }

    /// Block until `key` is produced, then fetch and return it
    /// (`compss_wait_on`). Fails if the producing task failed or was
    /// cancelled. On the memory plane this is a store lookup (plus one
    /// clone for ownership); on the file plane, a codec read.
    ///
    /// Pins the version first: the version GC never reclaims a pinned
    /// version, so repeated `wait_on`s of the same handle keep working.
    /// Waiting on a version the GC *already* reclaimed (its last consumer
    /// finished before this call) is an error, not a hang.
    pub fn wait_on(&self, key: DataKey) -> Result<RValue> {
        if !self.shared.table.pin(key) {
            bail!("unknown datum {key}");
        }
        loop {
            let mut core = self.shared.core.lock().unwrap();
            // A sync point: the buffered window must compile and move or
            // the producer below never dispatches. The pin above happened
            // first, so the compiler can no longer cull or fuse `key`.
            self.shared.flush_window(&mut core);
            loop {
                let info = self
                    .shared
                    .table
                    .info(key)
                    .ok_or_else(|| anyhow!("unknown datum {key}"))?;
                if info.collected {
                    if self.shared.compile_window
                        && core.registry.latest_key(key.data) != Some(key)
                    {
                        bail!(
                            "datum {key} was elided by the window compiler (superseded, \
                             never read); pin or fetch it before submitting its \
                             overwrite, or run --compile off"
                        );
                    }
                    bail!(
                        "datum {key} was reclaimed by the version GC before wait_on; \
                         fetch results before their last consumer finishes or disable gc"
                    );
                }
                if info.available {
                    break;
                }
                let producer = info
                    .producer
                    .ok_or_else(|| anyhow!("unknown datum {key}"))?;
                match core.graph.state(producer) {
                    Some(TaskState::Failed) => {
                        bail!(
                            "task producing {key} failed permanently: {}",
                            core.graph.failure_blurb(producer)
                        )
                    }
                    Some(TaskState::Cancelled) => {
                        match core.graph.node(producer).and_then(|n| n.cancelled_by) {
                            Some(root) => bail!(
                                "task {producer} producing {key} was cancelled by failed \
                                 ancestor {}",
                                core.graph.failure_blurb(root)
                            ),
                            None => bail!("task {producer} producing {key} was cancelled"),
                        }
                    }
                    // Producer retired without publishing: the window
                    // compiler fused the superseded version away and a
                    // waiter pinned it only after that decision. (A
                    // version lost with a node never matches — recovery
                    // reopens its producer under the same lock hold that
                    // drops the node, so `Done` + unavailable + armed
                    // compiler + superseded is unambiguous.)
                    Some(TaskState::Done)
                        if self.shared.compile_window
                            && core.registry.latest_key(key.data) != Some(key) =>
                    {
                        bail!(
                            "datum {key} was elided by the window compiler (superseded, \
                             producer retired); pin or fetch it before submitting its \
                             overwrite, or run --compile off"
                        );
                    }
                    _ => {}
                }
                core = self.shared.cv_done.wait(core).unwrap();
            }
            drop(core);
            if self.shared.store.enabled() {
                match executor::fetch_resident(&self.shared, key) {
                    Ok((value, _, _)) => return Ok((*value).clone()),
                    // Lost with a node between the availability check and
                    // the fetch: lineage recovery re-derives it — go back
                    // to waiting, don't surface a transient error.
                    Err(_)
                        if !self.shared.table.is_available(key)
                            && !self.shared.table.is_collected(key) =>
                    {
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            let path = self.shared.path_for(key);
            let start = self.shared.tracer.now();
            self.shared.store.cold().note_read();
            let v = self.shared.codec.read_file(&path)?;
            self.shared.tracer.record_at(
                self.master_wid(),
                EventKind::Deserialize,
                None,
                start,
                self.shared.tracer.now(),
            );
            return Ok(v);
        }
    }

    /// Block until every submitted task is in a terminal state
    /// (`compss_barrier`). Returns an error if any task failed.
    pub fn barrier(&self) -> Result<()> {
        let mut core = self.shared.core.lock().unwrap();
        self.shared.flush_window(&mut core);
        let core = self
            .shared
            .cv_done
            .wait_while(core, |c| !c.graph.quiescent())
            .unwrap();
        if core.graph.failed_count() > 0 {
            let root = core
                .graph
                .root_failure()
                .map(|n| core.graph.failure_blurb(n.id))
                .unwrap_or_else(|| "unknown".into());
            bail!(
                "{} task(s) failed, {} cancelled; root cause: {root}",
                core.graph.failed_count(),
                core.graph.cancelled_count()
            );
        }
        Ok(())
    }

    /// Stop the runtime (`compss_stop`): drain, join workers, return stats.
    pub fn stop(self) -> Result<RuntimeStats> {
        // Drain outstanding work first (stop() implies a barrier in COMPSs).
        {
            let mut core = self.shared.core.lock().unwrap();
            self.shared.flush_window(&mut core);
            let _quiescent = self
                .shared
                .cv_done
                .wait_while(core, |c| !c.graph.quiescent())
                .unwrap();
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.ready.stop();
        for w in self.workers {
            let _ = w.join();
        }
        self.shared.transfers.stop();
        for m in self.movers {
            let _ = m.join();
        }
        // Tear the transport down only after the movers are gone — no
        // fetch can be in flight on a closed socket.
        self.shared.transport.shutdown();
        let mut stats = self.shared.core.lock().unwrap().stats.clone();
        Self::fill_shared_stats(&self.shared, &mut stats);
        Ok(stats)
    }

    fn fill_shared_stats(shared: &Shared, stats: &mut RuntimeStats) {
        stats.store_hits = shared.store.hot().hit_count();
        stats.store_misses = shared.store.hot().miss_count();
        stats.spills = shared.store.hot().spill_count();
        stats.spill_bytes = shared.store.hot().spilled_bytes();
        stats.warm_hits = shared.store.warm().hit_count();
        stats.warm_misses = shared.store.warm().miss_count();
        stats.warm_fills = shared.store.warm().fill_count();
        stats.warm_evictions = shared.store.warm().eviction_count();
        stats.warm_resident_bytes = shared.store.warm().resident_bytes();
        stats.store_encodes = shared.store.encode_count();
        stats.store_file_reads = shared.store.cold().file_read_count();
        stats.store_file_writes = shared.store.cold().file_write_count();
        stats.sync_transfer_decodes = shared.store.hot().sync_transfer_decode_count();
        stats.store_resident_bytes = shared.store.hot().resident_bytes();
        stats.dead_version_bytes = shared.table.dead_bytes();
        stats.gc_collected = shared.gc_collected.load(Ordering::Relaxed);
        stats.gc_bytes = shared.gc_bytes.load(Ordering::Relaxed);
        stats.gc_files_deleted = shared.gc_files.load(Ordering::Relaxed);
        stats.transfers_requested = shared.transfers.requested();
        stats.transfers_prefetched = shared.transfers.prefetched();
        stats.transfers_waited = shared.transfers.waited();
        stats.transfers_dropped = shared.transfers.dropped();
        stats.transfers_failed = shared.transfers.failed();
        stats.transfers_retried = shared.transfers.retried();
        stats.transfer_states = shared.transfers.state_count() as u64;
        stats.transfer_bytes = shared.transfers.transfer_bytes();
        stats.checkpoints_written = shared.checkpoints_written.load(Ordering::Relaxed);
        stats.checkpoint_bytes = shared.checkpoint_bytes.load(Ordering::Relaxed);
        stats.sched_fuzz_perturbations =
            shared.fuzz.as_ref().map(|f| f.total_visits()).unwrap_or(0);
        stats.windows_flushed = shared.windows_flushed.load(Ordering::Relaxed);
        stats.window_culled = shared.window_culled.load(Ordering::Relaxed);
        stats.window_fused = shared.window_fused.load(Ordering::Relaxed);
        stats.aot_frees = shared.aot_frees.load(Ordering::Relaxed);
        stats.alias_reuses = shared.alias_reuses.load(Ordering::Relaxed);
        stats.placement_verdicts = shared.placement_verdicts.load(Ordering::Relaxed);
        stats.hot_peak_bytes = shared.store.hot().peak_resident_bytes();
        let ship = shared.transport.ship_stats();
        stats.direct_ships = ship.direct_ships;
        stats.relay_ships = ship.relay_ships;
        stats.seed_ships = ship.seed_ships;
        stats.pool_hits = ship.pool_hits;
        stats.coord_egress_bytes = ship.egress_bytes;
    }

    /// The observation sink behind an `adaptive` router (`None` for the
    /// static models). Benches and tests use it to pre-seed or inspect
    /// bandwidth/duration observations.
    pub fn feedback_stats(&self) -> Option<Arc<FeedbackStats>> {
        self.shared.feedback.as_ref().map(Arc::clone)
    }

    /// Snapshot statistics without stopping.
    pub fn stats(&self) -> RuntimeStats {
        let mut core = self.shared.core.lock().unwrap();
        // A snapshot is a progress observation point: programs that poll
        // it between submissions (instead of syncing) must see the
        // buffered window move, or an armed compiler would stall them.
        self.shared.flush_window(&mut core);
        let mut stats = core.stats.clone();
        drop(core);
        Self::fill_shared_stats(&self.shared, &mut stats);
        stats
    }

    /// DOT export of the current DAG (Figures 2-5).
    pub fn dag_dot(&self, title: &str) -> String {
        self.shared.core.lock().unwrap().graph.to_dot(title)
    }

    /// Finish and return the trace collected so far.
    pub fn trace(&self, label: &str) -> crate::trace::Trace {
        self.shared.tracer.finish(label)
    }

    /// Critical-path length of the submitted DAG.
    pub fn critical_path_len(&self) -> usize {
        self.shared.core.lock().unwrap().graph.critical_path_len()
    }

    /// Remove the workdir (after stop). Separate so tests can inspect files.
    pub fn cleanup_workdir(config: &CoordinatorConfig) {
        let _ = std::fs::remove_dir_all(&config.workdir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    fn mem_config(nodes: u32, wpn: u32) -> CoordinatorConfig {
        CoordinatorConfig::local(wpn)
            .with_nodes(nodes, wpn)
            .with_memory_budget(64 << 20)
    }

    /// Manufacture an available memory-resident literal on node 0 — the
    /// state a producer leaves behind — without going through tasks, so
    /// the transfer machinery can be driven deterministically.
    fn seed_value(coord: &Coordinator, n: usize) -> DataKey {
        let value = Arc::new(RValue::Real(vec![1.5; n]));
        let nbytes = value.byte_size() as u64;
        let key = {
            let mut core = coord.shared.core.lock().unwrap();
            core.registry.new_literal(nbytes, NodeId(0))
        };
        let victims = coord.shared.store.hot().put(key, value, false);
        assert!(victims.is_empty(), "budget must fit the seed value");
        coord
            .shared
            .table
            .mark_available_memory(key, NodeId(0), nbytes);
        key
    }

    #[test]
    fn transfer_is_prefetched_before_the_claim_needs_it() {
        let config = mem_config(2, 1);
        let coord = Coordinator::start(config.clone()).unwrap();
        let key = seed_value(&coord, 64);
        // Exactly what enqueue_ready issues when it routes a consumer of
        // `key` to node 1.
        coord.shared.transfers.request(key, NodeId(1), 64 * 8);
        // A mover stages the replica with no claimant anywhere near; the
        // completion counter flips once the transfer is fully published.
        let t0 = Instant::now();
        while coord.shared.transfers.prefetched() == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "mover never staged the value"
            );
            std::thread::yield_now();
        }
        assert!(coord.shared.table.is_local(key, NodeId(1)));
        assert_eq!(coord.shared.transfers.waited(), 0);
        // The claim path is now a zero-copy lookup: no codec invocation,
        // no blocking (`decoded == false` is the no-blocking-reload
        // witness the DataStore counter backs up).
        let (v, decoded, _) =
            executor::acquire_input(&coord.shared, key, NodeId(1), false).unwrap();
        assert!(!decoded, "claim of a staged replica must not decode");
        assert_eq!(v.as_real().unwrap()[0], 1.5);
        assert_eq!(coord.shared.store.hot().sync_transfer_decode_count(), 0);
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn claim_mid_transfer_parks_and_gets_the_staged_value() {
        let config = mem_config(2, 1);
        let coord = Coordinator::start(config.clone()).unwrap();
        let key = seed_value(&coord, 256);
        coord.shared.transfers.request(key, NodeId(1), 256 * 8);
        // Claim immediately, racing the mover: the claimant either finds
        // the replica staged (prefetched) or parks mid-transfer (waited) —
        // never a synchronous claim-path decode, always the right bytes.
        let (v, _, _) =
            executor::acquire_input(&coord.shared, key, NodeId(1), false).unwrap();
        assert_eq!(v.as_real().unwrap()[0], 1.5);
        assert!(coord.shared.table.is_local(key, NodeId(1)));
        // The claim can return (fast path) a hair before the mover files
        // its completion; poll the counters, then check the split.
        let t = &coord.shared.transfers;
        let t0 = Instant::now();
        while t.prefetched() + t.waited() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "transfer never completed");
            std::thread::yield_now();
        }
        assert_eq!(t.prefetched() + t.waited(), 1);
        assert_eq!(coord.shared.store.hot().sync_transfer_decode_count(), 0);
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn failed_transfer_is_restaged_without_sync_decode() {
        // Acceptance: after one injected mover failure, a later
        // await_staged for the same (version, node) pair succeeds via a
        // retried mover transfer — the claim path never runs the codec.
        let mut config = mem_config(2, 1);
        config.injector = Arc::new(FailureInjector::new(1.0, "__transfer__", 1, 5));
        let coord = Coordinator::start(config.clone()).unwrap();
        let key = seed_value(&coord, 64);
        coord.shared.transfers.request(key, NodeId(1), 64 * 8);
        // The injector fails exactly the first attempt.
        let t0 = Instant::now();
        while coord.shared.transfers.failed() == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "injected failure never fired"
            );
            std::thread::yield_now();
        }
        // The pair is re-stageable: await_staged clears the tombstone,
        // re-queues, and the second mover attempt stages the replica.
        coord
            .shared
            .transfers
            .await_staged(key, NodeId(1), 64 * 8)
            .expect("retried transfer must stage");
        assert!(coord.shared.table.is_local(key, NodeId(1)));
        assert_eq!(coord.shared.transfers.retried(), 1);
        let (v, decoded, _) =
            executor::acquire_input(&coord.shared, key, NodeId(1), false).unwrap();
        assert!(!decoded, "claim of the restaged replica must not decode");
        assert_eq!(v.as_real().unwrap()[0], 1.5);
        assert_eq!(coord.shared.store.hot().sync_transfer_decode_count(), 0);
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn adaptive_router_learns_from_live_transfers() {
        // The movers feed the adaptive model's sink: after a staged
        // transfer the bandwidth EWMA toward the destination is live.
        let config = mem_config(2, 1).with_router("adaptive");
        let coord = Coordinator::start(config.clone()).unwrap();
        let fb = coord.feedback_stats().expect("adaptive exposes its sink");
        assert_eq!(fb.transfer_observations(), 0);
        let key = seed_value(&coord, 256);
        coord.shared.transfers.request(key, NodeId(1), 256 * 8);
        let t0 = Instant::now();
        while fb.transfer_observations() == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "mover never recorded an observation"
            );
            std::thread::yield_now();
        }
        assert!(fb.bandwidth_toward(NodeId(1)).unwrap_or(0.0) > 0.0);
        // Static routers expose no sink.
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
        let plain_config = mem_config(1, 1).with_router("bytes");
        let plain = Coordinator::start(plain_config.clone()).unwrap();
        assert!(plain.feedback_stats().is_none());
        plain.stop().unwrap();
        Coordinator::cleanup_workdir(&plain_config);
    }

    #[test]
    fn fanout_transfer_encodes_once_with_zero_file_io() {
        // Tiered-store acceptance at the transfer plane: a memory-resident
        // version fanned out to N nodes costs exactly one `codec.encode`
        // (the lazy warm fill — racing movers park on it) and zero file
        // reads/writes; the movers ship the blob. Warm budget pinned
        // explicitly so the CI env matrix (RCOMPSS_WARM_BUDGET=0) cannot
        // turn the tier off under this test.
        let config = mem_config(4, 1).with_warm_budget(DEFAULT_WARM_BUDGET);
        let coord = Coordinator::start(config.clone()).unwrap();
        assert!(coord.shared.store.warm().enabled());
        let key = seed_value(&coord, 512);
        for node in 1..4u32 {
            coord.shared.transfers.request(key, NodeId(node), 512 * 8);
        }
        for node in 1..4u32 {
            coord
                .shared
                .transfers
                .await_staged(key, NodeId(node), 512 * 8)
                .expect("warm staging");
            assert!(coord.shared.table.is_local(key, NodeId(node)));
        }
        assert_eq!(coord.shared.store.encode_count(), 1, "one encode per fan-out");
        assert_eq!(coord.shared.store.cold().file_read_count(), 0);
        assert_eq!(coord.shared.store.cold().file_write_count(), 0);
        assert_eq!(coord.shared.store.warm().miss_count(), 1, "first transfer fills");
        assert_eq!(coord.shared.store.warm().hit_count(), 2, "N-1 replicas hit warm");
        assert_eq!(coord.shared.store.hot().sync_transfer_decode_count(), 0);
        // The fill upgraded the byte estimate to the real serialized size.
        let info = coord.shared.table.info(key).unwrap();
        assert_eq!(info.bytes, coord.shared.store.warm().resident_bytes());
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn warm_budget_zero_stages_through_files_as_before() {
        // `--warm-budget 0` must reproduce the pre-tier file staging path
        // byte for byte: the mover publishes a spill file, reads it back,
        // and the warm tier never sees traffic.
        let config = mem_config(2, 1).with_warm_budget(0);
        let coord = Coordinator::start(config.clone()).unwrap();
        assert!(!coord.shared.store.warm().enabled());
        let key = seed_value(&coord, 64);
        coord.shared.transfers.request(key, NodeId(1), 64 * 8);
        coord
            .shared
            .transfers
            .await_staged(key, NodeId(1), 64 * 8)
            .expect("file staging");
        assert!(coord.shared.table.is_local(key, NodeId(1)));
        assert_eq!(coord.shared.store.cold().file_write_count(), 1, "spill published");
        assert!(coord.shared.store.cold().file_read_count() >= 1, "staged from the file");
        assert_eq!(coord.shared.store.encode_count(), 1);
        assert_eq!(coord.shared.store.warm().fill_count(), 0);
        assert_eq!(coord.shared.store.warm().hit_count(), 0);
        assert!(coord.shared.table.path_of(key).is_some(), "file remains published");
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn kill_node_reseeds_lost_literals_and_join_is_idempotent() {
        let config = mem_config(2, 1);
        let coord = Coordinator::start(config.clone()).unwrap();
        // A literal resident only on node 0, retained like submit() does.
        let value = Arc::new(RValue::Real(vec![2.5; 32]));
        let nbytes = value.byte_size() as u64;
        let key = {
            let mut core = coord.shared.core.lock().unwrap();
            let key = core.registry.new_literal(nbytes, NodeId(0));
            core.registry.retain_literal(key, Arc::clone(&value));
            key
        };
        let victims = coord.shared.store.hot().put(key, value, false);
        assert!(victims.is_empty());
        coord.shared.table.mark_available_memory(key, NodeId(0), nbytes);
        // Kill the sole holder: recovery re-seeds the literal on the
        // surviving node — no task could re-derive it.
        assert!(coord.kill_node(NodeId(0)));
        assert!(!coord.kill_node(NodeId(0)), "kill is idempotent");
        let info = coord.shared.table.info(key).unwrap();
        assert!(info.available, "lost literal re-seeded");
        assert_eq!(info.locations, vec![NodeId(1)]);
        assert_eq!(
            coord.shared.table.info(key).unwrap().bytes,
            nbytes,
            "re-seed keeps the byte estimate"
        );
        // The last alive node is never killable.
        assert!(!coord.kill_node(NodeId(1)));
        // Rejoin re-opens the shard; both transitions count once.
        assert!(coord.add_node(NodeId(0)));
        assert!(!coord.add_node(NodeId(0)), "join is idempotent");
        let stats = coord.stats();
        assert_eq!(stats.nodes_killed, 1);
        assert_eq!(stats.nodes_joined, 1);
        assert_eq!(stats.lineage_resubmissions, 0, "no tasks to replay");
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }

    #[test]
    fn transfer_threads_zero_falls_back_to_synchronous_decode() {
        let config = mem_config(2, 1).with_transfer_threads(0);
        let coord = Coordinator::start(config.clone()).unwrap();
        assert!(!coord.shared.transfers.enabled());
        let key = seed_value(&coord, 64);
        // The seed behavior: the claim path itself spills + reloads, and
        // the DataStore counter records it.
        let (v, decoded, _) =
            executor::acquire_input(&coord.shared, key, NodeId(1), false).unwrap();
        assert!(decoded, "synchronous fallback decodes on the claim path");
        assert_eq!(v.as_real().unwrap()[0], 1.5);
        assert_eq!(coord.shared.store.hot().sync_transfer_decode_count(), 1);
        assert!(coord.shared.table.is_local(key, NodeId(1)));
        coord.stop().unwrap();
        Coordinator::cleanup_workdir(&config);
    }
}
