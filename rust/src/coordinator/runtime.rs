//! The orchestrator: glue between the API, the dependency machinery, the
//! scheduler, and the persistent worker pool.
//!
//! This is the RCOMPSs `Core` module of Figure 1b: it performs "all
//! necessary actions for task preparation (parameter serialization, task
//! registry, and object tracking) and COMPSs requests for execution or data
//! retrieval". The master thread runs the user's sequential program;
//! [`Coordinator::submit`] analyzes each call's data accesses against the
//! versioned registry, inserts the task into the DAG, and hands ready tasks
//! to the scheduler, while persistent workers (see [`super::executor`])
//! pull, deserialize, execute, and serialize asynchronously.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::access::Direction;
use crate::coordinator::dag::{EdgeKind, TaskGraph, TaskId, TaskState};
use crate::coordinator::executor;
use crate::coordinator::fault::{FailureInjector, RetryPolicy};
use crate::coordinator::registry::{DataKey, DataRegistry, NodeId};
use crate::coordinator::scheduler::{scheduler_by_name, ReadyTask, Scheduler};
use crate::serialization::{codec_by_name, Codec};
use crate::trace::{EventKind, Tracer, WorkerId};
use crate::value::RValue;

/// A task body: pure function from input values to output values.
pub type TaskBody = Arc<dyn Fn(&[RValue]) -> Result<Vec<RValue>> + Send + Sync>;

/// Registered task metadata (the product of the R-level `task()` call).
pub struct TaskSpec {
    pub name: String,
    pub arity: usize,
    pub n_outputs: usize,
    /// Per-argument directions; length == arity.
    pub directions: Vec<Direction>,
    pub body: TaskBody,
}

/// An argument at a call site: either a literal value (serialized by the
/// master at submission, like COMPSs does) or a reference to runtime data.
#[derive(Clone)]
pub enum Arg {
    Value(RValue),
    Ref(DataKey),
}

/// What `submit` returns: the OUT data produced by the call.
#[derive(Clone, Debug)]
pub struct SubmitOutcome {
    /// One key per declared output (function return values).
    pub returns: Vec<DataKey>,
    /// New versions of INOUT arguments, in argument order.
    pub updated: Vec<DataKey>,
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    /// Cluster nodes to emulate in live mode (workers are threads; node
    /// membership affects locality accounting and tracing).
    pub nodes: u32,
    pub workers_per_node: u32,
    /// Scheduling policy: "fifo" | "lifo" | "locality".
    pub scheduler: String,
    /// Parameter codec (Table 1): "rmvl" (default) | "qs" | ...
    pub codec: String,
    /// Directory for serialized parameter files.
    pub workdir: PathBuf,
    pub retry: RetryPolicy,
    /// Collect trace events.
    pub trace: bool,
    /// Failure injection (tests/chaos benches).
    pub injector: Arc<FailureInjector>,
}

impl CoordinatorConfig {
    /// Sensible local defaults: one node, `workers` executors, RMVL codec,
    /// FIFO policy, workdir under the system temp dir.
    pub fn local(workers: u32) -> CoordinatorConfig {
        CoordinatorConfig {
            nodes: 1,
            workers_per_node: workers.max(1),
            scheduler: "fifo".into(),
            codec: "rmvl".into(),
            workdir: std::env::temp_dir().join(format!(
                "rcompss_{}_{}",
                std::process::id(),
                unique_run_id()
            )),
            retry: RetryPolicy::default(),
            trace: false,
            injector: Arc::new(FailureInjector::none()),
        }
    }

    pub fn with_scheduler(mut self, name: &str) -> Self {
        self.scheduler = name.into();
        self
    }

    pub fn with_codec(mut self, name: &str) -> Self {
        self.codec = name.into();
        self
    }

    pub fn with_trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    pub fn with_nodes(mut self, nodes: u32, workers_per_node: u32) -> Self {
        self.nodes = nodes.max(1);
        self.workers_per_node = workers_per_node.max(1);
        self
    }
}

fn unique_run_id() -> u64 {
    use std::sync::atomic::AtomicU64;
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Aggregate runtime statistics, printed at `stop()` and used by benches.
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub tasks_submitted: u64,
    pub tasks_done: u64,
    pub tasks_failed: u64,
    pub tasks_cancelled: u64,
    pub resubmissions: u64,
    pub bytes_serialized: u64,
    pub bytes_deserialized: u64,
    pub serialize_s: f64,
    pub deserialize_s: f64,
    pub exec_s: f64,
    /// Per task type: (count, total execution seconds).
    pub per_type: HashMap<String, (u64, f64)>,
}

/// Everything a claimed task needs to run outside the lock.
/// `inputs` carries `(key, path, was_node_local)` — locality resolved at
/// claim time so the read path takes no extra locks.
pub(crate) struct Claim {
    pub id: TaskId,
    pub spec: Arc<TaskSpec>,
    pub inputs: Vec<(DataKey, PathBuf, bool)>,
    pub outputs: Vec<DataKey>,
}

pub(crate) struct TaskMeta {
    pub spec: Arc<TaskSpec>,
    pub inputs: Vec<DataKey>,
    pub outputs: Vec<DataKey>,
}

/// Mutable coordinator state (behind the big lock).
pub(crate) struct Core {
    pub graph: TaskGraph,
    pub registry: DataRegistry,
    pub scheduler: Box<dyn Scheduler>,
    pub meta: HashMap<TaskId, TaskMeta>,
    pub stats: RuntimeStats,
    pub shutdown: bool,
}

impl Core {
    /// Push a newly-ready task to the scheduler with locality metadata.
    pub(crate) fn enqueue_ready(&mut self, id: TaskId) {
        let meta = &self.meta[&id];
        let inputs = meta
            .inputs
            .iter()
            .map(|k| {
                let info = self.registry.info(*k).expect("input version missing");
                (info.bytes, info.locations.clone())
            })
            .collect();
        let type_name = meta.spec.name.clone();
        self.scheduler.push(ReadyTask {
            id,
            inputs,
            type_name,
        });
    }
}

/// Shared coordinator handle (master + workers).
pub(crate) struct Shared {
    pub core: Mutex<Core>,
    /// Workers wait here for ready tasks.
    pub cv_work: Condvar,
    /// Waiters (`wait_on`, `barrier`) wait here for completions.
    pub cv_done: Condvar,
    pub codec: Box<dyn Codec>,
    pub tracer: Tracer,
    pub workdir: PathBuf,
    pub retry: RetryPolicy,
    pub injector: Arc<FailureInjector>,
    pub stopping: AtomicBool,
}

impl Shared {
    /// File path for a datum version: `workdir/dXvY.par` — the on-disk
    /// sibling of the paper's `dXvY` labels.
    pub fn path_for(&self, key: DataKey) -> PathBuf {
        self.workdir.join(format!("{key}.par"))
    }
}

/// The coordinator: one per application run (`compss_start` .. `compss_stop`).
pub struct Coordinator {
    pub(crate) shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub config: CoordinatorConfig,
}

impl Coordinator {
    /// Start the runtime: create the workdir, spawn the persistent worker
    /// pool, and return the handle (the `compss_start()` of the paper).
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        std::fs::create_dir_all(&config.workdir)
            .with_context(|| format!("create workdir {}", config.workdir.display()))?;
        let scheduler = scheduler_by_name(&config.scheduler)
            .ok_or_else(|| anyhow!("unknown scheduler '{}'", config.scheduler))?;
        let codec = codec_by_name(&config.codec)
            .ok_or_else(|| anyhow!("unknown codec '{}'", config.codec))?;
        let shared = Arc::new(Shared {
            core: Mutex::new(Core {
                graph: TaskGraph::new(),
                registry: DataRegistry::new(),
                scheduler,
                meta: HashMap::new(),
                stats: RuntimeStats::default(),
                shutdown: false,
            }),
            cv_work: Condvar::new(),
            cv_done: Condvar::new(),
            codec,
            tracer: Tracer::new(config.trace),
            workdir: config.workdir.clone(),
            retry: config.retry,
            injector: config.injector.clone(),
            stopping: AtomicBool::new(false),
        });

        // Persistent worker pool: `nodes * workers_per_node` executors that
        // live for the whole application (the PyCOMPSs-inherited model,
        // §3.3.2).
        let mut workers = Vec::new();
        for node in 0..config.nodes {
            for slot in 0..config.workers_per_node {
                let wid = WorkerId {
                    node: NodeId(node),
                    slot,
                };
                let sh = Arc::clone(&shared);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("rcompss-{wid}"))
                        .spawn(move || executor::worker_loop(sh, wid))
                        .context("spawn worker")?,
                );
            }
        }
        Ok(Coordinator {
            shared,
            workers,
            config,
        })
    }

    /// Master pseudo-worker id used for submission-side serialization
    /// events in traces.
    fn master_wid(&self) -> WorkerId {
        WorkerId {
            node: NodeId(0),
            slot: u32::MAX,
        }
    }

    /// Submit a task call: analyze accesses, build edges, enqueue if ready.
    /// Returns the OUT data handles. This is asynchronous — it returns as
    /// soon as the task is in the DAG.
    pub fn submit(&self, spec: &Arc<TaskSpec>, args: &[Arg]) -> Result<SubmitOutcome> {
        if args.len() != spec.arity {
            bail!(
                "task '{}' expects {} arguments, got {}",
                spec.name,
                spec.arity,
                args.len()
            );
        }
        if self.shared.stopping.load(Ordering::SeqCst) {
            bail!("runtime is stopping");
        }

        // Phase 1: materialize literal arguments (master-side
        // serialization, traced). Reserve ids under a short lock, write
        // files outside it.
        let mut literal_keys: Vec<Option<DataKey>> = vec![None; args.len()];
        for (i, arg) in args.iter().enumerate() {
            if let Arg::Value(v) = arg {
                let start = self.shared.tracer.now();
                let bytes = self.shared.codec.encode(v)?;
                let nbytes = bytes.len() as u64;
                let key = {
                    let mut core = self.shared.core.lock().unwrap();
                    let key = core.registry.new_literal(nbytes, NodeId(0));
                    core.stats.bytes_serialized += nbytes;
                    key
                };
                let path = self.shared.path_for(key);
                std::fs::write(&path, &bytes)
                    .with_context(|| format!("write literal {}", path.display()))?;
                {
                    let mut core = self.shared.core.lock().unwrap();
                    core.registry.mark_available(key, NodeId(0), nbytes, path);
                    core.stats.serialize_s += self.shared.tracer.now() - start;
                }
                self.shared.tracer.record_at(
                    self.master_wid(),
                    EventKind::Serialize,
                    None,
                    start,
                    self.shared.tracer.now(),
                );
                literal_keys[i] = Some(key);
            }
        }

        // Phase 2: dependency analysis + DAG insertion under the lock.
        let mut core = self.shared.core.lock().unwrap();
        let core = &mut *core;
        let id = core.graph.next_task_id();
        let mut deps: Vec<(TaskId, EdgeKind, DataKey)> = Vec::new();
        let mut reads: Vec<DataKey> = Vec::new();
        let mut input_keys: Vec<DataKey> = Vec::with_capacity(args.len());
        let mut writes: Vec<DataKey> = Vec::new();
        let mut updated: Vec<DataKey> = Vec::new();

        for (i, arg) in args.iter().enumerate() {
            let dir = spec.directions[i];
            let data_id = match (arg, literal_keys[i]) {
                (_, Some(k)) => k.data,
                (Arg::Ref(k), _) => k.data,
                (Arg::Value(_), None) => unreachable!("literal not materialized"),
            };
            if dir.reads() {
                let (key, raw) = core.registry.record_read(data_id, id);
                if !core.registry.is_available(key) || raw.is_some() {
                    if let Some(p) = raw {
                        deps.push((p, EdgeKind::Raw, key));
                    }
                }
                reads.push(key);
                input_keys.push(key);
            }
            if dir.writes() {
                let (new_key, waw, war) = core.registry.record_write(data_id, id);
                if let Some(p) = waw {
                    deps.push((p, EdgeKind::Waw, new_key));
                }
                for r in war {
                    if r != id {
                        deps.push((r, EdgeKind::War, new_key));
                    }
                }
                writes.push(new_key);
                updated.push(new_key);
            }
        }

        // Return values: fresh data produced by this task.
        let mut returns = Vec::with_capacity(spec.n_outputs);
        for _ in 0..spec.n_outputs {
            let key = core.registry.new_future(id);
            writes.push(key);
            returns.push(key);
        }

        core.meta.insert(
            id,
            TaskMeta {
                spec: Arc::clone(spec),
                inputs: input_keys,
                outputs: writes.clone(),
            },
        );
        core.stats.tasks_submitted += 1;

        let ready = core.graph.insert_task(id, &spec.name, reads, writes, deps);
        if ready {
            core.enqueue_ready(id);
            self.shared.cv_work.notify_one();
        }
        // A task may have been cancelled on insert (failed upstream).
        if core.graph.state(id) == Some(TaskState::Cancelled) {
            core.stats.tasks_cancelled += 1;
            self.shared.cv_done.notify_all();
        }
        Ok(SubmitOutcome { returns, updated })
    }

    /// Block until `key` is produced, then deserialize and return it
    /// (`compss_wait_on`). Fails if the producing task failed or was
    /// cancelled.
    pub fn wait_on(&self, key: DataKey) -> Result<RValue> {
        let path = {
            let mut core = self.shared.core.lock().unwrap();
            loop {
                if core.registry.is_available(key) {
                    break self
                        .shared
                        .path_for(key);
                }
                let producer = core
                    .registry
                    .info(key)
                    .and_then(|i| i.producer)
                    .ok_or_else(|| anyhow!("unknown datum {key}"))?;
                match core.graph.state(producer) {
                    Some(TaskState::Failed) => {
                        bail!("task {producer} producing {key} failed permanently")
                    }
                    Some(TaskState::Cancelled) => {
                        bail!("task {producer} producing {key} was cancelled")
                    }
                    _ => {}
                }
                core = self.shared.cv_done.wait(core).unwrap();
            }
        };
        let start = self.shared.tracer.now();
        let v = self.shared.codec.read_file(&path)?;
        self.shared.tracer.record_at(
            self.master_wid(),
            EventKind::Deserialize,
            None,
            start,
            self.shared.tracer.now(),
        );
        Ok(v)
    }

    /// Block until every submitted task is in a terminal state
    /// (`compss_barrier`). Returns an error if any task failed.
    pub fn barrier(&self) -> Result<()> {
        let core = self.shared.core.lock().unwrap();
        let core = self
            .shared
            .cv_done
            .wait_while(core, |c| !c.graph.quiescent())
            .unwrap();
        if core.graph.failed_count() > 0 {
            bail!(
                "{} task(s) failed, {} cancelled",
                core.graph.failed_count(),
                core.graph.cancelled_count()
            );
        }
        Ok(())
    }

    /// Stop the runtime (`compss_stop`): drain, join workers, return stats.
    pub fn stop(self) -> Result<RuntimeStats> {
        // Drain outstanding work first (stop() implies a barrier in COMPSs).
        {
            let core = self.shared.core.lock().unwrap();
            let mut core = self
                .shared
                .cv_done
                .wait_while(core, |c| !c.graph.quiescent())
                .unwrap();
            core.shutdown = true;
        }
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.cv_work.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
        let core = self.shared.core.lock().unwrap();
        Ok(core.stats.clone())
    }

    /// Snapshot statistics without stopping.
    pub fn stats(&self) -> RuntimeStats {
        self.shared.core.lock().unwrap().stats.clone()
    }

    /// DOT export of the current DAG (Figures 2-5).
    pub fn dag_dot(&self, title: &str) -> String {
        self.shared.core.lock().unwrap().graph.to_dot(title)
    }

    /// Finish and return the trace collected so far.
    pub fn trace(&self, label: &str) -> crate::trace::Trace {
        self.shared.tracer.finish(label)
    }

    /// Critical-path length of the submitted DAG.
    pub fn critical_path_len(&self) -> usize {
        self.shared.core.lock().unwrap().graph.critical_path_len()
    }

    /// Remove the workdir (after stop). Separate so tests can inspect files.
    pub fn cleanup_workdir(config: &CoordinatorConfig) {
        let _ = std::fs::remove_dir_all(&config.workdir);
    }
}
