//! Fault tolerance: task resubmission and failure injection.
//!
//! RCOMPSs inherits COMPSs' fault-tolerance mechanisms — "automatic task
//! resubmission and exception management" (§1, §3.1). The policy here is
//! the COMPSs default: a failed task execution is retried up to
//! `max_retries` times (possibly on a different worker, since it simply
//! re-enters the ready queue); when the budget is exhausted the task is
//! marked failed and every transitive dependent is cancelled, which
//! `wait_on`/`barrier` surface as an error to the application.
//!
//! [`FailureInjector`] drives the failure-injection tests: it makes chosen
//! task types fail with a given probability on their first `n` attempts,
//! letting the integration suite prove that resubmission preserves results.

use crate::coordinator::registry::NodeId;
use crate::util::prng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Retry policy.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Additional executions allowed after the first failure
    /// (COMPSs' default is 2 resubmissions).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2 }
    }
}

impl RetryPolicy {
    /// May a task that has already run `attempts` times (and failed) run
    /// again?
    pub fn may_retry(&self, attempts: u32) -> bool {
        // First execution is attempt 1; retries allowed while
        // attempts <= max_retries.
        attempts <= self.max_retries
    }
}

/// Liveness of every node in the virtual cluster.
///
/// The coordinator consults this plane on the hot paths (claim, publish,
/// placement), so it is lock-free: one atomic per node plus a `degraded`
/// summary bit that lets the common all-alive case skip the per-node scan
/// entirely. Transitions happen under the core lock (in
/// `Coordinator::kill_node`/`add_node`), so readers may observe a node
/// flip at any point but never see torn state.
#[derive(Debug)]
pub struct NodeHealth {
    alive: Vec<AtomicBool>,
    dead_count: AtomicUsize,
}

impl NodeHealth {
    pub fn new(nodes: usize) -> Self {
        NodeHealth {
            alive: (0..nodes).map(|_| AtomicBool::new(true)).collect(),
            dead_count: AtomicUsize::new(0),
        }
    }

    pub fn nodes(&self) -> usize {
        self.alive.len()
    }

    pub fn is_alive(&self, node: NodeId) -> bool {
        self.alive
            .get(node.0 as usize)
            .map(|a| a.load(Ordering::Acquire))
            .unwrap_or(false)
    }

    /// Mark a node lost. Returns `false` if it was already dead (or out of
    /// range), so callers can make kill idempotent.
    pub fn mark_dead(&self, node: NodeId) -> bool {
        let Some(a) = self.alive.get(node.0 as usize) else {
            return false;
        };
        if a.swap(false, Ordering::AcqRel) {
            self.dead_count.fetch_add(1, Ordering::AcqRel);
            true
        } else {
            false
        }
    }

    /// Mark a node (re)joined. Returns `false` if it was already alive.
    pub fn mark_alive(&self, node: NodeId) -> bool {
        let Some(a) = self.alive.get(node.0 as usize) else {
            return false;
        };
        if !a.swap(true, Ordering::AcqRel) {
            self.dead_count.fetch_sub(1, Ordering::AcqRel);
            true
        } else {
            false
        }
    }

    /// Any node currently dead? Cheap summary for hot paths.
    pub fn any_dead(&self) -> bool {
        self.dead_count.load(Ordering::Acquire) > 0
    }

    pub fn alive_count(&self) -> usize {
        self.alive.len() - self.dead_count.load(Ordering::Acquire)
    }

    /// Lowest-numbered live node (re-publish target for lost literals).
    pub fn first_alive(&self) -> Option<NodeId> {
        self.alive
            .iter()
            .position(|a| a.load(Ordering::Acquire))
            .map(|i| NodeId(i as u32))
    }
}

/// Parsed `--chaos` / `RCOMPSS_CHAOS` directive.
///
/// Grammar: comma-separated terms out of
/// `task-fail:<p>` (each execution fails with probability `p`),
/// `node-kill` / `node-kill:<seed>` (one node dies at a seeded random
/// point mid-run), and `seed:<n>` (seeds both). `none` or the empty
/// string disables chaos.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosSpec {
    pub task_fail_p: f64,
    pub node_kill: bool,
    pub seed: u64,
}

impl ChaosSpec {
    pub fn parse(s: &str) -> Result<ChaosSpec, String> {
        let mut spec = ChaosSpec::default();
        for term in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if term == "none" {
                continue;
            }
            let (head, arg) = match term.split_once(':') {
                Some((h, a)) => (h, Some(a)),
                None => (term, None),
            };
            match head {
                "task-fail" => {
                    let p: f64 = arg
                        .ok_or_else(|| format!("task-fail needs a probability: {term}"))?
                        .parse()
                        .map_err(|_| format!("bad task-fail probability: {term}"))?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("task-fail probability out of [0,1]: {term}"));
                    }
                    spec.task_fail_p = p;
                }
                "node-kill" => {
                    spec.node_kill = true;
                    if let Some(a) = arg {
                        spec.seed =
                            a.parse().map_err(|_| format!("bad node-kill seed: {term}"))?;
                    }
                }
                "seed" => {
                    spec.seed = arg
                        .ok_or_else(|| format!("seed needs a value: {term}"))?
                        .parse()
                        .map_err(|_| format!("bad seed: {term}"))?;
                }
                _ => return Err(format!("unknown chaos term: {term}")),
            }
        }
        Ok(spec)
    }

    pub fn is_active(&self) -> bool {
        self.task_fail_p > 0.0 || self.node_kill
    }
}

/// Deterministic failure injector for tests and chaos benches.
pub struct FailureInjector {
    inner: Mutex<InjectorState>,
}

struct InjectorState {
    rng: Pcg64,
    /// Probability that a matching execution fails.
    probability: f64,
    /// Only task types containing this substring fail ("" = all).
    type_filter: String,
    /// Stop injecting after this many injected failures (u32::MAX = never).
    budget: u32,
    injected: u32,
    /// `--chaos node-kill`: kill a node once this many tasks completed.
    node_kill_after: Option<u64>,
    node_killed: bool,
}

impl FailureInjector {
    /// No-op injector.
    pub fn none() -> Self {
        Self::new(0.0, "", u32::MAX, 0)
    }

    pub fn new(probability: f64, type_filter: &str, budget: u32, seed: u64) -> Self {
        FailureInjector {
            inner: Mutex::new(InjectorState {
                rng: Pcg64::seeded(seed),
                probability,
                type_filter: type_filter.to_string(),
                budget,
                injected: 0,
                node_kill_after: None,
                node_killed: false,
            }),
        }
    }

    /// Arm the `--chaos node-kill` hook: [`FailureInjector::node_kill_due`]
    /// fires once, after `after_completions` tasks have finished. The
    /// trigger point is chosen by the caller from the chaos seed so the
    /// kill lands at a deterministic (but run-specific) point mid-run.
    pub fn arm_node_kill(&self, after_completions: u64) {
        let mut s = self.inner.lock().unwrap();
        s.node_kill_after = Some(after_completions);
        s.node_killed = false;
    }

    /// One-shot trigger: true exactly once, at the first call where
    /// `completed` reaches the armed threshold.
    pub fn node_kill_due(&self, completed: u64) -> bool {
        let mut s = self.inner.lock().unwrap();
        match s.node_kill_after {
            Some(after) if !s.node_killed && completed >= after => {
                s.node_killed = true;
                true
            }
            _ => false,
        }
    }

    /// Decide whether this execution should be made to fail.
    pub fn should_fail(&self, task_type: &str) -> bool {
        let mut s = self.inner.lock().unwrap();
        if s.probability <= 0.0 || s.injected >= s.budget {
            return false;
        }
        if !s.type_filter.is_empty() && !task_type.contains(&s.type_filter) {
            return false;
        }
        let p = s.probability;
        if s.rng.chance(p) {
            s.injected += 1;
            true
        } else {
            false
        }
    }

    /// Failures injected so far.
    pub fn injected(&self) -> u32 {
        self.inner.lock().unwrap().injected
    }

    /// True when this injector can never fire (the [`FailureInjector::none`]
    /// default). The runtime uses this to tell an explicitly-configured
    /// injector apart from the no-op default: an env/`--chaos` plan only
    /// replaces the latter, so tests that pin their own injector keep it
    /// even under a chaos-matrix environment.
    pub fn is_noop(&self) -> bool {
        let s = self.inner.lock().unwrap();
        s.probability <= 0.0 && s.node_kill_after.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_allows_two_resubmissions() {
        let p = RetryPolicy::default();
        assert!(p.may_retry(1)); // failed first run -> retry
        assert!(p.may_retry(2)); // failed second run -> retry
        assert!(!p.may_retry(3)); // failed third run -> permanent
    }

    #[test]
    fn zero_retry_policy() {
        let p = RetryPolicy { max_retries: 0 };
        assert!(!p.may_retry(1));
    }

    #[test]
    fn injector_respects_budget() {
        let inj = FailureInjector::new(1.0, "", 3, 42);
        let fails = (0..10).filter(|_| inj.should_fail("anything")).count();
        assert_eq!(fails, 3);
        assert_eq!(inj.injected(), 3);
    }

    #[test]
    fn injector_filters_by_type() {
        let inj = FailureInjector::new(1.0, "merge", u32::MAX, 1);
        assert!(!inj.should_fail("KNN_frag"));
        assert!(inj.should_fail("KNN_merge"));
    }

    #[test]
    fn none_injector_never_fails() {
        let inj = FailureInjector::none();
        assert!((0..100).all(|_| !inj.should_fail("x")));
    }

    #[test]
    fn node_health_tracks_kill_and_join() {
        let h = NodeHealth::new(4);
        assert!(!h.any_dead());
        assert_eq!(h.alive_count(), 4);
        assert!(h.mark_dead(NodeId(2)));
        assert!(!h.mark_dead(NodeId(2)), "kill is idempotent");
        assert!(h.any_dead());
        assert_eq!(h.alive_count(), 3);
        assert!(!h.is_alive(NodeId(2)));
        assert!(h.is_alive(NodeId(0)));
        assert_eq!(h.first_alive(), Some(NodeId(0)));
        assert!(h.mark_dead(NodeId(0)));
        assert_eq!(h.first_alive(), Some(NodeId(1)));
        assert!(h.mark_alive(NodeId(2)));
        assert!(!h.mark_alive(NodeId(2)), "join is idempotent");
        assert!(h.is_alive(NodeId(2)));
        assert!(h.any_dead(), "node 0 still down");
        assert!(h.mark_alive(NodeId(0)));
        assert!(!h.any_dead());
        assert!(!h.is_alive(NodeId(9)), "out of range reads as dead");
        assert!(!h.mark_dead(NodeId(9)));
    }

    #[test]
    fn chaos_spec_parses_terms() {
        assert_eq!(ChaosSpec::parse("").unwrap(), ChaosSpec::default());
        assert_eq!(ChaosSpec::parse("none").unwrap(), ChaosSpec::default());
        assert!(!ChaosSpec::parse("none").unwrap().is_active());
        let s = ChaosSpec::parse("task-fail:0.1").unwrap();
        assert!(s.is_active() && (s.task_fail_p - 0.1).abs() < 1e-12 && !s.node_kill);
        let s = ChaosSpec::parse("node-kill").unwrap();
        assert!(s.node_kill && s.task_fail_p == 0.0);
        let s = ChaosSpec::parse("task-fail:0.05, node-kill:77").unwrap();
        assert!(s.node_kill && s.seed == 77 && (s.task_fail_p - 0.05).abs() < 1e-12);
        let s = ChaosSpec::parse("node-kill,seed:9").unwrap();
        assert_eq!(s.seed, 9);
        assert!(ChaosSpec::parse("task-fail").is_err());
        assert!(ChaosSpec::parse("task-fail:1.5").is_err());
        assert!(ChaosSpec::parse("explode").is_err());
    }

    #[test]
    fn node_kill_hook_fires_once_at_threshold() {
        let inj = FailureInjector::none();
        assert!(!inj.node_kill_due(100), "unarmed never fires");
        inj.arm_node_kill(5);
        assert!(!inj.node_kill_due(4));
        assert!(inj.node_kill_due(5));
        assert!(!inj.node_kill_due(6), "one-shot");
    }

    #[test]
    fn probability_roughly_respected() {
        let inj = FailureInjector::new(0.3, "", u32::MAX, 7);
        let fails = (0..10_000).filter(|_| inj.should_fail("t")).count();
        assert!((2500..3500).contains(&fails), "fails={fails}");
    }
}
