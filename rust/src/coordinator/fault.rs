//! Fault tolerance: task resubmission and failure injection.
//!
//! RCOMPSs inherits COMPSs' fault-tolerance mechanisms — "automatic task
//! resubmission and exception management" (§1, §3.1). The policy here is
//! the COMPSs default: a failed task execution is retried up to
//! `max_retries` times (possibly on a different worker, since it simply
//! re-enters the ready queue); when the budget is exhausted the task is
//! marked failed and every transitive dependent is cancelled, which
//! `wait_on`/`barrier` surface as an error to the application.
//!
//! [`FailureInjector`] drives the failure-injection tests: it makes chosen
//! task types fail with a given probability on their first `n` attempts,
//! letting the integration suite prove that resubmission preserves results.

use crate::util::prng::Pcg64;
use std::sync::Mutex;

/// Retry policy.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Additional executions allowed after the first failure
    /// (COMPSs' default is 2 resubmissions).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2 }
    }
}

impl RetryPolicy {
    /// May a task that has already run `attempts` times (and failed) run
    /// again?
    pub fn may_retry(&self, attempts: u32) -> bool {
        // First execution is attempt 1; retries allowed while
        // attempts <= max_retries.
        attempts <= self.max_retries
    }
}

/// Deterministic failure injector for tests and chaos benches.
pub struct FailureInjector {
    inner: Mutex<InjectorState>,
}

struct InjectorState {
    rng: Pcg64,
    /// Probability that a matching execution fails.
    probability: f64,
    /// Only task types containing this substring fail ("" = all).
    type_filter: String,
    /// Stop injecting after this many injected failures (u32::MAX = never).
    budget: u32,
    injected: u32,
}

impl FailureInjector {
    /// No-op injector.
    pub fn none() -> Self {
        Self::new(0.0, "", u32::MAX, 0)
    }

    pub fn new(probability: f64, type_filter: &str, budget: u32, seed: u64) -> Self {
        FailureInjector {
            inner: Mutex::new(InjectorState {
                rng: Pcg64::seeded(seed),
                probability,
                type_filter: type_filter.to_string(),
                budget,
                injected: 0,
            }),
        }
    }

    /// Decide whether this execution should be made to fail.
    pub fn should_fail(&self, task_type: &str) -> bool {
        let mut s = self.inner.lock().unwrap();
        if s.probability <= 0.0 || s.injected >= s.budget {
            return false;
        }
        if !s.type_filter.is_empty() && !task_type.contains(&s.type_filter) {
            return false;
        }
        let p = s.probability;
        if s.rng.chance(p) {
            s.injected += 1;
            true
        } else {
            false
        }
    }

    /// Failures injected so far.
    pub fn injected(&self) -> u32 {
        self.inner.lock().unwrap().injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_allows_two_resubmissions() {
        let p = RetryPolicy::default();
        assert!(p.may_retry(1)); // failed first run -> retry
        assert!(p.may_retry(2)); // failed second run -> retry
        assert!(!p.may_retry(3)); // failed third run -> permanent
    }

    #[test]
    fn zero_retry_policy() {
        let p = RetryPolicy { max_retries: 0 };
        assert!(!p.may_retry(1));
    }

    #[test]
    fn injector_respects_budget() {
        let inj = FailureInjector::new(1.0, "", 3, 42);
        let fails = (0..10).filter(|_| inj.should_fail("anything")).count();
        assert_eq!(fails, 3);
        assert_eq!(inj.injected(), 3);
    }

    #[test]
    fn injector_filters_by_type() {
        let inj = FailureInjector::new(1.0, "merge", u32::MAX, 1);
        assert!(!inj.should_fail("KNN_frag"));
        assert!(inj.should_fail("KNN_merge"));
    }

    #[test]
    fn none_injector_never_fails() {
        let inj = FailureInjector::none();
        assert!((0..100).all(|_| !inj.should_fail("x")));
    }

    #[test]
    fn probability_roughly_respected() {
        let inj = FailureInjector::new(0.3, "", u32::MAX, 7);
        let fails = (0..10_000).filter(|_| inj.should_fail("t")).count();
        assert!((2500..3500).contains(&fails), "fails={fails}");
    }
}
