//! The unified placement engine: one routing verdict shared by the live
//! dispatch fabric, the schedule-time prefetcher, and the simulator.
//!
//! The paper attributes RCOMPSs' 70%+ efficiency at 128 cores to
//! runtime-aware placement — "data-locality-aware strategies" that keep
//! tasks next to their inputs while keeping workers busy (§3.1, §4).
//! Before this layer existed, the runtime had three disconnected
//! approximations of that idea: `ShardedReady` did a private most-bytes
//! scan, the simulator charged its own transfer costs, and the prefetcher
//! could actively fight the router (a replica already moving toward a node
//! counted for nothing). A [`PlacementModel`] is now the single authority:
//!
//! * [`ShardedReady`](super::scheduler::ShardedReady) consults an injected
//!   `Arc<dyn PlacementModel>` on every push — there is no private routing
//!   logic left in the dispatch fabric;
//! * `Shared::enqueue_ready` derives its prefetch targets from the *same*
//!   verdict (and the same locality snapshot) it routed with — one
//!   decision, not two;
//! * the simulator drives the identical model through [`RoutedReady`], so
//!   simulated and live placements provably agree for the same push
//!   sequence and the same signals (see the placement-equivalence
//!   property test; the simulator's in-flight pressure is always zero —
//!   it charges transfers at claim time).
//!
//! # Model inputs
//!
//! A model sees, per decision:
//!
//! * the task's **locality snapshot** — `(bytes, replica nodes)` per input,
//!   read once from the `VersionTable` at enqueue time ([`ReadyTask`]);
//! * **in-flight transfer pressure** — bytes queued or moving toward each
//!   node, from [`PlacementSignals::inflight_toward`] (backed by
//!   `TransferService::inflight_toward` in the live runtime);
//! * **queue depth** — ready tasks already waiting on each node's shard,
//!   from [`PlacementSignals::queue_depth`].
//!
//! # Models
//!
//! | name | verdict |
//! |------|---------|
//! | `bytes` | node holding the most resident input bytes, else round-robin (the historical `ShardedReady::route`) |
//! | `cost` | node minimizing *bytes still to move* (in-flight transfers count as already local) plus a queue-depth load penalty |
//! | `adaptive` | feedback-driven: minimizes estimated *time* — bytes still to move ÷ observed transfer bandwidth plus queue depth × observed task duration; cold-starts as `cost`; once the TCP transport's direct ships have measured real src→dst links it prices each input over the best observed *per-pair* bandwidth from a holding node (see [`feedback`](super::feedback)) |
//! | `roundrobin` | strict rotation, ignoring locality (baseline / ablation) |
//!
//! Selected via `CoordinatorConfig.router` / `--router` (live) and
//! `SimEngine::with_router` (simulator).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::dag::TaskId;
use super::feedback::{AdaptivePlacement, FeedbackStats};
use super::registry::NodeId;
use super::scheduler::{scheduler_by_name, ReadyTask, Scheduler};

/// Stack-allocated score buffer for typical node counts: routing a push
/// must not allocate (the historical implementation built a
/// `vec![0u64; nodes]` per task).
const INLINE_NODES: usize = 16;

/// Dynamic per-node signals a model may consult beyond the task's own
/// locality snapshot. Both callbacks must be cheap (atomic loads): they
/// run on the push hot path, once per node per decision.
pub trait PlacementSignals {
    /// Serialized bytes queued or in flight toward `node` (asynchronous
    /// transfer service). Zero when no transfer plane exists (simulator,
    /// file plane, `--transfer-threads 0`).
    fn inflight_toward(&self, node: NodeId) -> u64;

    /// Ready tasks currently queued on `node`'s shard.
    fn queue_depth(&self, node: NodeId) -> usize;

    /// Is `node` accepting work? Dead nodes (lost mid-run, see
    /// `NodeHealth`) are poisoned out of every model's scan so nothing new
    /// routes toward a machine that cannot execute it. Defaults to `true`:
    /// signal sources that predate node-loss recovery never kill anything.
    fn alive(&self, _node: NodeId) -> bool {
        true
    }
}

/// All-zero signals: locality-snapshot-only placement (unit tests, pure
/// structures).
pub struct NoSignals;

impl PlacementSignals for NoSignals {
    fn inflight_toward(&self, _node: NodeId) -> u64 {
        0
    }

    fn queue_depth(&self, _node: NodeId) -> usize {
        0
    }
}

/// Source of in-flight transfer pressure. Implemented by
/// `TransferService`; tests inject stubs to drive the `cost` model
/// deterministically.
pub trait InflightSource: Send + Sync {
    /// Serialized bytes queued or moving toward `node`.
    fn inflight_toward(&self, node: NodeId) -> u64;
}

/// A placement model: given a ready task and the per-node signals, pick
/// the node (shard) the task should run on. Implementations carry their
/// own round-robin cursors, so the verdict sequence is deterministic for a
/// given push order — the property the live-vs-sim equivalence test pins.
pub trait PlacementModel: Send + Sync {
    /// Model name for configs/CLI (`bytes`, `cost`, `roundrobin`,
    /// `adaptive`).
    fn name(&self) -> &'static str;

    /// The node `task` should land on, in `0..nodes`.
    fn place(&self, task: &ReadyTask, nodes: usize, signals: &dyn PlacementSignals) -> usize;

    /// The model's runtime-observation sink, when it learns from feedback
    /// (`adaptive`). The live runtime's movers and executor — and the
    /// simulator, from its virtual timings — feed it observed transfer
    /// throughput and task durations. Static models return `None`.
    fn feedback(&self) -> Option<Arc<FeedbackStats>> {
        None
    }
}

/// Construct a model by name.
pub fn placement_by_name(name: &str) -> Option<Arc<dyn PlacementModel>> {
    match name {
        "bytes" => Some(Arc::new(BytesPlacement::new())),
        "cost" => Some(Arc::new(CostPlacement::new())),
        "adaptive" => Some(Arc::new(AdaptivePlacement::new())),
        "roundrobin" => Some(Arc::new(RoundRobinPlacement::new())),
        _ => None,
    }
}

/// Run `f` over a zeroed per-node score slice without heap allocation:
/// a stack array up to [`INLINE_NODES`] nodes (the common case), a
/// thread-local scratch vec beyond that — at fleet scale (1,000 nodes) the
/// historical per-push `vec![0u64; nodes]` was an 8 KiB allocation on
/// every routing decision. `place` never nests inside itself, so the
/// borrow of the thread-local is never re-entered.
pub(crate) fn with_scores<R>(nodes: usize, f: impl FnOnce(&mut [u64]) -> R) -> R {
    if nodes <= INLINE_NODES {
        let mut buf = [0u64; INLINE_NODES];
        f(&mut buf[..nodes])
    } else {
        thread_local! {
            static SCRATCH: std::cell::RefCell<Vec<u64>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.clear();
            buf.resize(nodes, 0);
            f(&mut buf)
        })
    }
}

/// Round-robin cursor advance that lands on the next *alive* node: the
/// shared fallback for locality-free placement. With every node alive this
/// degenerates to the historical `fetch_add % nodes`, so verdict sequences
/// (and the tests pinning them) are unchanged until a node actually dies.
/// All-dead clusters fall back to the raw rotation — the push cannot block.
pub(crate) fn rr_next_alive(
    rr: &AtomicUsize,
    nodes: usize,
    signals: &dyn PlacementSignals,
) -> usize {
    let n = nodes.max(1);
    let start = rr.fetch_add(1, Ordering::Relaxed);
    for off in 0..n {
        let i = (start + off) % n;
        if signals.alive(NodeId(i as u32)) {
            return i;
        }
    }
    start % n
}

/// Sum each node's resident input bytes into `scores` (length `nodes`).
pub(crate) fn resident_per_node(task: &ReadyTask, scores: &mut [u64]) {
    for (bytes, locs) in &task.inputs {
        for n in locs {
            if let Some(slot) = scores.get_mut(n.0 as usize) {
                *slot += *bytes;
            }
        }
    }
}

/// The historical `ShardedReady::route` behavior: the node holding the
/// most resident input bytes wins (last index on ties, matching the old
/// `max_by_key` scan); tasks with no resident bytes round-robin.
pub struct BytesPlacement {
    rr: AtomicUsize,
}

impl BytesPlacement {
    pub fn new() -> BytesPlacement {
        BytesPlacement {
            rr: AtomicUsize::new(0),
        }
    }
}

impl Default for BytesPlacement {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementModel for BytesPlacement {
    fn name(&self) -> &'static str {
        "bytes"
    }

    fn place(&self, task: &ReadyTask, nodes: usize, signals: &dyn PlacementSignals) -> usize {
        with_scores(nodes, |scores| {
            resident_per_node(task, scores);
            scores
                .iter()
                .enumerate()
                .filter(|(i, _)| signals.alive(NodeId(*i as u32)))
                .max_by_key(|(_, b)| **b)
                .filter(|(_, b)| **b > 0)
                .map(|(i, _)| i)
                .unwrap_or_else(|| rr_next_alive(&self.rr, nodes, signals))
        })
    }
}

/// Transfer-aware cost model: pick the node with the fewest bytes still
/// to move, counting a replica already queued/moving toward a node as
/// local (so the router rides the prefetcher instead of fighting it), and
/// penalizing deep ready queues so locality never starves a node.
///
/// cost(N) = missing(N) − credit(N) + depth(N) × (total/8 + 1)
///
/// where `missing(N)` is the task's input bytes without a replica on N,
/// `credit(N)` caps the node's in-flight bytes at `missing(N)`, and the
/// per-queued-task penalty scales with the task's own footprint — a node
/// must be ahead by ~an eighth of the inputs per queued task to win. Ties
/// break toward the shallower queue, then the lower index. A task with no
/// inputs costs only the depth term, so locality-free work spreads to the
/// shallowest queue.
///
/// The in-flight gauge is a per-node *aggregate* (cheap atomic, no board
/// lock on the push path), so credit is an optimistic approximation: a
/// transfer of an unrelated version toward N also counts. Two guards keep
/// the approximation safe — credit is capped at `missing(N)`, and it only
/// participates in the cost (never in tie-breaks), so in-flight pressure
/// can at best make a node *tie* a fully-local home, and ties resolve by
/// load and index, never by credit. Unrelated traffic therefore cannot
/// hijack a task whose bytes are already resident somewhere idle.
pub struct CostPlacement;

impl CostPlacement {
    pub fn new() -> CostPlacement {
        CostPlacement
    }
}

impl Default for CostPlacement {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementModel for CostPlacement {
    fn name(&self) -> &'static str {
        "cost"
    }

    fn place(&self, task: &ReadyTask, nodes: usize, signals: &dyn PlacementSignals) -> usize {
        with_scores(nodes, |scores| {
            resident_per_node(task, scores);
            let total = task.total_bytes();
            let penalty_per_task = total / 8 + 1;
            let mut best: Option<(u128, usize, usize)> = None;
            for (i, resident) in scores.iter().enumerate() {
                if !signals.alive(NodeId(i as u32)) {
                    continue;
                }
                let missing = total.saturating_sub(*resident);
                let credit = signals.inflight_toward(NodeId(i as u32)).min(missing);
                let depth = signals.queue_depth(NodeId(i as u32));
                let cost = u128::from(missing - credit)
                    + u128::from(depth as u64) * u128::from(penalty_per_task);
                let key = (cost, depth, i);
                if best.map(|b| key < b).unwrap_or(true) {
                    best = Some(key);
                }
            }
            best.map(|(_, _, i)| i).unwrap_or(0)
        })
    }
}

/// Strict rotation, blind to locality — the load-spreading baseline the
/// scheduler ablations compare against.
pub struct RoundRobinPlacement {
    rr: AtomicUsize,
}

impl RoundRobinPlacement {
    pub fn new() -> RoundRobinPlacement {
        RoundRobinPlacement {
            rr: AtomicUsize::new(0),
        }
    }
}

impl Default for RoundRobinPlacement {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementModel for RoundRobinPlacement {
    fn name(&self) -> &'static str {
        "roundrobin"
    }

    fn place(&self, _task: &ReadyTask, nodes: usize, signals: &dyn PlacementSignals) -> usize {
        rr_next_alive(&self.rr, nodes, signals)
    }
}

/// Single-threaded sibling of
/// [`ShardedReady`](super::scheduler::ShardedReady): one policy instance
/// per node, pushes routed by the injected [`PlacementModel`], pops
/// preferring the worker's own shard and stealing in ring order. The
/// discrete-event simulator drives this, so a simulated run makes exactly
/// the placement decisions the live dispatch fabric would make for the
/// same push sequence — the live-vs-sim equivalence property.
pub struct RoutedReady {
    shards: Vec<Box<dyn Scheduler>>,
    model: Arc<dyn PlacementModel>,
    alive: Vec<bool>,
}

/// Queue-depth view over `RoutedReady`'s shards (no transfer plane in the
/// simulator: transfers are charged at claim time, so nothing is ever "in
/// flight" between events).
struct ShardDepths<'a> {
    shards: &'a [Box<dyn Scheduler>],
    alive: &'a [bool],
}

impl PlacementSignals for ShardDepths<'_> {
    fn inflight_toward(&self, _node: NodeId) -> u64 {
        0
    }

    fn queue_depth(&self, node: NodeId) -> usize {
        self.shards
            .get(node.0 as usize)
            .map(|s| s.queue_len())
            .unwrap_or(0)
    }

    fn alive(&self, node: NodeId) -> bool {
        self.alive.get(node.0 as usize).copied().unwrap_or(false)
    }
}

impl RoutedReady {
    /// One shard per node, each running the named policy, routed by
    /// `model`. `None` for an unknown policy name.
    pub fn new(policy: &str, nodes: u32, model: Arc<dyn PlacementModel>) -> Option<RoutedReady> {
        let shards = (0..nodes.max(1))
            .map(|_| scheduler_by_name(policy))
            .collect::<Option<Vec<_>>>()?;
        let alive = vec![true; shards.len()];
        Some(RoutedReady {
            shards,
            model,
            alive,
        })
    }

    pub fn nodes(&self) -> u32 {
        self.shards.len() as u32
    }

    /// Mark a node dead (false) or rejoined (true) for routing. Dead
    /// shards take no new pushes; tasks already queued there stay stealable
    /// through [`RoutedReady::pop_for`]'s ring scan, mirroring the live
    /// fabric's drain-by-stealing behavior.
    pub fn set_alive(&mut self, node: NodeId, alive: bool) {
        if let Some(slot) = self.alive.get_mut(node.0 as usize) {
            *slot = alive;
        }
    }

    /// Is `node` currently accepting work?
    pub fn is_alive(&self, node: NodeId) -> bool {
        self.alive.get(node.0 as usize).copied().unwrap_or(false)
    }

    /// Route and enqueue a ready task; returns the chosen node index.
    pub fn push(&mut self, task: ReadyTask) -> usize {
        let shard = self.model.place(
            &task,
            self.shards.len(),
            &ShardDepths {
                shards: &self.shards,
                alive: &self.alive,
            },
        );
        self.insert_at(shard, task)
    }

    /// Enqueue on a precomputed shard without a per-task model verdict —
    /// the simulator's half of the window compiler's dispatch path,
    /// mirroring `ShardedReady::push_routed`. The dead-shard belt guard
    /// still applies; returns the shard actually used.
    pub fn push_routed(&mut self, shard: usize, task: ReadyTask) -> usize {
        self.insert_at(shard.min(self.shards.len().saturating_sub(1)), task)
    }

    /// Score a (possibly synthetic, window-aggregate) task against the
    /// model without enqueueing — the whole-window anchor verdict.
    pub fn place_window(&self, task: &ReadyTask) -> usize {
        self.model.place(
            task,
            self.shards.len(),
            &ShardDepths {
                shards: &self.shards,
                alive: &self.alive,
            },
        )
    }

    fn insert_at(&mut self, mut shard: usize, task: ReadyTask) -> usize {
        // Belt guard: a model that ignores the alive signal must still not
        // strand work on a dead shard nothing will ever pop from first.
        if !self.alive.get(shard).copied().unwrap_or(false) {
            if let Some(fallback) = self
                .alive
                .iter()
                .enumerate()
                .filter(|(_, a)| **a)
                .map(|(i, _)| i)
                .min_by_key(|i| self.shards[*i].queue_len())
            {
                shard = fallback;
            }
        }
        self.shards[shard].push(task);
        shard
    }

    /// Pop for a worker on `node`: own shard first, then steal in ring
    /// order. `None` when every shard is empty.
    pub fn pop_for(&mut self, node: NodeId) -> Option<TaskId> {
        let nodes = self.shards.len();
        let home = (node.0 as usize) % nodes;
        for i in 0..nodes {
            let shard = (home + i) % nodes;
            if let Some(id) = self.shards[shard].pop_for(node) {
                return Some(id);
            }
        }
        None
    }

    /// Tasks currently queued (all shards).
    pub fn queue_len(&self) -> usize {
        self.shards.iter().map(|s| s.queue_len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs,
            type_name: "t".into(),
        }
    }

    /// Scriptable signals: fixed inflight/depth vectors.
    struct Stub {
        inflight: Vec<u64>,
        depth: Vec<usize>,
    }

    impl PlacementSignals for Stub {
        fn inflight_toward(&self, node: NodeId) -> u64 {
            self.inflight.get(node.0 as usize).copied().unwrap_or(0)
        }

        fn queue_depth(&self, node: NodeId) -> usize {
            self.depth.get(node.0 as usize).copied().unwrap_or(0)
        }
    }

    #[test]
    fn by_name_resolves_all_models() {
        for n in ["bytes", "cost", "roundrobin", "adaptive"] {
            assert_eq!(placement_by_name(n).unwrap().name(), n);
        }
        assert!(placement_by_name("zzz").is_none());
    }

    #[test]
    fn bytes_picks_most_resident_and_round_robins_without_signal() {
        let m = BytesPlacement::new();
        // Most resident bytes win.
        let t = rt(1, vec![(100, vec![NodeId(0)]), (300, vec![NodeId(2)])]);
        assert_eq!(m.place(&t, 3, &NoSignals), 2);
        // Locality-free tasks rotate.
        let free = rt(2, vec![]);
        assert_eq!(m.place(&free, 3, &NoSignals), 0);
        assert_eq!(m.place(&free, 3, &NoSignals), 1);
        assert_eq!(m.place(&free, 3, &NoSignals), 2);
        assert_eq!(m.place(&free, 3, &NoSignals), 0);
    }

    #[test]
    fn bytes_ignores_out_of_range_replicas() {
        let m = BytesPlacement::new();
        // A replica on a node beyond the cluster (stale location) cannot
        // panic or win.
        let t = rt(1, vec![(100, vec![NodeId(7)]), (10, vec![NodeId(1)])]);
        assert_eq!(m.place(&t, 2, &NoSignals), 1);
    }

    #[test]
    fn roundrobin_rotates_regardless_of_locality() {
        let m = RoundRobinPlacement::new();
        let t = rt(1, vec![(1 << 30, vec![NodeId(1)])]);
        assert_eq!(m.place(&t, 2, &NoSignals), 0);
        assert_eq!(m.place(&t, 2, &NoSignals), 1);
        assert_eq!(m.place(&t, 2, &NoSignals), 0);
    }

    #[test]
    fn cost_prefers_resident_bytes_like_bytes_model() {
        let m = CostPlacement::new();
        let t = rt(1, vec![(100, vec![NodeId(0)]), (300, vec![NodeId(2)])]);
        assert_eq!(m.place(&t, 3, &NoSignals), 2);
    }

    #[test]
    fn cost_counts_inflight_transfers_as_local() {
        // The regression the tentpole demands: a version mid-transfer
        // toward node 1 (prefetched there for an earlier consumer, whose
        // routing also queued work on node 0) routes the next consumer to
        // node 1 under `cost` — and not under `bytes`, which only ever
        // chases the resident replica.
        let t = rt(1, vec![(1000, vec![NodeId(0)])]);
        let signals = Stub {
            inflight: vec![0, 1000],
            depth: vec![1, 0],
        };
        assert_eq!(CostPlacement::new().place(&t, 2, &signals), 1);
        assert_eq!(BytesPlacement::new().place(&t, 2, &signals), 0);
    }

    #[test]
    fn cost_unrelated_inflight_cannot_hijack_a_fully_local_task() {
        // Aggregate in-flight pressure toward node 1 (some other value's
        // transfer) can at best tie a fully-local node 0 — and ties never
        // resolve by credit, so the task stays home instead of forcing a
        // brand-new transfer.
        let t = rt(1, vec![(1000, vec![NodeId(0)])]);
        let signals = Stub {
            inflight: vec![0, 1 << 20],
            depth: vec![0, 0],
        };
        assert_eq!(CostPlacement::new().place(&t, 2, &signals), 0);
    }

    #[test]
    fn cost_load_penalty_overrides_thin_locality() {
        // Node 0 holds 1/8 of the inputs but has a deep queue; node 1 is
        // idle. One queued task costs total/8+1, so depth 2 outweighs the
        // 125-byte locality edge.
        let t = rt(1, vec![(125, vec![NodeId(0)]), (875, vec![])]);
        let signals = Stub {
            inflight: vec![0, 0],
            depth: vec![2, 0],
        };
        assert_eq!(CostPlacement::new().place(&t, 2, &signals), 1);
    }

    #[test]
    fn cost_spreads_locality_free_tasks_to_shallow_queues() {
        let t = rt(1, vec![]);
        let signals = Stub {
            inflight: vec![0, 0, 0],
            depth: vec![3, 1, 2],
        };
        assert_eq!(CostPlacement::new().place(&t, 3, &signals), 1);
    }

    #[test]
    fn cost_partial_inflight_cannot_beat_fully_local() {
        // A transfer covering only part of the missing bytes leaves node 1
        // with a positive cost; the fully-local node 0 wins outright.
        let t = rt(1, vec![(1000, vec![NodeId(0)])]);
        let signals = Stub {
            inflight: vec![0, 400],
            depth: vec![0, 0],
        };
        assert_eq!(CostPlacement::new().place(&t, 2, &signals), 0);
    }

    #[test]
    fn models_handle_more_nodes_than_inline_buffer() {
        let nodes = INLINE_NODES + 8;
        let t = rt(1, vec![(64, vec![NodeId((nodes - 1) as u32)])]);
        assert_eq!(
            BytesPlacement::new().place(&t, nodes, &NoSignals),
            nodes - 1
        );
        assert_eq!(CostPlacement::new().place(&t, nodes, &NoSignals), nodes - 1);
    }

    #[test]
    fn routed_ready_routes_pops_and_steals() {
        let model = placement_by_name("bytes").unwrap();
        let mut q = RoutedReady::new("fifo", 2, model).unwrap();
        assert_eq!(q.push(rt(1, vec![(100, vec![NodeId(1)])])), 1);
        assert_eq!(q.push(rt(2, vec![(100, vec![NodeId(0)])])), 0);
        assert_eq!(q.queue_len(), 2);
        // Own shard first...
        assert_eq!(q.pop_for(NodeId(1)), Some(TaskId(1)));
        // ...then ring-order stealing keeps workers busy.
        assert_eq!(q.pop_for(NodeId(1)), Some(TaskId(2)));
        assert_eq!(q.pop_for(NodeId(1)), None);
        assert!(RoutedReady::new("zzz", 2, placement_by_name("cost").unwrap()).is_none());
    }

    #[test]
    fn routed_ready_push_routed_honors_plan_and_belt_guard() {
        let model = placement_by_name("bytes").unwrap();
        let mut q = RoutedReady::new("fifo", 2, model).unwrap();
        // The compiled plan overrides what the model would pick.
        assert_eq!(q.push_routed(1, rt(1, vec![(100, vec![NodeId(0)])])), 1);
        assert_eq!(q.pop_for(NodeId(1)), Some(TaskId(1)));
        // A dead planned shard falls back to a live one.
        q.set_alive(NodeId(1), false);
        assert_eq!(q.push_routed(1, rt(2, vec![])), 0);
        assert_eq!(q.pop_for(NodeId(0)), Some(TaskId(2)));
        // The anchor verdict consults the model without enqueueing.
        q.set_alive(NodeId(1), true);
        assert_eq!(q.place_window(&rt(3, vec![(100, vec![NodeId(1)])])), 1);
        assert_eq!(q.queue_len(), 0);
    }

    /// Signals with a dead-node mask and no other pressure.
    struct Mask {
        alive: Vec<bool>,
    }

    impl PlacementSignals for Mask {
        fn inflight_toward(&self, _node: NodeId) -> u64 {
            0
        }

        fn queue_depth(&self, _node: NodeId) -> usize {
            0
        }

        fn alive(&self, node: NodeId) -> bool {
            self.alive.get(node.0 as usize).copied().unwrap_or(false)
        }
    }

    #[test]
    fn dead_nodes_are_poisoned_out_of_every_model() {
        // All the resident bytes live on node 1 — but node 1 is dead, so
        // every model must route elsewhere.
        let dead1 = Mask {
            alive: vec![true, false, true],
        };
        let t = rt(1, vec![(1000, vec![NodeId(1)])]);
        assert_ne!(BytesPlacement::new().place(&t, 3, &dead1), 1);
        assert_ne!(CostPlacement::new().place(&t, 3, &dead1), 1);
        // Round-robin rotates over the survivors only.
        let m = RoundRobinPlacement::new();
        assert_eq!(m.place(&t, 3, &dead1), 0);
        assert_eq!(m.place(&t, 3, &dead1), 2);
        assert_eq!(m.place(&t, 3, &dead1), 2);
        assert_eq!(m.place(&t, 3, &dead1), 0);
        // With nobody alive the rotation still terminates.
        let none = Mask {
            alive: vec![false, false],
        };
        let free = rt(2, vec![]);
        let i = BytesPlacement::new().place(&free, 2, &none);
        assert!(i < 2);
    }

    #[test]
    fn routed_ready_reroutes_off_dead_shards_and_back_on_join() {
        let model = placement_by_name("bytes").unwrap();
        let mut q = RoutedReady::new("fifo", 2, model).unwrap();
        q.set_alive(NodeId(1), false);
        assert!(!q.is_alive(NodeId(1)));
        // Locality points at the dead node; the verdict must not.
        assert_eq!(q.push(rt(1, vec![(100, vec![NodeId(1)])])), 0);
        // Rejoin re-opens the shard for placement.
        q.set_alive(NodeId(1), true);
        assert_eq!(q.push(rt(2, vec![(100, vec![NodeId(1)])])), 1);
        assert_eq!(q.pop_for(NodeId(0)), Some(TaskId(1)));
        assert_eq!(q.pop_for(NodeId(0)), Some(TaskId(2)));
    }
}
