//! Pluggable task schedulers.
//!
//! COMPSs ships pluggable scheduling policies — FIFO, LIFO, and
//! data-locality-aware strategies (§3.1). The runtime asks the policy for a
//! task whenever a worker goes idle; the policy sees the ready frontier plus
//! enough metadata (input sizes and locations) to make locality decisions.
//!
//! Policies are pure data structures driven identically by the live
//! executor and the discrete-event simulator. The live executor no longer
//! drives a single policy instance behind the global lock: it instantiates
//! one per node inside [`ShardedReady`], which adds placement routing
//! (via the injected [`PlacementModel`](crate::coordinator::placement::PlacementModel)),
//! work stealing, and lock-free worker parking around the unchanged
//! policies (see `coordinator/mod.rs` § *Data plane & locking*). The
//! simulator drives the same per-node layout single-threaded through
//! [`RoutedReady`](crate::coordinator::placement::RoutedReady).

mod fifo;
mod lifo;
mod locality;
mod sharded;

pub use fifo::FifoScheduler;
pub use lifo::LifoScheduler;
pub use locality::LocalityScheduler;
pub use sharded::ShardedReady;

use std::sync::Arc;

use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;

/// Metadata the policy may use for placement.
#[derive(Clone, Debug)]
pub struct ReadyTask {
    pub id: TaskId,
    /// (bytes, nodes-holding-a-replica) per input.
    pub inputs: Vec<(u64, Vec<NodeId>)>,
    /// Task type, for policies that classify by type. Interned: the spec's
    /// `Arc<str>` is shared, never deep-copied per push/steal.
    pub type_name: Arc<str>,
}

impl ReadyTask {
    /// Bytes of input already resident on `node`.
    pub fn local_bytes(&self, node: NodeId) -> u64 {
        self.inputs
            .iter()
            .filter(|(_, locs)| locs.contains(&node))
            .map(|(b, _)| *b)
            .sum()
    }

    /// Total input bytes.
    pub fn total_bytes(&self) -> u64 {
        self.inputs.iter().map(|(b, _)| *b).sum()
    }
}

/// A scheduling policy over the ready frontier.
pub trait Scheduler: Send {
    /// Offer a task that just became ready.
    fn push(&mut self, task: ReadyTask);

    /// Pick a task for an idle worker on `node`; `None` leaves the worker
    /// parked until the next `push`.
    fn pop_for(&mut self, node: NodeId) -> Option<TaskId>;

    /// Number of queued ready tasks.
    fn queue_len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.queue_len() == 0
    }

    /// Policy name for configs/CLI (`fifo`, `lifo`, `locality`).
    fn name(&self) -> &'static str;
}

/// Construct a policy by name.
pub fn scheduler_by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(FifoScheduler::new())),
        "lifo" => Some(Box::new(LifoScheduler::new())),
        "locality" => Some(Box::new(LocalityScheduler::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: u64) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs: vec![],
            type_name: "t".into(),
        }
    }

    #[test]
    fn by_name_resolves() {
        for n in ["fifo", "lifo", "locality"] {
            assert_eq!(scheduler_by_name(n).unwrap().name(), n);
        }
        assert!(scheduler_by_name("zzz").is_none());
    }

    #[test]
    fn ready_task_locality_math() {
        let t = ReadyTask {
            id: TaskId(1),
            inputs: vec![
                (100, vec![NodeId(0)]),
                (50, vec![NodeId(0), NodeId(1)]),
                (25, vec![NodeId(2)]),
            ],
            type_name: "x".into(),
        };
        assert_eq!(t.local_bytes(NodeId(0)), 150);
        assert_eq!(t.local_bytes(NodeId(1)), 50);
        assert_eq!(t.local_bytes(NodeId(3)), 0);
        assert_eq!(t.total_bytes(), 175);
    }

    #[test]
    fn empty_schedulers_return_none() {
        for name in ["fifo", "lifo", "locality"] {
            let mut s = scheduler_by_name(name).unwrap();
            assert!(s.pop_for(NodeId(0)).is_none());
            s.push(rt(1));
            assert_eq!(s.queue_len(), 1);
            assert!(s.pop_for(NodeId(0)).is_some());
            assert!(s.is_empty());
        }
    }
}
