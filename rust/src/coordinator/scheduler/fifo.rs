//! FIFO policy: tasks run in the order they became ready. This is COMPSs'
//! default and the policy used for the paper's experiments; submission
//! order tends to match data-generation order, which keeps fragment
//! pipelines flowing front-to-back (visible in the Figure 10 traces).

use std::collections::VecDeque;

use super::{ReadyTask, Scheduler};
use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;

#[derive(Default)]
pub struct FifoScheduler {
    queue: VecDeque<ReadyTask>,
}

impl FifoScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for FifoScheduler {
    fn push(&mut self, task: ReadyTask) {
        self.queue.push_back(task);
    }

    fn pop_for(&mut self, _node: NodeId) -> Option<TaskId> {
        self.queue.pop_front().map(|t| t.id)
    }

    fn queue_len(&self) -> usize {
        self.queue.len()
    }

    fn name(&self) -> &'static str {
        "fifo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: u64) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs: vec![],
            type_name: "t".into(),
        }
    }

    #[test]
    fn pops_in_push_order() {
        let mut s = FifoScheduler::new();
        for i in 1..=5 {
            s.push(rt(i));
        }
        let order: Vec<u64> = (0..5).map(|_| s.pop_for(NodeId(0)).unwrap().0).collect();
        assert_eq!(order, vec![1, 2, 3, 4, 5]);
    }
}
