//! LIFO policy: newest-ready-first. Depth-first execution of the DAG keeps
//! the working set hot (a fragment's consumer runs right after its
//! producer) at the cost of worse breadth fairness; COMPSs exposes it as an
//! alternative pluggable policy (§3.1), and the ablation bench compares it
//! against FIFO and locality on the three apps.

use super::{ReadyTask, Scheduler};
use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;

#[derive(Default)]
pub struct LifoScheduler {
    stack: Vec<ReadyTask>,
}

impl LifoScheduler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Scheduler for LifoScheduler {
    fn push(&mut self, task: ReadyTask) {
        self.stack.push(task);
    }

    fn pop_for(&mut self, _node: NodeId) -> Option<TaskId> {
        self.stack.pop().map(|t| t.id)
    }

    fn queue_len(&self) -> usize {
        self.stack.len()
    }

    fn name(&self) -> &'static str {
        "lifo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: u64) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs: vec![],
            type_name: "t".into(),
        }
    }

    #[test]
    fn pops_newest_first() {
        let mut s = LifoScheduler::new();
        for i in 1..=3 {
            s.push(rt(i));
        }
        assert_eq!(s.pop_for(NodeId(0)).unwrap().0, 3);
        s.push(rt(9));
        assert_eq!(s.pop_for(NodeId(0)).unwrap().0, 9);
        assert_eq!(s.pop_for(NodeId(0)).unwrap().0, 2);
    }
}
