//! Data-locality policy: prefer the ready task with the most input bytes
//! already resident on the requesting node, falling back to FIFO order.
//! This models COMPSs' "data-locality-aware strategies" (§3.1) and is what
//! keeps merge trees node-local in the multi-node runs — the Figure 8/9
//! sweeps run under it.
//!
//! Implementation note (EXPERIMENTS.md §Perf): the first version scanned
//! the whole ready frontier per `pop_for` (O(n), which collapsed to
//! ~0.04 M ops/s at 100k queued tasks). Tasks are now *bucketed by their
//! best node at push time*: `pop_for(node)` takes the oldest task whose
//! dominant input locality is that node in O(1), falling back to the
//! global FIFO of locality-free tasks, then to work stealing from other
//! nodes' buckets. The placement decisions match the scan version whenever
//! a task has a single dominant node — the common case for fragment
//! pipelines — at >100x the throughput.

use super::{ReadyTask, Scheduler};
use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;
use std::collections::{BTreeMap, HashMap, VecDeque};

#[derive(Default)]
pub struct LocalityScheduler {
    /// Tasks whose inputs are dominantly resident on one node. Ordered map
    /// so victim selection on steals is deterministic across instances —
    /// the live fabric and the simulator's router must make identical
    /// decisions for identical content (placement-equivalence property).
    buckets: BTreeMap<NodeId, VecDeque<ReadyTask>>,
    /// Tasks with no locality signal (literals only, empty inputs).
    anywhere: VecDeque<ReadyTask>,
    len: usize,
}

impl LocalityScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// The node holding the most input bytes, if any bytes are localized.
    fn best_node(task: &ReadyTask) -> Option<NodeId> {
        let mut per_node: HashMap<NodeId, u64> = HashMap::new();
        for (bytes, locs) in &task.inputs {
            for n in locs {
                *per_node.entry(*n).or_insert(0) += *bytes;
            }
        }
        per_node
            .into_iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0 .0.cmp(&a.0 .0)))
            .filter(|(_, bytes)| *bytes > 0)
            .map(|(n, _)| n)
    }
}

impl Scheduler for LocalityScheduler {
    fn push(&mut self, task: ReadyTask) {
        self.len += 1;
        match Self::best_node(&task) {
            Some(node) => self.buckets.entry(node).or_default().push_back(task),
            None => self.anywhere.push_back(task),
        }
    }

    fn pop_for(&mut self, node: NodeId) -> Option<TaskId> {
        // 1. Own bucket (locality hit).
        if let Some(b) = self.buckets.get_mut(&node) {
            if let Some(t) = b.pop_front() {
                self.len -= 1;
                return Some(t.id);
            }
        }
        // 2. Locality-free pool, FIFO.
        if let Some(t) = self.anywhere.pop_front() {
            self.len -= 1;
            return Some(t.id);
        }
        // 3. Steal the oldest task from the fullest other bucket (keeps
        // workers busy over strict locality, as COMPSs does).
        let victim = self
            .buckets
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .max_by_key(|(_, q)| q.len())
            .map(|(n, _)| *n)?;
        let t = self.buckets.get_mut(&victim)?.pop_front()?;
        self.len -= 1;
        Some(t.id)
    }

    fn queue_len(&self) -> usize {
        self.len
    }

    fn name(&self) -> &'static str {
        "locality"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs,
            type_name: "t".into(),
        }
    }

    #[test]
    fn prefers_node_local_inputs() {
        let mut s = LocalityScheduler::new();
        s.push(rt(1, vec![(100, vec![NodeId(1)])]));
        s.push(rt(2, vec![(100, vec![NodeId(0)])]));
        // Node 0 should get task 2 despite FIFO order.
        assert_eq!(s.pop_for(NodeId(0)).unwrap().0, 2);
        // Node 1 gets its local task.
        assert_eq!(s.pop_for(NodeId(1)).unwrap().0, 1);
        assert!(s.is_empty());
    }

    #[test]
    fn steals_when_starved() {
        let mut s = LocalityScheduler::new();
        s.push(rt(1, vec![(10, vec![NodeId(5)])]));
        s.push(rt(2, vec![(10, vec![NodeId(6)])]));
        // Node 0 has no local work but must not idle.
        assert!(s.pop_for(NodeId(0)).is_some());
        assert!(s.pop_for(NodeId(0)).is_some());
        assert!(s.pop_for(NodeId(0)).is_none());
    }

    #[test]
    fn weighs_bytes_not_counts() {
        let mut s = LocalityScheduler::new();
        // Task dominated by node 9's big input despite node 0 replicas.
        s.push(rt(1, vec![(10, vec![NodeId(0)]), (1000, vec![NodeId(9)])]));
        // Task fully on node 0.
        s.push(rt(2, vec![(50, vec![NodeId(0)])]));
        assert_eq!(s.pop_for(NodeId(0)).unwrap().0, 2);
    }

    #[test]
    fn locality_free_tasks_go_anywhere_fifo() {
        let mut s = LocalityScheduler::new();
        s.push(rt(1, vec![]));
        s.push(rt(2, vec![]));
        assert_eq!(s.pop_for(NodeId(3)).unwrap().0, 1);
        assert_eq!(s.pop_for(NodeId(7)).unwrap().0, 2);
    }

    #[test]
    fn high_volume_pop_is_fast() {
        // 100k tasks: the old O(n^2) scan took ~minutes; this must finish
        // instantly.
        let mut s = LocalityScheduler::new();
        for i in 0..100_000u64 {
            s.push(rt(i, vec![(64, vec![NodeId((i % 4) as u32)])]));
        }
        let t0 = std::time::Instant::now();
        let mut popped = 0;
        while s.pop_for(NodeId(0)).is_some() {
            popped += 1;
        }
        assert_eq!(popped, 100_000);
        assert!(
            t0.elapsed().as_secs_f64() < 1.0,
            "pop loop too slow: {:?}",
            t0.elapsed()
        );
    }
}
