//! Sharded ready queues with work stealing — the live executor's dispatch
//! fabric.
//!
//! The seed runtime kept one scheduler instance behind the global
//! coordinator lock; every idle worker contended on that lock to pop a
//! task, which is exactly the per-task dispatch overhead the paper says
//! must stay small for 70%+ efficiency at 128 cores (§4). [`ShardedReady`]
//! breaks the claim loop apart:
//!
//! * one policy instance ([`Scheduler`]) per emulated node, each behind its
//!   own mutex — a worker's common-case pop touches only its node's shard;
//! * pushes are routed by the injected
//!   [`PlacementModel`](crate::coordinator::placement::PlacementModel) —
//!   the same engine the prefetcher and the simulator consult, so the
//!   fabric holds no private routing logic — while the configured policy
//!   keeps making its locality/order decisions *within* a shard;
//! * a worker that finds its shard empty steals from the other shards in
//!   ring order before parking — stealing trades strict policy order for
//!   utilization, exactly as COMPSs does;
//! * parking uses a separate mutex+condvar pair with a global ready count,
//!   so sleeping and waking never touch the coordinator control lock.
//!
//! The wakeup protocol is the standard no-lost-wakeup dance: a pusher
//! increments the ready count *before* taking the park lock to notify; a
//! parking worker re-checks the count *after* taking the park lock. Either
//! the worker sees the count and retries, or it is provably waiting when
//! the notification fires.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::{scheduler_by_name, ReadyTask, Scheduler};
use crate::coordinator::dag::TaskId;
use crate::coordinator::fault::NodeHealth;
use crate::coordinator::placement::{InflightSource, PlacementModel, PlacementSignals};
use crate::coordinator::registry::NodeId;
use crate::coordinator::schedfuzz::{yield_point, FuzzController, FuzzSite};

pub struct ShardedReady {
    shards: Vec<Mutex<Box<dyn Scheduler>>>,
    /// Ready tasks per shard — the placement model's load signal. Kept
    /// beside (not inside) the shard mutexes so routing reads them without
    /// taking any lock.
    depths: Vec<AtomicUsize>,
    /// Total tasks currently queued across all shards.
    queued: AtomicU64,
    /// The routing authority (shared with `enqueue_ready`'s prefetcher and
    /// the simulator's `RoutedReady`).
    model: Arc<dyn PlacementModel>,
    /// In-flight transfer pressure for the `cost` model; `None` means no
    /// transfer plane (file plane, movers disabled, unit tests).
    inflight: Option<Arc<dyn InflightSource>>,
    /// Node liveness plane; `None` (unit tests, simulator-owned fabrics)
    /// reads as everyone-alive and keeps the historical behavior bit for
    /// bit.
    health: Option<Arc<NodeHealth>>,
    /// Workers registered as parked (or about to park). Lets the push hot
    /// path skip the park lock entirely while everyone is busy.
    sleepers: AtomicUsize,
    park: Mutex<()>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Schedule-fuzz controller; `None` (production) makes every yield
    /// point a single no-op branch.
    fuzz: Option<Arc<FuzzController>>,
}

/// Lock-free signals view handed to the model on each push.
struct LiveSignals<'a> {
    depths: &'a [AtomicUsize],
    inflight: Option<&'a dyn InflightSource>,
    health: Option<&'a NodeHealth>,
}

impl PlacementSignals for LiveSignals<'_> {
    fn inflight_toward(&self, node: NodeId) -> u64 {
        self.inflight.map(|s| s.inflight_toward(node)).unwrap_or(0)
    }

    fn queue_depth(&self, node: NodeId) -> usize {
        self.depths
            .get(node.0 as usize)
            .map(|d| d.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    fn alive(&self, node: NodeId) -> bool {
        self.health.map(|h| h.is_alive(node)).unwrap_or(true)
    }
}

impl ShardedReady {
    /// One shard per node, each running the named policy, routed by
    /// `model`. `inflight` feeds the model's transfer-pressure signal
    /// (pass the runtime's `TransferService`; `None` reads as zero).
    pub fn new(
        policy: &str,
        nodes: u32,
        model: Arc<dyn PlacementModel>,
        inflight: Option<Arc<dyn InflightSource>>,
    ) -> Option<ShardedReady> {
        let n = nodes.max(1);
        let shards = (0..n)
            .map(|_| scheduler_by_name(policy).map(Mutex::new))
            .collect::<Option<Vec<_>>>()?;
        Some(ShardedReady {
            shards,
            depths: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            queued: AtomicU64::new(0),
            model,
            inflight,
            health: None,
            sleepers: AtomicUsize::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            fuzz: None,
        })
    }

    /// Arm the schedule-fuzz yield points (`None` keeps them no-op).
    pub fn with_fuzz(mut self, fuzz: Option<Arc<FuzzController>>) -> ShardedReady {
        self.fuzz = fuzz;
        self
    }

    /// Attach the node-liveness plane: dead nodes stop receiving routing
    /// verdicts and their workers park instead of spinning on shards they
    /// can never drain.
    pub fn with_health(mut self, health: Arc<NodeHealth>) -> ShardedReady {
        self.health = Some(health);
        self
    }

    pub fn nodes(&self) -> u32 {
        self.shards.len() as u32
    }

    /// Is `node` accepting work? Health-less fabrics treat everyone as
    /// alive.
    fn node_alive(&self, node: NodeId) -> bool {
        self.health
            .as_ref()
            .map(|h| h.is_alive(node))
            .unwrap_or(true)
    }

    /// Enqueue a ready task and wake one parked worker. Returns the shard
    /// (node) index the placement model routed the task to, so the caller
    /// can prefetch the task's remote inputs toward that node at schedule
    /// time — one verdict drives both decisions.
    pub fn push(&self, task: ReadyTask) -> usize {
        let shard = self.place(&task);
        self.insert_at(shard, task)
    }

    /// Enqueue a task on a precomputed shard, skipping the per-task
    /// placement verdict — the window compiler's dispatch path: one
    /// whole-window [`ShardedReady::place_window`] verdict covers many
    /// `push_routed` calls. The dead-node belt guard, the fuzz yield
    /// point, and the wakeup protocol are identical to
    /// [`ShardedReady::push`]; returns the shard actually used (the guard
    /// may redirect).
    pub fn push_routed(&self, shard: usize, task: ReadyTask) -> usize {
        self.insert_at(shard.min(self.shards.len().saturating_sub(1)), task)
    }

    /// Score a (possibly synthetic, window-aggregate) task against the
    /// placement model without enqueueing anything — the whole-window
    /// anchor verdict.
    pub fn place_window(&self, task: &ReadyTask) -> usize {
        self.place(task)
    }

    fn place(&self, task: &ReadyTask) -> usize {
        self.model.place(
            task,
            self.shards.len(),
            &LiveSignals {
                depths: &self.depths,
                inflight: self.inflight.as_deref(),
                health: self.health.as_deref(),
            },
        )
    }

    fn insert_at(&self, mut shard: usize, task: ReadyTask) -> usize {
        // Belt guard: every model filters dead nodes, but a custom model
        // (or a kill racing the verdict) must still not strand work on a
        // shard whose own worker will never pop again. Stealing would
        // eventually drain it, yet re-routing to the shallowest live shard
        // is strictly better.
        if !self.node_alive(NodeId(shard as u32)) {
            if let Some(best) = (0..self.shards.len())
                .filter(|i| self.node_alive(NodeId(*i as u32)))
                .min_by_key(|i| self.depths[*i].load(Ordering::Relaxed))
            {
                shard = best;
            }
        }
        // Hazard window: the routing verdict is out but the task is not yet
        // visible in any shard — a racing kill/steal sees stale depths.
        yield_point(&self.fuzz, FuzzSite::ReadyPush);
        {
            // Increment while holding the shard lock so a concurrent pop of
            // this very task (its matching decrement also runs under the
            // shard lock) can never observe the counter before the
            // increment and underflow it.
            let mut s = self.shards[shard].lock().unwrap();
            s.push(task);
            self.depths[shard].fetch_add(1, Ordering::Relaxed);
            self.queued.fetch_add(1, Ordering::SeqCst);
        }
        // Counted before reading `sleepers`: see the module-level wakeup
        // protocol (the parking side registers before re-reading `queued`,
        // so at least one of the two sides observes the other).
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _guard = self.park.lock().unwrap();
            // With dead nodes in the cluster a `notify_one` could land on a
            // dead node's worker, which re-parks without claiming anything —
            // a lost wakeup. Wake everyone; live workers race for the task
            // and the dead ones go straight back to sleep.
            if self.health.as_ref().map(|h| h.any_dead()).unwrap_or(false) {
                self.cv.notify_all();
            } else {
                self.cv.notify_one();
            }
        }
        shard
    }

    /// Wake every parked worker so it re-evaluates liveness and the queues
    /// — called after a node kill (its workers must park) or a join (its
    /// workers must resume).
    pub fn wake_all(&self) {
        let _guard = self.park.lock().unwrap();
        self.cv.notify_all();
    }

    /// Pop a task for a worker on `node`: own shard, then steal in ring
    /// order, then park. Returns `None` only at shutdown.
    pub fn pop(&self, node: NodeId) -> Option<TaskId> {
        let nodes = self.shards.len();
        let home = (node.0 as usize) % nodes;
        loop {
            // A worker on a dead node must not claim (or steal) anything:
            // park until the node rejoins or the runtime stops. It skips
            // the `queued > 0` re-check below on purpose — queued work it
            // can never pop would turn that re-check into a busy spin.
            if !self.node_alive(node) {
                if self.shutdown.load(Ordering::SeqCst) {
                    return None;
                }
                let guard = self.park.lock().unwrap();
                self.sleepers.fetch_add(1, Ordering::SeqCst);
                if self.shutdown.load(Ordering::SeqCst) || self.node_alive(node) {
                    self.sleepers.fetch_sub(1, Ordering::SeqCst);
                    continue;
                }
                let _unused = self.cv.wait(guard).unwrap();
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            // Scan own shard first, then the others (work stealing).
            // Hazard window: another worker's pop (or a push) can land
            // between the scan passes, so a perturbation here explores
            // steal-order races.
            yield_point(&self.fuzz, FuzzSite::ReadySteal);
            for i in 0..nodes {
                let shard = (home + i) % nodes;
                let mut s = self.shards[shard].lock().unwrap();
                if let Some(id) = s.pop_for(node) {
                    // Decrement under the same shard lock as the push's
                    // increment: the counters can never underflow.
                    self.depths[shard].fetch_sub(1, Ordering::Relaxed);
                    self.queued.fetch_sub(1, Ordering::SeqCst);
                    return Some(id);
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            // Park until a push or shutdown. Register as a sleeper first,
            // then re-check the count under the park lock, so a concurrent
            // push either sees the registration or is seen by the re-check.
            // Hazard window: a push can slip between the empty scan above
            // and the sleeper registration below — the no-lost-wakeup dance
            // must absorb it.
            yield_point(&self.fuzz, FuzzSite::ReadyPark);
            let guard = self.park.lock().unwrap();
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            if self.queued.load(Ordering::SeqCst) > 0 || self.shutdown.load(Ordering::SeqCst) {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                if self.shutdown.load(Ordering::SeqCst) && self.queued.load(Ordering::SeqCst) == 0
                {
                    return None;
                }
                continue;
            }
            let _unused = self.cv.wait(guard).unwrap();
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Wake everyone and make subsequent `pop`s return `None` once the
    /// queues drain.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.park.lock().unwrap();
        self.cv.notify_all();
    }

    /// Tasks currently queued (all shards).
    pub fn queue_len(&self) -> usize {
        self.queued.load(Ordering::SeqCst) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::placement::placement_by_name;

    fn fabric(policy: &str, nodes: u32, model: &str) -> ShardedReady {
        ShardedReady::new(policy, nodes, placement_by_name(model).unwrap(), None).unwrap()
    }

    fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs,
            type_name: "t".into(),
        }
    }

    #[test]
    fn routes_by_locality_and_round_robin() {
        let q = fabric("fifo", 2, "bytes");
        // Task with bytes on node 1 lands on shard 1 (push reports the
        // routed shard for schedule-time prefetching).
        assert_eq!(q.push(rt(1, vec![(100, vec![NodeId(1)])])), 1);
        // Node-1 worker gets it from its own shard.
        assert_eq!(q.pop(NodeId(1)), Some(TaskId(1)));
        // Locality-free tasks round-robin across both shards but any
        // worker can drain them all (stealing).
        for i in 2..=5 {
            q.push(rt(i, vec![]));
        }
        let mut got: Vec<u64> = (0..4).map(|_| q.pop(NodeId(0)).unwrap().0).collect();
        got.sort_unstable();
        assert_eq!(got, vec![2, 3, 4, 5]);
        assert_eq!(q.queue_len(), 0);
    }

    #[test]
    fn single_node_fifo_preserves_seed_order() {
        let q = fabric("fifo", 1, "bytes");
        for i in 1..=6 {
            q.push(rt(i, vec![]));
        }
        let order: Vec<u64> = (0..6).map(|_| q.pop(NodeId(0)).unwrap().0).collect();
        assert_eq!(order, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn stealing_keeps_workers_busy() {
        let q = fabric("locality", 4, "bytes");
        q.push(rt(1, vec![(10, vec![NodeId(3)])]));
        q.push(rt(2, vec![(10, vec![NodeId(2)])]));
        // A node-0 worker has no local work but must not park.
        assert!(q.pop(NodeId(0)).is_some());
        assert!(q.pop(NodeId(0)).is_some());
    }

    #[test]
    fn cost_model_follows_inflight_transfers() {
        // Regression for transfer-aware routing: a version mid-transfer
        // toward node 1 routes its consumer to shard 1 under `cost` (the
        // in-flight bytes erase node 1's transfer cost while shard 0
        // already has queued work), while `bytes` keeps chasing the
        // resident replica on node 0 regardless of either signal.
        struct Toward1;
        impl InflightSource for Toward1 {
            fn inflight_toward(&self, node: NodeId) -> u64 {
                if node == NodeId(1) {
                    1000
                } else {
                    0
                }
            }
        }
        let consumer = || rt(2, vec![(1000, vec![NodeId(0)])]);
        let cost = ShardedReady::new(
            "fifo",
            2,
            placement_by_name("cost").unwrap(),
            Some(Arc::new(Toward1)),
        )
        .unwrap();
        // Earlier routing left a task queued on shard 0 (no locality, no
        // pressure toward node 0: the cost model parks it there first).
        assert_eq!(cost.push(rt(1, vec![(8, vec![NodeId(0)])])), 0);
        assert_eq!(cost.push(consumer()), 1);
        let bytes = ShardedReady::new(
            "fifo",
            2,
            placement_by_name("bytes").unwrap(),
            Some(Arc::new(Toward1)),
        )
        .unwrap();
        assert_eq!(bytes.push(rt(1, vec![(8, vec![NodeId(0)])])), 0);
        assert_eq!(bytes.push(consumer()), 0);
    }

    #[test]
    fn cost_model_balances_by_shard_depth() {
        let q = fabric("fifo", 2, "cost");
        // Locality-free pushes spread to the shallowest shard.
        assert_eq!(q.push(rt(1, vec![])), 0);
        assert_eq!(q.push(rt(2, vec![])), 1);
        assert_eq!(q.push(rt(3, vec![])), 0);
        assert_eq!(q.push(rt(4, vec![])), 1);
    }

    #[test]
    fn stop_releases_parked_workers() {
        let q = Arc::new(fabric("fifo", 1, "bytes"));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || q.pop(NodeId(0))));
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.stop();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn concurrent_producers_and_consumers_drain_exactly() {
        let q = Arc::new(fabric("lifo", 3, "bytes"));
        let total = 3 * 500u64;
        let mut producers = Vec::new();
        for p in 0..3u64 {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    q.push(rt(p * 500 + i + 1, vec![]));
                }
            }));
        }
        let popped = Arc::new(AtomicU64::new(0));
        let mut consumers = Vec::new();
        for c in 0..4u32 {
            let q = Arc::clone(&q);
            let popped = Arc::clone(&popped);
            consumers.push(std::thread::spawn(move || {
                while q.pop(NodeId(c % 3)).is_some() {
                    popped.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        // Spin until drained, then stop to release the consumers.
        while q.queue_len() > 0 {
            std::thread::yield_now();
        }
        q.stop();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(popped.load(Ordering::SeqCst), total);
    }

    #[test]
    fn push_routed_skips_the_model_but_keeps_the_belt_guard() {
        // A compiled-window push lands on the precomputed shard even when
        // the model would have chosen otherwise (locality points at 0).
        let q = fabric("fifo", 2, "bytes");
        assert_eq!(q.push_routed(1, rt(1, vec![(100, vec![NodeId(0)])])), 1);
        assert_eq!(q.pop(NodeId(1)), Some(TaskId(1)));
        // Dead precomputed shard: the belt guard redirects to a live one.
        let health = Arc::new(NodeHealth::new(2));
        let q = fabric("fifo", 2, "bytes").with_health(Arc::clone(&health));
        health.mark_dead(NodeId(1));
        assert_eq!(q.push_routed(1, rt(2, vec![])), 0);
        assert_eq!(q.pop(NodeId(0)), Some(TaskId(2)));
        // The window-anchor verdict consults the model without enqueueing.
        let q = fabric("fifo", 2, "bytes");
        assert_eq!(q.place_window(&rt(3, vec![(100, vec![NodeId(1)])])), 1);
        assert_eq!(q.queue_len(), 0);
    }

    #[test]
    fn unknown_policy_is_rejected() {
        assert!(
            ShardedReady::new("zzz", 2, placement_by_name("bytes").unwrap(), None).is_none()
        );
    }

    #[test]
    fn dead_node_takes_no_pushes_and_its_queue_is_stealable() {
        let health = Arc::new(NodeHealth::new(2));
        let q = fabric("fifo", 2, "bytes").with_health(Arc::clone(&health));
        // Seed a task onto shard 1 while it is alive, then kill the node.
        assert_eq!(q.push(rt(1, vec![(100, vec![NodeId(1)])])), 1);
        health.mark_dead(NodeId(1));
        // Locality still points at node 1; routing must not.
        assert_eq!(q.push(rt(2, vec![(100, vec![NodeId(1)])])), 0);
        // The survivor drains both its own shard and the dead one's.
        let mut got: Vec<u64> = (0..2).map(|_| q.pop(NodeId(0)).unwrap().0).collect();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn dead_workers_park_and_stop_releases_them() {
        let health = Arc::new(NodeHealth::new(2));
        health.mark_dead(NodeId(1));
        let q = Arc::new(fabric("fifo", 2, "bytes").with_health(Arc::clone(&health)));
        // Queued work a dead worker could historically have stolen: it must
        // park instead of claiming (or spinning on) it.
        q.push(rt(1, vec![]));
        let dead = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop(NodeId(1)))
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!dead.is_finished(), "dead worker must park, not claim");
        // A live worker still gets the task; the dead one only returns at
        // shutdown, and with `None`.
        assert_eq!(q.pop(NodeId(0)), Some(TaskId(1)));
        q.stop();
        assert_eq!(dead.join().unwrap(), None);
    }

    #[test]
    fn rejoined_worker_resumes_popping() {
        let health = Arc::new(NodeHealth::new(2));
        health.mark_dead(NodeId(1));
        let q = Arc::new(fabric("fifo", 2, "bytes").with_health(Arc::clone(&health)));
        let worker = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop(NodeId(1)))
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        health.mark_alive(NodeId(1));
        q.wake_all();
        q.push(rt(7, vec![]));
        assert_eq!(worker.join().unwrap(), Some(TaskId(7)));
        q.stop();
    }
}
