//! Sharded ready queues with work stealing — the live executor's dispatch
//! fabric.
//!
//! The seed runtime kept one scheduler instance behind the global
//! coordinator lock; every idle worker contended on that lock to pop a
//! task, which is exactly the per-task dispatch overhead the paper says
//! must stay small for 70%+ efficiency at 128 cores (§4). [`ShardedReady`]
//! breaks the claim loop apart:
//!
//! * one policy instance ([`Scheduler`]) per emulated node, each behind its
//!   own mutex — a worker's common-case pop touches only its node's shard;
//! * pushes are routed to the node holding the most input bytes (falling
//!   back to round-robin), so the configured policy keeps making its
//!   locality/order decisions *within* a shard;
//! * a worker that finds its shard empty steals from the other shards in
//!   ring order before parking — stealing trades strict policy order for
//!   utilization, exactly as COMPSs does;
//! * parking uses a separate mutex+condvar pair with a global ready count,
//!   so sleeping and waking never touch the coordinator control lock.
//!
//! The wakeup protocol is the standard no-lost-wakeup dance: a pusher
//! increments the ready count *before* taking the park lock to notify; a
//! parking worker re-checks the count *after* taking the park lock. Either
//! the worker sees the count and retries, or it is provably waiting when
//! the notification fires.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::{scheduler_by_name, ReadyTask, Scheduler};
use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::NodeId;

pub struct ShardedReady {
    shards: Vec<Mutex<Box<dyn Scheduler>>>,
    /// Total tasks currently queued across all shards.
    queued: AtomicU64,
    /// Round-robin cursor for tasks with no locality signal.
    rr: AtomicUsize,
    /// Workers registered as parked (or about to park). Lets the push hot
    /// path skip the park lock entirely while everyone is busy.
    sleepers: AtomicUsize,
    park: Mutex<()>,
    cv: Condvar,
    shutdown: AtomicBool,
}

impl ShardedReady {
    /// One shard per node, each running the named policy.
    pub fn new(policy: &str, nodes: u32) -> Option<ShardedReady> {
        let shards = (0..nodes.max(1))
            .map(|_| scheduler_by_name(policy).map(Mutex::new))
            .collect::<Option<Vec<_>>>()?;
        Some(ShardedReady {
            shards,
            queued: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        })
    }

    pub fn nodes(&self) -> u32 {
        self.shards.len() as u32
    }

    /// The shard a task should land on: the node holding the most input
    /// bytes, else round-robin.
    fn route(&self, task: &ReadyTask) -> usize {
        let nodes = self.shards.len();
        let mut per_node = vec![0u64; nodes];
        for (bytes, locs) in &task.inputs {
            for n in locs {
                if (n.0 as usize) < nodes {
                    per_node[n.0 as usize] += *bytes;
                }
            }
        }
        let best = per_node
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| **b)
            .filter(|(_, b)| **b > 0)
            .map(|(i, _)| i);
        best.unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % nodes)
    }

    /// Enqueue a ready task and wake one parked worker. Returns the shard
    /// (node) index the task was routed to, so the caller can prefetch the
    /// task's remote inputs toward that node at schedule time.
    pub fn push(&self, task: ReadyTask) -> usize {
        let shard = self.route(&task);
        {
            // Increment while holding the shard lock so a concurrent pop of
            // this very task (its matching decrement also runs under the
            // shard lock) can never observe the counter before the
            // increment and underflow it.
            let mut s = self.shards[shard].lock().unwrap();
            s.push(task);
            self.queued.fetch_add(1, Ordering::SeqCst);
        }
        // Counted before reading `sleepers`: see the module-level wakeup
        // protocol (the parking side registers before re-reading `queued`,
        // so at least one of the two sides observes the other).
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            let _guard = self.park.lock().unwrap();
            self.cv.notify_one();
        }
        shard
    }

    /// Pop a task for a worker on `node`: own shard, then steal in ring
    /// order, then park. Returns `None` only at shutdown.
    pub fn pop(&self, node: NodeId) -> Option<TaskId> {
        let nodes = self.shards.len();
        let home = (node.0 as usize) % nodes;
        loop {
            // Scan own shard first, then the others (work stealing).
            for i in 0..nodes {
                let shard = (home + i) % nodes;
                let mut s = self.shards[shard].lock().unwrap();
                if let Some(id) = s.pop_for(node) {
                    // Decrement under the same shard lock as the push's
                    // increment: the counter can never underflow.
                    self.queued.fetch_sub(1, Ordering::SeqCst);
                    return Some(id);
                }
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            // Park until a push or shutdown. Register as a sleeper first,
            // then re-check the count under the park lock, so a concurrent
            // push either sees the registration or is seen by the re-check.
            let guard = self.park.lock().unwrap();
            self.sleepers.fetch_add(1, Ordering::SeqCst);
            if self.queued.load(Ordering::SeqCst) > 0 || self.shutdown.load(Ordering::SeqCst) {
                self.sleepers.fetch_sub(1, Ordering::SeqCst);
                if self.shutdown.load(Ordering::SeqCst) && self.queued.load(Ordering::SeqCst) == 0
                {
                    return None;
                }
                continue;
            }
            let _unused = self.cv.wait(guard).unwrap();
            self.sleepers.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Wake everyone and make subsequent `pop`s return `None` once the
    /// queues drain.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _guard = self.park.lock().unwrap();
        self.cv.notify_all();
    }

    /// Tasks currently queued (all shards).
    pub fn queue_len(&self) -> usize {
        self.queued.load(Ordering::SeqCst) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn rt(id: u64, inputs: Vec<(u64, Vec<NodeId>)>) -> ReadyTask {
        ReadyTask {
            id: TaskId(id),
            inputs,
            type_name: "t".into(),
        }
    }

    #[test]
    fn routes_by_locality_and_round_robin() {
        let q = ShardedReady::new("fifo", 2).unwrap();
        // Task with bytes on node 1 lands on shard 1 (push reports the
        // routed shard for schedule-time prefetching).
        assert_eq!(q.push(rt(1, vec![(100, vec![NodeId(1)])])), 1);
        // Node-1 worker gets it from its own shard.
        assert_eq!(q.pop(NodeId(1)), Some(TaskId(1)));
        // Locality-free tasks round-robin across both shards but any
        // worker can drain them all (stealing).
        for i in 2..=5 {
            q.push(rt(i, vec![]));
        }
        let mut got: Vec<u64> = (0..4).map(|_| q.pop(NodeId(0)).unwrap().0).collect();
        got.sort_unstable();
        assert_eq!(got, vec![2, 3, 4, 5]);
        assert_eq!(q.queue_len(), 0);
    }

    #[test]
    fn single_node_fifo_preserves_seed_order() {
        let q = ShardedReady::new("fifo", 1).unwrap();
        for i in 1..=6 {
            q.push(rt(i, vec![]));
        }
        let order: Vec<u64> = (0..6).map(|_| q.pop(NodeId(0)).unwrap().0).collect();
        assert_eq!(order, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn stealing_keeps_workers_busy() {
        let q = ShardedReady::new("locality", 4).unwrap();
        q.push(rt(1, vec![(10, vec![NodeId(3)])]));
        q.push(rt(2, vec![(10, vec![NodeId(2)])]));
        // A node-0 worker has no local work but must not park.
        assert!(q.pop(NodeId(0)).is_some());
        assert!(q.pop(NodeId(0)).is_some());
    }

    #[test]
    fn stop_releases_parked_workers() {
        let q = Arc::new(ShardedReady::new("fifo", 1).unwrap());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || q.pop(NodeId(0))));
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.stop();
        for h in handles {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn concurrent_producers_and_consumers_drain_exactly() {
        let q = Arc::new(ShardedReady::new("lifo", 3).unwrap());
        let total = 3 * 500u64;
        let mut producers = Vec::new();
        for p in 0..3u64 {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    q.push(rt(p * 500 + i + 1, vec![]));
                }
            }));
        }
        let popped = Arc::new(AtomicU64::new(0));
        let mut consumers = Vec::new();
        for c in 0..4u32 {
            let q = Arc::clone(&q);
            let popped = Arc::clone(&popped);
            consumers.push(std::thread::spawn(move || {
                while q.pop(NodeId(c % 3)).is_some() {
                    popped.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        // Spin until drained, then stop to release the consumers.
        while q.queue_len() > 0 {
            std::thread::yield_now();
        }
        q.stop();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(popped.load(Ordering::SeqCst), total);
    }

    #[test]
    fn unknown_policy_is_rejected() {
        assert!(ShardedReady::new("zzz", 2).is_none());
    }
}
