//! The window compiler: an ahead-of-time DAG compilation pass.
//!
//! The runtime normally routes every task with a greedy per-task verdict
//! the moment it becomes ready. This module borrows the render-graph
//! compilation idea (pass culling, resource lifetimes, memory aliasing,
//! whole-graph scheduling) and applies it to a bounded *window* of
//! submitted-but-unreleased tasks. Submission buffers tasks instead of
//! enqueueing them; the window flushes when it reaches [`WINDOW_CAP`]
//! tasks, or when `wait_on` / `barrier` / `stop` needs the frontier to
//! move. At flush, [`compile_window`] runs four passes over the buffered
//! tasks before any of them reaches the ready queues:
//!
//! 1. **Cull** — a task all of whose outputs are superseded (a newer
//!    version of each datum already exists, so no future `record_read`
//!    can name them), unpinned, and consumed only by tasks that are
//!    themselves culled, is retired without executing. Computed to a
//!    fixpoint so dead chains collapse bottom-up.
//! 2. **Lifetime analysis** — for every version whose registered
//!    consumers all sit inside the window, the last in-window reader is
//!    its ahead-of-time death point. That reader releases its consumer
//!    reference *before* publishing its own outputs (instead of after
//!    graph completion), so the hot tier frees the dying buffer exactly
//!    when the value goes dead and an equal-shape output allocation can
//!    reuse it — the store-level form of buffer **aliasing**: a dying
//!    chain's peak residency stays one value, not two.
//! 3. **Fusion** — a producer whose single output is superseded and has
//!    exactly one consumer, where that consumer is gated solely by the
//!    producer and the pair's known input bytes sit under
//!    [`FUSE_MAX_INPUT_BYTES`], becomes one dispatch unit with its
//!    consumer: one claim, one ready-queue push, and the intermediate
//!    value handed worker-local without ever being published. Links
//!    chain, so `t1 → t2 → t3` fuses into a single unit.
//! 4. **Whole-window placement** — the caller scores the window *once*
//!    against the [`PlacementModel`](crate::coordinator::placement) and
//!    round-robins the dispatch units from that anchor, replacing N
//!    greedy verdicts (each with its own `VersionTable` snapshot) with
//!    one. This pass lives with the caller because it needs live queue
//!    signals; the compiler contributes [`WindowPlan::units`], the
//!    dispatch-unit order with culled tasks and fused members removed.
//!
//! The compiler itself is pure: it sees the window as [`WindowTask`]
//! values and the registry/graph state as a prebuilt [`WindowCtx`]
//! snapshot, so the live runtime and the simulator drive the *identical*
//! pass pipeline and the fuzz sweeps cover both.
//!
//! # Invariants the passes preserve
//!
//! - A culled task's outputs are superseded **and** unpinned **and**
//!   read only by culled tasks, so no surviving task, `wait_on`, or
//!   future submission can ever need its bytes.
//! - Fused intermediates are superseded single-consumer versions; the
//!   member is the sole reader and rides the same worker, so skipping
//!   the publish is invisible outside the pair. Every fallback path
//!   (member unclaimable, member failure, node death mid-chain)
//!   publishes or lineage-recovers the intermediate before anyone else
//!   can ask for it.
//! - Aliasing is refcount-gated: the early release only collects when
//!   the reader really held the last reference, so a racing reader from
//!   an earlier window keeps the value alive and correctness never
//!   depends on the lifetime prediction being right.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::coordinator::dag::TaskId;
use crate::coordinator::registry::DataKey;

/// Tasks buffered before a size-triggered flush. 64 matches the ready
/// queues' batch sympathies: big enough to see whole app waves (one
/// KNN/K-means generation), small enough that submission latency stays
/// bounded when the app never syncs.
pub const WINDOW_CAP: usize = 64;

/// Fusion cost threshold: a pair (or chain link) fuses only when the
/// known input bytes of both sides stay under this, so fusion targets
/// short scalar/small-vector chains where dispatch overhead dominates,
/// and never serializes two large-kernel tasks that deserve separate
/// workers.
pub const FUSE_MAX_INPUT_BYTES: u64 = 1 << 20;

/// The compiler's view of one buffered task.
#[derive(Clone, Debug)]
pub struct WindowTask {
    pub id: TaskId,
    pub type_name: Arc<str>,
    /// Input versions, with multiplicity (one entry per reading
    /// argument, matching the registry's consumer refcounts).
    pub inputs: Vec<DataKey>,
    /// Output versions this task will produce.
    pub outputs: Vec<DataKey>,
}

/// Prebuilt registry/graph snapshot the passes consult. Both the live
/// runtime (under the control lock) and the simulator build one of
/// these, so the pass pipeline itself never touches a lock.
#[derive(Clone, Debug, Default)]
pub struct WindowCtx {
    /// Total consumer references ever registered per version
    /// (`consumers_total` in the version table).
    pub consumers: HashMap<DataKey, u32>,
    /// Versions pinned by a waiter — never culled, never aliased.
    pub pinned: HashSet<DataKey>,
    /// Versions that are no longer their datum's latest: no future
    /// `record_read` can return them.
    pub superseded: HashSet<DataKey>,
    /// Known byte sizes (0 / absent for not-yet-produced versions).
    pub bytes: HashMap<DataKey, u64>,
    /// `(task, pred)` pairs where `task`'s only unfinished gate is
    /// `pred` (`pending_deps == 1` and `pred` holds the dependent
    /// entry) — the structural precondition for fusing `pred → task`.
    pub sole_gate: HashSet<(TaskId, TaskId)>,
}

impl WindowCtx {
    fn consumers_total(&self, k: DataKey) -> u32 {
        self.consumers.get(&k).copied().unwrap_or(0)
    }

    fn known_bytes(&self, k: DataKey) -> u64 {
        self.bytes.get(&k).copied().unwrap_or(0)
    }

    /// A version no surviving code path can ever read again, provided
    /// its currently registered consumers are accounted for.
    fn dead_if_consumers_drain(&self, k: DataKey) -> bool {
        !self.pinned.contains(&k) && self.superseded.contains(&k)
    }
}

/// One fusion link: `member` runs inline on `head`'s worker, receiving
/// `key` (head's sole output) hand-to-hand without a publish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusedLink {
    pub head: TaskId,
    pub member: TaskId,
    pub key: DataKey,
}

/// The compiled window.
#[derive(Clone, Debug, Default)]
pub struct WindowPlan {
    /// Tasks retired without execution, in submission order.
    pub culled: Vec<TaskId>,
    /// Fusion links in submission order of their heads. Chains appear
    /// as consecutive links sharing a task (`t1→t2`, `t2→t3`).
    pub fused: Vec<FusedLink>,
    /// Per-task ahead-of-time death lists: input versions (with
    /// multiplicity) this task should release *before* publishing its
    /// outputs, because it is the predicted last reader.
    pub alias: HashMap<TaskId, Vec<DataKey>>,
    /// Dispatch units in submission order: window tasks minus culled
    /// tasks and fused members. The caller assigns one whole-window
    /// placement verdict across exactly these.
    pub units: Vec<TaskId>,
}

impl WindowPlan {
    /// `head → (member, intermediate)` lookup map for the executor.
    pub fn fused_next(&self) -> HashMap<TaskId, (TaskId, DataKey)> {
        self.fused.iter().map(|l| (l.head, (l.member, l.key))).collect()
    }
}

/// Run the cull / lifetime / fusion passes over one window. `tasks` is
/// the window in submission order; `ctx` is the registry/graph snapshot
/// taken at flush time (after every window task's `record_read` /
/// `record_write`, so consumer counts and supersession already include
/// the whole window).
pub fn compile_window(tasks: &[WindowTask], ctx: &WindowCtx) -> WindowPlan {
    let mut plan = WindowPlan::default();
    if tasks.is_empty() {
        return plan;
    }

    // ---- pass 1: cull to a fixpoint ------------------------------------
    // A task dies when every output is dead-if-drained and its remaining
    // consumers are all reads by already-culled window tasks. Culling a
    // task removes its own reads from the live set, which can kill its
    // producers — iterate in reverse submission order so consumer-first
    // chains collapse in one sweep, and loop until stable for the rest.
    let mut culled: HashSet<TaskId> = HashSet::new();
    let mut culled_reads: HashMap<DataKey, u32> = HashMap::new();
    loop {
        let mut changed = false;
        for t in tasks.iter().rev() {
            if culled.contains(&t.id) || t.outputs.is_empty() {
                // Output-less tasks are side-effect sinks: never cull.
                continue;
            }
            let dead = t.outputs.iter().all(|k| {
                ctx.dead_if_consumers_drain(*k)
                    && ctx.consumers_total(*k)
                        <= culled_reads.get(k).copied().unwrap_or(0)
            });
            if dead {
                culled.insert(t.id);
                for k in &t.inputs {
                    *culled_reads.entry(*k).or_insert(0) += 1;
                }
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    plan.culled = tasks
        .iter()
        .filter(|t| culled.contains(&t.id))
        .map(|t| t.id)
        .collect();

    // ---- pass 3 (ordered before lifetimes so death lists skip fused
    // intermediates): fusion ---------------------------------------------
    // Walk heads in submission order; a member may itself head the next
    // link, so chains form naturally.
    let mut members: HashSet<TaskId> = HashSet::new();
    let mut fused_keys: HashSet<DataKey> = HashSet::new();
    for t in tasks.iter() {
        if culled.contains(&t.id) || t.outputs.len() != 1 {
            continue;
        }
        let k = t.outputs[0];
        if !ctx.dead_if_consumers_drain(k) || ctx.consumers_total(k) != 1 {
            continue;
        }
        // The sole consumer must be a later, live window task reading the
        // key exactly once and gated by nothing but the head.
        let Some(m) = tasks.iter().find(|m| {
            !culled.contains(&m.id) && m.id != t.id && m.inputs.contains(&k)
        }) else {
            continue; // consumer already dispatched in an earlier window
        };
        if members.contains(&m.id)
            || m.inputs.iter().filter(|x| **x == k).count() != 1
            || !ctx.sole_gate.contains(&(m.id, t.id))
        {
            continue;
        }
        // Cost gate: both sides' known input bytes under the threshold.
        let known: u64 = t
            .inputs
            .iter()
            .chain(m.inputs.iter().filter(|x| **x != k))
            .map(|x| ctx.known_bytes(*x))
            .sum();
        if known > FUSE_MAX_INPUT_BYTES {
            continue;
        }
        members.insert(m.id);
        fused_keys.insert(k);
        plan.fused.push(FusedLink { head: t.id, member: m.id, key: k });
    }

    // ---- pass 2: lifetimes / ahead-of-time death lists -----------------
    // A version dies inside the window when every consumer it ever
    // registered is a window read (culled readers settle at flush;
    // surviving readers settle at completion). Its predicted death point
    // is the last surviving reader, which releases pre-publish so an
    // equal-shape output can reuse the allocation.
    let mut window_reads: HashMap<DataKey, u32> = HashMap::new();
    for t in tasks {
        for k in &t.inputs {
            *window_reads.entry(*k).or_insert(0) += 1;
        }
    }
    for (k, reads) in &window_reads {
        if fused_keys.contains(k)
            || !ctx.dead_if_consumers_drain(*k)
            || ctx.consumers_total(*k) != *reads
        {
            continue;
        }
        // Last surviving reader in submission order.
        let Some(last) = tasks
            .iter()
            .rev()
            .find(|t| !culled.contains(&t.id) && t.inputs.contains(k))
        else {
            continue; // every reader was culled; flush settles the refs
        };
        let occurrences = last.inputs.iter().filter(|x| **x == *k).count();
        let list = plan.alias.entry(last.id).or_default();
        for _ in 0..occurrences {
            list.push(*k);
        }
    }

    // ---- dispatch units ------------------------------------------------
    plan.units = tasks
        .iter()
        .filter(|t| !culled.contains(&t.id) && !members.contains(&t.id))
        .map(|t| t.id)
        .collect();
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::DataId;

    fn key(d: u64, v: u32) -> DataKey {
        DataKey { data: DataId(d), version: v }
    }

    fn task(id: u64, inputs: Vec<DataKey>, outputs: Vec<DataKey>) -> WindowTask {
        WindowTask {
            id: TaskId(id),
            type_name: Arc::from("t"),
            inputs,
            outputs,
        }
    }

    /// d1: v1 (literal, live) → t1 writes v2 → t2 reads v2, writes v3 →
    /// nothing reads v3 but v3 is the latest version... make v3 superseded
    /// by a later live writer t3 (v4) that never reads. t2's chain is dead.
    #[test]
    fn cull_collapses_dead_chains_to_a_fixpoint() {
        let t1 = task(1, vec![key(1, 1)], vec![key(1, 2)]);
        let t2 = task(2, vec![key(1, 2)], vec![key(1, 3)]);
        let t3 = task(3, vec![], vec![key(1, 4)]);
        let mut ctx = WindowCtx::default();
        // v2 read once (by t2), v3 never read, v1 read once (by t1).
        ctx.consumers.insert(key(1, 2), 1);
        ctx.consumers.insert(key(1, 1), 1);
        // Latest version is v4: v1..v3 superseded.
        for v in 1..=3 {
            ctx.superseded.insert(key(1, v));
        }
        let plan = compile_window(&[t1, t2, t3], &ctx);
        // t2's output is dead → t2 culled → v2's only read vanishes → t1
        // culled too. t3 writes the live latest version and survives.
        assert_eq!(plan.culled, vec![TaskId(1), TaskId(2)]);
        assert_eq!(plan.units, vec![TaskId(3)]);
        assert!(plan.fused.is_empty());
    }

    #[test]
    fn pinned_or_latest_outputs_are_never_culled() {
        // Terminal output (not superseded): survives.
        let t1 = task(1, vec![], vec![key(1, 1)]);
        let plan = compile_window(&[t1.clone()], &WindowCtx::default());
        assert!(plan.culled.is_empty());
        assert_eq!(plan.units, vec![TaskId(1)]);
        // Superseded but pinned (a waiter raced in): survives.
        let mut ctx = WindowCtx::default();
        ctx.superseded.insert(key(1, 1));
        ctx.pinned.insert(key(1, 1));
        let plan = compile_window(&[t1], &ctx);
        assert!(plan.culled.is_empty());
        // Output-less side-effect task: survives even with no consumers.
        let t2 = task(2, vec![key(1, 1)], vec![]);
        let plan = compile_window(&[t2], &ctx);
        assert!(plan.culled.is_empty());
    }

    #[test]
    fn fusion_chains_single_consumer_links_under_threshold() {
        // t1 → t2 → t3 on an INOUT chain d1: v1→v2→v3→v4; v4 is read
        // later by t4 (kept out of fusion because v4 has 1 consumer but
        // t4 is gated... give t4 a second gate so sole_gate excludes it).
        let t1 = task(1, vec![key(1, 1)], vec![key(1, 2)]);
        let t2 = task(2, vec![key(1, 2)], vec![key(1, 3)]);
        let t3 = task(3, vec![key(1, 3)], vec![key(1, 4)]);
        let t4 = task(4, vec![key(1, 4), key(2, 1)], vec![key(3, 1)]);
        let mut ctx = WindowCtx::default();
        for v in 1..=3 {
            ctx.superseded.insert(key(1, v));
            ctx.consumers.insert(key(1, v + 1), 1);
        }
        ctx.consumers.insert(key(1, 1), 1);
        ctx.sole_gate.insert((TaskId(2), TaskId(1)));
        ctx.sole_gate.insert((TaskId(3), TaskId(2)));
        // t4 gated by t3 AND the producer of d2 — not solely gated.
        let plan = compile_window(&[t1, t2, t3, t4], &ctx);
        assert_eq!(plan.fused, vec![
            FusedLink { head: TaskId(1), member: TaskId(2), key: key(1, 2) },
            FusedLink { head: TaskId(2), member: TaskId(3), key: key(1, 3) },
        ]);
        // One dispatch unit for the whole chain, plus t4.
        assert_eq!(plan.units, vec![TaskId(1), TaskId(4)]);
        let next = plan.fused_next();
        assert_eq!(next[&TaskId(1)], (TaskId(2), key(1, 2)));
        assert_eq!(next[&TaskId(2)], (TaskId(3), key(1, 3)));
    }

    #[test]
    fn fusion_respects_the_byte_threshold_and_multiplicity() {
        let t1 = task(1, vec![key(1, 1)], vec![key(1, 2)]);
        let t2 = task(2, vec![key(1, 2)], vec![key(1, 3)]);
        let mut ctx = WindowCtx::default();
        ctx.superseded.insert(key(1, 1));
        ctx.superseded.insert(key(1, 2));
        ctx.consumers.insert(key(1, 1), 1);
        ctx.consumers.insert(key(1, 2), 1);
        ctx.sole_gate.insert((TaskId(2), TaskId(1)));
        // Over-threshold head input: no fusion.
        ctx.bytes.insert(key(1, 1), FUSE_MAX_INPUT_BYTES + 1);
        let plan = compile_window(&[t1.clone(), t2.clone()], &ctx);
        assert!(plan.fused.is_empty());
        // Under threshold: fuses.
        ctx.bytes.insert(key(1, 1), 1024);
        let plan = compile_window(&[t1, t2.clone()], &ctx);
        assert_eq!(plan.fused.len(), 1);
        // A member reading the intermediate twice cannot take a single
        // hand-off: no fusion.
        let t1b = task(1, vec![], vec![key(1, 2)]);
        let t2b = task(2, vec![key(1, 2), key(1, 2)], vec![key(1, 3)]);
        let mut ctx2 = WindowCtx::default();
        ctx2.superseded.insert(key(1, 2));
        ctx2.consumers.insert(key(1, 2), 2);
        ctx2.sole_gate.insert((TaskId(2), TaskId(1)));
        let plan = compile_window(&[t1b, t2b], &ctx2);
        assert!(plan.fused.is_empty());
    }

    #[test]
    fn alias_lists_name_the_last_surviving_reader() {
        // v1 is read by t1 and t2 (both in-window, consumers_total == 2,
        // superseded): t2 — the later reader — gets the death-list entry.
        let t1 = task(1, vec![key(1, 1)], vec![key(2, 1)]);
        let t2 = task(2, vec![key(1, 1)], vec![key(3, 1)]);
        let mut ctx = WindowCtx::default();
        ctx.superseded.insert(key(1, 1));
        ctx.consumers.insert(key(1, 1), 2);
        let plan = compile_window(&[t1.clone(), t2.clone()], &ctx);
        assert_eq!(plan.alias.get(&TaskId(2)), Some(&vec![key(1, 1)]));
        assert!(plan.alias.get(&TaskId(1)).is_none());
        // An out-of-window consumer (consumers_total > window reads)
        // blocks the prediction entirely.
        ctx.consumers.insert(key(1, 1), 3);
        let plan = compile_window(&[t1, t2], &ctx);
        assert!(plan.alias.is_empty());
    }

    #[test]
    fn empty_window_compiles_to_an_empty_plan() {
        let plan = compile_window(&[], &WindowCtx::default());
        assert!(plan.culled.is_empty() && plan.fused.is_empty() && plan.units.is_empty());
    }
}
